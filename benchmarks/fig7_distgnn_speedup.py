"""Paper Fig. 7 + §4.3: DistGNN (full-batch) speedup distribution over the
GNN-parameter grid. Claims: HEP100 largest speedups; heavy-weight
partitioners beat streaming ones; speedups grow with k (Fig. 12a)."""

import numpy as np

from benchmarks.common import FEATURES, HIDDENS, KS, LAYERS, SCALE, cache, emit, spec
from repro.core.study import EDGE_METHODS, fullbatch_row, fullbatch_speedup


def main() -> None:
    c = cache()
    rows = []
    for k in KS:
        for f in FEATURES:
            for h in HIDDENS:
                for l in LAYERS:
                    s = spec(feature=f, hidden=h, layers=l)
                    for m in EDGE_METHODS:
                        rows.append(fullbatch_row(
                            "OR", m, k, s, scale=SCALE, cache=c))
    sp = fullbatch_speedup(rows)
    by = {}
    for r in sp:
        by.setdefault((r["method"], r["k"]), []).append(r["speedup"])
    for (m, k), vals in sorted(by.items()):
        emit(f"fig7.speedup.OR.k{k}.{m}", 0.0,
             f"mean={np.mean(vals):.3f};max={np.max(vals):.3f}")
    k0, k1 = KS[0], KS[-1]
    hep_best = np.mean(by[("hep100", k1)]) >= np.mean(by[("dbh", k1)])
    grows = np.mean(by[("hep100", k1)]) >= np.mean(by[("hep100", k0)])
    emit("fig7.claims", 0.0,
         f"hep100_beats_streaming={hep_best};speedup_grows_with_k={grows}")


if __name__ == "__main__":
    main()
