"""Wire-compression frontier: partitioner x codec, mini-batch regime.

The paper ranks partitioners by how many bytes they keep off the network;
a wire codec (core/wire.py) attacks the same bytes from the other side.
This figure trains REAL mini-batch steps for every {random, metis} x
{fp32, bf16, int8, variable} cell and reports:

  * the accuracy-vs-traffic frontier: short-run training loss against the
    measured encoded bytes each step actually shipped (a lossy codec moves
    a cell left on the traffic axis at some loss cost; a better partitioner
    moves it left at partition-time cost);
  * the fixed-time-budget crossover between the two strategies' extremes —
    random+int8 (no partition pass, quarter-width wire) vs metis+fp32
    (expensive pass, exact wire): with partition time pt and modeled epoch
    time et, random wins every budget below
        T* = (pt_m * et_r - pt_r * et_m) / (et_r - et_m)
    (the classic amortization break-even, tab3's question asked across the
    codec axis instead of across partitioners).

Claims checked in the smoke:
  * fp32 rows ship exactly their logical bytes (wire == fetch, every cell)
  * int8 rows ship < 0.3x their logical bytes
  * int8's short-run loss stays within 0.05 of fp32's on the same batches
  * the budget table emits and names a winner per budget

`--out-json` / `--out-csv` write the study-format rows + the printed CSV —
the CI artifacts. `--smoke` (or run.py --smoke / BENCH_FAST=1) keeps the
trimmed grid.
"""

import argparse
import sys

import numpy as np

from benchmarks.common import FAST, SCALE, cache, emit
from repro.core import cost_model
from repro.core.study import host_phase_means, minibatch_result_row, write_rows
from repro.core.wire import CODECS
from repro.gnn.minibatch import MiniBatchTrainer
from repro.gnn.models import GNNSpec

GRAPH = "OR"
METHODS = ("random", "metis")
SMOKE = FAST or "--smoke" in sys.argv
COMP_SCALE = 0.02 if SMOKE else SCALE
KS = (4,) if SMOKE else (4, 8)
STEPS = 8 if SMOKE else 24
LOSS_TOL = 0.05


def _train_cell(g, rec, k, spec, feats, labels, train_mask, codec, batch):
    """Train STEPS real steps under `codec`; return (row, mean_tail_loss)."""
    tr = MiniBatchTrainer.build(
        g, rec.assignment, k, spec, feats, labels, train_mask,
        global_batch=batch, seed=0, codec=codec,
    )
    ms = [tr.train_step() for _ in range(STEPS)]
    tr.close()
    inputs = np.stack([m.input_vertices for m in ms]).mean(axis=0)
    remote = np.stack([m.remote_vertices for m in ms]).mean(axis=0)
    edges = np.stack([m.edges for m in ms]).mean(axis=0)
    hits = np.stack([m.cache_hits for m in ms]).mean(axis=0)
    misses = np.stack([m.remote_misses for m in ms]).mean(axis=0)
    est = cost_model.minibatch_step(
        inputs, remote, edges, rec.book.sizes.astype(np.float64), spec,
        seeds_per_worker=max(batch // k, 1),
        remote_miss_vertices=misses, cached_vertices=tr.store.cache_sizes,
        codec=codec,
    )
    steps_per_epoch = max(int(train_mask.sum()) // batch, 1)
    row = minibatch_result_row(
        GRAPH, rec.method, k, spec, metrics=rec.metrics,
        partition_time=rec.partition_time, batch=batch,
        inputs=inputs, remote=remote, hits=hits, misses=misses,
        est=est, steps_per_epoch=steps_per_epoch,
        host_times=host_phase_means(ms), codec=codec,
    )
    # the measured (not modeled) encoded bytes the feature store shipped
    row["measured_wire_bytes"] = float(
        np.stack([m.wire_bytes for m in ms]).mean(axis=0).sum())
    row["measured_miss_bytes"] = float(
        np.stack([m.miss_bytes for m in ms]).mean(axis=0).sum())
    tail = [m.loss for m in ms[max(STEPS - 3, 0):]]
    row["loss"] = float(np.mean(tail))
    return row, row["loss"]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")  # grid set by env/common
    ap.add_argument("--out-json", default="")
    ap.add_argument("--out-csv", default="")
    args = ap.parse_args(argv if argv is not None else [])

    c = cache()
    g = c.graph(GRAPH, COMP_SCALE, 0)
    spec = GNNSpec(model="sage", feature_dim=32, hidden_dim=32,
                   num_classes=8, num_layers=2)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, 32)).astype(np.float32)
    labels = rng.integers(0, 8, g.num_vertices).astype(np.int32)
    train_mask = rng.random(g.num_vertices) < 0.3
    batch = 64 if SMOKE else 256

    rows, csv_lines = [], []

    def emit2(name, seconds, derived):
        emit(name, seconds, derived)
        csv_lines.append(f"{name},{seconds * 1e6:.1f},{derived}")

    claims_ok = True
    cells = {}
    for k in KS:
        losses = {}
        for method in METHODS:
            rec = c.vertex_partition(g, method, k, 0, train_mask)
            for codec in CODECS:
                row, loss = _train_cell(g, rec, k, spec, feats, labels,
                                        train_mask, codec, batch)
                rows.append(row)
                cells[(k, method, codec)] = row
                losses[(method, codec)] = loss
                emit2(f"fig_compression.train.{GRAPH}.k{k}.{method}.{codec}",
                      row["step_time"],
                      f"loss={loss:.4f};"
                      f"wire_bytes={row['measured_wire_bytes']:.0f};"
                      f"miss_bytes={row['measured_miss_bytes']:.0f};"
                      f"epoch_time_ms={row['epoch_time'] * 1e3:.2f}")
            fp32 = cells[(k, method, "fp32")]
            int8 = cells[(k, method, "int8")]
            exact = (fp32["measured_wire_bytes"]
                     == fp32["measured_miss_bytes"])
            shrink = (int8["measured_wire_bytes"]
                      < 0.3 * int8["measured_miss_bytes"])
            dev = abs(losses[(method, "int8")] - losses[(method, "fp32")])
            close = dev < LOSS_TOL
            claims_ok &= exact and shrink and close
            emit2(f"fig_compression.pins.{GRAPH}.k{k}.{method}", 0.0,
                  f"fp32_exact={exact};int8_shrinks={shrink};"
                  f"int8_loss_dev={dev:.4f};within_tol={close}")

        # fixed-time-budget crossover: random+int8 vs metis+fp32
        r8 = cells[(k, "random", "int8")]
        mf = cells[(k, "metis", "fp32")]
        pt_r, et_r = r8["partition_time"], r8["epoch_time"]
        pt_m, et_m = mf["partition_time"], mf["epoch_time"]
        if et_r > et_m:
            t_star = (pt_m * et_r - pt_r * et_m) / (et_r - et_m)
        else:
            # random+int8's epochs are no slower AND its pass is cheaper:
            # it wins every finite budget
            t_star = float("inf")
        emit2(f"fig_compression.crossover.{GRAPH}.k{k}", 0.0,
              f"t_star_s={t_star:.4f};"
              f"random_int8_pt={pt_r:.4f};random_int8_epoch={et_r:.6f};"
              f"metis_fp32_pt={pt_m:.4f};metis_fp32_epoch={et_m:.6f}")
        budget_rows = 0
        for mult in (2.0, 8.0, 32.0):
            budget = mult * (pt_m + et_m)  # scaled off the slow-start config
            ep_r = max((budget - pt_r) / et_r, 0.0)
            ep_m = max((budget - pt_m) / et_m, 0.0)
            winner = "random+int8" if ep_r >= ep_m else "metis+fp32"
            emit2(f"fig_compression.budget.{GRAPH}.k{k}.x{mult:g}", 0.0,
                  f"budget_s={budget:.4f};epochs_random_int8={ep_r:.2f};"
                  f"epochs_metis_fp32={ep_m:.2f};winner={winner}")
            rows.append({
                "graph": GRAPH, "k": k, "regime": "budget",
                "budget_s": budget, "t_star_s": t_star,
                "epochs_random_int8": ep_r, "epochs_metis_fp32": ep_m,
                "winner": winner,
            })
            budget_rows += 1
        claims_ok &= budget_rows == 3

    emit2("fig_compression.claims", 0.0, f"all_pinned={claims_ok}")
    if args.out_json:
        write_rows(rows, args.out_json)
    if args.out_csv:
        with open(args.out_csv, "w") as f:
            f.write("name,us_per_call,derived\n")
            f.write("\n".join(csv_lines) + "\n")
    if not claims_ok:
        raise SystemExit("fig_compression: codec pin failed")


if __name__ == "__main__":
    main(sys.argv[1:])
