"""Paper Fig. 13: edge-cut ratio per vertex partitioner x graph x k.
Claims: kahip/metis lowest cut, random highest; cut grows with k; the road
network DI gets a far lower cut than the power-law graphs."""

from benchmarks.common import GRAPHS, KS, SCALE, cache, emit, timed
from repro.core.study import VERTEX_METHODS


def main() -> None:
    c = cache()
    cuts = {}
    for gk in GRAPHS:
        g = c.graph(gk, SCALE)
        for k in KS:
            for m in VERTEX_METHODS:
                rec, dt = timed(lambda m=m, k=k: c.vertex_partition(g, m, k))
                cuts[(gk, k, m)] = rec.metrics.edge_cut
                emit(f"fig13.cut.{gk}.k{k}.{m}", dt,
                     f"cut={cuts[(gk, k, m)]:.4f}")
    k = KS[0]
    best_low = all(
        min(cuts[(gk, k, "kahip")], cuts[(gk, k, "metis")])
        <= cuts[(gk, k, "random")]
        for gk in GRAPHS
    )
    grows = all(
        cuts[(gk, KS[-1], m)] >= cuts[(gk, KS[0], m)] * 0.9
        for gk in GRAPHS for m in VERTEX_METHODS
    )
    di_low = ("DI" not in [g for g in GRAPHS]) or (
        cuts[("DI", k, "metis")] < min(
            cuts[(gk, k, "metis")] for gk in GRAPHS if gk != "DI")
    )
    emit("fig13.claims", 0.0,
         f"quality_ordering={best_low};cut_grows_with_k={grows};"
         f"road_graph_lowest={di_low}")


if __name__ == "__main__":
    main()
