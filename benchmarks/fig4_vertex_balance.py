"""Paper Fig. 4: vertex balance per edge partitioner. Claim: 2PS-L/HEP show
significant vertex imbalance (up to ~2.4) while random/DBH stay balanced."""

from benchmarks.common import GRAPHS, KS, SCALE, cache, emit, timed
from repro.core.study import EDGE_METHODS


def main() -> None:
    c = cache()
    heavy_max = 1.0
    light_max = 1.0
    for gk in GRAPHS:
        g = c.graph(gk, SCALE)
        for k in KS:
            for m in EDGE_METHODS:
                rec, dt = timed(lambda m=m, k=k: c.edge_partition(g, m, k))
                vb = rec.metrics.vertex_balance
                emit(f"fig4.vb.{gk}.k{k}.{m}", dt, f"vb={vb:.3f}")
                if m in ("2ps-l", "hep10", "hep100"):
                    heavy_max = max(heavy_max, vb)
                if m in ("random", "dbh"):
                    light_max = max(light_max, vb)
    emit("fig4.claims", 0.0,
         f"heavy_imbalance={heavy_max:.2f};light={light_max:.2f};"
         f"validated={heavy_max > light_max}")


if __name__ == "__main__":
    main()
