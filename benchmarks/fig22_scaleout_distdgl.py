"""Paper Fig. 22: DistDGL effectiveness vs scale-out. Claims: for power-law
graphs the effectiveness of partitioning (speedup, remote%random) DECREASES
with more machines — the opposite of DistGNN (Fig. 12)."""

from benchmarks.common import SCALE, cache, emit, spec
from repro.core.study import minibatch_row, minibatch_speedup


def main() -> None:
    c = cache()
    s = spec(feature=512, hidden=64, layers=3)
    remote_pcts, cut_pcts = [], []
    for k in (4, 16):
        rows = [minibatch_row("OR", m, k, s, scale=SCALE, cache=c,
                              global_batch=128, steps=3)
                for m in ("random", "metis")]
        sp = {r["method"]: r for r in minibatch_speedup(rows)}
        remote_pcts.append(sp["metis"]["remote_pct_random"])
        cut_pct = 100 * sp["metis"]["edge_cut"] / max(sp["random"]["edge_cut"], 1e-9)
        cut_pcts.append(cut_pct)
        emit(f"fig22.metis.k{k}", 0.0,
             f"speedup={sp['metis']['speedup']:.3f};"
             f"remote_pct_random={remote_pcts[-1]:.1f};"
             f"cut_pct_random={cut_pct:.1f}")
    # paper Fig. 22c: the partitioners' CUT relative to random rises with k
    # (the robust form of the claim; remote vertices track it, §5.3(4))
    emit("fig22.claims", 0.0,
         f"cut_pct_rises_with_k={cut_pcts[-1] >= cut_pcts[0]};"
         f"remote_pct_rises_with_k={remote_pcts[-1] >= remote_pcts[0] * 0.95}")


if __name__ == "__main__":
    main()
