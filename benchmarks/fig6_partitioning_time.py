"""Paper Fig. 6/15: partitioning time per algorithm and k (log scale in the
paper). Claims: streaming (random/dbh/2ps-l) nearly k-independent; hdrf grows
with k; in-memory vertex partitioners slowest, kahip the slowest of all."""

from benchmarks.common import KS, SCALE, cache, emit
from repro.core.study import EDGE_METHODS, VERTEX_METHODS


def main() -> None:
    c = cache()
    g = c.graph("EU", SCALE)
    times = {}
    for k in KS:
        for m in EDGE_METHODS:
            rec = c.edge_partition(g, m, k)
            times[(m, k)] = rec.partition_time
            emit(f"fig6.edge.{m}.k{k}", rec.partition_time, "")
        for m in VERTEX_METHODS:
            rec = c.vertex_partition(g, m, k)
            times[(m, k)] = rec.partition_time
            emit(f"fig15.vertex.{m}.k{k}", rec.partition_time, "")
    k0, k1 = KS[0], KS[-1]
    hdrf_growth = times[("hdrf", k1)] / max(times[("hdrf", k0)], 1e-9)
    kahip_slowest = times[("kahip", k0)] >= max(
        times[(m, k0)] for m in ("ldg", "spinner", "bytegnn"))
    emit("fig6.claims", 0.0,
         f"hdrf_growth_x={hdrf_growth:.1f};kahip_slowest={kahip_slowest}")


if __name__ == "__main__":
    main()
