"""Paper Fig. 2: replication factors per edge partitioner x graph x k.
Claim: HEP100 lowest, Random highest; RF grows with k."""

from benchmarks.common import GRAPHS, KS, SCALE, cache, emit, timed
from repro.core.study import EDGE_METHODS


def main() -> None:
    c = cache()
    ok = True
    for gk in GRAPHS:
        g = c.graph(gk, SCALE)
        for k in KS:
            rfs = {}
            for m in EDGE_METHODS:
                rec, dt = timed(lambda m=m: c.edge_partition(g, m, k))
                rfs[m] = rec.metrics.replication_factor
                emit(f"fig2.rf.{gk}.k{k}.{m}", dt,
                     f"rf={rfs[m]:.3f}")
            ok &= rfs["hep100"] <= rfs["random"]
            ok &= rfs["hdrf"] <= rfs["random"]
        # RF grows with k for every method
        for m in EDGE_METHODS:
            rf_small = c.edge_partition(g, m, KS[0]).metrics.replication_factor
            rf_large = c.edge_partition(g, m, KS[-1]).metrics.replication_factor
            ok &= rf_large >= rf_small * 0.95
    emit("fig2.claims", 0.0, f"validated={ok}")


if __name__ == "__main__":
    main()
