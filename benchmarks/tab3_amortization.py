"""Paper Tables 3/4: epochs until partitioning time is amortized by faster
training. Claims: DistGNN partitioners amortize within ~1-12 epochs (DBH
fastest); DistDGL metis amortizes <20 epochs while kahip barely does.
The 1.5D blockrow/ring row rides along as the no-partitioner regime the
paper omits: its contiguous split costs ~nothing up front, so amortization
is a non-question — the row makes that explicit next to the heuristics."""

from benchmarks.common import SCALE, cache, emit, spec
from repro.core.study import (
    EDGE_METHODS,
    VERTEX_METHODS,
    fullbatch_row,
    fullbatch_speedup,
    minibatch_row,
    minibatch_speedup,
)


def main() -> None:
    c = cache()
    s = spec(feature=512, hidden=64, layers=2)
    rows = [fullbatch_row("OR", m, 8, s, scale=SCALE, cache=c)
            for m in EDGE_METHODS]
    rows.append(fullbatch_row("OR", "blockrow", 8, s, scale=SCALE, cache=c,
                              sync_mode="ring"))
    sped = fullbatch_speedup(rows)
    amort = {r["method"]: r["amortize_epochs"] for r in sped}
    for m, a in amort.items():
        emit(f"tab3.amortize.OR.{m}", 0.0, f"epochs={a:.2f}")
    finite = [m for m in EDGE_METHODS
              if m != "random" and amort[m] != float("inf")]
    emit("tab3.claims", 0.0,
         f"amortizing_partitioners={len(finite)}/5")
    ptimes = {r["method"]: r["partition_time"] for r in rows}
    ring = next(r for r in sped if r["method"] == "blockrow")
    emit("tab3.amortize.OR.blockrow.detail", 0.0,
         f"partition_time={ptimes['blockrow']:.4f};"
         f"speedup_vs_random={ring['speedup']:.3f};"
         f"cheaper_than_every_heuristic="
         f"{ptimes['blockrow'] < min(ptimes[m] for m in EDGE_METHODS if m != 'random')}")

    rows = [minibatch_row("OR", m, 8, s, scale=SCALE, cache=c,
                          global_batch=128, steps=2)
            for m in VERTEX_METHODS]
    amort = {r["method"]: r["amortize_epochs"]
             for r in minibatch_speedup(rows)}
    for m, a in amort.items():
        emit(f"tab4.amortize.OR.{m}", 0.0, f"epochs={a:.2f}")
    ok = amort.get("metis", float("inf")) <= amort.get("kahip", float("inf"))
    emit("tab4.claims", 0.0, f"metis_amortizes_before_kahip={ok}")


if __name__ == "__main__":
    main()
