"""Paper Fig. 12: DistGNN effectiveness vs scale-out factor. Claims: speedup
and memory savings INCREASE with more machines (edge partitioning); RF in %
of random falls with k."""

import numpy as np

from benchmarks.common import SCALE, cache, emit, spec
from repro.core.study import fullbatch_row, fullbatch_speedup


def main() -> None:
    c = cache()
    s = spec(feature=512, hidden=64, layers=2)
    ks = (4, 8, 16, 32)
    for m in ["dbh", "hdrf", "hep100"]:
        sps, rf_pcts = [], []
        for k in ks:
            rows = [fullbatch_row("OR", mm, k, s, scale=SCALE, cache=c)
                    for mm in ("random", m)]
            sp = {r["method"]: r for r in fullbatch_speedup(rows)}
            sps.append(sp[m]["speedup"])
            rf_pct = 100 * sp[m]["rf"] / sp["random"]["rf"]
            rf_pcts.append(rf_pct)
            emit(f"fig12.{m}.k{k}", 0.0,
                 f"speedup={sps[-1]:.3f};rf_pct_random={rf_pct:.1f}")
        emit(f"fig12.claims.{m}", 0.0,
             f"speedup_increases={sps[-1] >= sps[0]};"
             f"rf_pct_falls={rf_pcts[-1] <= rf_pcts[0]}")


if __name__ == "__main__":
    main()
