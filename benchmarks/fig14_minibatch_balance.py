"""Paper Fig. 14/17: mini-batch balance metrics. Claims: although TRAINING
vertices are balanced, the sampled computation graphs (input vertices) are
imbalanced — and the imbalance grows with the number of partitions."""

import numpy as np

from benchmarks.common import SCALE, cache, emit
from repro.core.metrics import input_vertex_balance
from repro.core.study import minibatch_row


def main() -> None:
    c = cache()
    imb = {}
    for k in (4, 8):
        r = minibatch_row("OR", "bytegnn", k,
                          __import__("benchmarks.common", fromlist=["spec"]).spec(),
                          scale=SCALE, cache=c, global_batch=64, steps=3)
        imb[k] = r["input_vertex_balance"]
        emit(f"fig14.input_balance.k{k}", 0.0,
             f"train_vb={r['train_vertex_balance']:.3f};"
             f"input_vb={r['input_vertex_balance']:.3f}")
    emit("fig14.claims", 0.0,
         f"imbalance_despite_balanced_train_vertices={imb[4] > 1.0};"
         f"grows_with_k={imb[8] >= imb[4] * 0.9}")


if __name__ == "__main__":
    main()
