"""Run every paper-figure/table benchmark. Prints name,us_per_call,derived
CSV. One module per paper artifact (see the README's benchmark table);
roofline reads the dry-run cache.

Flags:
  --smoke        seconds-fast CI path: trimmed grids (BENCH_FAST=1) at a
                 small graph scale (BENCH_SCALE=0.02 unless already set)
  --only SUBSTR  run only modules whose name contains SUBSTR
"""

import argparse
import importlib
import os
import sys
import time
import traceback

MODULES = [
    "benchmarks.fig2_replication_factor",
    "benchmarks.fig3_rf_network",
    "benchmarks.fig4_vertex_balance",
    "benchmarks.fig6_partitioning_time",
    "benchmarks.fig7_distgnn_speedup",
    "benchmarks.fig10_memory",
    "benchmarks.fig12_scaleout_distgnn",
    "benchmarks.fig13_edgecut",
    "benchmarks.fig14_minibatch_balance",
    "benchmarks.fig16_distdgl_speedup",
    "benchmarks.fig19_phase_times",
    "benchmarks.fig22_scaleout_distdgl",
    "benchmarks.fig24_batchsize",
    "benchmarks.tab3_amortization",
    "benchmarks.fig_cache_sweep",
    "benchmarks.fig_serving",
    "benchmarks.fig_ring_scaleout",
    "benchmarks.fig_compression",
    "benchmarks.roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI path: trimmed grid, small graph scale")
    ap.add_argument("--only", default="",
                    help="run only modules whose name contains this substring")
    args = ap.parse_args()
    if args.smoke:
        # must be set before benchmarks.common is first imported
        os.environ["BENCH_FAST"] = "1"
        os.environ.setdefault("BENCH_SCALE", "0.02")

    modules = [m for m in MODULES if args.only in m]
    print("name,us_per_call,derived")
    failures = 0
    for name in modules:
        t0 = time.perf_counter()
        try:
            importlib.import_module(name).main()
            print(f"{name}.total,{(time.perf_counter()-t0)*1e6:.0f},ok")
        except Exception:
            failures += 1
            print(f"{name}.total,0,FAILED")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
