"""Paper Fig. 24 (§5.4): influence of the mini-batch size.

Claims: with a high feature size the partitioner keeps a clear advantage at
every batch size (net traffic well below random's). NOTE (scale artifact,
documented in EXPERIMENTS.md §Deviations): the paper's *falling* net%%random
trend requires paper-scale graphs (3M vertices); at CPU-tractable scale even
moderate batches touch most of the graph, so overlap saturates for the good
partitioner first and the ratio plateaus/rises instead. We validate the
batch-size-independent advantage and report the measured trend."""

from benchmarks.common import SCALE, cache, emit, spec
from repro.core.study import minibatch_row, minibatch_speedup


def main() -> None:
    c = cache()
    s = spec(feature=512, hidden=64, layers=3)
    k = 8
    # larger graph for this figure: batch-size overlap effects saturate on
    # small graphs (every batch covers the whole graph)
    scale = max(SCALE, 0.25)
    net_pcts, sps = [], []
    for gb in (64, 512):
        rows = [minibatch_row("OR", m, k, s, scale=scale, cache=c,
                              global_batch=gb, steps=2)
                for m in ("random", "kahip")]
        sp = {r["method"]: r for r in minibatch_speedup(rows)}
        net_pcts.append(sp["kahip"]["net_pct_random"])
        sps.append(sp["kahip"]["speedup"])
        emit(f"fig24.kahip.batch{gb}", 0.0,
             f"net_pct_random={net_pcts[-1]:.1f};speedup={sps[-1]:.3f}")
    emit("fig24.claims", 0.0,
         f"advantage_at_all_batch_sizes={all(p < 100 for p in net_pcts)};"
         f"speedup_gt1_at_all={all(s > 1 for s in sps)};"
         f"net_pct_trend={'falls' if net_pcts[-1] <= net_pcts[0] else 'saturates(scale_artifact)'}")


if __name__ == "__main__":
    main()
