"""Paper Fig. 19-21/23: per-phase time breakdown (sampling / feature
loading / compute). Claims: at feature size 512 feature fetching dominates
sampling; at small features (<=64) sampling >= fetching; on the road network
DI sampling always dominates.

Beyond-paper section: overlapped-vs-serial MEASURED rows — the pipelined
execution engine (gnn/pipeline.py) against the serial oracle on the same
seed (bitwise-identical batches), reporting true per-phase wall times
(sample / fetch / transfer / compute), overlap efficiency (hidden host time
/ total host time) and the end-to-end step speedup. This is exactly the
structural reason DistDGL overlaps its sampler processes with device
compute: the host phases this figure shows dominating are hideable.

`--smoke` (or `run.py --smoke`) trims the modeled grid and runs the
measured section at the CI scale; `--out-json PATH` writes every row
(modeled study rows + measured overlap rows) through the shared
`study.write_rows` emitter — CI uploads the smoke JSON as an artifact.
"""

import os
import sys
import time

import numpy as np

from benchmarks.common import SCALE, cache, emit, spec
from repro.core.study import minibatch_row, write_rows

# the measured overlap bench sizes itself independently of common.SCALE so a
# direct `python benchmarks/fig19_phase_times.py --smoke` is CI-fast
# without env setup (same convention as roofline.py's AGG_SCALE)
OVERLAP_SCALE = float(os.environ.get("BENCH_SCALE", "0.02"))


def measure_overlap(
    scale: float = OVERLAP_SCALE,
    *,
    k: int = 4,
    model: str = "sage",
    feature: int = 64,
    hidden: int = 32,
    global_batch: int = 256,
    prefetch_depth: int = 2,
    warmup: int = 2,
    steps: int = 6,
) -> dict:
    """Run the SAME (graph, partition, seed) serially and pipelined; return
    per-mode mean measured phase times + wall, and the end-to-end speedup.
    Shared with roofline.py's --smoke rows."""
    from repro.core.graph import paper_graph
    from repro.core.vertex_partition import partition_vertices
    from repro.gnn.minibatch import MiniBatchTrainer
    from repro.gnn.models import GNNSpec
    from repro.obs.aggregate import phase_means

    g = paper_graph("OR", scale=scale, seed=0)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, feature)).astype(np.float32)
    labels = rng.integers(0, 16, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.3
    gspec = GNNSpec(model=model, feature_dim=feature, hidden_dim=hidden,
                    num_classes=16, num_layers=2)
    owner = partition_vertices(g, k, "metis", seed=0)

    out = {"graph": "OR", "method": "metis", "k": k, "model": model,
           "feature": feature, "hidden": hidden, "batch": global_batch,
           "prefetch_depth": prefetch_depth, "steps": steps}
    for mode, overlap in (("serial", False), ("pipelined", True)):
        tr = MiniBatchTrainer.build(
            g, owner, k, gspec, feats, labels, train,
            global_batch=global_batch, seed=7, overlap=overlap,
            prefetch_depth=prefetch_depth,
        )
        for _ in range(warmup):  # compile + fill the prefetch queue
            tr.train_step()
        t0 = time.perf_counter()
        ms = [tr.train_step() for _ in range(steps)]
        wall = (time.perf_counter() - t0) / steps
        tr.close()
        # one shared phase reduction (repro.obs.aggregate) — the same
        # helper study.host_phase_means delegates to
        pm = phase_means(ms)
        out[mode] = {
            "sample": pm["host_sample_time"],
            "fetch": pm["host_fetch_time"],
            "transfer": pm["host_transfer_time"],
            "compute": pm["host_compute_time"],
            "step_wall": pm["host_step_wall"],
            "wall": wall,
            "overlap_efficiency": pm["overlap_efficiency"],
            "loss_last": ms[-1].loss,
        }
    out["speedup"] = out["serial"]["wall"] / out["pipelined"]["wall"]
    # same seed => the two modes trained on identical batches
    out["losses_identical"] = out["serial"]["loss_last"] == out["pipelined"]["loss_last"]
    return out


def _overlap_rows(measured: dict) -> "list[dict]":
    """Flatten the measured dict into two study-style JSON rows."""
    rows = []
    for mode in ("serial", "pipelined"):
        m = measured[mode]
        rows.append({
            "figure": "fig19_overlap",
            "graph": measured["graph"], "method": measured["method"],
            "k": measured["k"], "model": measured["model"],
            "feature": measured["feature"], "hidden": measured["hidden"],
            "batch": measured["batch"], "mode": mode,
            "overlap": mode == "pipelined",
            "prefetch_depth": (measured["prefetch_depth"]
                               if mode == "pipelined" else 0),
            "host_sample_time": m["sample"],
            "host_fetch_time": m["fetch"],
            "host_transfer_time": m["transfer"],
            "host_compute_time": m["compute"],
            "host_step_wall": m["wall"],
            "overlap_efficiency": m["overlap_efficiency"],
            "speedup_vs_serial": measured["serial"]["wall"] / m["wall"],
        })
    return rows


def overlap_bench(smoke: bool) -> "list[dict]":
    """Emit the overlapped-vs-serial rows + acceptance claims."""
    measured = measure_overlap(OVERLAP_SCALE if smoke else max(OVERLAP_SCALE, 0.05))
    for mode in ("serial", "pipelined"):
        m = measured[mode]
        extra = ("" if mode == "serial"
                 else f";overlap_eff={m['overlap_efficiency']:.2f}")
        emit(f"fig19.overlap.{mode}", m["wall"],
             f"sample={m['sample']*1e3:.2f}ms;fetch={m['fetch']*1e3:.2f}ms;"
             f"transfer={m['transfer']*1e3:.2f}ms;"
             f"compute={m['compute']*1e3:.2f}ms{extra}")
    s = measured["serial"]
    phase_sum = s["sample"] + s["fetch"] + s["transfer"] + s["compute"]
    emit("fig19.overlap.claims", 0.0,
         f"pipelined_below_serial={measured['speedup'] > 1.0};"
         f"speedup={measured['speedup']:.2f};"
         f"serial_phase_sum_covers_step={phase_sum >= s['step_wall'] * (1 - 1e-9)};"
         f"losses_identical={measured['losses_identical']}")
    return _overlap_rows(measured)


def main(out_json: str = "", smoke: "bool | None" = None) -> None:
    if smoke is None:  # run.py --smoke exports BENCH_FAST=1 before importing
        smoke = os.environ.get("BENCH_FAST") == "1"
    c = cache()
    k = 4
    scale = min(SCALE, 0.02) if smoke else SCALE
    results = {}
    rows = []
    # DI's phase profile in the paper reflects its very low edge-cut
    # (Fig. 13) — use metis there; EU uses a streaming partitioner.
    for gk, method in [("EU", "ldg"), ("DI", "metis")]:
        for f in (16, 512):
            r = minibatch_row(gk, method, k, spec(feature=f, layers=3),
                              scale=scale, cache=c, global_batch=128, steps=2)
            results[(gk, f)] = r
            rows.append(r)
            emit(f"fig19.phases.{gk}.f{f}", 0.0,
                 f"sample={r['sample_time']*1e3:.2f}ms;"
                 f"fetch={r['fetch_time']*1e3:.2f}ms;"
                 f"compute={r['compute_time']*1e3:.2f}ms;"
                 f"step_overlap={r['step_time_overlap']*1e3:.2f}ms")
    big_fetch = results[("EU", 512)]
    small = results[("EU", 16)]
    di = results[("DI", 512)]
    emit("fig19.claims", 0.0,
         f"fetch_dominates_at_512={big_fetch['fetch_time'] > big_fetch['sample_time']};"
         f"sampling_matters_at_16={small['sample_time'] >= small['fetch_time'] * 0.5};"
         f"DI_sampling_dominates={di['sample_time'] > di['fetch_time']};"
         f"overlap_model_helps={big_fetch['step_time_overlap'] < big_fetch['step_time']}")
    rows.extend(overlap_bench(smoke))
    if out_json:
        write_rows(rows, out_json)
        print(f"fig19.out_json,0.0,wrote={out_json}", file=sys.stderr)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-fast: trimmed modeled grid + small measured bench")
    ap.add_argument("--out-json", default="",
                    help="write modeled + measured rows here (study.write_rows)")
    args = ap.parse_args()
    main(out_json=args.out_json, smoke=args.smoke)
