"""Paper Fig. 19-21/23: per-phase time breakdown (sampling / feature
loading / compute). Claims: at feature size 512 feature fetching dominates
sampling; at small features (<=64) sampling >= fetching; on the road network
DI sampling always dominates."""

from benchmarks.common import SCALE, cache, emit, spec
from repro.core import cost_model
from repro.core.study import minibatch_row


def main() -> None:
    c = cache()
    k = 4
    results = {}
    # DI's phase profile in the paper reflects its very low edge-cut
    # (Fig. 13) — use metis there; EU uses a streaming partitioner.
    for gk, method in [("EU", "ldg"), ("DI", "metis")]:
        for f in (16, 512):
            r = minibatch_row(gk, method, k, spec(feature=f, layers=3),
                              scale=SCALE, cache=c, global_batch=128, steps=2)
            results[(gk, f)] = r
            emit(f"fig19.phases.{gk}.f{f}", 0.0,
                 f"sample={r['sample_time']*1e3:.2f}ms;"
                 f"fetch={r['fetch_time']*1e3:.2f}ms;"
                 f"compute={r['compute_time']*1e3:.2f}ms")
    big_fetch = results[("EU", 512)]
    small = results[("EU", 16)]
    di = results[("DI", 512)]
    emit("fig19.claims", 0.0,
         f"fetch_dominates_at_512={big_fetch['fetch_time'] > big_fetch['sample_time']};"
         f"sampling_matters_at_16={small['sample_time'] >= small['fetch_time'] * 0.5};"
         f"DI_sampling_dominates={di['sample_time'] > di['fetch_time']}")


if __name__ == "__main__":
    main()
