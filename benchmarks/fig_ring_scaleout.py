"""Ring vs halo vs dense scale-out (the 1.5D axis the paper leaves out).

For each k: measured full-batch step time under all three sync strategies
(vmap-sim, same trainer), analytic per-aggregate collective bytes, and the
ring's COMPILED collective-permute bytes (subprocess shard_map over k host
devices, parsed with launch/hlo.py) pinned against `ring_bytes_per_round`.

Claims checked per k in the smoke:
  * ring HLO bytes == analytic k·(k−1)·(Vb+1)·d·4 (exactly k−1 permutes)
  * ring bytes < DenseSync's O(V·d) at every k
  * blockrow partition time is near-zero (no heuristic pass)

`--out-json` / `--out-csv` write the study-format rows + the printed CSV —
the CI artifacts. `--smoke` (or run.py --smoke / BENCH_FAST=1) keeps the
trimmed grid.
"""

import argparse
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from benchmarks.common import FAST, KS, SCALE, cache, emit
from repro.core import cost_model
from repro.core.study import fullbatch_result_row, write_rows
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.models import GNNSpec

GRAPH = "OR"
HALO_METHOD = "hep100"
# standalone `--smoke` runs the trimmed scale without env setup, same
# convention as fig_serving (run.py --smoke sets BENCH_FAST for the suite)
SMOKE = FAST or "--smoke" in sys.argv
RING_SCALE = float(os.environ.get("BENCH_SCALE", "0.02")) if SMOKE else SCALE


def _time_steps(step_fn, reps: int = 3) -> float:
    step_fn()  # compile + warm up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        step_fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _ring_hlo_bytes(k: int, d: int, scale: float) -> tuple[int, int]:
    """(permute_count, per_device_bytes) of ONE compiled ring aggregate,
    measured from real shard_map HLO over k host devices (subprocess, so
    this process keeps its single-device view)."""
    code = textwrap.dedent(f"""
        import numpy as np, jax
        from jax.sharding import PartitionSpec as P
        from repro.core.graph import paper_graph
        from repro.core.partition_book import build_blockrow_book
        from repro.gnn.sync import RingSync, build_ring_blocks
        from repro.launch.hlo import collective_bytes_from_hlo
        from repro.launch.mesh import make_mesh

        g = paper_graph("{GRAPH}", scale={scale}, seed=0)
        k, d = {k}, {d}
        book = build_blockrow_book(g, k)
        feats = np.zeros((g.num_vertices, d), np.float32)
        blocks = build_ring_blocks(book, feats,
                                   np.zeros(g.num_vertices, np.int32),
                                   np.zeros(g.num_vertices, bool))
        mesh = make_mesh((k,), ("parts",))

        def per_device(blocks_local):
            blk = jax.tree.map(lambda a: a[0], blocks_local)
            sync = RingSync(axis="parts", k=k)
            h = sync.edge_aggregate(blk, blk.x,
                                    lambda s, dst, m: s * m[:, None])
            return h[None]

        shard_map = (jax.shard_map if hasattr(jax, "shard_map")
                     else __import__("jax.experimental.shard_map",
                                     fromlist=["shard_map"]).shard_map)
        kw = ({{"check_vma": False}} if hasattr(jax, "shard_map")
              else {{"check_rep": False}})
        fn = shard_map(per_device, mesh=mesh, in_specs=(P("parts"),),
                       out_specs=P("parts"), **kw)
        hlo = jax.jit(fn).lower(blocks).compile().as_text()
        coll = collective_bytes_from_hlo(hlo)
        print(coll["count_per_kind"].get("collective-permute", 0),
              coll["bytes_per_kind"].get("collective-permute", 0))
    """)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900,
        env={"XLA_FLAGS": f"--xla_force_host_platform_device_count={k}",
             "JAX_PLATFORMS": "cpu",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    count, per_dev = proc.stdout.strip().splitlines()[-1].split()
    return int(count), int(per_dev)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")  # grid set by env/common
    ap.add_argument("--out-json", default="")
    ap.add_argument("--out-csv", default="")
    ap.add_argument("--skip-hlo", action="store_true",
                    help="skip the subprocess HLO measurement (fast local "
                         "iteration; the analytic bytes rows still emit)")
    args = ap.parse_args(argv if argv is not None else [])

    from repro.gnn.sync import sync_bytes_per_round

    c = cache()
    g = c.graph(GRAPH, RING_SCALE, 0)
    spec = GNNSpec(model="sage", feature_dim=32, hidden_dim=32,
                   num_classes=8, num_layers=2)
    d = spec.hidden_dim
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, 32)).astype(np.float32)
    labels = rng.integers(0, 8, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.3

    rows, csv_lines = [], []

    def emit2(name, seconds, derived):
        emit(name, seconds, derived)
        csv_lines.append(f"{name},{seconds * 1e6:.1f},{derived}")

    claims_ok = True
    for k in KS:
        ring_rec = c.blockrow_partition(g, k)
        halo_rec = c.edge_partition(g, HALO_METHOD, k, 0)
        recs = {"ring": ring_rec, "halo": halo_rec, "dense": halo_rec}
        per_round = {
            "ring": sync_bytes_per_round(ring_rec.book, d, "ring"),
            "halo": sync_bytes_per_round(halo_rec.book, d, "halo"),
            "dense": sync_bytes_per_round(halo_rec.book, d, "dense"),
        }
        for mode, rec in recs.items():
            assignment = None if mode == "ring" else rec.assignment
            tr = FullBatchTrainer.build(
                g, assignment, k, spec, feats, labels, train,
                sync_mode=mode, mode="sim", seed=0)
            step_s = _time_steps(tr.train_step)
            est = cost_model.fullbatch_epoch(tr.book, spec)
            emit2(f"fig_ring.step.{GRAPH}.k{k}.{mode}", step_s,
                  f"round_bytes={per_round[mode]};"
                  f"partition_time={rec.partition_time:.4f};"
                  f"model_epoch_ms={est.epoch_time * 1e3:.2f}")
            row = fullbatch_result_row(
                GRAPH, rec.method, k, spec, metrics=rec.metrics,
                partition_time=rec.partition_time, est=est,
                sync_mode=mode)
            row["round_bytes"] = per_round[mode]
            row["measured_step_s"] = step_s
            rows.append(row)

        ring_below_dense = per_round["ring"] < per_round["dense"]
        claims_ok &= ring_below_dense
        if not args.skip_hlo:
            count, per_dev = _ring_hlo_bytes(k, d, RING_SCALE)
            match = (count == k - 1 and per_dev * k == per_round["ring"])
            claims_ok &= match
            emit2(f"fig_ring.hlo.{GRAPH}.k{k}", 0.0,
                  f"permutes={count};hlo_cluster_bytes={per_dev * k};"
                  f"analytic={per_round['ring']};match={match}")
            rows[-3]["hlo_round_bytes"] = per_dev * k  # the ring row
        emit2(f"fig_ring.bytes.{GRAPH}.k{k}", 0.0,
              f"ring={per_round['ring']};halo={per_round['halo']};"
              f"dense={per_round['dense']};"
              f"ring_below_dense={ring_below_dense}")

    emit2("fig_ring.claims", 0.0, f"all_pinned={claims_ok}")
    if args.out_json:
        write_rows(rows, args.out_json)
    if args.out_csv:
        with open(args.out_csv, "w") as f:
            f.write("name,us_per_call,derived\n")
            f.write("\n".join(csv_lines) + "\n")
    if not claims_ok:
        raise SystemExit("fig_ring: analytic/HLO byte pin failed")


if __name__ == "__main__":
    main(sys.argv[1:])
