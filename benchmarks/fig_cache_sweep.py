"""Beyond-paper sweep: feature-cache policy x budget x partitioner.

The paper (Figs. 16-19) shows remote feature loading is the dominant,
partitioning-sensitive phase of DistDGL training; PaGraph/BGL-style caching
is the standard systems lever on the same cost. This sweep shows the two
compose: a high-quality partition (metis) lowers remote traffic AND a hot
cache removes most of what remains, so miss bytes fall monotonically with
budget for every partitioner, with degree/halo >> random at equal budget.

Emits one JSON row per (policy, budget, partitioner) combination (the PR's
acceptance format) plus the usual name,us,derived CSV claims.
"""

import json

from benchmarks.common import FAST, SCALE, cache, emit, spec
from repro.core.study import minibatch_row

POLICIES = ("none", "random", "degree", "halo")
BUDGET_FRACS = (0.02, 0.1) if FAST else (0.01, 0.02, 0.05, 0.1, 0.2)
PARTITIONERS = ("random", "metis") if FAST else ("random", "ldg", "metis", "kahip")


def main() -> None:
    c = cache()
    k = 4
    g = c.graph("OR", SCALE, 0)
    rows = []
    for method in PARTITIONERS:
        for frac in BUDGET_FRACS:
            budget = max(int(frac * g.num_vertices), 1)
            for policy in POLICIES:
                # small per-worker batches keep the sampled frontier well
                # below |V| — otherwise every cached vertex trivially hits
                r = minibatch_row(
                    "OR", method, k, spec(feature=64, layers=2),
                    scale=SCALE, cache=c, global_batch=32, steps=2,
                    cache_policy=policy, cache_budget=budget,
                )
                rows.append(r)
                print(json.dumps({
                    "figure": "cache_sweep", "graph": "OR", "k": k,
                    "partitioner": method, "policy": policy,
                    "budget": budget, "budget_frac": frac,
                    "hit_rate": round(r["hit_rate"], 4),
                    "remote_vertices": r["remote_vertices"],
                    "remote_misses": r["remote_misses"],
                    "fetch_bytes": r["fetch_bytes"],
                    "fetch_time": r["fetch_time"],
                    "step_time": r["step_time"],
                }))

    def total(method, policy, frac):
        for r in rows:
            if (r["method"], r["cache_policy"]) == (method, policy) and (
                    r["cache_budget"] == max(int(frac * g.num_vertices), 1)):
                return r
        raise KeyError((method, policy, frac))

    big = BUDGET_FRACS[-1]
    for method in PARTITIONERS:
        none = total(method, "none", big)
        deg = total(method, "degree", big)
        rnd = total(method, "random", big)
        emit(f"cache_sweep.{method}", 0.0,
             f"miss_pct_uncached={100.0 * deg['fetch_bytes'] / max(none['fetch_bytes'], 1e-9):.1f};"
             f"degree_hit={deg['hit_rate']:.3f};random_hit={rnd['hit_rate']:.3f}")
    deg_m = total("metis", "degree", big)
    none_r = total("random", "none", big)
    emit("cache_sweep.claims", 0.0,
         f"degree_beats_none={deg_m['fetch_bytes'] < total('metis', 'none', big)['fetch_bytes']};"
         f"degree_beats_random_cache={deg_m['hit_rate'] >= total('metis', 'random', big)['hit_rate']};"
         f"compose_pct={100.0 * deg_m['fetch_bytes'] / max(none_r['fetch_bytes'], 1e-9):.1f}")


if __name__ == "__main__":
    main()
