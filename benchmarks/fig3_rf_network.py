"""Paper Fig. 3: replication factor vs network communication, R^2 >= 0.98.
Our system computes the exact replica-sync volume from the partition book;
the correlation across partitioners/k must be near-perfect linear."""

import numpy as np

from benchmarks.common import SCALE, cache, emit, spec, timed
from repro.core import cost_model
from repro.core.study import EDGE_METHODS


def main() -> None:
    c = cache()
    g = c.graph("OR", SCALE)
    s = spec(feature=64, hidden=64, layers=2)
    rfs, comms = [], []
    for k in (4, 8):
        for m in EDGE_METHODS:
            rec, dt = timed(lambda m=m, k=k: c.edge_partition(g, m, k))
            est = cost_model.fullbatch_epoch(rec.book, s)
            rfs.append(rec.metrics.replication_factor)
            comms.append(est.comm_bytes.sum())
            emit(f"fig3.point.k{k}.{m}", dt,
                 f"rf={rfs[-1]:.2f};bytes={comms[-1]:.0f}")
    r = np.corrcoef(rfs, comms)[0, 1]
    emit("fig3.correlation", 0.0, f"r2={r*r:.4f};claim_r2>=0.98={r*r >= 0.98}")


if __name__ == "__main__":
    main()
