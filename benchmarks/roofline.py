"""Assignment §Roofline: three-term roofline per (arch x shape) on the
single-pod 16x16 mesh, read from the dry-run cache (dryrun_results.json).

Prints, per cell: compute/memory/collective seconds (analytic model,
repro.dist.costs), the dominant term, MODEL_FLOPS=6ND (or 2ND), the
useful-flops ratio, peak bytes/device from the compiled memory analysis,
plus the HLO-derived terms as the compiled cross-check.
"""

import json
import os

from benchmarks.common import emit

RESULTS = os.environ.get("DRYRUN_RESULTS", "/root/repo/dryrun_results.json")


def main() -> None:
    if not os.path.exists(RESULTS):
        emit("roofline.missing", 0.0,
             "run `python -m repro.launch.dryrun --all --both-meshes` first")
        return
    with open(RESULTS) as f:
        results = json.load(f)
    cells = {k: v for k, v in sorted(results.items())
             if "error" not in v and v.get("mesh") == "16x16"}
    fits = 0
    for key, v in cells.items():
        r = v["roofline"]
        peak_gib = v["bytes_per_device"]["peak"] / 2**30
        fits += peak_gib <= 16.0
        # optimized §Perf variants are stored under "...|<strategy>" keys
        variant = ".{}".format(key.split("|")[3]) if key.count("|") >= 3 else ""
        emit(
            f"roofline.{v['arch']}.{v['shape']}{variant}", r["bound_s"],
            f"dom={r['dominant']};c_ms={r['compute_s']*1e3:.2f};"
            f"m_ms={r['memory_s']*1e3:.2f};n_ms={r['collective_s']*1e3:.2f};"
            f"mfu_bound={r['mfu_bound']:.3f};"
            f"useful={r['useful_flops_ratio']:.3f};"
            f"peak_GiB={peak_gib:.2f};"
            f"hlo_coll_ms={v['roofline_hlo']['collective_s']*1e3:.2f}",
        )
    multi = {k: v for k, v in results.items()
             if "error" not in v and v.get("mesh") == "2x16x16"}
    emit("roofline.summary", 0.0,
         f"single_pod_cells={len(cells)};fits_16GiB={fits};"
         f"multi_pod_cells={len(multi)};"
         f"multi_pod_ok={sum(1 for v in multi.values() if 'error' not in v)}")


if __name__ == "__main__":
    main()
