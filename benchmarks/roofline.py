"""Assignment §Roofline: three-term roofline per (arch x shape) on the
single-pod 16x16 mesh, read from the dry-run cache (dryrun_results.json) —
plus the GNN aggregation-backend bench: measured scatter-vs-tiled
segment-reduce (sum AND max) microbench rows, and scatter-vs-tiled step time
+ aggregate traffic bytes for the full-batch (sage/gcn/gat, k in {1, 4}) and
mini-batch (sage) trainers — gat exercises the segment-max path end to end —
and the serial-vs-pipelined mini-batch step rows (the overlapped execution
engine, gnn/pipeline.py, sharing fig19's measured bench), and the
ring-vs-halo-vs-dense sync-strategy step rows (gnn/sync.py; the full k
sweep + HLO byte pin is fig_ring_scaleout).
`--smoke` (or `run.py --smoke`) runs the aggregation bench at the trimmed CI
scale; the dry-run section still needs the cache.
"""

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import emit

# the agg bench sizes itself independently of common.SCALE so a direct
# `python benchmarks/roofline.py --smoke` is CI-fast without env setup
AGG_SCALE = float(os.environ.get("BENCH_SCALE", "0.02"))

RESULTS = os.environ.get("DRYRUN_RESULTS", "/root/repo/dryrun_results.json")


def _agg_traffic_bytes(book, spec, backend) -> str:
    """Analytic per-step aggregate traffic (all partitions, fwd only):
    message bytes streamed through the aggregation. The scatter backend
    reads/writes the raw symmetrised edge list; the tiled backend streams
    the blocked layout (real edges + tile padding; its book carries the
    layout — the scatter book is built without one). sage/gcn stream one
    [E, hidden] sum per layer; gat streams two [E, heads] score reduces
    (segment-max + den sum) plus the [E, hidden] num sum."""
    width = spec.hidden_dim
    if spec.model == "gat":
        width += 2 * spec.gat_heads
    e2 = 2 * int(book.emask.sum())          # real symmetrised edges
    if backend == "scatter":
        return f"agg_bytes={spec.num_layers * 2 * e2 * width * 4}"
    e_tiled = int(np.prod(book.agg_order.shape))
    return (f"agg_bytes={spec.num_layers * 2 * e_tiled * width * 4};"
            f"tiled_pad_frac={1.0 - e2 / max(e_tiled, 1):.3f}")


def _time_steps(step_fn, reps: int = 3) -> float:
    step_fn()  # compile + warm up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        step_fn()
        best = min(best, time.perf_counter() - t0)
    return best


def segment_reduce_bench() -> None:
    """Measured scatter-vs-tiled segment-reduce rows, one per combiner:
    the kernel-level proof that BOTH the sum (GNN neighbor aggregation) and
    the max (GAT softmax stabilisation) run without a data-dependent
    scatter under the tiled backend."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    v = max(int(65536 * AGG_SCALE / 0.02), 1024)
    e, f = 16 * v, 64
    dst = rng.integers(0, v, e).astype(np.int32)
    msgs = jnp.asarray(rng.normal(size=(e, f)).astype(np.float32))
    order, ldst, _ = ops.prepare_tiled_edges(dst, v)
    jdst = jnp.asarray(dst)
    order, ldst = jnp.asarray(order), jnp.asarray(ldst)
    for reduce in ("sum", "max"):
        times = {}
        for backend in ("scatter", "tiled"):
            kw = ({} if backend == "scatter"
                  else {"edge_order": order, "local_dst": ldst})
            fn = jax.jit(lambda m, bk=backend, rd=reduce, kw=kw: ops.aggregate(
                m, jdst, v, backend=bk, reduce=rd, **kw))
            times[backend] = _time_steps(
                lambda: jax.block_until_ready(fn(msgs)))
            emit(f"roofline.agg.segreduce.{reduce}.{backend}",
                 times[backend], f"edges={e};rows={v};feat={f}")
        emit(f"roofline.agg.segreduce.{reduce}.speedup", 0.0,
             f"scatter_over_tiled={times['scatter'] / times['tiled']:.3f}")


def agg_backend_bench() -> None:
    """Measured scatter-vs-tiled step time (the tentpole's proof row);
    gat additionally runs its softmax max through the tiled segment-max."""
    import dataclasses

    from repro.core.edge_partition import partition_edges
    from repro.core.graph import paper_graph
    from repro.core.vertex_partition import partition_vertices
    from repro.gnn.fullbatch import FullBatchTrainer
    from repro.gnn.minibatch import MiniBatchTrainer
    from repro.gnn.models import GNNSpec

    g = paper_graph("OR", scale=AGG_SCALE, seed=0)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, 32)).astype(np.float32)
    labels = rng.integers(0, 8, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.3

    for model in ("sage", "gcn", "gat"):
        spec = GNNSpec(model=model, feature_dim=32, hidden_dim=32,
                       num_classes=8, num_layers=2)
        for k in (1, 4):
            asg = (np.zeros(g.num_edges, np.int32) if k == 1
                   else partition_edges(g, k, "hep100", seed=0))
            times = {}
            for backend in ("scatter", "tiled"):
                tr = FullBatchTrainer.build(
                    g, asg, k, dataclasses.replace(spec, agg_backend=backend),
                    feats, labels, train, seed=0)
                times[backend] = _time_steps(tr.train_step)
                emit(f"roofline.agg.fullbatch.{model}.k{k}.{backend}",
                     times[backend],
                     f"{_agg_traffic_bytes(tr.book, spec, backend)};"
                     f"edges={g.num_edges}")
            emit(f"roofline.agg.fullbatch.{model}.k{k}.speedup", 0.0,
                 f"scatter_over_tiled={times['scatter'] / times['tiled']:.3f}")

    spec = GNNSpec(model="sage", feature_dim=32, hidden_dim=32,
                   num_classes=8, num_layers=2)
    owner = partition_vertices(g, 4, "metis", seed=0)
    times = {}
    for backend in ("scatter", "tiled"):
        tr = MiniBatchTrainer.build(
            g, owner, 4, dataclasses.replace(spec, agg_backend=backend),
            feats, labels, train, global_batch=256, seed=0)
        tr.train_step()  # compile
        metrics = [tr.train_step() for _ in range(3)]
        times[backend] = min(m.compute_time_host for m in metrics)
        emit(f"roofline.agg.minibatch.sage.k4.{backend}", times[backend],
             f"edges_per_step={int(metrics[-1].edges.sum())}")
    emit("roofline.agg.minibatch.sage.k4.speedup", 0.0,
         f"scatter_over_tiled={times['scatter'] / times['tiled']:.3f}")


def sync_mode_bench() -> None:
    """Measured ring-vs-halo-vs-dense step time at one k (the SyncStrategy
    seam end to end, same trainer): the per-aggregate collective volume of
    each mode rides along so the step-time ordering can be read against the
    bytes ordering. The full k sweep lives in fig_ring_scaleout."""
    from repro.core.edge_partition import partition_edges
    from repro.core.graph import paper_graph
    from repro.gnn.fullbatch import FullBatchTrainer
    from repro.gnn.models import GNNSpec
    from repro.gnn.sync import sync_bytes_per_round, sync_wire_bytes_per_round

    g = paper_graph("OR", scale=AGG_SCALE, seed=0)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, 32)).astype(np.float32)
    labels = rng.integers(0, 8, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.3
    spec = GNNSpec(model="sage", feature_dim=32, hidden_dim=32,
                   num_classes=8, num_layers=2)
    k = 4
    asg = partition_edges(g, k, "hep100", seed=0)
    times = {}
    for mode in ("ring", "halo", "dense"):
        tr = FullBatchTrainer.build(
            g, None if mode == "ring" else asg, k, spec,
            feats, labels, train, sync_mode=mode, seed=0)
        times[mode] = _time_steps(tr.train_step)
        emit(f"roofline.sync.fullbatch.sage.k{k}.{mode}", times[mode],
             f"codec=fp32;"
             f"round_bytes={sync_bytes_per_round(tr.book, spec.hidden_dim, mode)};"
             f"wire_bytes={sync_wire_bytes_per_round(tr.book, spec.hidden_dim, mode)}")
    # the compressed-wire point: same ring step trained through the int8+EF
    # codec — the wire column shrinks ~4x while round_bytes stays logical
    tr8 = FullBatchTrainer.build(
        g, None, k, spec, feats, labels, train,
        sync_mode="ring", seed=0, codec="int8")
    t8 = _time_steps(tr8.train_step)
    emit(f"roofline.sync.fullbatch.sage.k{k}.ring_int8", t8,
         f"codec=int8;"
         f"round_bytes={sync_bytes_per_round(tr8.book, spec.hidden_dim, 'ring')};"
         f"wire_bytes={sync_wire_bytes_per_round(tr8.book, spec.hidden_dim, 'ring', codec='int8')}")
    emit(f"roofline.sync.fullbatch.sage.k{k}.speedup", 0.0,
         f"halo_over_ring={times['halo'] / times['ring']:.3f};"
         f"dense_over_ring={times['dense'] / times['ring']:.3f}")


def overlap_bench() -> None:
    """Measured serial-vs-pipelined mini-batch step rows (the overlapped
    execution engine, gnn/pipeline.py) — shares fig19's bench so the two
    smoke artifacts can't drift apart."""
    from benchmarks.fig19_phase_times import measure_overlap

    m = measure_overlap(AGG_SCALE)
    for mode in ("serial", "pipelined"):
        r = m[mode]
        emit(f"roofline.overlap.minibatch.sage.k{m['k']}.{mode}", r["wall"],
             f"host={r['sample']+r['fetch']+r['transfer']:.4f}s;"
             f"compute={r['compute']:.4f}s;"
             f"overlap_eff={r['overlap_efficiency']:.2f}")
    emit(f"roofline.overlap.minibatch.sage.k{m['k']}.speedup", 0.0,
         f"serial_over_pipelined={m['speedup']:.3f};"
         f"losses_identical={m['losses_identical']}")


def serving_bench() -> None:
    """Measured serve-step rows (scatter vs tiled): the online micro-batch
    path — embedding-store gather + final-layer recompute through
    `ops.aggregate` — alongside the modeled cluster service time. The
    layer-wise offline pass is timed too (host, per layer)."""
    import dataclasses

    from repro.core.partition_book import build_vertex_book
    from repro.core.vertex_partition import partition_vertices
    from repro.core.graph import paper_graph
    from repro.gnn.inference import (
        LayerwiseInference,
        edge_assignment_from_vertex,
    )
    from repro.gnn.models import GNNSpec, init_params
    from repro.serve import build_serving

    g = paper_graph("OR", scale=AGG_SCALE, seed=0)
    rng = np.random.default_rng(0)
    spec0 = GNNSpec(model="sage", feature_dim=32, hidden_dim=32,
                    num_classes=8, num_layers=2)
    feats = rng.normal(size=(g.num_vertices, 32)).astype(np.float32)
    owner = partition_vertices(g, 4, "metis", seed=0)
    vbook = build_vertex_book(g, owner, 4)
    ids = rng.integers(0, g.num_vertices, 32)

    times = {}
    for backend in ("scatter", "tiled"):
        spec = dataclasses.replace(spec0, agg_backend=backend)
        params = init_params(spec, seed=0)
        eng = LayerwiseInference.build(
            g, edge_assignment_from_vertex(g, owner), 4, spec, params, feats)
        embeddings = eng.run()
        emit(f"roofline.serve.layerwise.{backend}", sum(eng.layer_times),
             f"layers={spec.num_layers};"
             f"halo_bytes={eng.sync_bytes()}")
        engines, batchers, _ = build_serving(
            g, vbook, spec, params, embeddings, hops=1, fanout=10,
            max_batch=32, cache_policy="degree",
            cache_budget=max(g.num_vertices // 10, 1))
        batch = batchers[0].build_mfg(ids)
        _, stats, _ = engines[0].answer(batch)  # compile + warm
        times[backend] = _time_steps(lambda: engines[0].answer(batch))
        est = engines[0].estimate(batch, stats)
        emit(f"roofline.serve.microbatch.sage.{backend}", times[backend],
             f"batch=32;edges={batch.num_edges};"
             f"miss_bytes={stats.miss_bytes};"
             f"model_service_us={est.service_time*1e6:.0f}")
    emit("roofline.serve.microbatch.sage.speedup", 0.0,
         f"scatter_over_tiled={times['scatter'] / times['tiled']:.3f}")


def main() -> None:
    smoke = "--smoke" in sys.argv or os.environ.get("BENCH_FAST") == "1"
    if smoke:
        segment_reduce_bench()
        agg_backend_bench()
        sync_mode_bench()
        overlap_bench()
        serving_bench()
    if not os.path.exists(RESULTS):
        emit("roofline.missing", 0.0,
             "run `python -m repro.launch.dryrun --all --both-meshes` first")
        return
    with open(RESULTS) as f:
        results = json.load(f)
    cells = {k: v for k, v in sorted(results.items())
             if "error" not in v and v.get("mesh") == "16x16"}
    fits = 0
    for key, v in cells.items():
        r = v["roofline"]
        peak_gib = v["bytes_per_device"]["peak"] / 2**30
        fits += peak_gib <= 16.0
        # optimized §Perf variants are stored under "...|<strategy>" keys
        variant = ".{}".format(key.split("|")[3]) if key.count("|") >= 3 else ""
        emit(
            f"roofline.{v['arch']}.{v['shape']}{variant}", r["bound_s"],
            f"dom={r['dominant']};c_ms={r['compute_s']*1e3:.2f};"
            f"m_ms={r['memory_s']*1e3:.2f};n_ms={r['collective_s']*1e3:.2f};"
            f"mfu_bound={r['mfu_bound']:.3f};"
            f"useful={r['useful_flops_ratio']:.3f};"
            f"peak_GiB={peak_gib:.2f};"
            f"hlo_coll_ms={v['roofline_hlo']['collective_s']*1e3:.2f}",
        )
    multi = {k: v for k, v in results.items()
             if "error" not in v and v.get("mesh") == "2x16x16"}
    emit("roofline.summary", 0.0,
         f"single_pod_cells={len(cells)};fits_16GiB={fits};"
         f"multi_pod_cells={len(multi)};"
         f"multi_pod_ok={sum(1 for v in multi.values() if 'error' not in v)}")


if __name__ == "__main__":
    main()
