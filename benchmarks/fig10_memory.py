"""Paper Fig. 10/11 + §4.3(1-3): memory footprint in % of random
partitioning, and its dependence on feature size / hidden dim / layers.
Claims: RF<->memory correlation R^2>=0.99; bigger features/hidden/layers =>
partitioning more effective at reducing memory."""

import numpy as np

from benchmarks.common import SCALE, cache, emit, spec
from repro.core import cost_model
from repro.core.study import EDGE_METHODS, fullbatch_row, fullbatch_speedup


def main() -> None:
    c = cache()
    k = 8
    # (1) RF vs memory correlation across partitioners
    g = c.graph("OR", SCALE)
    s = spec(feature=64, hidden=64, layers=2)
    rfs, mems = [], []
    for m in EDGE_METHODS:
        rec = c.edge_partition(g, m, k)
        est = cost_model.fullbatch_epoch(rec.book, s)
        rfs.append(rec.metrics.replication_factor)
        mems.append(est.memory.sum())
    r = np.corrcoef(rfs, mems)[0, 1]
    emit("fig10.rf_memory_corr", 0.0, f"r2={r*r:.4f};claim>=0.99={r*r >= 0.99}")

    # (2) feature-size trend (paper fig 11a): memory%random falls as F grows
    for m in ["hep10", "2ps-l", "hep100"]:
        pcts = {}
        for f in (16, 512):
            rows = [fullbatch_row("OR", mm, k, spec(feature=f), scale=SCALE,
                                  cache=c) for mm in ("random", m)]
            sp = {r["method"]: r for r in fullbatch_speedup(rows)}
            pcts[f] = sp[m]["memory_pct_random"]
            emit(f"fig11a.mem_pct.{m}.f{f}", 0.0, f"pct={pcts[f]:.1f}")
        emit(f"fig11a.trend.{m}", 0.0,
             f"more_effective_at_large_features={pcts[512] <= pcts[16]}")

    # (3) hidden-dim trend (fig 11b)
    for m in ["2ps-l", "hep100"]:
        pcts = {}
        for h in (16, 512):
            rows = [fullbatch_row("OR", mm, k, spec(hidden=h), scale=SCALE,
                                  cache=c) for mm in ("random", m)]
            sp = {r["method"]: r for r in fullbatch_speedup(rows)}
            pcts[h] = sp[m]["memory_pct_random"]
            emit(f"fig11b.mem_pct.{m}.h{h}", 0.0, f"pct={pcts[h]:.1f}")
        emit(f"fig11b.trend.{m}", 0.0,
             f"more_effective_at_large_hidden={pcts[512] <= pcts[16]}")

    # (4) layer trend (fig 11c/d). NOTE (scale artifact, documented in
    # EXPERIMENTS.md): the paper's layer effect is driven by the
    # replication-INsensitive graph-structure bytes shrinking relative to the
    # replication-sensitive per-layer activations; at our reduced graph scale
    # the structure share is ~4x smaller than at paper scale, so the trend is
    # flat (within ~1%) rather than clearly decreasing. We assert
    # non-divergence and report the values.
    for hid in (16, 64):
        pcts = {}
        for l in (2, 4):
            rows = [fullbatch_row("OR", mm, k, spec(hidden=hid, layers=l),
                                  scale=SCALE, cache=c)
                    for mm in ("random", "hep100")]
            sp = {r["method"]: r for r in fullbatch_speedup(rows)}
            pcts[l] = sp["hep100"]["memory_pct_random"]
            emit(f"fig11cd.mem_pct.h{hid}.l{l}", 0.0, f"pct={pcts[l]:.1f}")
        emit(f"fig11cd.trend.h{hid}", 0.0,
             f"flat_or_more_effective={pcts[4] <= pcts[2] + 1.0};"
             f"note=scale_artifact_structure_share")


if __name__ == "__main__":
    main()
