"""Paper Fig. 16/18: DistDGL (mini-batch) speedups and their GNN-parameter
dependence. Claims: kahip/metis lead; partitioning more effective at LARGE
feature sizes (18a) and LESS effective at large hidden dims (18b); number of
layers has no strong trend (18c)."""

from benchmarks.common import SCALE, cache, emit, spec
from repro.core.study import VERTEX_METHODS, minibatch_row, minibatch_speedup


def main() -> None:
    c = cache()
    k = 4
    # speedup distribution at defaults
    rows = [minibatch_row("OR", m, k, spec(feature=512), scale=SCALE,
                          cache=c, global_batch=128, steps=2)
            for m in VERTEX_METHODS]
    sp = {r["method"]: r for r in minibatch_speedup(rows)}
    for m, r in sp.items():
        emit(f"fig16.speedup.k{k}.{m}", 0.0, f"speedup={r['speedup']:.3f}")
    lead = max(sp, key=lambda m: sp[m]["speedup"])
    emit("fig16.claims", 0.0,
         f"leader={lead};quality_leader_in_top2="
         f"{lead in ('kahip', 'metis', 'spinner')}")

    # 18a: feature-size trend for kahip
    sps = {}
    for f in (16, 512):
        rows = [minibatch_row("OR", m, k, spec(feature=f), scale=SCALE,
                              cache=c, global_batch=128, steps=2)
                for m in ("random", "kahip")]
        sps[f] = {r["method"]: r for r in minibatch_speedup(rows)}["kahip"]["speedup"]
        emit(f"fig18a.kahip.f{f}", 0.0, f"speedup={sps[f]:.3f}")
    emit("fig18a.claims", 0.0,
         f"more_effective_at_large_features={sps[512] >= sps[16]}")

    # 18b: hidden-dim trend for kahip
    sps = {}
    for h in (16, 512):
        rows = [minibatch_row("OR", m, k, spec(hidden=h), scale=SCALE,
                              cache=c, global_batch=128, steps=2)
                for m in ("random", "kahip")]
        sps[h] = {r["method"]: r for r in minibatch_speedup(rows)}["kahip"]["speedup"]
        emit(f"fig18b.kahip.h{h}", 0.0, f"speedup={sps[h]:.3f}")
    emit("fig18b.claims", 0.0,
         f"less_effective_at_large_hidden={sps[512] <= sps[16] * 1.05}")


if __name__ == "__main__":
    main()
