"""Beyond-paper sweep: serving latency vs partitioner x cache policy x QPS.

The paper's finding — partitioning quality governs remote traffic — carried
to the serving workload (repro.serve): every row runs the REAL layer-wise
inference engine + micro-batched request simulator on a real partition and
prices per-request latency on the paper's cluster
(`cost_model.serve_request`). The claims: modeled latency and embedding
miss bytes fall with partitioning quality (metis < random edge-cut) at
every cache policy and offered load, and an embedding cache composes with
— not substitutes for — a good partition, exactly like the training-side
cache sweep (fig_cache_sweep.py).

Emits one JSON row per (partitioner, policy, qps) combination via the
shared `core/study.py` serializer; `--out-json PATH` additionally writes
them as one file (the CI artifact). Standalone `--smoke` runs the trimmed
grid without env setup (run.py --smoke sets BENCH_FAST for the full suite).
"""

import argparse
import json
import os
import sys

from benchmarks.common import FAST, SCALE, cache, emit, spec
from repro.core.study import serve_row, write_rows

SMOKE = FAST or "--smoke" in sys.argv
# hidden=512 is a paper Table-2 grid point; KB-scale embedding rows make the
# network term visible against the fixed per-batch overheads
PARTITIONERS = ("random", "metis") if SMOKE else ("random", "ldg", "metis", "kahip")
POLICIES = ("none", "degree") if SMOKE else ("none", "random", "degree", "halo")
QPS = (100.0, 400.0) if SMOKE else (100.0, 400.0, 1200.0)
SERVE_SCALE = float(os.environ.get("BENCH_SCALE", "0.02")) if SMOKE else SCALE
N_REQUESTS = 160 if SMOKE else 400


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out-json", default="",
                    help="also write all rows to this file (CI artifact)")
    args, _ = ap.parse_known_args()

    c = cache()
    k = 4
    sp = spec(feature=64, hidden=512, layers=2)
    g = c.graph("OR", SERVE_SCALE, 0)
    budget = max(g.num_vertices // 10, 1)
    rows = []
    for method in PARTITIONERS:
        for policy in POLICIES:
            for qps in QPS:
                r = serve_row(
                    "OR", method, k, sp, scale=SERVE_SCALE, cache=c,
                    qps=qps, n_requests=N_REQUESTS, hops=1, fanout=10,
                    max_batch=32, max_wait=5e-4, cache_policy=policy,
                    cache_budget=0 if policy == "none" else budget,
                )
                rows.append(r)
                print(json.dumps({
                    "figure": "serving", "graph": "OR", "k": k,
                    "partitioner": method, "policy": policy, "qps": qps,
                    "edge_cut": round(r["partition_quality"], 4),
                    "p50_ms": round(r["latency_p50"] * 1e3, 4),
                    "p99_ms": round(r["latency_p99"] * 1e3, 4),
                    "mean_ms": round(r["latency_mean"] * 1e3, 4),
                    "service_ms": round(r["service_mean"] * 1e3, 4),
                    # p99 attribution: queueing vs compute (obs.aggregate
                    # request_breakdown columns on the study row)
                    "queue_wait_p99_ms": round(
                        r.get("queue_wait_p99", 0.0) * 1e3, 4),
                    "service_p99_ms": round(
                        r.get("service_p99", 0.0) * 1e3, 4),
                    "p99_queue_share": round(
                        r.get("p99_queue_share", 0.0), 4),
                    "hit_rate": round(r["hit_rate"], 4),
                    "miss_bytes": r["miss_bytes"],
                    "qps_sustainable": round(r["qps_sustainable"], 1),
                }))

    def pick(method, policy, qps):
        for r in rows:
            if (r["method"], r["cache_policy"], r["qps_offered"]) == (
                    method, policy, qps):
                return r
        raise KeyError((method, policy, qps))

    # claims: partitioning quality -> latency/miss-bytes, at every load
    best = "metis"
    for qps in QPS:
        rnd, bst = pick("random", "none", qps), pick(best, "none", qps)
        emit(f"serving.quality.qps{qps:.0f}", 0.0,
             f"latency_decreases={bst['latency_mean'] < rnd['latency_mean']};"
             f"miss_pct_random={100.0 * bst['miss_bytes'] / max(rnd['miss_bytes'], 1e-9):.1f};"
             f"p50_ms={bst['latency_p50']*1e3:.3f}vs{rnd['latency_p50']*1e3:.3f}")
    cached, uncached = pick(best, "degree", QPS[0]), pick(best, "none", QPS[0])
    rnd_cached = pick("random", "degree", QPS[0])
    emit("serving.claims", 0.0,
         f"cache_composes={cached['miss_bytes'] < uncached['miss_bytes']};"
         f"quality_beats_cache={cached['miss_bytes'] < rnd_cached['miss_bytes']};"
         f"hit_rate={cached['hit_rate']:.3f}")
    # p99 attribution: under rising load the queue share of tail latency
    # must grow (service time is load-independent in the simulator)
    lo, hi = pick(best, "none", QPS[0]), pick(best, "none", QPS[-1])
    emit("serving.p99_attribution", 0.0,
         f"queue_share_lo={lo.get('p99_queue_share', 0.0):.3f};"
         f"queue_share_hi={hi.get('p99_queue_share', 0.0):.3f};"
         f"queueing_grows_with_load="
         f"{hi.get('p99_queue_share', 0.0) >= lo.get('p99_queue_share', 0.0)}")

    if args.out_json:
        write_rows(rows, args.out_json)
        print(f"# wrote {len(rows)} rows -> {args.out_json}", file=sys.stderr)


if __name__ == "__main__":
    main()
