"""Beyond-paper sweep: recovery cost vs partitioner under injected faults.

Part A (elastic training): a seeded worker-loss at a crash epoch shrinks a
REAL full-batch run k -> k-1 (repro.fault.run_elastic_fullbatch), a later
worker-join grows it back; each rescale is priced by the cost model
(restore + re-partition + re-compile). The claim: a quality partitioner
(hep100) pays a larger re-partition bill per fault than random, so churn
taxes its per-epoch advantage — the crossover row says how many post-fault
epochs the advantage needs to amortise the extra recovery cost.

Part B (serving failover): a seeded worker-death mid-trace re-routes the
dead worker's requests to survivors (replica-aware for edge partitions).
EVERY request must still be answered — the script exits non-zero if any
are dropped — and the degraded-window p50/p99 quantify the transition:
quality partitions route fewer vertices per survivor, so their degraded
tail stays lower.

Emits one JSON row per cell via the shared `core/study.py` serializers;
`--out-json PATH` additionally writes them as one file (the CI artifact).
Standalone `--smoke` runs the trimmed grid without env setup.
"""

import argparse
import json
import os
import sys

from benchmarks.common import FAST, SCALE, cache, emit, spec, timed

SMOKE = FAST or "--smoke" in sys.argv
PARTITIONERS = ("random", "hep100")
CRASH_EPOCHS = (1,) if SMOKE else (1, 3)
EPOCHS = 4 if SMOKE else 8
K = 4
SERVE_PARTITIONERS = ("random", "metis") if SMOKE else ("random", "metis", "hep100")
REC_SCALE = float(os.environ.get("BENCH_SCALE", "0.02")) if SMOKE else SCALE
N_REQUESTS = 160 if SMOKE else 400


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out-json", default="",
                    help="also write all rows to this file (CI artifact)")
    args, _ = ap.parse_known_args()

    import numpy as np

    from repro.core import cost_model
    from repro.core.study import (
        fullbatch_result_row,
        serve_row,
        write_rows,
    )
    from repro.fault import FaultPlan
    from repro.fault.recovery import run_elastic_fullbatch

    c = cache()
    sp = spec(feature=32, hidden=32, layers=2)
    g = c.graph("OR", REC_SCALE, 0)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, sp.feature_dim)).astype(np.float32)
    labels = rng.integers(0, sp.num_classes, g.num_vertices).astype(np.int32)
    train_mask = rng.random(g.num_vertices) < 0.3
    rows = []

    # ---------------------------------------- Part A: elastic degrade/recover
    grow_gap = 2  # epochs between the loss and the rejoin
    for method in PARTITIONERS:
        rec = c.edge_partition(g, method, K, 0)
        est = cost_model.fullbatch_epoch(rec.book, sp)
        for crash in CRASH_EPOCHS:
            plan = FaultPlan.parse(
                [f"worker-loss@epoch:{crash}",
                 f"worker-join@epoch:{crash + grow_gap}"], seed=0)
            res, wall = timed(lambda: run_elastic_fullbatch(
                g, feats, labels, train_mask, sp, k=K, epochs=EPOCHS,
                plan=plan, partitioner=method, seed=0))
            assert plan.handled_count == plan.injected_count == len(res.events)
            shrink = res.events[0].estimate
            row = fullbatch_result_row(
                "OR", method, K, sp, metrics=rec.metrics,
                partition_time=rec.partition_time, est=est, recovery=shrink)
            row.update({
                "crash_epoch": crash,
                "epochs": EPOCHS,
                "k_history": res.k_history,
                "n_rescale": len(res.events),
                "recovery_time_total": res.recovery_time_total,
                "loss_final": res.losses[-1],
                "elastic_wall": wall,
            })
            rows.append(row)
            print(json.dumps({
                "figure": "recovery", "part": "elastic", "graph": "OR",
                "k": K, "partitioner": method, "crash_epoch": crash,
                "k_history": res.k_history,
                "recovery_time_s": round(shrink.recovery_time, 4),
                "restore_s": round(shrink.restore_time, 6),
                "repartition_s": round(shrink.repartition_time, 4),
                "recompile_s": round(shrink.recompile_time, 4),
                "epoch_time_s": round(est.epoch_time, 4),
                "loss_final": round(res.losses[-1], 4),
            }))

    def pick_a(method, crash):
        for r in rows:
            if (r.get("method"), r.get("crash_epoch")) == (method, crash):
                return r
        raise KeyError((method, crash))

    # claims: time-to-recover per partitioner + amortization crossover —
    # the epochs hep100's per-epoch advantage needs to pay back its extra
    # recovery cost after one fault (inf when random recovers no cheaper)
    for crash in CRASH_EPOCHS:
        rnd, hq = pick_a("random", crash), pick_a("hep100", crash)
        adv = rnd["epoch_time"] - hq["epoch_time"]
        extra = hq["recovery_time"] - rnd["recovery_time"]
        crossover = extra / adv if adv > 0 and extra > 0 else (
            0.0 if extra <= 0 else float("inf"))
        emit(f"recovery.elastic.crash{crash}", 0.0,
             f"recovery_random_s={rnd['recovery_time']:.4f};"
             f"recovery_hep100_s={hq['recovery_time']:.4f};"
             f"epoch_advantage_s={adv:.4f};"
             f"crossover_epochs={crossover:.2f};"
             f"shrink_and_grow={rnd['n_rescale'] == hq['n_rescale'] == 2}")

    # ------------------------------------------- Part B: serving worker-death
    sp_serve = spec(feature=32, hidden=64, layers=2)
    dropped = False
    for method in SERVE_PARTITIONERS:
        plan = FaultPlan.parse(["worker-death@t:0.25,worker:1"], seed=0)
        r = serve_row(
            "OR", method, K, sp_serve, scale=REC_SCALE, cache=c,
            qps=200.0, n_requests=N_REQUESTS, hops=1, fanout=10,
            max_batch=32, max_wait=5e-4,
            fault_plan=plan, detect_delay=0.005,
        )
        answered = r["requests"] == N_REQUESTS
        dropped = dropped or not answered
        rows.append(r)
        print(json.dumps({
            "figure": "recovery", "part": "serving", "graph": "OR", "k": K,
            "partitioner": method, "dead_worker": r.get("dead_worker", -1),
            "rerouted": r.get("rerouted", 0),
            "answered": answered,
            "served": r["requests"],
            "transition_window_ms": round(
                r.get("transition_window", 0.0) * 1e3, 3),
            "transition_p50_ms": round(r.get("transition_p50", 0.0) * 1e3, 4),
            "transition_p99_ms": round(r.get("transition_p99", 0.0) * 1e3, 4),
            "p99_ms": round(r["latency_p99"] * 1e3, 4),
        }))

    def pick_b(method):
        for r in rows:
            if r.get("method") == method and "transition_p99" in r:
                return r
        raise KeyError(method)

    rnd, met = pick_b("random"), pick_b("metis")
    emit("recovery.serving", 0.0,
         f"every_request_answered={not dropped};"
         f"rerouted_random={rnd['rerouted']};rerouted_metis={met['rerouted']};"
         f"degraded_p99_random_ms={rnd['transition_p99']*1e3:.3f};"
         f"degraded_p99_metis_ms={met['transition_p99']*1e3:.3f}")

    if args.out_json:
        write_rows(rows, args.out_json)
        print(f"# wrote {len(rows)} rows -> {args.out_json}", file=sys.stderr)
    if dropped:
        print("# FAIL: requests dropped during worker-death failover",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
