"""Shared benchmark scaffolding.

Every module reproduces one paper table/figure and prints
``name,us_per_call,derived`` CSV rows (derived = the figure's own metric).
Scale knobs: BENCH_SCALE (graph size multiplier) and BENCH_FAST=1 trims the
grid for CI-speed runs.
"""

from __future__ import annotations

import os
import time

from repro.core.study import StudyCache
from repro.gnn.models import GNNSpec

SCALE = float(os.environ.get("BENCH_SCALE", "0.1"))
FAST = os.environ.get("BENCH_FAST", "1") != "0"

GRAPHS = ["OR", "EN", "EU", "DI", "HO"] if not FAST else ["OR", "EU", "DI"]
KS = (4, 32) if not FAST else (4, 8)
# paper Table 2 grid (trimmed in FAST mode)
FEATURES = (16, 64, 512) if not FAST else (16, 512)
HIDDENS = (16, 64, 512) if not FAST else (16, 64)
LAYERS = (2, 3, 4) if not FAST else (2, 3)

_CACHE = StudyCache()


def cache() -> StudyCache:
    return _CACHE


def spec(model="sage", feature=64, hidden=64, layers=2) -> GNNSpec:
    return GNNSpec(model=model, feature_dim=feature, hidden_dim=hidden,
                   num_classes=16, num_layers=layers)


def emit(name: str, seconds: float, derived: str) -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0
