"""Distribution-layer tests that need >1 device: run in a subprocess with
placeholder host devices so the main test process keeps 1 device."""

import importlib.util
import json
import subprocess
import sys
import textwrap

import pytest

# The LM distribution layer (repro.dist: step builders, sharding policies,
# analytic costs) is not part of every build of this repo; the GNN study
# stands alone without it. Gate rather than fail.
requires_dist = pytest.mark.skipif(
    importlib.util.find_spec("repro.dist") is None,
    reason="repro.dist (LM distribution layer) not present in this build",
)


def _run(code: str, devices: int = 8) -> str:
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
        env={"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
             # pin the backend: without it jax burns minutes probing for
             # TPU/GPU plugins before falling back to CPU
             "JAX_PLATFORMS": "cpu",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


@requires_dist
def test_small_mesh_lowering_all_kinds():
    """train/prefill/decode cells lower+compile on a small (2,4) mesh for a
    smoke config — the same machinery the 512-device dry-run uses."""
    out = _run("""
        import dataclasses, jax, json
        from repro.configs.base import smoke_config, SHAPES
        from repro.dist import steps as S
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = smoke_config("qwen3-4b")
        results = {}
        for name, seq, batch in [("train_4k", 128, 8), ("prefill_32k", 256, 8),
                                 ("decode_32k", 256, 8)]:
            shape = dataclasses.replace(SHAPES[name], seq_len=seq, global_batch=batch)
            cell = S.build_cell(cfg, shape, mesh)
            compiled = cell.lower(mesh).compile()
            results[name] = compiled.cost_analysis().get("flops", 0) > 0
        print(json.dumps(results))
    """)
    results = json.loads(out.strip().splitlines()[-1])
    assert all(results.values()), results


def test_multipod_mesh_axes():
    out = _run("""
        from repro.launch.mesh import make_production_mesh
        m = make_production_mesh(multi_pod=True)
        print(sorted(m.shape.items()))
    """, devices=512)
    assert "('data', 16)" in out and "('model', 16)" in out and "('pod', 2)" in out


def test_gnn_fullbatch_shard_map_multidevice():
    """The GNN full-batch trainer runs under REAL shard_map over 4 devices
    and matches the single-device oracle."""
    out = _run("""
        import numpy as np, jax
        from repro.core.graph import paper_graph
        from repro.core.edge_partition import partition_edges
        from repro.gnn.fullbatch import FullBatchTrainer
        from repro.gnn.models import GNNSpec
        from repro.launch.mesh import make_mesh

        g = paper_graph("OR", scale=0.01, seed=0)
        rng = np.random.default_rng(0)
        feats = rng.normal(size=(g.num_vertices, 8)).astype(np.float32)
        labels = rng.integers(0, 4, g.num_vertices).astype(np.int32)
        train = rng.random(g.num_vertices) < 0.3
        spec = GNNSpec(model="sage", feature_dim=8, hidden_dim=8, num_classes=4)

        ref = FullBatchTrainer.build(g, np.zeros(g.num_edges, np.int32), 1,
                                     spec, feats, labels, train, seed=7)
        a = partition_edges(g, 4, "hdrf", seed=1)
        mesh = make_mesh((4,), ("parts",))
        tr = FullBatchTrainer.build(g, a, 4, spec, feats, labels, train,
                                    sync_mode="halo", mode="shard_map",
                                    mesh=mesh, seed=7)
        err = np.abs(tr.forward_logits_global() - ref.forward_logits_global()).max()
        print("maxerr", err)
        assert err < 2e-4, err
    """, devices=4)
    assert "maxerr" in out


def test_gnn_fullbatch_tiled_backend_shard_map():
    """The tiled aggregation backend under REAL shard_map over 4 devices ==
    the scatter oracle (the tentpole's multi-device correctness gate)."""
    out = _run("""
        import dataclasses, numpy as np, jax
        from repro.core.graph import paper_graph
        from repro.core.edge_partition import partition_edges
        from repro.gnn.fullbatch import FullBatchTrainer
        from repro.gnn.models import GNNSpec
        from repro.launch.mesh import make_mesh

        g = paper_graph("OR", scale=0.01, seed=0)
        rng = np.random.default_rng(0)
        feats = rng.normal(size=(g.num_vertices, 8)).astype(np.float32)
        labels = rng.integers(0, 4, g.num_vertices).astype(np.int32)
        train = rng.random(g.num_vertices) < 0.3
        spec = GNNSpec(model="sage", feature_dim=8, hidden_dim=8, num_classes=4)

        a = partition_edges(g, 4, "hdrf", seed=1)
        mesh = make_mesh((4,), ("parts",))
        outs = {}
        for backend in ("scatter", "tiled"):
            tr = FullBatchTrainer.build(
                g, a, 4, dataclasses.replace(spec, agg_backend=backend),
                feats, labels, train, sync_mode="halo", mode="shard_map",
                mesh=mesh, seed=7)
            loss = tr.train_step()
            outs[backend] = (loss, tr.forward_logits_global())
        err = np.abs(outs["tiled"][1] - outs["scatter"][1]).max()
        dl = abs(outs["tiled"][0] - outs["scatter"][0])
        print("maxerr", err, "dloss", dl)
        assert err < 1e-5 and dl < 1e-6, (err, dl)
    """, devices=4)
    assert "maxerr" in out


def test_segment_max_tiled_under_shard_map():
    """aggregate(reduce="max") with the tiled backend under REAL shard_map
    over 4 devices == the scatter `at[].max` oracle (the segment-max leg of
    the tentpole's multi-device correctness gate)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.kernels import ops
        from repro.launch.mesh import make_mesh

        k, e, v, f = 4, 400, 300, 8
        rng = np.random.default_rng(0)
        # cover every row so no -inf (empty-row identity) enters the diff
        dst = np.stack([np.concatenate([rng.permutation(v),
                                        rng.integers(0, v, e - v)])
                        for _ in range(k)]).astype(np.int32)
        msgs = rng.normal(size=(k, e, f)).astype(np.float32)
        per_tile = max(ops.prepare_tiled_edges(dst[p], v)[0].shape[0]
                       for p in range(k)) // ops.tiled_shape(v)[1]
        lay = [ops.prepare_tiled_edges(dst[p], v, per_tile=per_tile)[:2]
               for p in range(k)]
        order = np.stack([o for o, _ in lay])
        ldst = np.stack([l for _, l in lay])
        mesh = make_mesh((k,), ("parts",))

        def per_device(m, d, o, l):
            out = ops.aggregate(m[0], d[0], v, edge_order=o[0],
                                local_dst=l[0], backend="tiled", reduce="max")
            return out[None]

        shard_map = (jax.shard_map if hasattr(jax, "shard_map")
                     else __import__("jax.experimental.shard_map",
                                     fromlist=["shard_map"]).shard_map)
        kw = ({"check_vma": False} if hasattr(jax, "shard_map")
              else {"check_rep": False})
        fn = shard_map(per_device, mesh=mesh, in_specs=(P("parts"),) * 4,
                       out_specs=P("parts"), **kw)
        got = jax.jit(fn)(jnp.asarray(msgs), jnp.asarray(dst),
                          jnp.asarray(order), jnp.asarray(ldst))
        expect = jax.vmap(lambda m, d: ops.aggregate(
            m, d, v, backend="scatter", reduce="max"))(
            jnp.asarray(msgs), jnp.asarray(dst))
        err = np.abs(np.asarray(got) - np.asarray(expect)).max()
        print("maxerr", err)
        assert err < 1e-6, err
    """, devices=4)
    assert "maxerr" in out


def test_gnn_fullbatch_ring_shard_map_multidevice():
    """RingSync (1.5D ppermute rotation) under REAL shard_map over 4 devices
    matches the single-device oracle, forward and loss trajectory — the
    tentpole's multi-device correctness gate for the ring strategy."""
    out = _run("""
        import numpy as np, jax
        from repro.core.graph import paper_graph
        from repro.gnn.fullbatch import FullBatchTrainer
        from repro.gnn.models import GNNSpec
        from repro.launch.mesh import make_mesh

        g = paper_graph("OR", scale=0.01, seed=0)
        rng = np.random.default_rng(0)
        feats = rng.normal(size=(g.num_vertices, 8)).astype(np.float32)
        labels = rng.integers(0, 4, g.num_vertices).astype(np.int32)
        train = rng.random(g.num_vertices) < 0.3
        mesh = make_mesh((4,), ("parts",))
        for model in ("sage", "gat"):
            spec = GNNSpec(model=model, feature_dim=8, hidden_dim=8,
                           num_classes=4)
            ref = FullBatchTrainer.build(g, np.zeros(g.num_edges, np.int32),
                                         1, spec, feats, labels, train, seed=7)
            tr = FullBatchTrainer.build(g, None, 4, spec, feats, labels,
                                        train, sync_mode="ring",
                                        mode="shard_map", mesh=mesh, seed=7)
            err = np.abs(tr.forward_logits_global()
                         - ref.forward_logits_global()).max()
            assert err < 2e-4, (model, err)
            for step in range(2):
                dl = abs(ref.train_step() - tr.train_step())
                assert dl < 1e-4, (model, step, dl)
            print("model", model, "maxerr", err)
    """, devices=4)
    assert "maxerr" in out


def test_ring_sync_bytes_match_compiled_hlo():
    """`ring_bytes_per_round` (k·(k−1)·(Vb+1)·d·4 cluster-wide) pinned
    against the collective-permute bytes XLA actually emitted: one ring
    aggregate compiles to EXACTLY k−1 permutes of the [Vb+1, d] payload
    block per device (the last rotation is elided). Driven through the
    gnn_lint collective-budget rule over the analysis program grid — the
    exact byte equality is now the rule's budget prediction."""
    out = _run("""
        import numpy as np
        from repro.analysis import analyze_hlo, build_programs, run_rules
        from repro.core.graph import paper_graph
        from repro.core.partition_book import build_blockrow_book
        from repro.gnn.sync import ring_bytes_per_round

        k, d = 4, 8
        progs = [p for p in build_programs("smoke")
                 if p.name == "hlo/ring-fp32"]
        assert len(progs) == 1
        report = run_rules(progs, ["collective-budget"])
        assert report.exit_code == 0, [f.message for f in report.errors]
        assert not any("skipped" in f.message for f in report.findings)

        # the rule's budget IS the analytic pin, and the compiled HLO
        # matches it exactly
        res = analyze_hlo(progs[0].make())
        got = res["bytes_per_kind"]["collective-permute"]
        book = build_blockrow_book(paper_graph("OR", scale=0.01, seed=0), k)
        expect_cluster = ring_bytes_per_round(book, d)
        print("cp_count", res["count_per_kind"]["collective-permute"],
              "per_device", got, "cluster", expect_cluster)
        assert res["count_per_kind"]["collective-permute"] == k - 1
        assert got * k == expect_cluster, (got, k, expect_cluster)
    """, devices=4)
    assert "cp_count 3" in out


def test_ring_sync_int8_codec_shrinks_compiled_hlo():
    """With the int8 wire codec the compiled ring rotation moves s8 payload
    (+ one f32 scale per block): cluster permute bytes equal
    `sync_wire_bytes_per_round(..., codec="int8")` = k·(k−1)·((Vb+1)·d + 4)
    — a ~4x shrink vs the fp32 pin above. The payload and its scale may
    lower as separate permutes, so the op count lands in [k−1, 2(k−1)].
    Driven through the gnn_lint collective-budget rule."""
    out = _run("""
        import numpy as np
        from repro.analysis import analyze_hlo, build_programs, run_rules
        from repro.core.graph import paper_graph
        from repro.core.partition_book import build_blockrow_book
        from repro.gnn.sync import ring_bytes_per_round, \\
            sync_wire_bytes_per_round

        k, d = 4, 8
        progs = [p for p in build_programs("smoke")
                 if p.name == "hlo/ring-int8"]
        report = run_rules(progs, ["collective-budget"])
        assert report.exit_code == 0, [f.message for f in report.errors]
        assert not any("skipped" in f.message for f in report.findings)

        res = analyze_hlo(progs[0].make())
        got = res["bytes_per_kind"]["collective-permute"]
        count = res["count_per_kind"]["collective-permute"]
        book = build_blockrow_book(paper_graph("OR", scale=0.01, seed=0), k)
        expect_wire = sync_wire_bytes_per_round(book, d, "ring",
                                                codec="int8")
        fp32_cluster = ring_bytes_per_round(book, d)
        print("cp_count", count, "cluster", got * k,
              "wire", expect_wire, "fp32", fp32_cluster)
        assert got * k == expect_wire, (got, k, expect_wire)
        assert k - 1 <= count <= 2 * (k - 1), count
        # the quarter-width claim, with slack for the per-block f32 scale
        assert got * k < 0.3 * fp32_cluster, (got * k, fp32_cluster)
    """, devices=4)
    assert "cp_count" in out


def test_halo_sync_bytes_match_compiled_hlo():
    """`sync_bytes_per_round` (2*k^2*B*d*4 cluster-wide for halo) pinned
    against the all-to-all bytes XLA actually emitted: the compiled
    per-device program moves 2*k*B*d*4 bytes per reduce+broadcast pair.
    Driven through the gnn_lint collective-budget rule."""
    out = _run("""
        import numpy as np
        from repro.analysis import analyze_hlo, build_programs, run_rules
        from repro.core.edge_partition import partition_edges
        from repro.core.graph import paper_graph
        from repro.core.partition_book import build_edge_book
        from repro.gnn.sync import sync_bytes_per_round

        k, d = 4, 8
        progs = [p for p in build_programs("smoke")
                 if p.name == "hlo/halo-fp32"]
        report = run_rules(progs, ["collective-budget"])
        assert report.exit_code == 0, [f.message for f in report.errors]
        assert not any("skipped" in f.message for f in report.findings)

        res = analyze_hlo(progs[0].make())
        got = res["bytes_per_kind"]["all-to-all"]
        g = paper_graph("OR", scale=0.01, seed=0)
        book = build_edge_book(g, partition_edges(g, k, "hdrf", seed=1), k)
        expect_cluster = sync_bytes_per_round(book, d, "halo")
        print("a2a_count", res["count_per_kind"]["all-to-all"],
              "per_device", got, "cluster", expect_cluster)
        assert res["count_per_kind"]["all-to-all"] == 2
        assert got * k == expect_cluster, (got, k, expect_cluster)
    """, devices=4)
    assert "a2a_count 2" in out


@requires_dist  # launch.dryrun imports the repro.dist cost/step builders
def test_dryrun_collective_parser():
    from repro.launch.dryrun import collective_bytes_from_hlo

    hlo = """
      %ar = f32[1024,8]{1,0} all-reduce(f32[1024,8]{1,0} %x), replica_groups={}
      %ag = (bf16[64]{0}, bf16[32]{0}) all-gather-start(bf16[32]{0} %y)
      %aa = f32[16,4]{1,0} all-to-all(f32[16,4]{1,0} %z)
      %c = f32[2] copy(f32[2] %w)
    """
    res = collective_bytes_from_hlo(hlo)
    assert res["count_per_kind"]["all-reduce"] == 1
    assert res["bytes_per_kind"]["all-reduce"] == 1024 * 8 * 4
    assert res["count_per_kind"]["all-gather"] == 1
    # the -start tuple echoes its bf16[32] operand; only the gathered
    # bf16[64] result is payload under the hardened parser
    assert res["bytes_per_kind"]["all-gather"] == 64 * 2
    assert res["count_per_kind"]["all-to-all"] == 1
    assert "copy" not in res["count_per_kind"]
