"""Substrate: optimizer, checkpointing, elastic restore, compression,
minibatch straggler mitigation, study harness sanity."""

import importlib.util
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.optim import adam_init, adam_update, clip_by_global_norm
from repro.optim.compress import compress_init, compressed_psum


def test_adam_converges_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    target = jnp.asarray([1.0, 1.0, 1.0])
    state = adam_init(params)
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = adam_update(grads, state, params, lr=5e-2)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_clip_by_global_norm():
    grads = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert abs(total - 1.0) < 1e-5
    assert float(norm) > 100.0


def test_checkpoint_roundtrip(tmp_path):
    from repro.ckpt import CheckpointManager

    tree = {"w": jnp.arange(12.0).reshape(3, 4), "step": jnp.asarray(3)}
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    mgr.maybe_save(0, tree)
    mgr.maybe_save(1, jax.tree.map(lambda x: x + 1, tree))
    mgr.maybe_save(2, jax.tree.map(lambda x: x + 2, tree))
    # keep=2: step_0 garbage-collected
    names = sorted(os.listdir(tmp_path))
    assert "step_0000000000" not in names
    step, restored = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 2
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(tree["w"]) + 2)


def test_checkpoint_ignores_partial(tmp_path):
    """A crash mid-write must never corrupt restores."""
    from repro.ckpt import CheckpointManager, save_checkpoint

    tree = {"w": jnp.ones((4,))}
    save_checkpoint(str(tmp_path), 5, tree)
    # fake a partial write
    os.makedirs(tmp_path / "step_0000000009.tmp")
    (tmp_path / "step_0000000009.tmp" / "leaf_00000.npy").write_bytes(b"junk")
    mgr = CheckpointManager(str(tmp_path))
    step, restored = mgr.restore({"w": jnp.zeros((4,))})
    assert step == 5
    np.testing.assert_allclose(np.asarray(restored["w"]), 1.0)


@pytest.mark.skipif(
    importlib.util.find_spec("repro.dist") is None,
    reason="repro.dist (LM distribution layer) not present in this build",
)
def test_train_resume_deterministic(tmp_path):
    """Crash/restart must land on the same trajectory: train 10 steps
    straight vs train 6, 'crash', resume to 10."""
    from repro.launch.train import train

    losses_straight = train(
        "qwen1.5-0.5b", steps=10, batch=2, seq=32, seed=5,
        ckpt_dir=None, log_every=100,
    )
    d = str(tmp_path / "ck")
    train("qwen1.5-0.5b", steps=6, batch=2, seq=32, seed=5,
          ckpt_dir=d, ckpt_every=5, log_every=100)
    losses_resumed = train(
        "qwen1.5-0.5b", steps=10, batch=2, seq=32, seed=5,
        ckpt_dir=d, ckpt_every=5, log_every=100,
    )
    # resumed run re-executes steps 6..9; compare the final losses
    np.testing.assert_allclose(losses_resumed[-1], losses_straight[-1],
                               rtol=1e-4)


def test_compressed_psum_error_feedback():
    """int8 error-feedback compression: the *accumulated* update over many
    steps converges to the true mean despite per-step quantisation."""
    rng = np.random.default_rng(0)
    k = 4
    grads_per_worker = jnp.asarray(rng.normal(size=(k, 64)), jnp.float32)
    true_mean = grads_per_worker.mean(axis=0)

    def per_worker(g, state):
        return compressed_psum({"g": g}, state, "dp")

    states = jax.vmap(lambda g: compress_init({"g": g}))(grads_per_worker)
    acc = jnp.zeros((64,))
    exact = jnp.zeros((64,))
    for step in range(50):
        out, states = jax.vmap(per_worker, axis_name="dp")(
            grads_per_worker, states)
        acc = acc + out["g"][0]
        exact = exact + true_mean
    err = float(jnp.abs(acc - exact).max() / jnp.abs(exact).max())
    assert err < 0.02, err


def test_straggler_rebalance_reduces_imbalance(or_graph, node_data):
    """Dynamic seed re-balancing shifts load away from heavy workers."""
    from repro.core.vertex_partition import partition_vertices
    from repro.gnn.minibatch import MiniBatchTrainer
    from repro.gnn.models import GNNSpec

    feats, labels, train = node_data
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    a = partition_vertices(or_graph, 4, "spinner", seed=0)

    def run(rebalance):
        tr = MiniBatchTrainer.build(
            or_graph, a, 4, spec, feats, labels, train,
            global_batch=64, seed=3, rebalance=rebalance,
        )
        imb = []
        for _ in range(6):
            m = tr.train_step()
            imb.append(m.input_vertices.max() / max(m.input_vertices.mean(), 1))
        return np.mean(imb[2:])  # after EMA warmup

    assert run(True) <= run(False) * 1.1


def test_study_rows_consistent():
    from repro.core.study import StudyCache, fullbatch_row, fullbatch_speedup
    from repro.gnn.models import GNNSpec

    cache = StudyCache()
    spec = GNNSpec(model="sage", feature_dim=64, hidden_dim=32, num_classes=8,
                   num_layers=2)
    rows = [fullbatch_row("OR", m, 4, spec, scale=0.01, cache=cache)
            for m in ["random", "hep100"]]
    sp = fullbatch_speedup(rows)
    by = {r["method"]: r for r in sp}
    assert by["random"]["speedup"] == 1.0
    assert by["hep100"]["speedup"] >= 1.0
    assert by["hep100"]["memory_pct_random"] <= 100.0
