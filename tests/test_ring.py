"""1.5D ring-pipelined full-batch training (BlockRowBook + RingSync).

The tentpole invariant: ring == halo == the k=1 LocalSync oracle, to fp32
tolerance, for every model and aggregation backend — the block-rotation
schedule moves features instead of replica partials, but the mathematics is
the same global symmetrised aggregation.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.edge_partition import partition_edges
from repro.core.graph import paper_graph
from repro.core.partition_book import BlockRowBook, build_blockrow_book
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.models import GNNSpec
from repro.gnn.sync import (
    SYNC_MODES,
    RingBlock,
    build_ring_blocks,
    make_sync,
    ring_bytes_per_round,
    sync_bytes_per_round,
)
from repro.kernels.tiling import prepare_tiled_edges, tiled_shape


# ---------------------------------------------------------------------------
# BlockRowBook invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 3, 4])
def test_blockrow_book_blocks_partition_vertices(or_graph, k):
    """The k blocks partition [0, V): every vertex appears exactly once, in
    its contiguous block, and pads are marked invalid."""
    book = build_blockrow_book(or_graph, k)
    V = or_graph.num_vertices
    assert book.vmask.sum() == V
    owned = book.vglobal[book.vmask]
    assert sorted(owned.tolist()) == list(range(V))
    # contiguity: block p owns exactly [p*Vb, min((p+1)*Vb, V))
    for p in range(k):
        lo, hi = p * book.v_block, min((p + 1) * book.v_block, V)
        got = np.sort(book.vglobal[p][book.vmask[p]])
        np.testing.assert_array_equal(got, np.arange(lo, hi))
    # the dummy row (index v_block) is never a real vertex
    assert not book.vmask[:, book.v_block].any()


@pytest.mark.parametrize("k", [1, 4])
def test_blockrow_chunk_edges_sum_to_E(or_graph, k):
    """Block-column chunk edge counts sum to 2E (both directions of every
    stored edge live in exactly one chunk) and every chunk holds only edges
    with dst in its block row and src in its stage's block."""
    book = build_blockrow_book(or_graph, k)
    assert int(book.chunk_emask.sum()) == 2 * or_graph.num_edges
    want = set(zip(
        np.concatenate([or_graph.src, or_graph.dst]).tolist(),
        np.concatenate([or_graph.dst, or_graph.src]).tolist(),
    ))
    got = set()
    for p in range(k):
        for s in range(k):
            m = book.chunk_emask[p, s]
            src_blk = (p + s) % k
            gsrc = book.chunk_esrc[p, s][m] + src_blk * book.v_block
            gdst = book.chunk_edst[p, s][m] + p * book.v_block
            # locality: dst in block p, src in block (p+s) mod k
            assert (gdst // book.v_block == p).all()
            assert (gsrc // book.v_block == src_blk).all()
            got.update(zip(gsrc.tolist(), gdst.tolist()))
    assert got == want
    # pads point at the dummy row
    pads = ~book.chunk_emask
    assert (book.chunk_esrc[pads] == book.v_block).all()
    assert (book.chunk_edst[pads] == book.v_block).all()


@pytest.mark.parametrize("k", [1, 4])
def test_blockrow_tiled_layouts_roundtrip(or_graph, k):
    """Per-chunk tiled layouts agree with a fresh `prepare_tiled_edges` pass
    over the same chunk (validation round-trip), with ONE uniform per_tile
    so the stacked [k, k, ...] arrays have a static shape."""
    book = build_blockrow_book(or_graph, k, tiled_layout=True)
    n_rows = book.v_block + 1
    _, n_tiles = tiled_shape(n_rows)
    e_tiled = book.chunk_agg_order.shape[-1]
    assert e_tiled % n_tiles == 0
    per_tile = e_tiled // n_tiles
    for p in range(k):
        for s in range(k):
            order, ldst, rows_padded = prepare_tiled_edges(
                book.chunk_edst[p, s], n_rows, per_tile=per_tile,
                valid=book.chunk_emask[p, s])
            np.testing.assert_array_equal(book.chunk_agg_order[p, s], order)
            np.testing.assert_array_equal(book.chunk_agg_ldst[p, s], ldst)


def test_blockrow_partitioner_registered(or_graph):
    """"blockrow" is a plain edge partitioner too, so the 1.5D layout can be
    measured by the standard metrics and driven through halo/dense sync."""
    a = partition_edges(or_graph, 4, "blockrow")
    v_block = -(-or_graph.num_vertices // 4)
    np.testing.assert_array_equal(a, or_graph.dst // v_block)


# ---------------------------------------------------------------------------
# Ring == halo == k=1 oracle (sim mode; shard_map in test_dist_lowering.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["sage", "gcn", "gat"])
@pytest.mark.parametrize("backend", ["scatter", "tiled"])
def test_ring_equals_oracle_forward(or_graph, node_data, model, backend):
    feats, labels, train = node_data
    spec = GNNSpec(model=model, feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2, agg_backend=backend)
    ref = FullBatchTrainer.build(
        or_graph, np.zeros(or_graph.num_edges, np.int32), 1, spec,
        feats, labels, train, seed=7)
    ref_logits = ref.forward_logits_global()
    for k in (1, 4):
        tr = FullBatchTrainer.build(
            or_graph, None, k, spec, feats, labels, train,
            sync_mode="ring", mode="sim", seed=7)
        assert isinstance(tr.book, BlockRowBook)
        np.testing.assert_allclose(tr.forward_logits_global(), ref_logits,
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("model", ["sage", "gcn", "gat"])
def test_ring_equals_halo_training(or_graph, node_data, model):
    """Loss trajectories: ring == halo == k=1 oracle over 3 steps."""
    feats, labels, train = node_data
    spec = GNNSpec(model=model, feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    ref = FullBatchTrainer.build(
        or_graph, np.zeros(or_graph.num_edges, np.int32), 1, spec,
        feats, labels, train, seed=7)
    halo = FullBatchTrainer.build(
        or_graph, partition_edges(or_graph, 4, "hdrf", seed=1), 4, spec,
        feats, labels, train, sync_mode="halo", mode="sim", seed=7)
    ring = FullBatchTrainer.build(
        or_graph, None, 4, spec, feats, labels, train,
        sync_mode="ring", mode="sim", seed=7)
    for step in range(3):
        l_ref = ref.train_step()
        l_halo = halo.train_step()
        l_ring = ring.train_step()
        assert abs(l_ref - l_ring) < 1e-4, (step, l_ref, l_ring)
        assert abs(l_halo - l_ring) < 1e-4, (step, l_halo, l_ring)


def test_ring_loss_decreases(or_graph, node_data):
    feats, labels, train = node_data
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=16, num_classes=5,
                   num_layers=2)
    tr = FullBatchTrainer.build(
        or_graph, None, 4, spec, feats, labels, train,
        sync_mode="ring", mode="sim", seed=3, lr=5e-2)
    losses = [tr.train_step() for _ in range(8)]
    assert losses[-1] < losses[0]


def test_ring_tiled_equals_scatter_training(or_graph, node_data):
    """The tiled backend's ring gradients match the scatter oracle's."""
    feats, labels, train = node_data
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5)
    outs = {}
    for backend in ("scatter", "tiled"):
        tr = FullBatchTrainer.build(
            or_graph, None, 4, dataclasses.replace(spec, agg_backend=backend),
            feats, labels, train, sync_mode="ring", mode="sim", seed=7)
        losses = [tr.train_step() for _ in range(2)]
        outs[backend] = (losses, tr.forward_logits_global())
    assert abs(outs["tiled"][0][-1] - outs["scatter"][0][-1]) < 1e-6
    np.testing.assert_allclose(outs["tiled"][1], outs["scatter"][1],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# make_sync surface + analytic volume
# ---------------------------------------------------------------------------


def test_make_sync_unknown_mode_lists_strategies():
    with pytest.raises(ValueError) as exc:
        make_sync("gossip", None, 10, "parts")
    msg = str(exc.value)
    for mode in SYNC_MODES:
        assert mode in msg, (mode, msg)


def test_make_sync_ring_needs_ring_block(or_graph, node_data):
    """A halo Block cannot drive the ring (no chunk tables): clear TypeError
    instead of a silent attribute crash mid-trace."""
    from repro.core.partition_book import build_edge_book
    from repro.gnn.sync import build_blocks

    feats, labels, train = node_data
    book = build_edge_book(
        or_graph, np.zeros(or_graph.num_edges, np.int32), 1)
    blk = build_blocks(book, feats, labels, train)
    with pytest.raises(TypeError):
        make_sync("ring", blk, or_graph.num_vertices, "parts")


@pytest.mark.parametrize("k", [2, 4, 8])
def test_ring_bytes_formula_below_dense(or_graph, k):
    """ring = k·(k−1)·(Vb+1)·d·4 cluster-wide — strictly below DenseSync's
    2·k·(V+1)·d·4 at every k (the 1.5D regime's bandwidth argument)."""
    from repro.core import cost_model
    from repro.core.partition_book import build_edge_book

    d = 64
    book = build_blockrow_book(or_graph, k)
    ring = sync_bytes_per_round(book, d, "ring")
    assert ring == book.k * (book.k - 1) * (book.v_block + 1) * d * 4
    assert ring == ring_bytes_per_round(book, d)
    assert ring == cost_model.ring_bytes_per_round(book, d)
    ebook = build_edge_book(
        or_graph, partition_edges(or_graph, k, "blockrow"), k)
    dense = sync_bytes_per_round(ebook, d, "dense")
    assert ring < dense, (k, ring, dense)


def test_ring_cost_model_epoch(or_graph):
    """The overlap-aware ring estimate prices a BlockRowBook end-to-end and
    exposes only the non-overlapped transfer remainder as comm_time."""
    from repro.core import cost_model

    spec = GNNSpec(model="sage", feature_dim=64, hidden_dim=64, num_classes=16)
    book = build_blockrow_book(or_graph, 4)
    est = cost_model.fullbatch_epoch(book, spec)
    assert est.epoch_time > 0
    assert est.comm_bytes.shape == (4,)
    syncs = 2  # sage: 1 aggregate per layer, fwd+bwd
    dims = [dout for _, dout in spec.dims()]
    expect = 3 * (book.v_block + 1) * 4 * sum(dims) * syncs
    np.testing.assert_allclose(est.comm_bytes, expect)
    # exposed comm can never exceed the full (unoverlapped) transfer time
    assert (est.comm_time >= 0).all()


def test_ring_study_row(or_graph):
    """study.fullbatch_row(sync_mode="ring") emits a blockrow row with
    near-zero partition time — the tab3 amortization contender."""
    from repro.core import study

    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=16, num_classes=5)
    row = study.fullbatch_row("OR", "blockrow", 4, spec, scale=0.02,
                              cache=study.StudyCache(), sync_mode="ring")
    assert row["sync_mode"] == "ring"
    assert row["method"] == "blockrow"
    assert row["partition_time"] < 0.1
    assert row["epoch_time"] > 0


# ---------------------------------------------------------------------------
# RingBlock plumbing
# ---------------------------------------------------------------------------


def test_ring_blocks_layout(or_graph, node_data):
    feats, labels, train = node_data
    book = build_blockrow_book(or_graph, 4)
    blocks = build_ring_blocks(book, feats, labels, train)
    assert isinstance(blocks, RingBlock)
    assert blocks.x.shape == (4, book.v_block + 1, feats.shape[1])
    # features land on the owner's rows
    x = np.asarray(blocks.x)
    for p in range(4):
        vm = book.vmask[p]
        np.testing.assert_array_equal(x[p][vm], feats[book.vglobal[p][vm]])
        np.testing.assert_array_equal(x[p][~vm], 0.0)
    # masters == vmask (single-owner layout)
    np.testing.assert_array_equal(np.asarray(blocks.master), book.vmask)
