"""Pipelined mini-batch execution (gnn/pipeline.py).

  * overlapped and serial modes produce bitwise-identical batches from the
    same seed (per-(step, worker) RNG streams, not thread schedule) — and
    therefore identical 5-step loss trajectories for sage + gat on the
    scatter + tiled backends
  * FeatureStore.gather is safe under concurrent calls (read-only
    contract): k threads hammering the same store reproduce the serial
    results bitwise
  * serial phase accounting is contiguous: sample + fetch + transfer +
    compute == the measured step wall, and overlap efficiency is 0
  * the cost model's overlapped step time is max(host, compute)-shaped:
    never above the serial estimate, never below compute + allreduce
"""

import dataclasses
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np
import pytest

from repro.core import cost_model
from repro.core.vertex_partition import partition_vertices
from repro.gnn.minibatch import MiniBatchTrainer
from repro.gnn.models import GNNSpec


def _trainer(graph, node_data, *, overlap, model="sage", backend="scatter",
             seed=3, **kw):
    feats, labels, train = node_data
    a = partition_vertices(graph, 4, "metis", seed=0)
    spec = GNNSpec(model=model, feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2, agg_backend=backend)
    return MiniBatchTrainer.build(
        graph, a, 4, spec, feats, labels, train,
        global_batch=32, seed=seed, overlap=overlap, **kw)


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_overlap_batches_bitwise_identical_to_serial(or_graph, node_data):
    """The acceptance gate: same seed => same batches, regardless of mode,
    prefetch depth, or producer thread schedule."""
    serial = _trainer(or_graph, node_data, overlap=False)
    overlap = _trainer(or_graph, node_data, overlap=True, prefetch_depth=3)
    try:
        for _ in range(4):
            pb_s, _ = serial.engine.next_batch()
            pb_o, _ = overlap.engine.next_batch()
            assert pb_s.index == pb_o.index
            _tree_equal(pb_s.stacked, pb_o.stacked)
            assert pb_s.fetch_stats == pb_o.fetch_stats
            np.testing.assert_array_equal(pb_s.input_vertices,
                                          pb_o.input_vertices)
            np.testing.assert_array_equal(pb_s.edges, pb_o.edges)
    finally:
        serial.close()
        overlap.close()


@pytest.mark.parametrize("model", ["sage", "gat"])
@pytest.mark.parametrize("backend", ["scatter", "tiled"])
def test_overlap_loss_trajectory_matches_serial(or_graph, node_data, model,
                                                backend):
    """Identical batches + one deterministic compiled step => identical
    loss trajectories, 5 steps, both models, both aggregation backends."""
    losses = {}
    for overlap in (False, True):
        tr = _trainer(or_graph, node_data, overlap=overlap, model=model,
                      backend=backend)
        losses[overlap] = [tr.train_step().loss for _ in range(5)]
        tr.close()
    assert losses[True] == losses[False]


def test_concurrent_gather_matches_serial(or_graph, node_data):
    """RowStore read-only contract: k threads x many gathers == serial."""
    tr = _trainer(or_graph, node_data, overlap=False, cache_policy="degree",
                  cache_budget=64)
    store = tr.store
    rng = np.random.default_rng(0)
    jobs = [(w, rng.integers(0, or_graph.num_vertices, 257))
            for w in range(4) for _ in range(8)]
    serial = [store.gather(w, ids) for w, ids in jobs]
    with ThreadPoolExecutor(max_workers=4) as pool:
        threaded = list(pool.map(lambda j: store.gather(*j), jobs))
    for (x_s, st_s), (x_t, st_t) in zip(serial, threaded):
        np.testing.assert_array_equal(x_s, x_t)
        assert st_s == st_t


def test_serial_phase_accounting_covers_wall(or_graph, node_data):
    tr = _trainer(or_graph, node_data, overlap=False)
    tr.train_step()  # compile
    for _ in range(2):
        m = tr.train_step()
        phases = (m.sample_time_host + m.fetch_time_host
                  + m.transfer_time_host + m.compute_time_host)
        assert phases >= m.step_wall_host * (1 - 1e-9)
        assert m.overlap_efficiency == 0.0
        assert not m.overlap
    tr.close()


def test_overlap_hides_host_time_in_steady_state(or_graph, node_data):
    tr = _trainer(or_graph, node_data, overlap=True, prefetch_depth=2)
    tr.train_step()  # compile (producer races ahead meanwhile)
    ms = [tr.train_step() for _ in range(6)]
    tr.close()
    for m in ms:
        assert m.overlap
        assert 0.0 <= m.overlap_efficiency <= 1.0
        assert m.host_time > 0.0
    # the queue must have hidden a real fraction of host time overall
    hidden = sum(max(m.host_time - m.queue_wait_host, 0.0) for m in ms)
    assert hidden > 0.0


def test_rebalance_composes_with_overlap(or_graph, node_data):
    """Delayed-feedback seed shares: steps keep running and the share
    vector the trainer publishes reaches the producer."""
    tr = _trainer(or_graph, node_data, overlap=True, rebalance=True)
    ms = [tr.train_step() for _ in range(4)]
    share = tr._seed_share.copy()
    engine_share = tr.engine._current_share()
    tr.close()
    assert all(np.isfinite(m.loss) for m in ms)
    np.testing.assert_allclose(engine_share, share)


def test_engine_rejects_bad_depth(or_graph, node_data):
    with pytest.raises(ValueError):
        _trainer(or_graph, node_data, overlap=True, prefetch_depth=0).engine


def test_engine_close_is_idempotent(or_graph, node_data):
    tr = _trainer(or_graph, node_data, overlap=True)
    tr.train_step()
    tr.close()
    tr.close()
    assert not tr.engine._producer.is_alive()


@pytest.mark.parametrize("overlap", [False, True])
def test_next_batch_after_close_raises(or_graph, node_data, overlap):
    """A closed engine must raise in BOTH modes, never block or keep
    silently producing (and advancing the RNG tree)."""
    tr = _trainer(or_graph, node_data, overlap=overlap)
    tr.engine.next_batch()
    tr.close()
    with pytest.raises(RuntimeError):
        tr.engine.next_batch()


def test_producer_error_surfaces_in_consumer(or_graph, node_data):
    """A producer crash is delivered as a RuntimeError (poison token or
    liveness check), even when the queue was full at crash time."""
    tr = _trainer(or_graph, node_data, overlap=True, prefetch_depth=1)
    engine = tr.engine
    engine.next_batch()  # ensure the producer is up and producing
    boom = ValueError("sampler exploded")

    def bad_prepare(*a, **kw):
        raise boom

    engine.preparer.prepare = bad_prepare
    with pytest.raises(RuntimeError) as ei:
        for _ in range(8):  # drain whatever was prefetched before the crash
            engine.next_batch()
    assert ei.value.__cause__ is boom
    tr.close()


def test_cost_model_overlapped_step_time():
    spec = GNNSpec(model="sage", feature_dim=64, hidden_dim=32, num_classes=8)
    inputs = np.array([1000.0, 900.0])
    remote = np.array([400.0, 350.0])
    edges = np.array([5000.0, 4500.0])
    owned = np.array([2000.0, 2000.0])
    est = cost_model.minibatch_step(inputs, remote, edges, owned, spec)
    t_over = cost_model.overlapped_step_time(est)
    assert est.allreduce_time > 0.0
    assert t_over <= est.step_time
    # overlap hides host time behind compute but can't beat either bound
    host = est.sample_time + est.fetch_time
    assert t_over >= float(est.compute_time.max()) + est.allreduce_time
    assert t_over >= float(host.max()) + est.allreduce_time
    np.testing.assert_allclose(
        t_over, float(np.maximum(host, est.compute_time).max())
        + est.allreduce_time)


def test_study_row_overlap_columns():
    from repro.core.study import StudyCache, minibatch_row

    cache = StudyCache()
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    rows = {ov: minibatch_row("OR", "metis", 4, spec, scale=0.01, cache=cache,
                              global_batch=32, steps=2, run_device_step=True,
                              overlap=ov)
            for ov in (False, True)}
    for ov, r in rows.items():
        assert r["overlap"] == ov
        assert r["step_time_overlap"] <= r["step_time"]
        for col in ("host_sample_time", "host_fetch_time",
                    "host_transfer_time", "host_compute_time",
                    "host_step_wall", "overlap_efficiency"):
            assert col in r
    # identical batches both modes => identical sampled metrics in the row
    for col in ("input_vertices", "remote_vertices", "fetch_bytes"):
        assert rows[True][col] == pytest.approx(rows[False][col])
    # the sampling-only path carries the model columns but no host ones,
    # and never claims pipelined execution (nothing executed)
    r = minibatch_row("OR", "metis", 4, spec, scale=0.01, cache=cache,
                      global_batch=32, steps=2, overlap=True)
    assert r["overlap"] is False and r["prefetch_depth"] == 0
    assert "host_sample_time" not in r
    assert r["step_time_overlap"] <= r["step_time"]
