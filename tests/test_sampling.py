"""Mini-batch sampler properties (DistDGL regime)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.graph import generate_graph
from repro.core.vertex_partition import partition_vertices
from repro.gnn.sampling import PAPER_FANOUTS, SamplePlan, sample_blocks


def _sample(g, seeds, fanouts, seed=0, owner=None, worker=0):
    plan = SamplePlan.build(len(seeds), fanouts)
    rng = np.random.default_rng(seed)
    labels = np.zeros(g.num_vertices, np.int32)
    return plan, sample_blocks(
        g, np.asarray(seeds, np.int64), fanouts, plan, rng, labels,
        owner=owner, worker=worker,
    )


@pytest.mark.parametrize("layers", [2, 3, 4])
def test_fanout_bounds(or_graph, layers):
    fanouts = PAPER_FANOUTS[layers]
    seeds = np.arange(16)
    plan, batch = _sample(or_graph, seeds, fanouts)
    assert len(batch.layers) == layers
    for li, lay in enumerate(batch.layers):
        deg = lay.sampled_deg[:-1]
        assert deg.max() <= fanouts[li]
    # seeds form the final output prefix
    assert int(batch.layers[-1].n_dst) == len(seeds)


def test_edges_reference_valid_positions(or_graph):
    seeds = np.arange(12)
    plan, batch = _sample(or_graph, seeds, (5, 3))
    for li, lay in enumerate(batch.layers):
        pad = plan.layers[li]
        assert (lay.esrc[lay.emask] < pad.n_src).all()
        assert (lay.edst[lay.emask] < int(lay.n_dst)).all()


def test_remote_vertex_accounting(or_graph):
    owner = partition_vertices(or_graph, 4, "metis", seed=0)
    seeds = np.where(owner == 1)[0][:16]
    plan, batch = _sample(or_graph, seeds, (5, 5), owner=owner, worker=1)
    ids = batch.input_ids[batch.input_mask]
    expect_remote = int((owner[ids] != 1).sum())
    assert batch.num_remote == expect_remote
    assert batch.num_input == ids.shape[0]


def test_num_remote_matches_partition_book(or_graph):
    """Brute-force cross-check: SampledBatch.num_remote == the count of
    input vertices whose partition-book owner is another worker."""
    from repro.core.partition_book import build_vertex_book

    a = partition_vertices(or_graph, 4, "ldg", seed=2)
    book = build_vertex_book(or_graph, a, 4)
    for w in range(4):
        pool = np.where(book.owner == w)[0][:16]
        if pool.size == 0:
            continue
        _, batch = _sample(or_graph, pool, (5, 5), seed=w,
                           owner=book.owner, worker=w)
        ids = batch.input_ids[batch.input_mask]
        assert batch.num_remote == int((book.owner[ids] != w).sum())
        # seeds are owned by this worker, so remote < input
        assert batch.num_remote < batch.num_input


def test_better_partition_fewer_remote(or_graph):
    """Paper Fig. 22b/24c: metis yields fewer remote vertices than random."""
    totals = {}
    for method in ["random", "metis"]:
        owner = partition_vertices(or_graph, 4, method, seed=0)
        remote = 0
        for w in range(4):
            pool = np.where(owner == w)[0][:24]
            if pool.size == 0:
                continue
            _, b = _sample(or_graph, pool, (10, 10), seed=5, owner=owner, worker=w)
            remote += b.num_remote
        totals[method] = remote
    assert totals["metis"] < totals["random"]


def _sample_hop_two_repeat_reference(indptr, indices, frontier, fanout, rng):
    """The pre-dedupe `_sample_hop`: seg_off and pos_in_group computed as
    two separate `np.repeat(cum, deg)` materialisations. Kept verbatim as
    the oracle for the dedupe refactor."""
    deg = (indptr[frontier + 1] - indptr[frontier]).astype(np.int64)
    total = int(deg.sum())
    if total == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    cum = np.cumsum(deg) - deg
    seg_off = np.arange(total, dtype=np.int64) - np.repeat(cum, deg)
    all_pos = np.repeat(indptr[frontier], deg) + seg_off
    all_src = indices[all_pos].astype(np.int64)
    all_dst = np.repeat(np.arange(frontier.shape[0], dtype=np.int64), deg)
    keys = rng.random(total)
    order = np.lexsort((keys, all_dst))
    pos_in_group = np.arange(total, dtype=np.int64) - np.repeat(cum, deg)
    keep = order[pos_in_group < fanout]
    return all_src[keep], all_dst[keep]


@pytest.mark.parametrize("fanout", [1, 4, 25])
@pytest.mark.parametrize("seed", [0, 7])
def test_sample_hop_dedup_unchanged(or_graph, fanout, seed):
    """Micro-assert for the seg_off/pos_in_group dedupe: bit-identical
    edges to the two-repeat formulation, same RNG stream consumption."""
    from repro.gnn.sampling import _sample_hop

    indptr, indices = or_graph.csr()
    rng = np.random.default_rng(seed)
    frontier = rng.choice(or_graph.num_vertices, size=48, replace=False)
    src_new, dst_new = _sample_hop(
        indptr, indices, frontier, fanout, np.random.default_rng(seed + 1))
    src_ref, dst_ref = _sample_hop_two_repeat_reference(
        indptr, indices, frontier, fanout, np.random.default_rng(seed + 1))
    np.testing.assert_array_equal(src_new, src_ref)
    np.testing.assert_array_equal(dst_new, dst_ref)
    # the empty-frontier fast path too
    empty = np.zeros(0, np.int64)
    for arr in _sample_hop(indptr, indices, empty, fanout,
                           np.random.default_rng(0)):
        assert arr.shape == (0,)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=30, max_value=200),
    f1=st.integers(min_value=1, max_value=8),
    f2=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_property_sampler(n, f1, f2, seed):
    g = generate_graph("social", n, n * 4, seed=seed)
    seeds = np.arange(min(8, g.num_vertices))
    plan, batch = _sample(g, seeds, (f1, f2), seed=seed)
    # inputs unique & within range
    ids = batch.input_ids[batch.input_mask]
    assert len(np.unique(ids)) == len(ids)
    assert ids.max(initial=0) < g.num_vertices
    # every sampled edge is a real graph edge
    indptr, indices = g.csr()
    frontier0 = ids
    lay = batch.layers[0]
    for e in np.where(lay.emask)[0][:50]:
        src_g = frontier0[lay.esrc[e]]
        # dst position indexes the dst frontier, a prefix of the src frontier
        dst_g = frontier0[lay.edst[e]]
        assert src_g in indices[indptr[dst_g]: indptr[dst_g + 1]]
