"""Partitioner invariants: unit + property-based (hypothesis).

System invariants per DESIGN.md §3:
  * edge partitioners assign every edge to exactly one partition
  * vertex partitioners assign every vertex to exactly one partition
  * deterministic given a seed
  * quality metrics in their mathematical ranges
  * the paper's quality ORDERING holds on every graph category:
      RF: hep100 <= hdrf <= random;  cut: kahip/metis < random
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.edge_partition import EDGE_PARTITIONERS, partition_edges
from repro.core.graph import generate_graph, paper_graph
from repro.core.metrics import (
    edge_partition_metrics,
    vertex_partition_metrics,
)
from repro.core.vertex_partition import VERTEX_PARTITIONERS, partition_vertices


@pytest.mark.parametrize("method", sorted(EDGE_PARTITIONERS))
@pytest.mark.parametrize("k", [2, 5, 8])
def test_edge_partition_complete_and_valid(small_graphs, method, k):
    g = small_graphs["EN"]
    a = partition_edges(g, k, method, seed=3)
    assert a.shape == (g.num_edges,)
    assert a.min() >= 0 and a.max() < k
    m = edge_partition_metrics(g, a, k)
    assert m.replication_factor >= 1.0
    assert m.replication_factor <= k
    assert m.edge_balance >= 1.0
    assert m.vertex_balance >= 1.0
    assert m.edges_per_partition.sum() == g.num_edges


@pytest.mark.parametrize("method", sorted(VERTEX_PARTITIONERS))
@pytest.mark.parametrize("k", [2, 5, 8])
def test_vertex_partition_complete_and_valid(small_graphs, method, k):
    g = small_graphs["EU"]
    a = partition_vertices(g, k, method, seed=3)
    assert a.shape == (g.num_vertices,)
    assert a.min() >= 0 and a.max() < k
    m = vertex_partition_metrics(g, a, k)
    assert 0.0 <= m.edge_cut <= 1.0
    assert m.vertices_per_partition.sum() == g.num_vertices


@pytest.mark.parametrize("method", sorted(EDGE_PARTITIONERS))
def test_edge_partition_deterministic(small_graphs, method):
    g = small_graphs["DI"]
    a1 = partition_edges(g, 4, method, seed=11)
    a2 = partition_edges(g, 4, method, seed=11)
    np.testing.assert_array_equal(a1, a2)


@pytest.mark.parametrize("method", sorted(VERTEX_PARTITIONERS))
def test_vertex_partition_deterministic(small_graphs, method):
    g = small_graphs["DI"]
    a1 = partition_vertices(g, 4, method, seed=11)
    a2 = partition_vertices(g, 4, method, seed=11)
    np.testing.assert_array_equal(a1, a2)


@pytest.mark.parametrize("graph_key", ["OR", "EN", "EU", "DI", "HO"])
def test_paper_quality_ordering_edge(small_graphs, graph_key):
    """Paper Fig. 2: HEP produces the lowest RF, random the highest."""
    g = small_graphs[graph_key]
    k = 8
    rf = {
        m: edge_partition_metrics(g, partition_edges(g, k, m, seed=1), k)
        .replication_factor
        for m in ["random", "hdrf", "hep100"]
    }
    assert rf["hep100"] <= rf["hdrf"] * 1.2
    assert rf["hdrf"] < rf["random"]
    assert rf["hep100"] < rf["random"]


@pytest.mark.parametrize("graph_key", ["OR", "EU", "DI"])
def test_paper_quality_ordering_vertex(small_graphs, graph_key):
    """Paper Fig. 13: kahip/metis cut << random cut."""
    g = small_graphs[graph_key]
    k = 8
    cut = {
        m: vertex_partition_metrics(g, partition_vertices(g, k, m, seed=1), k)
        .edge_cut
        for m in ["random", "metis", "kahip"]
    }
    assert cut["metis"] < cut["random"] * 0.9
    assert cut["kahip"] < cut["random"] * 0.9


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=20, max_value=300),
    avg_deg=st.integers(min_value=2, max_value=10),
    k=st.integers(min_value=2, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    category=st.sampled_from(["social", "web", "road"]),
)
def test_property_edge_partitions(n, avg_deg, k, seed, category):
    """Property: for ANY graph/partitioner, assignment is total, RF and
    balances are in range, and the vertex cover counts are consistent."""
    g = generate_graph(category, n, n * avg_deg, seed=seed)
    if g.num_edges == 0:
        return
    for method in ["random", "dbh", "2ps-l"]:
        a = partition_edges(g, k, method, seed=seed % 1000)
        m = edge_partition_metrics(g, a, k)
        assert 1.0 <= m.replication_factor <= k
        assert m.edges_per_partition.sum() == g.num_edges
        # cover of partition i is at most 2x its edge count and at least 1
        nz = m.edges_per_partition > 0
        assert (m.vertices_per_partition[nz] <= 2 * m.edges_per_partition[nz]).all()


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=20, max_value=300),
    avg_deg=st.integers(min_value=2, max_value=8),
    k=st.integers(min_value=2, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_vertex_partitions(n, avg_deg, k, seed):
    g = generate_graph("social", n, n * avg_deg, seed=seed)
    for method in ["random", "ldg", "bytegnn"]:
        a = partition_vertices(g, k, method, seed=seed % 1000)
        m = vertex_partition_metrics(g, a, k)
        assert 0.0 <= m.edge_cut <= 1.0
        assert m.vertices_per_partition.sum() == g.num_vertices
        # recompute cut independently
        cut = float((a[g.src] != a[g.dst]).mean()) if g.num_edges else 0.0
        assert abs(cut - m.edge_cut) < 1e-9


def test_partition_book_roundtrip(small_graphs):
    """Replication bookkeeping: every vertex has exactly one master; the
    number of (partition, vertex) pairs equals RF * covered vertices."""
    from repro.core.partition_book import build_edge_book

    g = small_graphs["OR"]
    k = 6
    a = partition_edges(g, k, "hdrf", seed=2)
    book = build_edge_book(g, a, k)
    masters = book.master & book.vmask
    covered = np.unique(np.concatenate([g.src, g.dst]))
    assert masters.sum() == covered.shape[0]
    m = edge_partition_metrics(g, a, k)
    assert book.vmask.sum() == int(round(m.replication_factor * covered.shape[0]))
    # every real edge endpoint is a valid local slot
    assert (book.esrc[book.emask] < book.v_max).all()
    assert (book.edst[book.emask] < book.v_max).all()
    # padding waste is a fraction
    assert 0.0 <= book.padding_waste() <= 1.0


def test_hep_stream_capacity_overflow_falls_back_to_least_loaded():
    """When every partition is at capacity, the HDRF score is all -inf and
    argmax would silently dump every remaining edge on partition 0; the
    streaming phase must fall back to the least-loaded partition instead."""
    from repro.core.edge_partition import _hdrf_stream

    g = generate_graph("social", 60, 120, seed=0)
    k = 4
    assigned = np.full(g.num_edges, -1, dtype=np.int32)
    rng = np.random.default_rng(0)
    # capacity=1 forces overflow almost immediately
    _hdrf_stream(g, assigned, k, capacity=1, rng=rng, deg=g.degrees())
    assert (assigned >= 0).all() and (assigned < k).all()
    sizes = np.bincount(assigned, minlength=k)
    # least-loaded fallback keeps the stream balanced, not piled on part 0
    assert sizes.max() - sizes.min() <= 1, sizes


def test_hep_full_assignment_small_capacity_graph():
    """End-to-end: hep on a tiny graph with many partitions (capacity ~1)
    still assigns every edge to a valid partition."""
    g = generate_graph("social", 12, 14, seed=1)
    a = partition_edges(g, 8, "hep10", seed=0)
    assert (a >= 0).all() and (a < 8).all()
