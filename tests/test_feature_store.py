"""Feature-store invariants (DistDGL feature-loading phase).

  * the {local, cache-hit, remote-miss} split equals a brute-force
    recomputation from the partition book and the cache contents
  * gathered features are exactly the global features (shard + cache + RPC
    assembly is lossless)
  * degree/halo policies beat the random baseline on a power-law graph
  * MiniBatchTrainer with a degree cache moves strictly fewer remote bytes
    than the uncached trainer (the PR's acceptance criterion)
  * the cost model prices the fetch phase from missed bytes
"""

import numpy as np
import pytest

from repro.core import cost_model
from repro.core.partition_book import build_vertex_book
from repro.core.vertex_partition import partition_vertices
from repro.gnn.feature_store import CACHE_POLICIES, FeatureStore, FetchStats
from repro.gnn.models import GNNSpec
from repro.gnn.sampling import SamplePlan, sample_blocks


@pytest.fixture(scope="module")
def store_setup(or_graph):
    g = or_graph
    a = partition_vertices(g, 4, "metis", seed=0)
    book = build_vertex_book(g, a, 4)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, 8)).astype(np.float32)
    return g, book, feats


def _sample_ids(g, book, worker, n_seeds=24, seed=0):
    pool = np.where(book.owner == worker)[0][:n_seeds]
    plan = SamplePlan.build(pool.shape[0], (10, 10))
    rng = np.random.default_rng(seed)
    b = sample_blocks(g, pool.astype(np.int64), (10, 10), plan, rng,
                      np.zeros(g.num_vertices, np.int32),
                      owner=book.owner, worker=worker)
    return b.input_ids[b.input_mask]


@pytest.mark.parametrize("policy", CACHE_POLICIES)
def test_split_matches_bruteforce(store_setup, policy):
    g, book, feats = store_setup
    store = FeatureStore.build(g, book, policy=policy, budget=64,
                               features=feats, seed=1)
    for w in range(book.k):
        ids = _sample_ids(g, book, w, seed=w)
        stats = store.stats(w, ids)
        cached = np.zeros(g.num_vertices, dtype=bool)
        cached[store.cached_ids(w)] = True
        is_local = book.owner[ids] == w
        expect_hit = int((~is_local & cached[ids]).sum())
        expect_miss = int((~is_local & ~cached[ids]).sum())
        assert stats.num_local == int(is_local.sum())
        assert stats.num_cache_hit == expect_hit
        assert stats.num_remote_miss == expect_miss
        assert stats.num_input == ids.shape[0]
        assert stats.num_local + stats.num_remote == stats.num_input
        assert stats.miss_bytes == expect_miss * 4 * feats.shape[1]
        # cache never stores locally-owned vertices
        assert (book.owner[store.cached_ids(w)] != w).all()
        assert store.cached_ids(w).shape[0] <= 64


@pytest.mark.parametrize("policy", CACHE_POLICIES)
def test_gather_is_lossless(store_setup, policy):
    g, book, feats = store_setup
    store = FeatureStore.build(g, book, policy=policy, budget=48,
                               features=feats, seed=2)
    for w in range(book.k):
        ids = _sample_ids(g, book, w, seed=10 + w)
        x, stats = store.gather(w, ids)
        np.testing.assert_array_equal(x, feats[ids])
        assert stats == store.stats(w, ids)


def test_hot_policies_beat_random(store_setup):
    """On a power-law graph, degree and halo caches hit far more often than
    a same-budget random cache."""
    g, book, feats = store_setup
    hits = {}
    for policy in ("random", "degree", "halo"):
        store = FeatureStore.build(g, book, policy=policy, budget=96,
                                   features=feats, seed=3)
        per = [store.stats(w, _sample_ids(g, book, w, seed=20 + w))
               for w in range(book.k)]
        hits[policy] = FetchStats.merge(per).num_cache_hit
    assert hits["degree"] > hits["random"]
    assert hits["halo"] > hits["random"]


def test_hit_rate_grows_with_budget(store_setup):
    g, book, feats = store_setup
    rates = []
    for budget in (0, 32, 128):
        store = FeatureStore.build(g, book, policy="degree", budget=budget,
                                   features=feats)
        per = [store.stats(w, _sample_ids(g, book, w, seed=30 + w))
               for w in range(book.k)]
        rates.append(FetchStats.merge(per).hit_rate)
    assert rates[0] == 0.0
    assert rates[0] <= rates[1] <= rates[2]
    assert rates[2] > 0.0


def test_trainer_degree_cache_cuts_miss_bytes(or_graph, node_data):
    """Acceptance criterion: cache_policy='degree' strictly lowers the
    remote-miss byte count vs 'none' on paper OR + metis."""
    from repro.gnn.minibatch import MiniBatchTrainer

    feats, labels, train = node_data
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    a = partition_vertices(or_graph, 4, "metis", seed=0)
    budget = max(or_graph.num_vertices // 10, 1)
    totals = {}
    for policy in ("none", "degree"):
        tr = MiniBatchTrainer.build(
            or_graph, a, 4, spec, feats, labels, train,
            global_batch=64, seed=3, cache_policy=policy, cache_budget=budget,
        )
        ms = [tr.train_step() for _ in range(2)]
        totals[policy] = sum(int(m.miss_bytes.sum()) for m in ms)
        # conservation: remote = hits + misses, every step and worker
        for m in ms:
            np.testing.assert_array_equal(
                m.remote_vertices, m.cache_hits + m.remote_misses)
            assert 0.0 <= m.hit_rate <= 1.0
    assert totals["degree"] < totals["none"]


def test_cost_model_prices_missed_bytes():
    spec = GNNSpec(model="sage", feature_dim=64, hidden_dim=32, num_classes=8)
    inputs = np.array([1000.0, 900.0])
    remote = np.array([400.0, 350.0])
    edges = np.array([5000.0, 4500.0])
    owned = np.array([2000.0, 2000.0])
    base = cost_model.minibatch_step(inputs, remote, edges, owned, spec)
    miss = remote * 0.25
    cached = np.array([128.0, 128.0])
    est = cost_model.minibatch_step(
        inputs, remote, edges, owned, spec,
        remote_miss_vertices=miss, cached_vertices=cached,
    )
    np.testing.assert_allclose(est.fetch_bytes, miss * spec.feature_dim * 4)
    assert est.fetch_bytes.sum() < base.fetch_bytes.sum()
    assert (est.fetch_time < base.fetch_time).all()
    # sampling still pays full remote adjacency; memory charges the cache
    np.testing.assert_allclose(est.sample_time, base.sample_time)
    np.testing.assert_allclose(
        est.memory, base.memory + cached * spec.feature_dim * 4)


def test_study_row_cache_columns():
    from repro.core.study import StudyCache, minibatch_row

    cache = StudyCache()
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    rows = {p: minibatch_row("OR", "metis", 4, spec, scale=0.01, cache=cache,
                             global_batch=64, steps=2,
                             cache_policy=p, cache_budget=40)
            for p in ("none", "degree")}
    assert rows["none"]["hit_rate"] == 0.0 or rows["none"]["remote_vertices"] == 0
    assert rows["degree"]["cache_hits"] > 0
    assert rows["degree"]["fetch_bytes"] < rows["none"]["fetch_bytes"]
    for r in rows.values():
        assert r["cache_hits"] + r["remote_misses"] == pytest.approx(
            r["remote_vertices"])


def test_step_metrics_hit_rate_edge_cases():
    """hit_rate: 1.0 when no remote vertices were needed; 0.0 when remote
    vertices exist but hit accounting is absent (cache_hits=None default);
    the ratio otherwise."""
    from repro.gnn.minibatch import StepMetrics

    def metrics(remote, hits):
        return StepMetrics(
            loss=0.0,
            input_vertices=np.array([10, 10]),
            remote_vertices=np.asarray(remote),
            edges=np.array([5, 5]),
            sample_time_host=0.0,
            compute_time_host=0.0,
            cache_hits=None if hits is None else np.asarray(hits),
        )

    assert metrics([0, 0], None).hit_rate == 1.0      # nothing remote at all
    assert metrics([0, 0], [0, 0]).hit_rate == 1.0
    assert metrics([4, 4], None).hit_rate == 0.0      # no store consulted
    assert metrics([4, 4], [2, 0]).hit_rate == pytest.approx(0.25)
