"""The examples can't rot: both GNN examples run end-to-end at tiny scale.

Run as subprocesses — exactly how a user runs them — with the same backend
pin the other subprocess tests use. Each example is also the knob-drift
guard: quickstart exercises `agg_backend` parity + `cache_policy` miss
accounting, the study example exercises the cached mini-batch rows and the
serving regime, so a knob rename breaks CI here rather than silently
leaving the examples on an old API.
"""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, *argv: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script), *argv],
        capture_output=True, text=True, env=env, timeout=600,
    )


def test_quickstart_runs():
    r = _run("quickstart.py", "--scale", "0.01", "--k", "2")
    assert r.returncode == 0, r.stderr[-2000:]
    out = r.stdout
    assert "tiled agg backend == scatter oracle" in out
    assert "minibatch cache=degree" in out
    # the invariant lines actually printed small errors
    for line in out.splitlines():
        if "max err" in line:
            assert float(line.split()[-1]) < 1e-3, line


def test_partitioning_study_runs():
    r = _run("gnn_partitioning_study.py", "--scale", "0.01", "--k", "2")
    assert r.returncode == 0, r.stderr[-2000:]
    out = r.stdout
    assert "DistGNN regime" in out
    assert "DistDGL regime" in out
    assert "serving regime" in out
    assert "hit_rate" in out
