"""THE core system invariant: partitioning must not change semantics.

Distributed full-batch forward/backward over k partitions == single-device
forward/backward, allclose, for every model x partitioner x sync mode.
"""

import numpy as np
import pytest

from repro.core.edge_partition import partition_edges
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.models import GNNSpec


def _ref_trainer(g, spec, feats, labels, train):
    return FullBatchTrainer.build(
        g, np.zeros(g.num_edges, np.int32), 1, spec, feats, labels, train,
        seed=7,
    )


@pytest.mark.parametrize("model", ["sage", "gcn", "gat"])
@pytest.mark.parametrize("method", ["random", "hep100", "2ps-l"])
@pytest.mark.parametrize("sync", ["halo", "dense"])
def test_distributed_equals_single_forward(or_graph, node_data, model, method, sync):
    feats, labels, train = node_data
    spec = GNNSpec(model=model, feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    ref = _ref_trainer(or_graph, spec, feats, labels, train)
    ref_logits = ref.forward_logits_global()

    a = partition_edges(or_graph, 4, method, seed=1)
    tr = FullBatchTrainer.build(
        or_graph, a, 4, spec, feats, labels, train, sync_mode=sync,
        mode="sim", seed=7,
    )
    logits = tr.forward_logits_global()
    np.testing.assert_allclose(logits, ref_logits, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("model", ["sage", "gcn", "gat"])
def test_distributed_equals_single_training(or_graph, node_data, model):
    feats, labels, train = node_data
    spec = GNNSpec(model=model, feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    ref = _ref_trainer(or_graph, spec, feats, labels, train)
    a = partition_edges(or_graph, 4, "hdrf", seed=1)
    tr = FullBatchTrainer.build(
        or_graph, a, 4, spec, feats, labels, train, sync_mode="halo",
        mode="sim", seed=7,
    )
    for step in range(3):
        l_ref = ref.train_step()
        l_dist = tr.train_step()
        assert abs(l_ref - l_dist) < 1e-4, (step, l_ref, l_dist)


def test_loss_decreases_fullbatch(or_graph, node_data):
    feats, labels, train = node_data
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=16, num_classes=5,
                   num_layers=2)
    a = partition_edges(or_graph, 4, "hep100", seed=1)
    tr = FullBatchTrainer.build(
        or_graph, a, 4, spec, feats, labels, train, mode="sim", seed=3, lr=5e-2,
    )
    losses = [tr.train_step() for _ in range(8)]
    assert losses[-1] < losses[0]


def test_halo_comm_tracks_replication_factor(or_graph, node_data):
    """The paper's central mechanism, verified end-to-end in our system:
    better partitioning (lower RF) => smaller halo-exchange collectives."""
    from repro.core.metrics import edge_partition_metrics

    feats, labels, train = node_data
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5)
    stats = {}
    for method in ["random", "hep100"]:
        a = partition_edges(or_graph, 8, method, seed=1)
        tr = FullBatchTrainer.build(
            or_graph, a, 8, spec, feats, labels, train, mode="sim", seed=7,
        )
        rf = edge_partition_metrics(or_graph, a, 8).replication_factor
        stats[method] = (rf, tr.comm_bytes_per_epoch())
    rf_r, bytes_r = stats["random"]
    rf_h, bytes_h = stats["hep100"]
    assert rf_h < rf_r
    assert bytes_h < bytes_r


def test_elastic_rescale_preserves_semantics(or_graph, node_data):
    """Scale 4 -> 8 workers mid-training: the model state transfers and the
    distributed forward still equals the single-device forward."""
    from repro.ckpt.elastic import rescale_fullbatch

    feats, labels, train = node_data
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5)
    a = partition_edges(or_graph, 4, "hdrf", seed=1)
    tr = FullBatchTrainer.build(
        or_graph, a, 4, spec, feats, labels, train, mode="sim", seed=7,
    )
    tr.train_step()
    tr2 = rescale_fullbatch(tr, or_graph, 8, feats, labels, train, seed=2)
    ref = _ref_trainer(or_graph, spec, feats, labels, train)
    ref.params = tr.params
    np.testing.assert_allclose(
        tr2.forward_logits_global(), ref.forward_logits_global(),
        rtol=2e-4, atol=2e-4,
    )
