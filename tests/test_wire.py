"""The wire-codec layer (core/wire.py) and its four byte-moving paths.

Three families of pins:

  algebra    — each codec's roundtrip error bound, the wire_bytes ==
               encoded-payload-nbytes property, the variable-ratio schedule,
               and the error-feedback telescoping identity (under vmap here;
               the real-shard_map twin lives in the subprocess test below)
  identity   — `codec="fp32"` is the exact identity on every path: trainers
               (halo/ring full-batch, mini-batch), feature store, cost model
               produce BITWISE-identical results vs codec=None
  tolerance  — int8+EF 20-step loss trajectories stay within a pinned
               tolerance of fp32 for sage/gcn/gat x halo/ring and mini-batch
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import paper_graph
from repro.core.edge_partition import partition_edges
from repro.core.vertex_partition import partition_vertices
from repro.core.wire import (
    CODECS,
    Fp32Codec,
    VariableRatioCodec,
    as_codec,
    codec_grad_reduce,
    ef_init,
    make_codec,
    roundtrip,
)
from repro.gnn.models import GNNSpec


@pytest.fixture(scope="module")
def wg():
    """Small graph + node data shared by the end-to-end codec tests."""
    g = paper_graph("OR", scale=0.01, seed=0)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, 8)).astype(np.float32)
    labels = rng.integers(0, 4, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.3
    return g, feats, labels, train


def _spec(model="sage"):
    return GNNSpec(model=model, feature_dim=8, hidden_dim=8, num_classes=4)


# ---------------------------------------------------------------------------
# codec algebra
# ---------------------------------------------------------------------------


def test_registry_and_normalisation():
    for name in CODECS:
        assert make_codec(name).name == name
    assert isinstance(as_codec(None), Fp32Codec)
    assert as_codec("int8") is make_codec("int8")
    c = make_codec("bf16")
    assert as_codec(c) is c
    with pytest.raises(ValueError, match="unknown codec"):
        make_codec("fp8")


@pytest.mark.parametrize("to_dev", [False, True])
def test_fp32_is_the_exact_identity(to_dev):
    """encode/decode return their argument UNTOUCHED — same object, so the
    default paths cannot even in principle perturb bytes or the jaxpr."""
    x = np.random.default_rng(1).normal(size=(7, 5)).astype(np.float32)
    if to_dev:
        x = jnp.asarray(x)
    c = make_codec("fp32")
    payload, meta = c.encode(x)
    assert payload is x and meta is None
    assert c.decode(payload, meta) is x
    assert c.wire_bytes(x.shape) == x.size * 4
    assert c.ratio(0) == c.ratio(3) == 1.0


def test_wire_dtype_policy_via_analysis(wg):
    """`narrow_wire_dtypes` declares each codec's on-wire narrow dtypes and
    the gnn_lint dtype-policy check holds traced train steps to exactly
    those: the fp32 step contains NO narrowing convert anywhere in its
    jaxpr, the int8 step narrows to s8 only — the jaxpr-level twin of the
    bitwise-identity pins below."""
    from repro.analysis import check_narrowing
    from repro.core.wire import narrow_wire_dtypes
    from repro.gnn.fullbatch import FullBatchTrainer

    assert narrow_wire_dtypes("fp32") == frozenset()
    assert narrow_wire_dtypes("bf16") == frozenset({"bfloat16"})
    assert narrow_wire_dtypes("int8") == frozenset({"int8"})
    assert narrow_wire_dtypes("variable")  # schedules are never identity
    assert narrow_wire_dtypes("variable") <= {"int8", "bfloat16"}

    g, feats, labels, train = wg
    jaxprs = {}
    for codec in ("fp32", "int8"):
        tr = FullBatchTrainer.build(g, None, 4, _spec(), feats, labels,
                                    train, sync_mode="ring", mode="sim",
                                    seed=7, codec=codec)
        loss, _ = tr._step_fns
        jaxprs[codec] = jax.make_jaxpr(tr._wrap(loss))(tr.params, tr.blocks)
    assert check_narrowing([jaxprs["fp32"]], "fp32") == []
    assert check_narrowing([jaxprs["int8"]], "int8") == []
    # the int8 trace genuinely narrows (f32 -> s8 on the wire), so the
    # clean fp32 result above is not the walker being blind
    assert check_narrowing([jaxprs["int8"]], "fp32")


@pytest.mark.parametrize("to_dev", [False, True])
def test_bf16_roundtrip_relative_bound(to_dev):
    x = np.random.default_rng(2).normal(size=(64, 9)).astype(np.float32)
    if to_dev:
        x = jnp.asarray(x)
    y = np.asarray(roundtrip(make_codec("bf16"), x))
    rel = np.abs(y - np.asarray(x)) / np.maximum(np.abs(np.asarray(x)), 1e-12)
    # half a ulp of the 8-bit bf16 significand
    assert rel.max() <= 2.0 ** -8 + 1e-7


@pytest.mark.parametrize("to_dev", [False, True])
def test_int8_roundtrip_absolute_bound(to_dev):
    x = np.random.default_rng(3).normal(size=(33, 17)).astype(np.float32)
    if to_dev:
        x = jnp.asarray(x)
    c = make_codec("int8")
    payload, meta = c.encode(x)
    assert np.asarray(payload).dtype == np.int8
    y = np.asarray(c.decode(payload, meta))
    # uniform quantisation: error <= half a step of scale = max|x|/127
    bound = np.abs(np.asarray(x)).max() / 127.0 * 0.5 + 1e-6
    assert np.abs(y - np.asarray(x)).max() <= bound


@pytest.mark.parametrize("name", ["fp32", "bf16", "int8"])
@pytest.mark.parametrize("shape", [(5,), (3, 4), (2, 3, 5), (128, 16)])
@pytest.mark.parametrize("to_dev", [False, True])
def test_wire_bytes_equals_encoded_nbytes(name, shape, to_dev):
    """The analytic `wire_bytes(shape)` IS the encoded representation's size:
    payload.nbytes + meta.nbytes, for numpy and jax inputs alike."""
    c = make_codec(name)
    x = np.random.default_rng(5).normal(size=shape).astype(np.float32)
    if to_dev:
        x = jnp.asarray(x)
    payload, meta = c.encode(x)
    measured = np.asarray(payload).nbytes
    if meta is not None:
        measured += np.asarray(meta).nbytes
    assert c.wire_bytes(shape) == measured


@pytest.mark.parametrize("name", ["fp32", "bf16", "int8"])
def test_wire_bytes_empty_tensor_is_zero(name):
    # nothing crosses the wire for an empty tensor (no scale either)
    assert make_codec(name).wire_bytes((0, 16)) == 0


def test_variable_ratio_schedule():
    c = make_codec("variable")
    assert isinstance(c, VariableRatioCodec)
    # warmup (epoch 0 < warmup_epochs=2): one notch softer everywhere
    assert (c.ratio(0), c.ratio(1), c.ratio(2)) == (0.5, 1.0, 1.0)
    hard = c.at_epoch(2)
    assert hard is not c and c.epoch == 0  # at_epoch builds a NEW codec
    assert (hard.ratio(0), hard.ratio(1)) == (0.25, 0.5)
    # wire_bytes follows the per-layer tier
    assert hard.wire_bytes((10, 4), layer=0) == 10 * 4 + 4      # int8 + scale
    assert hard.wire_bytes((10, 4), layer=1) == 10 * 4 * 2      # bf16
    assert c.wire_bytes((10, 4), layer=1) == 10 * 4 * 4         # warmup fp32
    # decode dispatches on the payload dtype, per sub-codec
    x = jnp.asarray(np.random.default_rng(7).normal(size=(6, 3)),
                    dtype=jnp.float32)
    p0, m0 = hard.encode(x, layer=0)
    assert p0.dtype == jnp.int8
    assert np.abs(np.asarray(hard.decode(p0, m0)) - np.asarray(x)).max() < 0.1
    p1, m1 = hard.encode(x, layer=1)
    assert p1.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(hard.decode(p1, m1)),
                               np.asarray(x), rtol=2.0 ** -8 + 1e-7)


# ---------------------------------------------------------------------------
# error-feedback gradient reduction
# ---------------------------------------------------------------------------


def _lane_grads(rng, k, steps):
    return [{"w": rng.normal(size=(k, 6, 5)).astype(np.float32),
             "b": rng.normal(size=(k, 5)).astype(np.float32)}
            for _ in range(steps)]


def test_fp32_grad_reduce_is_plain_pmean_under_vmap():
    k = 4
    g = _lane_grads(np.random.default_rng(11), k, 1)[0]
    ef = ef_init(g)
    fn = jax.vmap(lambda gr, e: codec_grad_reduce(make_codec("fp32"), gr, e,
                                                  "parts"),
                  axis_name="parts")
    mean, new_ef = fn(g, ef)
    for leaf, got in zip(jax.tree.leaves(g), jax.tree.leaves(mean)):
        # pmean's summation order may differ from numpy's by float rounding
        np.testing.assert_allclose(np.asarray(got),
                                   np.broadcast_to(leaf.mean(0), leaf.shape),
                                   atol=1e-6)
    for e in jax.tree.leaves(new_ef):  # lossless: EF stays zero forever
        assert not np.asarray(e).any()


def test_int8_ef_telescoping_bias_bound_under_vmap():
    """The EF invariant: summed over T steps, the reduced gradients equal the
    true mean-gradient sum minus only the FINAL residual — compression error
    does not accumulate with T (it acts like one delayed gradient)."""
    k, steps = 4, 20
    seq = _lane_grads(np.random.default_rng(12), k, steps)
    codec = make_codec("int8")
    fn = jax.jit(jax.vmap(lambda gr, e: codec_grad_reduce(codec, gr, e,
                                                          "parts"),
                          axis_name="parts"))
    ef = ef_init(seq[0])
    out_sum = {key: 0.0 for key in seq[0]}
    for g in seq:
        mean, ef = fn(g, ef)
        for key in out_sum:
            out_sum[key] = out_sum[key] + np.asarray(mean[key])[0]
    for key in out_sum:
        true_sum = sum(np.asarray(g[key]).mean(0) for g in seq)
        resid = np.asarray(ef[key]).mean(0)
        # exact telescoping identity (up to f32 accumulation)
        np.testing.assert_allclose(out_sum[key], true_sum - resid, atol=1e-3)
        # and the residual is one quantisation step, independent of T
        step_bound = max(np.abs(np.asarray(g[key])).max() for g in seq)
        step_bound = 1.5 * step_bound / 127.0
        assert np.abs(resid).max() <= step_bound
        assert np.abs(out_sum[key] - true_sum).max() <= step_bound


def test_int8_ef_grad_reduce_shard_map_matches_vmap():
    """The same EF reduce under REAL shard_map over 4 devices is numerically
    identical to the vmap simulation, step for step."""
    code = """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.wire import make_codec, ef_init, codec_grad_reduce

        k, steps = 4, 6
        rng = np.random.default_rng(0)
        seq = [{"w": rng.normal(size=(k, 6, 5)).astype(np.float32),
                "b": rng.normal(size=(k, 5)).astype(np.float32)}
               for _ in range(steps)]
        codec = make_codec("int8")

        def reduce_lane(g, e):
            return codec_grad_reduce(codec, g, e, "parts")

        vfn = jax.jit(jax.vmap(reduce_lane, axis_name="parts"))
        mesh = jax.make_mesh((k,), ("parts",))
        shard_map = (jax.shard_map if hasattr(jax, "shard_map")
                     else __import__("jax.experimental.shard_map",
                                     fromlist=["shard_map"]).shard_map)
        kw = ({"check_vma": False} if hasattr(jax, "shard_map")
              else {"check_rep": False})
        sfn = jax.jit(shard_map(reduce_lane, mesh=mesh,
                                in_specs=(P("parts"), P("parts")),
                                out_specs=(P("parts"), P("parts")), **kw))

        ef_v, ef_s = ef_init(seq[0]), ef_init(seq[0])
        maxerr = 0.0
        for g in seq:
            mv, ef_v = vfn(g, ef_v)
            ms, ef_s = sfn(g, ef_s)
            for a, b in zip(jax.tree.leaves((mv, ef_v)),
                            jax.tree.leaves((ms, ef_s))):
                maxerr = max(maxerr,
                             float(np.abs(np.asarray(a) - np.asarray(b)).max()))
        print("maxerr", maxerr)
        assert maxerr < 1e-5, maxerr
    """
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
             "JAX_PLATFORMS": "cpu",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "maxerr" in proc.stdout


# ---------------------------------------------------------------------------
# fp32 is bitwise-identical on every path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sync", ["halo", "ring"])
def test_fp32_codec_bitwise_identical_fullbatch(wg, sync):
    from repro.gnn.fullbatch import FullBatchTrainer

    g, feats, labels, train = wg
    a = None if sync == "ring" else partition_edges(g, 4, "hep100", seed=1)
    trainers = [
        FullBatchTrainer.build(g, a, 4, _spec(), feats, labels, train,
                               sync_mode=sync, mode="sim", seed=7,
                               codec=codec)
        for codec in (None, "fp32")
    ]
    for _ in range(3):
        losses = [tr.train_step() for tr in trainers]
        assert losses[0] == losses[1], losses
    for p0, p1 in zip(jax.tree.leaves(trainers[0].params),
                      jax.tree.leaves(trainers[1].params)):
        np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))


def test_fp32_codec_bitwise_identical_minibatch(wg):
    from repro.gnn.minibatch import MiniBatchTrainer

    g, feats, labels, train = wg
    a = partition_vertices(g, 4, "metis", seed=1)
    trainers = [
        MiniBatchTrainer.build(g, a, 4, _spec(), feats, labels, train,
                               global_batch=32, seed=7, codec=codec)
        for codec in (None, "fp32")
    ]
    for _ in range(3):
        m0, m1 = (tr.train_step() for tr in trainers)
        assert m0.loss == m1.loss
        np.testing.assert_array_equal(m0.wire_bytes, m0.miss_bytes)
    for p0, p1 in zip(jax.tree.leaves(trainers[0].params),
                      jax.tree.leaves(trainers[1].params)):
        np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))


def test_fp32_codec_bitwise_identical_feature_store(wg):
    from repro.gnn.feature_store import FeatureStore
    from repro.core.partition_book import build_vertex_book

    g, feats, _, _ = wg
    a = partition_vertices(g, 4, "metis", seed=1)
    book = build_vertex_book(g, a, 4)
    ids = np.random.default_rng(9).integers(0, g.num_vertices, 200)
    stores = [FeatureStore.build(g, book, policy="degree", budget=16,
                                 features=feats, codec=codec)
              for codec in (None, "fp32")]
    for w in range(4):
        blocks, stats = zip(*(s.gather(w, ids) for s in stores))
        np.testing.assert_array_equal(blocks[0], blocks[1])
        assert stats[0] == stats[1]
        assert stats[0].wire_bytes == stats[0].miss_bytes


def test_int8_feature_store_roundtrips_only_miss_rows(wg):
    """Lossy stores perturb exactly the rows that cross the network: local
    and cache-hit rows stay bitwise, misses carry the int8 roundtrip."""
    from repro.gnn.feature_store import FeatureStore
    from repro.core.partition_book import build_vertex_book

    g, feats, _, _ = wg
    a = partition_vertices(g, 4, "metis", seed=1)
    book = build_vertex_book(g, a, 4)
    ids = np.random.default_rng(10).integers(0, g.num_vertices, 200)
    exact = FeatureStore.build(g, book, policy="degree", budget=16,
                               features=feats)
    lossy = FeatureStore.build(g, book, policy="degree", budget=16,
                               features=feats, codec="int8")
    w = 0
    b_exact, s_exact = exact.gather(w, ids)
    b_lossy, s_lossy = lossy.gather(w, ids)
    local, hit, miss = lossy.split(w, ids)
    assert miss.sum() > 0  # the pin below must actually bite
    np.testing.assert_array_equal(b_exact[local], b_lossy[local])
    np.testing.assert_array_equal(b_exact[hit], b_lossy[hit])
    miss_err = np.abs(b_exact[miss] - b_lossy[miss]).max()
    bound = np.abs(b_exact[miss]).max() / 127.0 * 0.5 + 1e-6
    assert 0.0 < miss_err <= bound
    # the split and logical accounting are codec-independent
    assert s_exact._replace(wire_bytes=0) == s_lossy._replace(wire_bytes=0)
    nm, d = int(miss.sum()), feats.shape[1]
    assert s_lossy.wire_bytes == nm * d + 4
    assert s_exact.wire_bytes == s_exact.miss_bytes == nm * d * 4


def test_fetchstats_merge_empty_is_the_zero_record():
    from repro.gnn.feature_store import FetchStats

    z = FetchStats.merge([])
    assert z == FetchStats(0, 0, 0, 0, 0, 0, 0, 0)
    assert z.num_remote == 0 and z.hit_rate == 1.0
    a = FetchStats(10, 5, 3, 2, 500, 300, 200, 54)
    b = FetchStats(4, 4, 0, 0, 400, 0, 0, 0)
    m = FetchStats.merge([a, b])
    assert m.num_input == 14 and m.miss_bytes == 200 and m.wire_bytes == 54


# ---------------------------------------------------------------------------
# int8 loss trajectories stay within tolerance of fp32
# ---------------------------------------------------------------------------

LOSS_TOL = 0.05       # mini-batch: only gradients + feature misses are lossy
LOSS_TOL_FULL = 0.1   # full-batch: the activation exchange quantises too


# GAT over ring is the one combination where naive int8 payloads bias
# training: the ring rotates PRE-message payloads, so exp() is applied to
# quantised attention scores — a convex function of the noise, i.e. a
# systematic softmax bias (halo quantises the post-exp partial sums and is
# fine). That is precisely the case the SAR-style variable ramp exists
# for: its hard tier keeps int8 on the max ordinal and bf16 on the
# exp-bearing ones, and tracks fp32 — so that is the codec pinned there.
@pytest.mark.parametrize("model,sync,codec", [
    ("sage", "halo", "int8"),
    ("sage", "ring", "int8"),
    ("gcn", "halo", "int8"),
    ("gcn", "ring", "int8"),
    ("gat", "halo", "int8"),
    ("gat", "ring", "variable"),
])
def test_lossy_loss_trajectory_fullbatch(wg, model, sync, codec):
    from repro.gnn.fullbatch import FullBatchTrainer

    g, feats, labels, train = wg
    a = None if sync == "ring" else partition_edges(g, 4, "hep100", seed=1)
    if codec == "variable":
        codec = make_codec("variable").at_epoch(2)  # post-warmup (hard) tier
    ref, lossy = (
        FullBatchTrainer.build(g, a, 4, _spec(model), feats, labels, train,
                               sync_mode=sync, mode="sim", seed=7, lr=5e-2,
                               codec=c)
        for c in ("fp32", codec)
    )
    traj_ref = [ref.train_step() for _ in range(20)]
    traj_lossy = [lossy.train_step() for _ in range(20)]
    dev = max(abs(a - b) for a, b in zip(traj_ref, traj_lossy))
    assert dev < LOSS_TOL_FULL, dev
    assert traj_lossy[-1] < traj_lossy[0]  # compression didn't stall training


def test_int8_loss_trajectory_minibatch(wg):
    from repro.gnn.minibatch import MiniBatchTrainer

    g, feats, labels, train = wg
    a = partition_vertices(g, 4, "metis", seed=1)
    ref, lossy = (
        MiniBatchTrainer.build(g, a, 4, _spec(), feats, labels, train,
                               global_batch=32, seed=7, lr=5e-2, codec=codec)
        for codec in ("fp32", "int8")
    )
    devs, wire_ratios = [], []
    for _ in range(20):
        m_ref, m_lossy = ref.train_step(), lossy.train_step()
        devs.append(abs(m_ref.loss - m_lossy.loss))
        if m_lossy.miss_bytes.sum():
            wire_ratios.append(m_lossy.wire_bytes.sum()
                               / m_lossy.miss_bytes.sum())
    assert max(devs) < LOSS_TOL, max(devs)
    # the int8 store ships ~1/4 of the logical miss bytes every step
    assert wire_ratios and max(wire_ratios) < 0.3


# ---------------------------------------------------------------------------
# analytic twins: cost model and study rows
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sync", ["halo", "ring"])
def test_cost_model_wire_bytes_fullbatch(wg, sync):
    from repro.core import cost_model
    from repro.core.partition_book import build_blockrow_book, build_edge_book

    g, *_ = wg
    if sync == "ring":
        book = build_blockrow_book(g, 4)
    else:
        book = build_edge_book(g, partition_edges(g, 4, "hep100", seed=1), 4)
    spec = _spec()
    base = cost_model.fullbatch_epoch(book, spec)
    fp32 = cost_model.fullbatch_epoch(book, spec, codec="fp32")
    int8 = cost_model.fullbatch_epoch(book, spec, codec="int8")
    # fp32/default: wire == logical, and the estimate is float-identical
    np.testing.assert_array_equal(base.wire_bytes, base.comm_bytes)
    np.testing.assert_array_equal(base.epoch_time, fp32.epoch_time)
    np.testing.assert_array_equal(base.comm_time, fp32.comm_time)
    # int8: quarter wire, cheaper comm, compute terms untouched
    np.testing.assert_allclose(int8.wire_bytes, 0.25 * int8.comm_bytes)
    assert (int8.comm_time <= base.comm_time + 1e-12).all()
    np.testing.assert_array_equal(int8.compute_time, base.compute_time)


def test_cost_model_wire_bytes_minibatch_and_serve():
    from repro.core import cost_model

    spec = _spec()
    args = (np.array([900.0]), np.array([400.0]), np.array([4000.0]),
            np.array([250.0]))
    base = cost_model.minibatch_step(*args, spec)
    int8 = cost_model.minibatch_step(*args, spec, codec="int8")
    np.testing.assert_array_equal(base.wire_bytes, base.fetch_bytes)
    np.testing.assert_allclose(int8.wire_bytes, 0.25 * base.fetch_bytes)
    assert (int8.fetch_time < base.fetch_time).all()
    assert int8.allreduce_time < base.allreduce_time

    sb = cost_model.serve_request(64, 40, 25, 300, spec, embed_dim=8, hops=1)
    s8 = cost_model.serve_request(64, 40, 25, 300, spec, embed_dim=8, hops=1,
                                  codec="int8")
    assert sb.wire_bytes == sb.fetch_bytes
    assert s8.wire_bytes == int(round(0.25 * sb.fetch_bytes))
    assert s8.service_time < sb.service_time


def test_study_rows_carry_codec_and_wire_columns():
    from repro.core.study import fullbatch_row

    kw = dict(scale=0.01, seed=0)
    base = fullbatch_row("OR", "hep100", 4, _spec(), **kw)
    int8 = fullbatch_row("OR", "hep100", 4, _spec(), codec="int8", **kw)
    assert base["codec"] == "fp32" and int8["codec"] == "int8"
    assert base["wire_bytes"] == base["comm_bytes"]
    assert int8["wire_bytes"] == pytest.approx(0.25 * int8["comm_bytes"])
    assert int8["epoch_time"] < base["epoch_time"]
