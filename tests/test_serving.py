"""The serving subsystem's acceptance gate.

  * layer-wise inference == full-batch forward (fp32 tolerance) under the
    scatter, tiled AND pallas aggregation backends — the embedding stores
    hold exactly what training's forward would compute
  * embedding stores are lossless row stores with conserved accounting
  * the micro-batcher pads every request mix to ONE static shape (the
    serve step compiles once)
  * the online answer (store fetch + final-layer recompute) equals the
    offline layer-wise logits exactly when the fanout covers the full
    neighborhood (SAGE; sampled fanouts are approximate by design)
  * the cost model is monotone: more embedding misses => strictly larger
    modeled service time; a better partitioner => fewer miss bytes =>
    lower modeled latency, end to end
"""

import dataclasses

import numpy as np
import pytest

from repro.core import cost_model
from repro.core.graph import generate_graph
from repro.core.partition_book import build_vertex_book
from repro.core.vertex_partition import partition_vertices
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.inference import (
    LayerwiseInference,
    build_embedding_stores,
    edge_assignment_from_vertex,
    vertex_book_for,
)
from repro.gnn.models import GNNSpec, init_params
from repro.serve import MicroBatcher, build_serving, run_serving_sim
from repro.serve.batcher import plan_dispatch


@pytest.fixture(scope="module")
def tiny_graph():
    """Small undirected social graph (self-loop-free by construction)."""
    return generate_graph("social", 150, 900, seed=3)


@pytest.fixture(scope="module")
def node_setup(tiny_graph):
    g = tiny_graph
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, 12)).astype(np.float32)
    return g, feats


# ---------------------------------------------------------------------------
# layer-wise inference engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["scatter", "tiled", "pallas"])
@pytest.mark.parametrize("model", ["sage", "gat"])
def test_layerwise_matches_fullbatch_forward(node_setup, backend, model):
    """Acceptance: the embedding-store inference equals the full-batch
    forward to fp32 tolerance under all three aggregation backends."""
    g, feats = node_setup
    rng = np.random.default_rng(1)
    labels = rng.integers(0, 5, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.3
    spec = GNNSpec(model=model, feature_dim=12, hidden_dim=8, num_classes=5,
                   num_layers=2, agg_backend=backend)
    from repro.core.edge_partition import partition_edges
    a = partition_edges(g, 4, "hep100", seed=0)
    tr = FullBatchTrainer.build(g, a, 4, spec, feats, labels, train, seed=0)
    eng = LayerwiseInference.build(g, a, 4, spec, tr.params, feats)
    embs = eng.run()
    assert len(embs) == spec.num_layers
    assert embs[0].shape == (g.num_vertices, spec.hidden_dim)
    assert embs[-1].shape == (g.num_vertices, spec.num_classes)
    np.testing.assert_allclose(
        embs[-1], tr.forward_logits_global(), rtol=1e-5, atol=1e-5)


def test_layerwise_k1_is_single_machine(node_setup):
    g, feats = node_setup
    spec = GNNSpec(model="gcn", feature_dim=12, hidden_dim=8, num_classes=5,
                   num_layers=3)
    params = init_params(spec, seed=2)
    single = LayerwiseInference.build(
        g, np.zeros(g.num_edges, np.int64), 1, spec, params, feats)
    multi = LayerwiseInference.build(
        g, edge_assignment_from_vertex(
            g, partition_vertices(g, 3, "metis", seed=0)), 3, spec, params,
        feats)
    for a, b in zip(single.run(), multi.run()):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_embedding_stores_are_lossless(node_setup):
    g, feats = node_setup
    spec = GNNSpec(model="sage", feature_dim=12, hidden_dim=8, num_classes=5)
    params = init_params(spec, seed=0)
    owner = partition_vertices(g, 3, "metis", seed=0)
    eng = LayerwiseInference.build(
        g, edge_assignment_from_vertex(g, owner), 3, spec, params, feats)
    embs = eng.run()
    vbook = build_vertex_book(g, owner, 3)
    stores = build_embedding_stores(g, vbook, embs, policy="degree",
                                    budget=20, seed=0)
    rng = np.random.default_rng(5)
    for li, store in enumerate(stores):
        assert store.row_dim == embs[li].shape[1]
        for w in range(3):
            ids = rng.integers(0, g.num_vertices, 64)
            rows, st = store.gather(w, ids)
            np.testing.assert_array_equal(rows, embs[li][ids])
            assert st.num_local + st.num_cache_hit + st.num_remote_miss == 64
            assert st.miss_bytes == st.num_remote_miss * 4 * store.row_dim
    # one shared cache selection across layers
    for w in range(3):
        np.testing.assert_array_equal(stores[0].cached_ids(w),
                                      stores[1].cached_ids(w))


def test_master_assignment_roundtrip(node_setup):
    g, feats = node_setup
    from repro.core.edge_partition import partition_edges
    from repro.core.partition_book import build_edge_book
    book = build_edge_book(g, partition_edges(g, 4, "hdrf", seed=0), 4)
    owner = book.master_assignment()
    assert owner.shape == (g.num_vertices,)
    assert owner.min() >= 0 and owner.max() < 4
    vb = vertex_book_for(g, book)
    assert vb.k == 4
    np.testing.assert_array_equal(vb.owner, owner)


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------


def test_batcher_static_shapes(node_setup):
    """Padding invariant: every request mix produces identical shapes."""
    g, _ = node_setup
    owner = partition_vertices(g, 2, "metis", seed=0)
    b = MicroBatcher.build(g, fanouts=(5, 5), max_batch=16, owner=owner,
                           worker=0, tiled_layout=True, seed=0)
    hub = int(np.argmax(g.degrees()))
    mixes = [
        np.array([0]),                                  # single request
        np.arange(16),                                  # full batch
        np.full(16, hub),                               # duplicates of a hub
        np.array([hub] * 3 + [0, 1]),                   # mixed
    ]
    shapes = set()
    for ids in mixes:
        mfg = b.build_mfg(ids)
        sig = (mfg.input_ids.shape, tuple(
            (l.esrc.shape, l.edst.shape, l.emask.shape,
             l.sampled_deg.shape, l.agg_order.shape, l.agg_ldst.shape)
            for l in mfg.layers), mfg.seed_labels.shape)
        shapes.add(sig)
        assert int(mfg.seed_mask.sum()) == ids.shape[0]
    assert len(shapes) == 1
    with pytest.raises(ValueError):
        b.build_mfg(np.arange(17))
    with pytest.raises(ValueError):
        b.build_mfg(np.zeros(0, np.int64))


def test_plan_dispatch_policy():
    arrivals = np.array([0.0, 0.001, 0.002, 0.010, 0.011])
    # full batch available and worker free -> dispatch at the filling arrival
    n, t = plan_dispatch(arrivals, 0, t_free=0.0, max_batch=3, max_wait=0.05)
    assert (n, t) == (3, 0.002)
    # partial batch -> wait out max_wait from the oldest request
    n, t = plan_dispatch(arrivals, 3, t_free=0.0, max_batch=3, max_wait=0.005)
    assert n == 2 and t == pytest.approx(0.015)
    # busy worker: riders accumulate until t_free
    n, t = plan_dispatch(arrivals, 0, t_free=0.02, max_batch=10, max_wait=0.001)
    assert (n, t) == (5, 0.02)
    # never dispatch before the worker is free
    n, t = plan_dispatch(arrivals, 0, t_free=0.5, max_batch=3, max_wait=0.001)
    assert (n, t) == (3, 0.5)


# ---------------------------------------------------------------------------
# online answer correctness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["scatter", "tiled"])
@pytest.mark.parametrize("hops", [1, 2])
def test_serve_answer_exact_with_full_fanout(node_setup, backend, hops):
    """SAGE + fanout >= max degree: the sampled MFG covers the entire
    neighborhood, so store-fetch + recompute must equal the offline
    layer-wise logits exactly (same floats, both backends)."""
    g, feats = node_setup
    spec = GNNSpec(model="sage", feature_dim=12, hidden_dim=8, num_classes=5,
                   num_layers=3, agg_backend=backend)
    params = init_params(spec, seed=0)
    owner = partition_vertices(g, 2, "metis", seed=0)
    vbook = build_vertex_book(g, owner, 2)
    eng = LayerwiseInference.build(
        g, edge_assignment_from_vertex(g, owner), 2, spec, params, feats)
    embs = eng.run()
    indptr, _ = g.csr()
    full_fanout = int(np.diff(indptr).max())
    engines, batchers, _ = build_serving(
        g, vbook, spec, params, embs, hops=hops, fanout=full_fanout,
        max_batch=6, seed=0)
    rng = np.random.default_rng(3)
    for w in range(2):
        ids = rng.choice(np.where(owner == w)[0], size=6, replace=False)
        mfg = batchers[w].build_mfg(ids)
        logits, stats, _ = engines[w].answer(mfg)
        np.testing.assert_allclose(logits[:6], embs[-1][ids],
                                   rtol=1e-5, atol=1e-6)
        assert stats.num_input == int(mfg.input_mask.sum())


def test_serve_hops_validation(node_setup):
    g, feats = node_setup
    spec = GNNSpec(model="sage", feature_dim=12, hidden_dim=8, num_classes=5,
                   num_layers=2)
    params = init_params(spec, seed=0)
    owner = partition_vertices(g, 2, "metis", seed=0)
    vbook = build_vertex_book(g, owner, 2)
    eng = LayerwiseInference.build(
        g, edge_assignment_from_vertex(g, owner), 2, spec, params, feats)
    embs = eng.run()
    with pytest.raises(ValueError):
        build_serving(g, vbook, spec, params, embs, hops=2)  # hops == L
    from repro.serve import ServeEngine
    from repro.gnn.sampling import SamplePlan
    stores = build_embedding_stores(g, vbook, embs)
    with pytest.raises(ValueError):  # store dim mismatch (logits store)
        ServeEngine(spec=spec, params=params, store=stores[-1],
                    plan=SamplePlan.build(4, (5,)), hops=1, worker=0)


# ---------------------------------------------------------------------------
# cost model + end-to-end monotonicity
# ---------------------------------------------------------------------------


def test_serve_request_monotone_in_misses():
    spec = GNNSpec(model="sage", feature_dim=64, hidden_dim=64,
                   num_classes=8, num_layers=2)
    kw = dict(spec=spec, embed_dim=64, hops=1)
    base = cost_model.serve_request(200, 80, 0, 1000, **kw)
    prev = base
    for miss in (10, 40, 80):
        est = cost_model.serve_request(200, 80, miss, 1000, **kw)
        assert est.fetch_bytes == miss * 64 * 4
        assert est.service_time > prev.service_time
        assert est.sample_time == base.sample_time  # adjacency unaffected
        assert est.compute_time == base.compute_time
        prev = est
    # forward-only: cheaper than a training step of the same shape
    mb = cost_model.minibatch_step(
        np.array([200.0]), np.array([80.0]), np.array([1000.0]),
        np.array([500.0]), spec)
    assert base.compute_time < float(mb.compute_time[0])


def test_better_partitioner_lowers_modeled_latency(or_graph):
    """The tentpole's claim end to end: metis (low edge-cut) must move
    strictly fewer embedding miss bytes AND deliver lower modeled request
    latency than random partitioning, same trace, same model."""
    g = or_graph
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=256,
                   num_classes=8, num_layers=2)
    params = init_params(spec, seed=0)
    rng = np.random.default_rng(11)
    feats = rng.normal(size=(g.num_vertices, 16)).astype(np.float32)
    n, qps = 240, 300.0
    req = rng.integers(0, g.num_vertices, n)
    arr = np.sort(rng.uniform(0, n / qps, n))
    out = {}
    for method in ("random", "metis"):
        owner = partition_vertices(g, 4, method, seed=0)
        vbook = build_vertex_book(g, owner, 4)
        eng = LayerwiseInference.build(
            g, edge_assignment_from_vertex(g, owner), 4, spec, params, feats)
        engines, batchers, _ = build_serving(
            g, vbook, spec, params, eng.run(), hops=1, fanout=10,
            max_batch=16, max_wait=5e-4, seed=0)
        out[method] = run_serving_sim(engines, batchers, owner, req, arr)
    assert out["metis"].fetch.miss_bytes < out["random"].fetch.miss_bytes
    assert (out["metis"].latency.mean() < out["random"].latency.mean())
    assert out["metis"].p50() < out["random"].p50()
    # conservation on the merged accounting
    for rep in out.values():
        f = rep.fetch
        assert f.num_local + f.num_cache_hit + f.num_remote_miss == f.num_input
        assert rep.served() == n


def test_serving_sim_under_load_queues():
    """Offered load far above sustainable must show up as queueing delay
    (latency >> service time), not silently dropped requests."""
    g = generate_graph("social", 120, 500, seed=1)
    spec = GNNSpec(model="sage", feature_dim=8, hidden_dim=8, num_classes=4,
                   num_layers=2)
    params = init_params(spec, seed=0)
    owner = partition_vertices(g, 2, "metis", seed=0)
    vbook = build_vertex_book(g, owner, 2)
    eng = LayerwiseInference.build(
        g, edge_assignment_from_vertex(g, owner), 2, spec, params,
        np.zeros((g.num_vertices, 8), np.float32))
    engines, batchers, _ = build_serving(
        g, vbook, spec, params, eng.run(), hops=1, fanout=5, max_batch=4,
        max_wait=1e-4, seed=0)
    rng = np.random.default_rng(0)
    n = 64
    req = rng.integers(0, g.num_vertices, n)
    arr = np.sort(rng.uniform(0, 1e-3, n))  # effectively simultaneous
    rep = run_serving_sim(engines, batchers, owner, req, arr)
    assert rep.served() == n
    # the last-served requests waited behind ~n/(2 workers * 4 batch) batches
    assert rep.latency.max() > 3 * rep.service_time.mean()
    assert rep.p99() > rep.p50()
