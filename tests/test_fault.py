"""Fault tolerance: deterministic injection, retry, checkpoint/resume,
elastic degrade-and-recover, serving failover (the ISSUE-10 contracts).

  * spec grammar round-trips; unknown kinds fail with the valid-kind list
  * a retried phase is BITWISE the first attempt (per-(step, worker)
    SeedSequence re-derivation), serial and overlapped
  * crash-and-resume reproduces the unfaulted fp32 loss trajectory
    bitwise, mini-batch (sage + gat x serial/overlap) and full-batch
  * elastic rescale carries lr/codec/EF state and preserves the
    distributed==single invariant; the supervised driver shrinks and
    grows back with priced recovery events that reconcile exactly
  * serving worker-death answers EVERY request via the failover map
  * the CLI exit conventions: unknown spec -> 1, injected crash -> 3
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, checkpoint_extra
from repro.core.edge_partition import partition_edges
from repro.core.vertex_partition import partition_vertices
from repro.fault import (
    FaultEscalation,
    FaultInjector,
    FaultPlan,
    FaultSpecError,
    TransientFetchFault,
    WorkerCrash,
    clear_fetch_hook,
    install_fetch_hook,
    parse_fault_spec,
    retry_call,
)
from repro.fault.recovery import failover_assignment, run_elastic_fullbatch
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.minibatch import MiniBatchTrainer
from repro.gnn.models import GNNSpec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _trainer(graph, node_data, *, overlap, model="sage", seed=3, **kw):
    feats, labels, train = node_data
    a = partition_vertices(graph, 4, "metis", seed=0)
    spec = GNNSpec(model=model, feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    return MiniBatchTrainer.build(
        graph, a, 4, spec, feats, labels, train,
        global_batch=32, seed=seed, overlap=overlap, **kw)


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# spec grammar + plan bookkeeping
# ---------------------------------------------------------------------------


def test_parse_fault_specs():
    ev = parse_fault_spec("crash@step:3")
    assert (ev.kind, ev.step, ev.worker) == ("crash", 3, -1)
    ev = parse_fault_spec("straggler@step:1,worker:2,delay:0.05")
    assert (ev.step, ev.worker, ev.delay) == (1, 2, 0.05)
    ev = parse_fault_spec("worker-death@t:0.5,worker:1")
    assert (ev.at, ev.worker) == (0.5, 1)
    assert parse_fault_spec("corrupt-ckpt").kind == "corrupt-ckpt"


def test_unknown_kind_lists_valid_kinds():
    with pytest.raises(FaultSpecError) as ei:
        parse_fault_spec("explode@step:1")
    msg = str(ei.value)
    assert "valid kinds" in msg and "crash" in msg, msg


@pytest.mark.parametrize("spec", ["crash@step", "crash@step:x",
                                  "crash@fuse:1"])
def test_malformed_specs_raise(spec):
    with pytest.raises(FaultSpecError):
        parse_fault_spec(spec)


def test_plan_fire_once_and_seeded_worker():
    plan = FaultPlan.parse(["crash@step:3", "worker-death@t:0.5"], seed=7)
    ev = plan.events[0]
    assert plan.fire(ev) and not plan.fire(ev)
    assert plan.injected_count == 1 and plan.handled_count == 0
    assert plan.mark_handled(ev) and not plan.mark_handled(ev)
    # unfired events can't be marked handled
    assert not plan.mark_handled(plan.events[1])
    # seeded worker choice is stable across calls and across equal plans
    death = plan.events[1]
    w = plan.resolve_worker(death, 4)
    assert 0 <= w < 4
    assert w == plan.resolve_worker(death, 4)
    twin = FaultPlan.parse(["crash@step:3", "worker-death@t:0.5"], seed=7)
    assert w == twin.resolve_worker(twin.events[1], 4)


def test_retry_call_books_and_escalates():
    plan = FaultPlan.parse(["fetch-error@step:0,worker:0"], seed=0)
    ev = plan.events[0]
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1 and plan.fire(ev):
            raise TransientFetchFault("injected", event=ev, plan=plan)
        return calls["n"]

    assert retry_call(flaky, phase="fetch", backoff=1e-4) == 2
    assert plan.injected_count == plan.handled_count == 1

    def always():
        raise TransientFetchFault("down")

    with pytest.raises(FaultEscalation):
        retry_call(always, phase="fetch", attempts=2, backoff=1e-4)


# ---------------------------------------------------------------------------
# the pipeline seams: retried phases are bitwise the first attempt
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("overlap", [False, True])
def test_retried_batches_bitwise_identical(or_graph, node_data, overlap):
    """One straggler + one sampler fault + one fetch fault, all retried or
    absorbed: every batch still bitwise matches the unfaulted run."""
    plan = FaultPlan.parse([
        "straggler@step:0,worker:1,delay:0.01",
        "sample-error@step:1,worker:2",
        "fetch-error@step:2,worker:0",
    ], seed=0)
    clean = _trainer(or_graph, node_data, overlap=overlap)
    faulted = _trainer(or_graph, node_data, overlap=overlap,
                       injector=FaultInjector(plan))
    try:
        for _ in range(4):
            pb_c, _ = clean.engine.next_batch()
            pb_f, _ = faulted.engine.next_batch()
            assert pb_c.index == pb_f.index
            _tree_equal(pb_c.stacked, pb_f.stacked)
            np.testing.assert_array_equal(pb_c.input_vertices,
                                          pb_f.input_vertices)
    finally:
        clean.close()
        faulted.close()
    assert plan.injected_count == 3
    assert plan.handled_count == 3


@pytest.mark.parametrize("overlap", [False, True])
def test_crash_surfaces_as_worker_crash(or_graph, node_data, overlap):
    """A fatal crash travels through the poison token in overlap mode and
    arrives as WorkerCrash (not a wrapped RuntimeError) in both modes."""
    plan = FaultPlan.parse(["crash@step:2"], seed=0)
    tr = _trainer(or_graph, node_data, overlap=overlap,
                  injector=FaultInjector(plan))
    try:
        with pytest.raises(WorkerCrash):
            for _ in range(4):
                tr.engine.next_batch()
    finally:
        tr.close()
    assert plan.injected_count == 1


def test_gather_seam_global_hook(or_graph, node_data):
    """The module-level RowStore.gather hook (paths that don't thread an
    injector): a step-agnostic fetch-error is raised at the store and
    recovered by the pipeline's caller-side retry, bitwise."""
    plan = FaultPlan.parse(["fetch-error@worker:1"], seed=0)
    clean = _trainer(or_graph, node_data, overlap=False)
    faulted = _trainer(or_graph, node_data, overlap=False)
    install_fetch_hook(FaultInjector(plan, k=4).gather_hook())
    try:
        for _ in range(2):
            pb_c, _ = clean.engine.next_batch()
            pb_f, _ = faulted.engine.next_batch()
            _tree_equal(pb_c.stacked, pb_f.stacked)
    finally:
        clear_fetch_hook()
        clean.close()
        faulted.close()
    assert plan.injected_count == plan.handled_count == 1


# ---------------------------------------------------------------------------
# crash-and-resume: bitwise fp32 loss trajectories (the acceptance gate)
# ---------------------------------------------------------------------------


def _run_minibatch(graph, node_data, *, overlap, model, steps, ckpt_dir=None,
                   plan=None, start_step=0, seed=3):
    """The gnn_train mini-batch loop in miniature: per-step checkpoints,
    crash capture, resume via start_step + restore."""
    mgr = CheckpointManager(ckpt_dir, keep=3, every=1) if ckpt_dir else None
    tr = _trainer(graph, node_data, overlap=overlap, model=model, seed=seed,
                  injector=FaultInjector(plan) if plan else None,
                  start_step=start_step)
    losses, crashed = [], False
    try:
        if mgr is not None and start_step > 0:
            _, restored = mgr.restore(
                {"params": tr.params, "opt_state": tr.opt_state})
            tr.params = restored["params"]
            tr.opt_state = restored["opt_state"]
        for step in range(start_step, steps):
            losses.append(tr.train_step().loss)
            if mgr is not None:
                mgr.maybe_save(step, {"params": tr.params,
                                      "opt_state": tr.opt_state})
    except WorkerCrash:
        crashed = True
    finally:
        tr.close()
    return losses, crashed


@pytest.mark.parametrize("model", ["sage", "gat"])
@pytest.mark.parametrize("overlap", [False, True])
def test_minibatch_crash_resume_bitwise(or_graph, node_data, tmp_path,
                                        model, overlap):
    """Kill at step 3 of 6, resume from the checkpoint: steps 3..5 must be
    BITWISE the unfaulted oracle's (fp32, same RNG tree, same order)."""
    oracle, crashed = _run_minibatch(or_graph, node_data, overlap=overlap,
                                     model=model, steps=6)
    assert not crashed and len(oracle) == 6
    d = str(tmp_path / f"{model}-{overlap}")
    plan = FaultPlan.parse(["crash@step:3"], seed=0)
    pre, crashed = _run_minibatch(or_graph, node_data, overlap=overlap,
                                  model=model, steps=6, ckpt_dir=d, plan=plan)
    assert crashed and len(pre) == 3
    assert pre == oracle[:3]
    step_r, _ = checkpoint_extra(d)
    assert step_r == 2
    post, crashed = _run_minibatch(or_graph, node_data, overlap=overlap,
                                   model=model, steps=6, ckpt_dir=d,
                                   start_step=step_r + 1)
    assert not crashed
    assert post == oracle[3:]  # bitwise: fp32 float equality


def test_fullbatch_crash_resume_bitwise(or_graph, node_data, tmp_path):
    feats, labels, train = node_data
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    a = partition_edges(or_graph, 4, "hep100", seed=1)

    def build():
        return FullBatchTrainer.build(
            or_graph, a, 4, spec, feats, labels, train, mode="sim", seed=7)

    tr = build()
    oracle = [tr.train_step() for _ in range(5)]

    d = str(tmp_path / "fb")
    mgr = CheckpointManager(d, keep=3, every=1)
    plan = FaultPlan.parse(["crash@step:2"], seed=0)
    injector = FaultInjector(plan, k=4)
    tr = build()
    pre = []
    with pytest.raises(WorkerCrash):
        for epoch in range(5):
            injector.at_epoch(epoch)
            pre.append(tr.train_step())
            mgr.maybe_save(epoch, {"params": tr.params,
                                   "opt_state": tr.opt_state},
                           extra={"epoch": epoch})
    assert pre == oracle[:2]

    step_r, extra = checkpoint_extra(d)
    assert (step_r, extra["epoch"]) == (1, 1)
    tr = build()
    _, restored = mgr.restore({"params": tr.params,
                               "opt_state": tr.opt_state})
    tr.params, tr.opt_state = restored["params"], restored["opt_state"]
    post = [tr.train_step() for _ in range(extra["epoch"] + 1, 5)]
    assert post == oracle[2:]


# ---------------------------------------------------------------------------
# elastic degrade-and-recover
# ---------------------------------------------------------------------------


def test_rescale_carries_runtime_state(or_graph, node_data):
    """The satellite regression: lr, codec tier, and EF carry must survive
    a rescale — and the distributed==single invariant must still hold."""
    from repro.ckpt.elastic import rescale_fullbatch
    from repro.core.wire import as_codec

    feats, labels, train = node_data
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    a = partition_edges(or_graph, 4, "hdrf", seed=1)
    # lossy-codec trainer: lr, codec name, and EF carry must transfer
    tr = FullBatchTrainer.build(
        or_graph, a, 4, spec, feats, labels, train, mode="sim", seed=7,
        lr=5e-2, codec="int8")
    tr.train_step()
    assert tr.ef_state is not None
    tr2 = rescale_fullbatch(tr, or_graph, 3, feats, labels, train, seed=2)
    assert tr2.lr == tr.lr == 5e-2
    assert as_codec(tr2.codec).name == "int8"
    assert tr2.ef_state is not None
    for leaf in jax.tree.leaves(tr2.ef_state):
        assert leaf.shape[0] == 3
    # fp32 shrink 4 -> 3: distributed==single parity must survive the
    # rescale (the lossless path, where forward equality is exact-ish)
    tr = FullBatchTrainer.build(
        or_graph, a, 4, spec, feats, labels, train, mode="sim", seed=7,
        lr=5e-2)
    tr.train_step()
    tr2 = rescale_fullbatch(tr, or_graph, 3, feats, labels, train, seed=2)
    assert tr2.lr == 5e-2
    ref = FullBatchTrainer.build(
        or_graph, np.zeros(or_graph.num_edges, np.int32), 1, spec,
        feats, labels, train, seed=7)
    ref.params = tr.params
    np.testing.assert_allclose(
        tr2.forward_logits_global(), ref.forward_logits_global(),
        rtol=2e-4, atol=2e-4)


def test_elastic_driver_shrinks_and_recovers(or_graph, node_data):
    from repro.obs import Tracer, install, uninstall
    from repro.obs.reconcile import reconcile_recovery

    feats, labels, train = node_data
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    plan = FaultPlan.parse(["worker-loss@epoch:1,worker:2",
                            "worker-join@epoch:3"], seed=0)
    tracer = install(Tracer())
    try:
        res = run_elastic_fullbatch(
            or_graph, feats, labels, train, spec, k=4, epochs=5, plan=plan,
            partitioner="hep100", seed=0)
    finally:
        uninstall()
    assert res.k_history == [4, 3, 3, 4, 4]
    assert [e.action for e in res.events] == ["shrink", "grow"]
    assert all(e.estimate.recovery_time > 0 for e in res.events)
    assert plan.injected_count == plan.handled_count == 2
    assert all(np.isfinite(res.losses))
    checks = reconcile_recovery(plan, tracer=tracer,
                                estimates=res.recovery_estimates)
    assert checks and all(c.level == "ok" for c in checks), [
        (c.quantity, c.message) for c in checks if c.level != "ok"]


def test_failover_assignment_spread_and_replicas():
    owner = np.array([0, 1, 1, 2, 0])
    new = failover_assignment(owner, 1, 3)
    assert not (new == 1).any()
    # untouched vertices keep their owner; moved ones spread over survivors
    np.testing.assert_array_equal(new[[0, 3, 4]], owner[[0, 3, 4]])
    assert set(new[[1, 2]]) <= {0, 2}

    class _Book:  # minimal replica map: vglobal[p][vmask[p]] = copies on p
        vglobal = [np.array([0, 1, 2]), np.array([1, 3]), np.array([3, 4])]
        vmask = [np.ones(3, bool), np.ones(2, bool), np.ones(2, bool)]

    owner = np.array([0, 1, 2, 1, 2])
    new = failover_assignment(owner, 1, 3, book=_Book())
    # v1 has a replica on partition 0, v3 on partition 2 — both preferred
    np.testing.assert_array_equal(new, [0, 0, 2, 2, 2])

    with pytest.raises(ValueError):
        failover_assignment(np.zeros(3, np.int64), 0, 1)


# ---------------------------------------------------------------------------
# serving worker-death
# ---------------------------------------------------------------------------


def test_serving_worker_death_answers_every_request():
    from repro.core.study import StudyCache, serve_row

    cache = StudyCache()
    spec = GNNSpec(model="sage", feature_dim=16, hidden_dim=8, num_classes=5,
                   num_layers=2)
    n = 120
    plan = FaultPlan.parse(["worker-death@t:0.25,worker:1"], seed=0)
    row = serve_row("OR", "metis", 4, spec, scale=0.02, cache=cache,
                    qps=300.0, n_requests=n, hops=1, fanout=8,
                    fault_plan=plan, detect_delay=0.005)
    assert row["requests"] == n            # every request answered
    assert row["dead_worker"] == 1
    assert row["rerouted"] > 0
    assert row["transition_requests"] >= row["rerouted"]
    assert row["transition_p99"] >= row["transition_p50"] > 0.0
    assert plan.injected_count == plan.handled_count == 1
    # the unfaulted twin serves the same trace with no degraded columns
    clean = serve_row("OR", "metis", 4, spec, scale=0.02, cache=cache,
                      qps=300.0, n_requests=n, hops=1, fanout=8)
    assert clean["requests"] == n and "transition_p99" not in clean


# ---------------------------------------------------------------------------
# CLI conventions (subprocess, like the examples tests)
# ---------------------------------------------------------------------------


def _train_cli(*argv, timeout=600):
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.gnn_train", *argv],
        capture_output=True, text=True, env=env, timeout=timeout)


def test_cli_unknown_fault_spec_exits_1():
    r = _train_cli("--inject-fault", "explode@step:1")
    assert r.returncode == 1, (r.returncode, r.stdout[-500:])
    assert "valid kinds" in r.stdout


def test_cli_crash_exit_code_and_resume(tmp_path):
    """crash@step -> exit 3 (distinct from real failures); --resume
    completes and reproduces the unfaulted final-epoch loss exactly.
    (scale 0.02, batch 64 => 2 steps/epoch: step 2 is inside epoch 1.)"""
    common = ("--graph", "OR", "--scale", "0.02", "--regime", "minibatch",
              "--partitioner", "metis", "--k", "2", "--epochs", "2",
              "--batch", "64", "--features", "16", "--hidden", "8",
              "--classes", "8", "--ckpt-every", "1")

    def last_loss(out):
        vals = [ln.split("loss")[1].split()[0] for ln in out.splitlines()
                if "] epoch" in ln and "loss" in ln]
        assert vals, out[-800:]
        return vals[-1]

    oracle = _train_cli(*common)
    assert oracle.returncode == 0, oracle.stderr[-2000:]

    d = str(tmp_path / "ck")
    r = _train_cli(*common, "--ckpt-dir", d, "--inject-fault", "crash@step:2")
    assert r.returncode == 3, (r.returncode, r.stdout[-500:],
                               r.stderr[-1000:])
    assert "FATAL" in r.stdout and "--resume" in r.stdout

    r = _train_cli(*common, "--ckpt-dir", d, "--resume")
    assert r.returncode == 0, (r.stdout[-500:], r.stderr[-2000:])
    assert "resumed" in r.stdout
    assert last_loss(r.stdout) == last_loss(oracle.stdout)
