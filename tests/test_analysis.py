"""Unit tests for the `repro.analysis` static-analysis subsystem.

Parser-level tests use handcrafted HLO snippets shaped like real XLA:CPU
output (async tuple `-start` forms with operand echoes and u32[] control
slots, `-done` pairs, replica-group annotations) so the byte-accounting
conventions are pinned independently of whatever XLA emits today. The
rule/CLI tests run the real grid programs and the seeded violations.
"""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    Program,
    analyze_hlo,
    check_narrowing,
    check_scatter,
    collective_bytes_from_hlo,
    convert_ops,
    count_primitives,
    input_output_aliases_from_hlo,
    iter_eqns,
    narrowing_converts,
    primitive_names,
    run_rules,
    violation_program,
)
from repro.analysis.deadcode import (
    collect_exports,
    dead_exports,
    reference_counts,
)


# ---------------------------------------------------------------------------
# HLO parser: handcrafted snippets
# ---------------------------------------------------------------------------


def test_hlo_plain_collective_bytes():
    """A sync collective's payload is its output shape."""
    hlo = """
      ar = f32[128,8]{1,0} all-reduce(f32[128,8]{1,0} x), replica_groups={{0,1,2,3}}, to_apply=add
    """
    res = analyze_hlo(hlo)
    assert res["count_per_kind"] == {"all-reduce": 1}
    assert res["bytes_per_kind"] == {"all-reduce": 128 * 8 * 4}
    (op,) = res["collectives"]
    assert not op.is_start
    assert op.replica_groups == [[0, 1, 2, 3]]
    assert op.group_size == 4


def test_hlo_start_done_counted_once():
    """An async pair is one transfer: the -start tuple drops the u32[]
    control slots and the operand echo; the -done line is skipped."""
    hlo = """
      ags = (f32[64]{0}, f32[128]{0}, u32[], u32[]) all-gather-start(f32[64]{0} p), replica_groups={{0,1}}, dimensions={0}
      agd = f32[128]{0} all-gather-done((f32[64]{0}, f32[128]{0}, u32[], u32[]) ags)
    """
    res = analyze_hlo(hlo)
    assert res["count_per_kind"] == {"all-gather": 1}
    # 128 floats survive: the 64-float operand echo and both u32[] slots go
    assert res["bytes_per_kind"] == {"all-gather": 128 * 4}
    assert res["collectives"][0].is_start


def test_hlo_start_identity_output_not_zeroed():
    """An all-reduce-start whose output equals its operand still counts its
    single payload — echo-dropping never removes the last entry."""
    hlo = """
      ars = (f32[32]{0}, f32[32]{0}, u32[], u32[]) all-reduce-start(f32[32]{0} p), to_apply=add
      ard = f32[32]{0} all-reduce-done((f32[32]{0}, f32[32]{0}, u32[], u32[]) ars)
    """
    res = analyze_hlo(hlo)
    assert res["bytes_per_kind"] == {"all-reduce": 32 * 4}
    assert res["count_per_kind"] == {"all-reduce": 1}


def test_hlo_int8_payload_and_permute_pairs():
    hlo = """
      cp = s8[1024]{0} collective-permute(s8[1024]{0} x), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
      ag = f32[8]{0} all-gather(f32[4]{0} y), replica_groups={{0,1},{2,3}}, dimensions={0}
    """
    res = analyze_hlo(hlo)
    assert res["bytes_per_kind"] == {"collective-permute": 1024,
                                     "all-gather": 32}
    cp, ag = res["collectives"]
    assert cp.dtypes == ("s8",)
    assert cp.source_target_pairs == [(0, 1), (1, 2), (2, 3), (3, 0)]
    assert cp.group_size == 4
    # multi-group annotations must not truncate at the first inner brace
    assert ag.replica_groups == [[0, 1], [2, 3]]
    assert ag.group_size == 2


def test_hlo_scatter_census_excludes_lookalikes():
    """reduce-scatter and select-and-scatter are NOT data-dependent
    scatters; a real `scatter` is."""
    hlo = """
      rs = f32[16]{0} reduce-scatter(f32[64]{0} x), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=add
      sas = f32[8,8]{1,0} select-and-scatter(f32[8,8]{1,0} a, f32[4,4]{1,0} b, f32[] c), select=ge, scatter=add
      sc = f32[64,8]{1,0} scatter(f32[64,8]{1,0} h, s32[32,1]{1,0} idx, f32[32,8]{1,0} upd), to_apply=add
    """
    res = analyze_hlo(hlo)
    assert res["scatter_ops"] == 1
    assert res["count_per_kind"] == {"reduce-scatter": 1}
    assert res["bytes_per_kind"] == {"reduce-scatter": 16 * 4}


def test_hlo_convert_ops():
    hlo = """
      c1 = s8[256]{0} convert(f32[256]{0} x)
      c2 = s8[256]{0} convert(f32[256]{0} y)
      c3 = f32[256]{0} convert(s8[256]{0} z)
    """
    res = analyze_hlo(hlo)
    assert res["convert_ops"] == {("f32", "s8"): 2, ("s8", "f32"): 1}


def test_hlo_input_output_alias_header():
    hlo = ("HloModule jit_step, input_output_alias={ {0}: (1, {}, may-alias),"
           " {1}: (3, {}, may-alias) }, entry_computation_layout=...")
    assert input_output_aliases_from_hlo(hlo) == [(0, 1), (1, 3)]
    assert input_output_aliases_from_hlo("HloModule jit_f\n  x = f32[]") == []


def test_collective_bytes_historical_shape():
    hlo = "  ar = f32[4]{0} all-reduce(f32[4]{0} x), to_apply=add"
    res = collective_bytes_from_hlo(hlo)
    assert set(res) == {"bytes_per_kind", "count_per_kind", "total_bytes"}
    assert res["total_bytes"] == 16


def test_donation_probe_aliases_on_cpu():
    """jit(donate_argnums) leaves an input_output_alias header even on
    XLA:CPU — the donation rule's alias probe is meaningful here."""
    f = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    txt = f.lower(jnp.ones(16, jnp.float32)).compile().as_text()
    pairs = input_output_aliases_from_hlo(txt)
    assert pairs and pairs[0][1] == 0


# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------


def test_iter_eqns_recurses_into_subjaxprs():
    """Primitives inside scan/pjit bodies are visible to the walker."""

    def body(c, _):
        return jnp.sin(c) * 2.0, None

    def fn(x):
        inner = jax.jit(lambda y: jnp.cos(y))(x)
        out, _ = jax.lax.scan(body, inner, None, length=3)
        return out

    cj = jax.make_jaxpr(fn)(jnp.ones(4))
    names = primitive_names(cj)
    assert {"sin", "cos", "scan", "pjit"} <= names
    counts = count_primitives(cj)
    assert counts["sin"] == 1 and counts["cos"] == 1
    assert len(list(iter_eqns(cj))) == sum(counts.values())


def test_convert_walker_and_narrowing_filter():
    def fn(x, idx):
        wire = x.astype(jnp.bfloat16).astype(jnp.float32)   # narrowing
        small = idx.astype(jnp.int8)                        # integer churn
        return wire.sum() + small.sum()

    cj = jax.make_jaxpr(fn)(jnp.ones(8, jnp.float32),
                            np.arange(8, dtype=np.int32))
    conv = convert_ops(cj)
    assert conv[("float32", "bfloat16")] == 1
    assert conv[("int32", "int8")] == 1
    # only the float shrink is wire compression
    assert narrowing_converts(cj) == {("float32", "bfloat16"): 1}


def test_check_scatter_both_directions():
    def scatters(x, idx):
        return jnp.zeros(16).at[idx].add(x)

    def clean(x):
        return x * 2.0

    cj_scatter = jax.make_jaxpr(scatters)(jnp.ones(4), jnp.arange(4))
    cj_clean = jax.make_jaxpr(clean)(jnp.ones(4))
    assert check_scatter([cj_clean], expect_free=True) is None
    assert check_scatter([cj_scatter], expect_free=False) is None
    msg = check_scatter([cj_scatter], expect_free=True)
    assert msg and "scatter" in msg
    # anchor direction: a clean trace where a scatter was REQUIRED means
    # the walker went blind
    assert check_scatter([cj_clean], expect_free=False) is not None


def test_check_narrowing_respects_codec_license():
    def narrow(x):
        return x.astype(jnp.bfloat16).astype(jnp.float32).sum()

    cj = jax.make_jaxpr(narrow)(jnp.ones(8, jnp.float32))
    assert check_narrowing([cj], "bf16") == []
    offenders = check_narrowing([cj], "fp32")
    assert offenders == [("float32", "bfloat16", 1)]


# ---------------------------------------------------------------------------
# retrace-guard (satellite: deliberate shape-dependent retrace is caught)
# ---------------------------------------------------------------------------


def test_retrace_guard_green_path():
    """A warmed, shape-stable hot loop compiles nothing: budget 0 holds."""
    step = jax.jit(lambda x: x * 2.0)

    def sweep():
        def hot():
            step(jnp.ones(4, jnp.float32)).block_until_ready()
            step(jnp.ones(4, jnp.float32)).block_until_ready()
        return hot

    prog = Program(name="retrace/green", kind="retrace",
                   sweep=sweep, retrace_budget=0)
    report = run_rules([prog], ["retrace-guard"])
    assert report.exit_code == 0, [f.message for f in report.findings]


def test_retrace_guard_catches_shape_dependent_retrace():
    """The seeded violation — a fresh jit fed three distinct shapes —
    exceeds its budget and turns the gate red."""
    report = run_rules([violation_program("retrace-guard")],
                       ["retrace-guard"])
    assert report.exit_code == 1
    (err,) = report.errors
    assert "compiles" in err.message and "budget" in err.message


# ---------------------------------------------------------------------------
# dead-export sweep
# ---------------------------------------------------------------------------


def _fake_repo(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "mod.py").write_text(
        "def used():\n    return 1\n\n"
        "def unused():\n    return 2\n\n"
        "def kept():  # lint: keep\n    return 3\n\n"
        "def _private():\n    return 4\n\n"
        "CONST = 7\n"
    )
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_mod.py").write_text(
        "from repro.mod import used\n\n"
        "def test_u():\n    assert used() == 1\n"
    )
    return tmp_path


def test_dead_exports_flags_only_unreferenced_public(tmp_path):
    root = _fake_repo(tmp_path)
    exports = collect_exports(root)
    assert set(exports) == {"used", "unused", "CONST"}  # kept/_private skipped
    dead = dict(dead_exports(root))
    assert set(dead) == {"unused", "CONST"}


def test_reference_counts_are_token_matches(tmp_path):
    f = tmp_path / "x.py"
    f.write_text("run_rules = 1\nrerun = 2\n")
    counts = reference_counts(["run"], [f])
    assert counts["run"] == 0  # substrings of other identifiers don't count


def test_repo_has_no_unannotated_dead_exports():
    """The advisory sweep stays clean on the repo itself — new dead exports
    must be deleted or `# lint: keep`-annotated."""
    assert dead_exports("/root/repo") == []


# ---------------------------------------------------------------------------
# gnn_lint CLI
# ---------------------------------------------------------------------------


def _lint(*argv):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.gnn_lint", *argv],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )


def test_cli_tiny_grid_green_and_report_schema(tmp_path):
    out = tmp_path / "report.json"
    proc = _lint("--grid", "tiny", "--out-json", str(out))
    assert proc.returncode == 0, proc.stderr[-3000:] + proc.stdout[-1000:]
    report = json.loads(out.read_text())
    assert report["schema"] == "gnn-lint-report/v1"
    assert set(report) >= {"programs", "rules", "counts", "exit_code",
                           "elapsed_s", "findings"}
    assert report["exit_code"] == 0 and report["counts"]["error"] == 0
    assert set(report["rules"]) == {"no-scatter", "dtype-policy",
                                    "collective-budget", "donation",
                                    "retrace-guard"}


def test_cli_seeded_violation_exits_nonzero():
    proc = _lint("--grid", "tiny", "--rules", "no-scatter",
                 "--inject-violation", "no-scatter", "--out-json", "-")
    assert proc.returncode == 1, proc.stderr[-3000:]
    report = json.loads(proc.stdout[: proc.stdout.rindex("}") + 1])
    errs = [f for f in report["findings"] if f["level"] == "error"]
    assert errs and errs[0]["rule"] == "no-scatter"


def test_cli_rejects_unknown_rule():
    proc = _lint("--rules", "no-such-rule")
    assert proc.returncode == 2
    assert "unknown rules" in proc.stderr
