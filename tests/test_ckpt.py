"""Checkpoint layer hardening: atomic publish, GC, dtype round-trips,
structure guards, corruption fallback, metadata-only reads."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (
    CheckpointManager,
    checkpoint_extra,
    restore_latest,
    save_checkpoint,
)


def _tree(shift=0.0):
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3) + shift,
                       "b": jnp.ones((3,)) * (1.0 + shift)},
            "step": jnp.asarray(int(shift))}


def test_atomic_publish_survives_mid_write_kill(tmp_path):
    """A kill between the leaf writes and the rename leaves only a .tmp
    directory — even one with a complete-looking manifest inside. Restore
    must ignore it and manager construction must GC it."""
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1.0))
    # fake a mid-write kill at step 2: everything written, rename never ran
    tmp = os.path.join(d, "step_0000000002.tmp")
    os.makedirs(tmp)
    np.save(os.path.join(tmp, "leaf_00000.npy"), np.zeros((3,)))
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump({"step": 2, "extra": {}, "leaves": []}, fh)

    step, restored = restore_latest(d, _tree())
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["params"]["b"]), 2.0)

    CheckpointManager(d, keep=3, every=1)  # init GCs partial dirs
    assert not os.path.exists(tmp)
    assert os.path.exists(os.path.join(d, "step_0000000001"))


def test_keep_last_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, every=1)
    for s in range(7):
        mgr.maybe_save(s, _tree(float(s)))
    names = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert names == [f"step_{s:010d}" for s in (4, 5, 6)]
    step, restored = mgr.restore(_tree())
    assert step == 6
    np.testing.assert_array_equal(np.asarray(restored["step"]), 6)


def test_save_every_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=10, every=5)
    saved = [s for s in range(12) if mgr.maybe_save(s, _tree(float(s)))]
    assert saved == [0, 5, 10]
    assert mgr.maybe_save(12, _tree(), force=True) is not None


def test_bf16_round_trip(tmp_path):
    """bf16 leaves are widened to f32 on disk (numpy can't serialise
    ml_dtypes) and cast back to the target leaf's dtype on restore."""
    tree = {"w": jnp.arange(8.0, dtype=jnp.bfloat16) / 3.0,
            "v": jnp.ones((4,), jnp.float32)}
    save_checkpoint(str(tmp_path), 0, tree)
    # on-disk leaf is f32, manifest remembers the original dtype
    (ck,) = [n for n in os.listdir(tmp_path) if n.startswith("step_")]
    with open(tmp_path / ck / "manifest.json") as fh:
        manifest = json.load(fh)
    by_path = {rec["path"]: rec for rec in manifest["leaves"]}
    assert by_path["w"]["dtype"] == "bfloat16"
    raw = np.load(tmp_path / ck / by_path["w"]["file"])
    assert raw.dtype == np.float32

    step, restored = restore_latest(str(tmp_path), jax.tree.map(
        jnp.zeros_like, tree))
    assert step == 0
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


def test_restore_rejects_path_mismatch(tmp_path):
    """Same leaf count, different tree structure: restore must fail by NAME
    instead of silently loading leaves into the wrong slots."""
    save_checkpoint(str(tmp_path), 0, {"a": jnp.ones((2,)),
                                       "b": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="mismatch at leaf 'b'"):
        restore_latest(str(tmp_path), {"a": jnp.ones((2,)),
                                       "c": jnp.zeros((2,))})


def test_stray_step_dir_skipped_with_warning(tmp_path):
    """step_final/ etc. (satellite: non-integer step_* names) must not kill
    the scan — skipped loudly, newest REAL checkpoint still restores."""
    d = str(tmp_path)
    save_checkpoint(d, 3, _tree(3.0))
    stray = os.path.join(d, "step_final")
    os.makedirs(stray)
    with open(os.path.join(stray, "manifest.json"), "w") as fh:
        json.dump({"step": "final", "extra": {}, "leaves": []}, fh)
    with pytest.warns(UserWarning, match="step_final"):
        step, restored = restore_latest(d, _tree())
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["step"]), 3)


def test_corrupt_newest_falls_back_to_previous(tmp_path):
    """The corrupt-ckpt fault: breaking the newest manifest makes restore
    fall back to the previous complete checkpoint."""
    from repro.fault import corrupt_latest_checkpoint

    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1.0))
    save_checkpoint(d, 2, _tree(2.0))
    path = corrupt_latest_checkpoint(d, mode="manifest")
    assert path.endswith("step_0000000002")
    step, restored = restore_latest(d, _tree())
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["step"]), 1)
    assert corrupt_latest_checkpoint(str(tmp_path / "empty")) is None


def test_checkpoint_extra_reads_metadata_only(tmp_path):
    """Resume coordinates (epoch/step/has_ef) are readable BEFORE the
    target tree exists — and without touching any leaf file."""
    d = str(tmp_path)
    assert checkpoint_extra(d) == (None, {})
    save_checkpoint(d, 7, _tree(7.0), extra={"epoch": 3, "step": 1,
                                             "has_ef": True})
    # leaf files should not be needed: remove them all
    (ck,) = [n for n in os.listdir(d) if n.startswith("step_")]
    for n in os.listdir(os.path.join(d, ck)):
        if n.endswith(".npy"):
            os.remove(os.path.join(d, ck, n))
    step, extra = checkpoint_extra(d)
    assert step == 7
    assert extra == {"epoch": 3, "step": 1, "has_ef": True}
