"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU — output shapes + no NaNs.
Also checks prefill->decode consistency against full-sequence forward.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config, smoke_config
from repro.models import lm


def _make_batch(cfg, rng, B=2, S=32):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        P = cfg.num_patches
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, P, cfg.d_model)), jnp.bfloat16)
        total = P + S
        batch["pos3"] = jnp.broadcast_to(
            jnp.arange(total)[None, None], (3, B, total)).astype(jnp.int32)
    if cfg.encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    lm.set_activation_sharding(None)
    rng = np.random.default_rng(0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _make_batch(cfg, rng)
    loss = jax.jit(lambda p, b: lm.loss_fn(cfg, p, b, remat=False))(params, batch)
    assert np.isfinite(float(loss))
    assert 1.0 < float(loss) < 20.0  # ~ln(V) at init


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_consistent(arch):
    """logits(prefill prompt; decode token t) == logits(forward over
    prompt+t) — the KV-cache path must agree with the full pass.

    MoE archs are exempt by design: capacity-bucketed dispatch drops tokens
    based on the WHOLE sequence's competition for expert capacity, so a
    token's routing can legitimately differ between prefill (competing) and
    decode (alone in its bucket). This is inherent to capacity-based MoE
    (GShard/Switch semantics), not a cache bug.
    """
    cfg = smoke_config(arch)
    if cfg.moe:
        pytest.skip("capacity-based MoE: routing is sequence-context dependent")
    lm.set_activation_sharding(None)
    rng = np.random.default_rng(1)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    tokens = rng.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)

    batch_prompt = _make_batch(cfg, np.random.default_rng(2), B=B, S=S)
    batch_prompt["tokens"] = jnp.asarray(tokens[:, :S])
    prefix = 0
    if cfg.family == "vlm":
        prefix = cfg.num_patches
    logits_p, caches = lm.prefill(cfg, params, batch_prompt, max_len=prefix + S + 8)
    pos3 = None
    idx = jnp.asarray(prefix + S, jnp.int32)
    if cfg.family == "vlm":
        pos3 = jnp.broadcast_to(idx, (3, B, 1)).astype(jnp.int32)
    logits_d, _ = lm.decode_step(
        cfg, params, jnp.asarray(tokens[:, S:S + 1]), caches, idx, pos3=pos3)

    batch_full = dict(batch_prompt)
    batch_full["tokens"] = jnp.asarray(tokens)
    if cfg.family == "vlm":
        total = prefix + S + 1
        batch_full["pos3"] = jnp.broadcast_to(
            jnp.arange(total)[None, None], (3, B, total)).astype(jnp.int32)
    logits_f, _ = lm.prefill(cfg, params, batch_full, max_len=prefix + S + 8)

    np.testing.assert_allclose(
        np.asarray(logits_d, np.float32), np.asarray(logits_f, np.float32),
        rtol=0.15, atol=0.15,  # bf16 accumulation-order differences
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_values(arch):
    """The FULL configs carry the exact assignment-table values."""
    cfg = get_config(arch)
    table = {
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    }
    L, d, h, kv, ff, v = table[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_param_counts_plausible():
    """Sanity of the analytic 6ND inputs: param counts near the names."""
    expect = {
        "qwen1.5-0.5b": (0.3e9, 0.8e9),
        "qwen3-4b": (3e9, 5e9),
        "h2o-danube-1.8b": (1.3e9, 2.3e9),
        "yi-6b": (5e9, 7e9),
        "hymba-1.5b": (1.0e9, 2.1e9),
        "qwen2-vl-2b": (1.2e9, 2.5e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "whisper-tiny": (2e7, 9e7),
        "mamba2-370m": (2.5e8, 5e8),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    active = cfg.active_param_count()
    assert active < cfg.param_count() * 0.3
    assert 5e9 < active < 9e9  # ~6.6B active
