"""The observability layer's acceptance gate (repro.obs + gnn_trace).

  * the disabled tracer is a true no-op: zero events recorded, and a
    traced run's loss trajectory is bitwise identical to an untraced one
  * the phase spans ARE the StepMetrics phase times (one timing source),
    so the pinned phases-sum-to-wall contract survives the migration
  * the Chrome trace-event export round-trips through its own loader:
    every B has its E, per-track timestamps are monotonic, counters and
    the two clock domains (wall / simulated serving clock) land on
    separate pids, and the schema tag is present
  * reconciliation is exact for fp32: measured fetch wire bytes equal the
    codec formula, traced full-batch collectives equal collective_budget /
    sync_wire_bytes_per_round, and a single injected byte flips the
    report to exit code 1 (the seeded red path)
  * `study.serve_result_row` carries the queue-wait / service-time
    breakdown columns that attribute p99 to queueing vs compute
"""

import json
import threading

import numpy as np
import pytest

from repro.core.edge_partition import partition_edges
from repro.core.graph import generate_graph
from repro.core.vertex_partition import partition_vertices
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.minibatch import MiniBatchTrainer
from repro.gnn.models import GNNSpec
from repro.obs import (
    TRACE_SCHEMA,
    Tracer,
    get_tracer,
    install,
    load_trace,
    phase_means,
    reconcile,
    to_chrome_trace,
    tracing,
    uninstall,
    validate_chrome_trace,
    write_trace,
)


@pytest.fixture(scope="module")
def tiny_graph():
    return generate_graph("social", 150, 900, seed=3)


@pytest.fixture(scope="module")
def node_setup(tiny_graph):
    g = tiny_graph
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, 12)).astype(np.float32)
    labels = rng.integers(0, 5, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.4
    return g, feats, labels, train


def _minibatch(node_setup, *, codec=None, overlap=False, steps=3):
    g, feats, labels, train = node_setup
    owner = partition_vertices(g, 2, "metis", seed=0)
    spec = GNNSpec(model="sage", feature_dim=12, hidden_dim=8, num_classes=5,
                   num_layers=2)
    tr = MiniBatchTrainer.build(g, owner, 2, spec, feats, labels, train,
                                global_batch=32, seed=3, codec=codec,
                                overlap=overlap)
    ms = [tr.train_step() for _ in range(steps)]
    tr.close()
    return tr, ms


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_disabled_tracer_records_nothing():
    """The module singleton starts disabled and stays empty no matter how
    much the instrumentation fires."""
    tr = get_tracer()
    assert not tr.enabled
    before = len(tr)
    with tr.span("x", cat="test"):
        pass
    tr.add("c", 123)
    tr.gauge("g", 1.0)
    tr.collective("all-reduce", 64)
    assert len(tr) == before == 0
    assert tr.total("c") is None


def test_tracing_no_behavior_change(node_setup):
    """Bitwise-identical loss trajectory with and without the tracer —
    the instrumentation may observe, never perturb."""
    _, ms_off = _minibatch(node_setup)
    with tracing() as tr:
        _, ms_on = _minibatch(node_setup)
        assert len(tr) > 0
    assert [m.loss for m in ms_off] == [m.loss for m in ms_on]


def test_span_records_thread_and_duration():
    with tracing() as tr:
        def work():
            with tr.span("worker.op", cat="test", track="pool"):
                pass
        t = threading.Thread(target=work, name="pool-0")
        t.start()
        t.join()
        with tr.span("main.op", cat="test"):
            pass
    spans = tr.spans()
    assert {s.name for s in spans} == {"worker.op", "main.op"}
    by_name = {s.name: s for s in spans}
    assert by_name["worker.op"].thread == "pool-0"
    assert all(s.t1 >= s.t0 for s in spans)


def test_counter_totals_survive_ring_wrap():
    """`total()` is exact even after the event ring truncates."""
    with tracing(capacity=8) as tr:
        for _ in range(100):
            tr.add("bytes", 3)
    assert tr.total("bytes") == 300
    assert len(tr.counters("bytes")) == 8  # ring kept only the tail


def test_phase_clock_sums_to_wall():
    with tracing() as tr:
        clock = tr.phase_clock(cat="test")
        parts = [clock.split(f"p{i}") for i in range(4)]
    spans = tr.spans()
    assert len(spans) == 4
    # contiguous: each phase starts exactly where the previous ended
    for a, b in zip(spans, spans[1:]):
        assert a.t1 == b.t0
    assert sum(parts) == spans[-1].t1 - spans[0].t0


# ---------------------------------------------------------------------------
# phase accounting migration (satellite 1: one timing source)
# ---------------------------------------------------------------------------


def test_step_metrics_phases_are_the_spans(node_setup):
    """The serial engine's StepMetrics phase times and the recorded spans
    are the same numbers — not two parallel clocks."""
    with tracing() as tr:
        _, ms = _minibatch(node_setup, steps=2)
    by_name = {}
    for s in tr.spans():
        by_name.setdefault(s.name, []).append(s)
    for phase in ("sample", "fetch", "transfer"):
        spans = by_name[f"pipeline.{phase}"]
        assert len(spans) == len(ms)
        for s, m in zip(spans, ms):
            assert s.duration == getattr(m, f"{phase}_time_host")
    # serial contract stays pinned: phases sum exactly to the step wall
    for m in ms:
        assert (m.sample_time_host + m.fetch_time_host
                + m.transfer_time_host + m.compute_time_host
                ) == pytest.approx(m.step_wall_host, abs=0, rel=0)


def test_phase_means_matches_study(node_setup):
    from repro.core import study

    _, ms = _minibatch(node_setup, steps=3)
    assert study.host_phase_means(ms) == phase_means(ms)
    pm = phase_means(ms)
    assert set(pm) == {"host_sample_time", "host_fetch_time",
                       "host_transfer_time", "host_compute_time",
                       "host_step_wall", "overlap_efficiency"}


# ---------------------------------------------------------------------------
# export round-trip (satellite 4)
# ---------------------------------------------------------------------------


def test_export_round_trip(tmp_path, node_setup):
    with tracing() as tr:
        _minibatch(node_setup, steps=2)
    path = tmp_path / "trace.json"
    payload = write_trace(str(path), tr)
    assert validate_chrome_trace(payload) == []
    loaded = load_trace(str(path))
    assert loaded["otherData"]["schema"] == TRACE_SCHEMA
    events = loaded["traceEvents"]
    # every B paired with an E, per (pid, tid)
    open_stacks = {}
    for e in events:
        key = (e["pid"], e["tid"])
        if e["ph"] == "B":
            open_stacks.setdefault(key, []).append(e["name"])
        elif e["ph"] == "E":
            assert e["name"] in open_stacks.get(key, [])
            open_stacks[key].remove(e["name"])
    assert all(not v for v in open_stacks.values())
    # per-track timestamps monotonic non-decreasing
    last = {}
    for e in events:
        if e["ph"] == "M":
            continue
        key = (e["pid"], e["tid"])
        assert e["ts"] >= last.get(key, 0.0)
        last[key] = e["ts"]


def test_export_merges_tracers_and_clocks():
    t1 = Tracer()
    t1.record_span("a", 1.0, 2.0, cat="x")
    t2 = Tracer()
    t2.record_span("b", 5.0, 6.0, cat="x", clock="model", track="sim")
    t2.add("wire", 7, t=5.5, track="wire")
    payload = to_chrome_trace([t1, t2])
    assert validate_chrome_trace(payload) == []
    by_ph = {}
    for e in payload["traceEvents"]:
        by_ph.setdefault(e["ph"], []).append(e)
    pids = {e["pid"] for e in by_ph["B"]}
    assert len(pids) == 2  # wall clock and model clock are separate pids
    assert by_ph["C"][0]["name"] == "wire"
    assert by_ph["C"][0]["args"] == {"value": 7.0}


def test_validator_flags_unpaired_and_nonmonotonic():
    bad = {"otherData": {"schema": TRACE_SCHEMA}, "traceEvents": [
        {"ph": "B", "name": "a", "pid": 1, "tid": 1, "ts": 2.0,
         "cat": "x", "args": {}},
        {"ph": "E", "name": "zzz", "pid": 1, "tid": 1, "ts": 1.0},
    ]}
    problems = validate_chrome_trace(bad)
    assert any("no open B" in p for p in problems)
    assert any("unclosed" in p for p in problems)
    assert any(" < " in p for p in problems)


# ---------------------------------------------------------------------------
# reconciliation (satellite 4: green fp32, seeded red path)
# ---------------------------------------------------------------------------


def test_reconcile_minibatch_fp32_exact(node_setup):
    with tracing() as tr:
        trainer, ms = _minibatch(node_setup, steps=3)
        checks = reconcile.reconcile_minibatch(trainer, ms, tracer=tr)
    by_q = {c.quantity: c for c in checks}
    assert by_q["fetch.wire_bytes"].level == "ok"
    assert by_q["fetch.wire_bytes"].tol_rel == 0.0  # bitwise contract
    assert by_q["fetch.miss_bytes"].level == "ok"
    assert by_q["phase.closure"].level == "ok"
    assert all(c.level != "error" for c in checks)


def test_reconcile_minibatch_int8_ratio(node_setup):
    with tracing() as tr:
        trainer, ms = _minibatch(node_setup, codec="int8", steps=3)
        checks = reconcile.reconcile_minibatch(trainer, ms, tracer=tr)
    by_q = {c.quantity: c for c in checks}
    assert by_q["fetch.wire_bytes"].level == "ok"  # still exact: formula
    assert by_q["fetch.wire_ratio"].level == "ok"  # ~0.25 + meta slack
    assert abs(by_q["fetch.wire_ratio"].measured - 0.25) < 0.05


def test_reconcile_minibatch_overlap_skips_fetch(node_setup):
    """The prefetcher fetches ahead of consumption, so the pipelined
    engine's fetch/phase checks must warn-skip, never error."""
    with tracing() as tr:
        trainer, ms = _minibatch(node_setup, overlap=True, steps=2)
        checks = reconcile.reconcile_minibatch(trainer, ms, tracer=tr)
    by_q = {c.quantity: c for c in checks}
    assert by_q["fetch.wire_bytes"].level == "warn"
    assert by_q["phase.closure"].level == "warn"
    assert reconcile.build_report(checks).exit_code == 0


def test_reconcile_injected_byte_is_an_error(node_setup):
    """The seeded red path: one stray byte through the real measured
    counter must flip the exact check and the report's exit code."""
    with tracing() as tr:
        trainer, ms = _minibatch(node_setup, steps=2)
        tr.add("fetch.wire_bytes", 1)
        checks = reconcile.reconcile_minibatch(trainer, ms, tracer=tr)
    by_q = {c.quantity: c for c in checks}
    assert by_q["fetch.wire_bytes"].level == "error"
    report = reconcile.build_report(checks)
    assert report.exit_code == 1
    assert report.counts["error"] == 1


@pytest.mark.parametrize("model", ["sage", "gat"])
def test_reconcile_fullbatch_halo_exact(node_setup, model):
    g, feats, labels, train = node_setup
    spec = GNNSpec(model=model, feature_dim=12, hidden_dim=8, num_classes=5,
                   num_layers=2)
    a = partition_edges(g, 4, "hep100", seed=0)
    with tracing() as tr:
        trainer = FullBatchTrainer.build(g, a, 4, spec, feats, labels,
                                         train, sync_mode="halo", mode="sim")
        trainer.train_step()
        checks = reconcile.reconcile_fullbatch(trainer, tracer=tr)
    by_q = {c.quantity: c for c in checks}
    assert by_q["sync.count.all-to-all"].level == "ok"
    assert by_q["sync.cluster_bytes.all-to-all"].level == "ok"
    assert by_q["sync.wire_bytes.forward"].level == "ok"
    assert by_q["epoch.wire_bytes"].level == "ok"
    # every full-batch byte check is bitwise for fp32
    assert all(c.tol_rel == 0.0 for c in checks)


def test_reconcile_fullbatch_requires_trace_before_compile(node_setup):
    """Installing the tracer after the step compiled yields a warn-level
    skip, never a silent pass."""
    g, feats, labels, train = node_setup
    spec = GNNSpec(model="sage", feature_dim=12, hidden_dim=8,
                   num_classes=5, num_layers=2)
    a = partition_edges(g, 2, "hep100", seed=0)
    trainer = FullBatchTrainer.build(g, a, 2, spec, feats, labels, train,
                                     sync_mode="halo", mode="sim")
    trainer.train_step()  # compiles untraced
    with tracing() as tr:
        trainer.train_step()  # cached executable: no trace, no events
        checks = reconcile.reconcile_fullbatch(trainer, tracer=tr)
    assert len(checks) == 1
    assert checks[0].level == "warn"


# ---------------------------------------------------------------------------
# serving breakdown columns (satellite 3) + serve reconciliation
# ---------------------------------------------------------------------------


def _serving_run(node_setup, requests=80):
    from repro.core.partition_book import build_vertex_book
    from repro.gnn.inference import LayerwiseInference
    from repro.gnn.models import init_params
    from repro.serve import build_serving, run_serving_sim

    g, feats, _, _ = node_setup
    spec = GNNSpec(model="sage", feature_dim=12, hidden_dim=8,
                   num_classes=5, num_layers=2)
    params = init_params(spec, seed=0)
    a = partition_edges(g, 2, "hep100", seed=0)
    eng = LayerwiseInference.build(g, a, 2, spec, params, feats)
    embeddings = eng.run()
    owner = eng.book.master_assignment()
    vbook = build_vertex_book(g, owner, 2)
    engines, batchers, store = build_serving(
        g, vbook, spec, params, embeddings, hops=1, fanout=6, max_batch=8,
        max_wait=5e-4, seed=0)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, g.num_vertices, requests)
    arrivals = np.sort(rng.uniform(0.0, requests / 300.0, requests))
    report = run_serving_sim(engines, batchers, owner, ids, arrivals)
    return spec, report, store


def test_serve_result_row_breakdown_columns(node_setup):
    from repro.core import study

    spec, report, store = _serving_run(node_setup)
    row = study.serve_result_row(
        "OR", "hep100", 2, spec, report, qps=300.0, hops=1, fanout=6,
        max_batch=8, max_wait=5e-4, cache_policy="none", cache_budget=0,
        partition_time=0.0, partition_quality=1.0)
    for col in ("queue_wait_p50", "queue_wait_p99", "queue_wait_mean",
                "service_p50", "service_p99", "service_mean_req",
                "p99_queue_share"):
        assert col in row, col
    # queue wait + service == latency, so the breakdown means must close
    assert (row["queue_wait_mean"] + row["service_mean_req"]
            == pytest.approx(row["latency_mean"], rel=1e-9))
    assert 0.0 <= row["p99_queue_share"] <= 1.0


def test_reconcile_serving_exact(node_setup):
    with tracing() as tr:
        _, report, store = _serving_run(node_setup)
        checks = reconcile.reconcile_serving(report, store, tracer=tr)
    by_q = {c.quantity: c for c in checks}
    assert by_q["serve.fetch.wire_bytes"].level == "ok"
    assert by_q["serve.fetch.stats_wire_bytes"].level == "ok"
    assert by_q["serve.latency.closure"].level == "ok"
    # the model-clock spans carry the request lifecycle
    tracks = {s.track for s in tr.spans() if s.clock == "model"}
    assert any(t and t.endswith(".queue") for t in tracks)


# ---------------------------------------------------------------------------
# the CLI gate end to end (satellite 4 + acceptance)
# ---------------------------------------------------------------------------


def test_gnn_trace_cli_green_and_red(tmp_path):
    from repro.launch import gnn_trace

    out_trace = tmp_path / "t.json"
    out_json = tmp_path / "r.json"
    argv = ["--scale", "0.01", "--k", "2", "--steps", "1",
            "--requests", "30", "--out-trace", str(out_trace),
            "--out-json", str(out_json)]
    assert gnn_trace.main(argv) == 0
    report = json.loads(out_json.read_text())
    assert report["schema"] == "gnn-trace-report/v2"
    assert report["counts"]["error"] == 0
    assert set(report["programs"]) == {"fullbatch-halo", "fullbatch-ring",
                                       "minibatch", "serve"}
    loaded = load_trace(str(out_trace))
    assert loaded["otherData"]["schema"] == TRACE_SCHEMA

    assert gnn_trace.main(argv + ["--inject-violation"]) == 1
    report = json.loads(out_json.read_text())
    assert report["exit_code"] == 1
    bad = [c for c in report["checks"] if c["level"] == "error"]
    assert len(bad) == 1
    assert bad[0]["quantity"] == "fetch.wire_bytes"


def test_install_uninstall_restores_null():
    prev = get_tracer()
    t = install(Tracer())
    assert get_tracer() is t
    uninstall()
    assert get_tracer() is prev
    assert not get_tracer().enabled
