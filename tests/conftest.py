import numpy as np
import pytest

from repro.core.graph import paper_graph


@pytest.fixture(scope="session")
def small_graphs():
    """One small graph per paper category (session-cached)."""
    return {key: paper_graph(key, scale=0.01, seed=0) for key in
            ["HO", "DI", "EN", "EU", "OR"]}


@pytest.fixture(scope="session")
def or_graph():
    return paper_graph("OR", scale=0.02, seed=0)


@pytest.fixture()
def node_data(or_graph):
    rng = np.random.default_rng(0)
    g = or_graph
    feats = rng.normal(size=(g.num_vertices, 16)).astype(np.float32)
    labels = rng.integers(0, 5, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.3
    return feats, labels, train
