"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,v,f", [(257, 256, 128), (1024, 512, 256),
                                   (50, 256, 128), (2000, 768, 128)])
def test_segment_spmm_sweep(e, v, f, dtype):
    rng = np.random.default_rng(e + v + f)
    dst = rng.integers(0, v, e).astype(np.int32)
    msgs = rng.normal(size=(e, f)).astype(np.float32)
    order, local_dst, rows_p = ops.prepare_tiled_edges(dst, v)
    msgs_pad = np.concatenate([msgs, np.zeros((1, f), np.float32)])[order]
    out = ops.segment_spmm(
        jnp.asarray(msgs_pad, dtype), jnp.asarray(local_dst), rows_p,
        interpret=True,
    )
    expect = ref.segment_sum_ref(jnp.asarray(msgs, dtype), jnp.asarray(dst), v)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out[:v], np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol * 8,
    )


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,v,f", [(257, 256, 128), (1024, 512, 256),
                                   (50, 256, 4), (2000, 768, 128)])
def test_segment_reduce_max_sweep(e, v, f, dtype):
    """combiner="max" through the Pallas kernel (interpret) == the scatter
    `at[].max` oracle; rows with no edges are -inf under both. f=4 covers
    the GAT attention-score width (lane-padded tile)."""
    rng = np.random.default_rng(e + v + f)
    dst = rng.integers(0, v, e).astype(np.int32)
    msgs = rng.normal(size=(e, f)).astype(np.float32)
    order, local_dst, rows_p = ops.prepare_tiled_edges(dst, v)
    msgs_pad = np.concatenate([msgs, np.full((1, f), -np.inf, np.float32)])[order]
    expect = ref.segment_max_ref(jnp.asarray(msgs, dtype), jnp.asarray(dst), v)
    tol = 1e-6 if dtype == np.float32 else 2e-2
    for kw in ({"use_pallas": False}, {"interpret": True}):
        out = ops.segment_spmm(
            jnp.asarray(msgs_pad, dtype), jnp.asarray(local_dst), rows_p,
            combiner="max", **kw)
        np.testing.assert_allclose(
            np.asarray(out[:v], np.float32), np.asarray(expect, np.float32),
            rtol=tol, atol=tol * 8,
        )


@pytest.mark.parametrize("combiner", ["sum", "max"])
def test_segment_spmm_oracle_unpadded_num_rows(combiner):
    """Regression: the oracle path derived n_tiles by floor division and
    assumed divisibility, so a direct call with an UNPADDED num_rows
    silently mis-binned every edge of the trailing tiles. Both paths now
    derive the grid from tiled_shape and return [num_rows, F]."""
    rng = np.random.default_rng(5)
    e, v, f = 900, 300, 8  # 300 rows -> 2 tiles of 256; 300 // 256 == 1
    dst = rng.integers(0, v, e).astype(np.int32)
    msgs = rng.normal(size=(e, f)).astype(np.float32)
    order, local_dst, _ = ops.prepare_tiled_edges(dst, v)
    fill = 0.0 if combiner == "sum" else -np.inf
    msgs_pad = np.concatenate([msgs, np.full((1, f), fill, np.float32)])[order]
    ref_fn = ref.segment_sum_ref if combiner == "sum" else ref.segment_max_ref
    expect = ref_fn(jnp.asarray(msgs), jnp.asarray(dst), v)
    for kw in ({"use_pallas": False}, {"interpret": True}):
        out = ops.segment_spmm(
            jnp.asarray(msgs_pad), jnp.asarray(local_dst), v,  # unpadded!
            combiner=combiner, **kw)
        assert out.shape == (v, f)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)


def test_segment_spmm_layout_mismatch_fails_loudly():
    """An edge count that cannot split over the tile grid (layout built for
    a different num_rows/tile_v) must assert, not mis-bin silently."""
    msgs = jnp.zeros((3, 8), jnp.float32)  # 3 edges over 2 tiles of v=300
    local_dst = jnp.zeros((3,), jnp.int32)
    with pytest.raises(AssertionError, match="tiled layout mismatch"):
        ops.segment_spmm(msgs, local_dst, 300, use_pallas=False)


@pytest.mark.parametrize("fn", ["prepare_tiled_edges", "tiled_need_per_tile"])
def test_tiled_layout_rejects_out_of_range_dst(fn):
    """Regression: dst >= rows_padded grew the bincount past n_tiles and the
    trailing tiles' edges silently vanished from the aggregate. Both layout
    entry points now reject them; `valid`-masked bad edges stay allowed."""
    layout_fn = getattr(ops, fn)
    v = 100  # rows_padded = 256
    bad = np.array([0, 50, 600], np.int32)
    with pytest.raises(ValueError, match="dst out of range"):
        layout_fn(bad, v)
    with pytest.raises(ValueError, match="dst out of range"):
        layout_fn(np.array([-1, 3], np.int32), v)
    # masked out via `valid` -> accepted
    layout_fn(bad, v, valid=np.array([True, True, False]))
    # dst inside the padded range but past num_rows is an explicit padding
    # sink: allowed, lands in rows sliced off by the consumer
    layout_fn(np.array([0, 255], np.int32), v)


@pytest.mark.parametrize("tile_v,block_e", [(128, 256), (64, 128), (512, 512)])
def test_segment_spmm_nondefault_tiling(tile_v, block_e):
    """The oracle path must reconstruct global dst ids with the SAME tiling
    the layout was built with (regression: it hardcoded DEFAULT_TILE_V)."""
    rng = np.random.default_rng(tile_v + block_e)
    e, v, f = 900, 700, 32
    dst = rng.integers(0, v, e).astype(np.int32)
    msgs = rng.normal(size=(e, f)).astype(np.float32)
    order, local_dst, rows_p = ops.prepare_tiled_edges(
        dst, v, tile_v=tile_v, block_e=block_e)
    msgs_pad = np.concatenate([msgs, np.zeros((1, f), np.float32)])[order]
    expect = ref.segment_sum_ref(jnp.asarray(msgs), jnp.asarray(dst), v)
    for kw in ({"use_pallas": False}, {"interpret": True}):
        out = ops.segment_spmm(
            jnp.asarray(msgs_pad), jnp.asarray(local_dst), rows_p,
            tile_v=tile_v, block_e=block_e, **kw)
        np.testing.assert_allclose(np.asarray(out[:v]), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("case", ["empty_tiles", "ragged_e", "tiny_rows"])
def test_prepare_tiled_edges_ragged(case):
    """Layout pass corner cases: row tiles with no edges, edge counts that
    don't divide block_e, and fewer rows than one tile."""
    rng = np.random.default_rng(0)
    f = 16
    if case == "empty_tiles":
        v, e = 1024, 300
        dst = rng.integers(0, 128, e).astype(np.int32)  # tiles 1..3 empty
    elif case == "ragged_e":
        v, e = 512, 515  # not a multiple of any block size
        dst = rng.integers(0, v, e).astype(np.int32)
    else:
        v, e = 7, 40  # num_rows < tile_v
        dst = rng.integers(0, v, e).astype(np.int32)
    msgs = rng.normal(size=(e, f)).astype(np.float32)
    order, local_dst, rows_p = ops.prepare_tiled_edges(dst, v)
    assert rows_p % ops.DEFAULT_TILE_V == 0 and rows_p >= v
    assert order.shape == local_dst.shape
    assert (local_dst <= ops.DEFAULT_TILE_V).all()
    msgs_pad = np.concatenate([msgs, np.zeros((1, f), np.float32)])[order]
    expect = ref.segment_sum_ref(jnp.asarray(msgs), jnp.asarray(dst), v)
    for kw in ({"use_pallas": False}, {"interpret": True}):
        out = ops.segment_spmm(
            jnp.asarray(msgs_pad), jnp.asarray(local_dst), rows_p, **kw)
        np.testing.assert_allclose(np.asarray(out[:v]), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)


def test_prepare_tiled_edges_valid_mask_and_per_tile():
    """`valid` drops (zero-message) edges from the layout; `per_tile` forces
    a shared static shape."""
    rng = np.random.default_rng(3)
    v, e = 300, 400
    dst = rng.integers(0, v, e).astype(np.int32)
    valid = rng.random(e) < 0.5
    order, local_dst, rows_p = ops.prepare_tiled_edges(
        dst, v, per_tile=1024, valid=valid)
    n_tiles = rows_p // ops.DEFAULT_TILE_V
    assert order.shape[0] == n_tiles * 1024
    kept = order[order < e]
    assert sorted(kept) == sorted(np.where(valid)[0])
    msgs = rng.normal(size=(e, 8)).astype(np.float32)
    msgs_pad = np.concatenate([msgs, np.zeros((1, 8), np.float32)])[order]
    out = ops.segment_spmm(
        jnp.asarray(msgs_pad), jnp.asarray(local_dst), rows_p,
        use_pallas=False)
    expect = ref.segment_sum_ref(
        jnp.asarray(msgs * valid[:, None]), jnp.asarray(dst), v)
    np.testing.assert_allclose(np.asarray(out[:v]), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,sq,skv,d", [
    (1, 2, 256, 256, 64),
    (2, 1, 512, 512, 128),
    (1, 2, 256, 1024, 64),   # cross-ish (longer kv)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, sq, skv, d, dtype, causal):
    if causal and sq != skv:
        pytest.skip("causal requires square for this contract")
    rng = np.random.default_rng(b * h + sq + d)
    q = jnp.asarray(rng.normal(size=(b, h, sq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, h, skv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, h, skv, d)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol * 5,
    )


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,s,d,valid", [
    (1, 2, 1024, 64, 700),
    (2, 4, 2048, 128, 2048),
    (1, 1, 1024, 64, 1),
])
def test_decode_attention_sweep(b, h, s, d, valid, dtype):
    rng = np.random.default_rng(s + d + valid)
    q = jnp.asarray(rng.normal(size=(b, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype)
    out = ops.decode_attention(q, k, v, jnp.asarray(valid), interpret=True)
    expect = ref.decode_attention_ref(q, k, v, valid)
    tol = 2e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol * 5,
    )


def test_flash_custom_vjp_grads_match_reference():
    """The pure-JAX flash path (models.layers.attention) must produce the
    same gradients as direct-softmax autodiff."""
    from repro.models import layers as L

    rng = np.random.default_rng(0)
    B, H, S, D = 1, 2, 2048, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)

    def loss_flash(q, k, v):
        return (L.attention(q, k, v, causal=True, block_q=256, block_k=512) ** 2).sum()

    def loss_ref(q, k, v):
        return (ref.flash_attention_ref(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_ssd_chunked_matches_sequential():
    """SSD chunked scan == naive per-token recurrence."""
    from repro.models.layers import ssd_chunked, ssd_decode_step

    rng = np.random.default_rng(1)
    b, s, h, p, g, n = 2, 64, 4, 8, 2, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)

    y_chunk, final = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)

    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y_t, state = ssd_decode_step(
            x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], state)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               rtol=2e-4, atol=2e-4)


def test_moe_matches_dense_reference():
    """Capacity-bucketed MoE == dense per-expert computation (no drops)."""
    from repro.models import layers as L

    rng = np.random.default_rng(0)
    B, S, d, E, f, k = 2, 16, 8, 4, 12, 2
    p = {"router": jnp.asarray(rng.normal(size=(d, E)), jnp.float32),
         "w1": jnp.asarray(rng.normal(size=(E, d, f)), jnp.float32) * 0.1,
         "w3": jnp.asarray(rng.normal(size=(E, d, f)), jnp.float32) * 0.1,
         "w2": jnp.asarray(rng.normal(size=(E, f, d)), jnp.float32) * 0.1}
    x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    out, aux = L.moe_ffn(p, x, top_k=k, capacity_factor=100.0)

    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, k)
    vals = vals / vals.sum(-1, keepdims=True)
    expect = jnp.zeros_like(x)
    for e in range(E):
        ye = (jax.nn.silu(x @ p["w1"][e]) * (x @ p["w3"][e])) @ p["w2"][e]
        w = jnp.where(idx == e, vals, 0).sum(-1)
        expect = expect + ye * w[..., None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    assert float(aux) > 0
