"""Integrity checks over the committed dry-run artifact (the multi-pod
deliverable): every required cell present, compiled, and within HBM."""

import json
import os

import pytest

RESULTS = "/root/repo/dryrun_results.json"

pytestmark = pytest.mark.skipif(
    not os.path.exists(RESULTS),
    reason="dryrun_results.json not generated yet "
           "(python -m repro.launch.dryrun --all --both-meshes)",
)


def _load():
    with open(RESULTS) as f:
        return json.load(f)


def test_all_required_cells_present_and_clean():
    from repro.configs.base import list_archs, shape_cells

    d = _load()
    missing, errors = [], []
    for mesh in ["16x16", "2x16x16"]:
        for arch in list_archs():
            for sh in shape_cells(arch):
                key = f"{arch}|{sh}|{mesh}"
                if key not in d:
                    missing.append(key)
                elif "error" in d[key]:
                    errors.append(key)
    assert not missing, missing
    assert not errors, errors


def test_every_cell_fits_hbm():
    d = _load()
    over = [
        k for k, v in d.items()
        if "error" not in v and "bytes_per_device" in v
        and v["bytes_per_device"]["peak"] > 16 * 2**30
    ]
    assert not over, over


def test_roofline_terms_positive_and_consistent():
    d = _load()
    for k, v in d.items():
        if "error" in v or "roofline" not in v:
            continue
        r = v["roofline"]
        assert r["compute_s"] >= 0 and r["memory_s"] >= 0
        assert r["collective_s"] >= 0
        assert r["bound_s"] == pytest.approx(
            max(r["compute_s"], r["memory_s"], r["collective_s"]), rel=1e-6)
        assert r["dominant"].replace("_s", "") in ("compute", "memory", "collective")


def test_multipod_pod_axis_engaged():
    """The 2x16x16 cells must actually spread over 512 devices."""
    d = _load()
    mp = [v for k, v in d.items()
          if v.get("mesh") == "2x16x16" and "error" not in v]
    assert mp and all(v["devices"] == 512 for v in mp)


def test_optimized_variants_beat_baseline():
    """§Perf: the persisted fsdp variants must have a lower collective term
    than their tp_sp baselines (the confirmed H1 hypothesis)."""
    d = _load()
    for arch in ["mamba2-370m", "yi-6b", "deepseek-moe-16b"]:
        base = d.get(f"{arch}|train_4k|16x16")
        opt = d.get(f"{arch}|train_4k|16x16|fsdp")
        if base is None or opt is None:
            pytest.skip("optimized variants not generated")
        assert opt["roofline"]["collective_s"] < base["roofline"]["collective_s"]
        assert opt["roofline"]["bound_s"] < base["roofline"]["bound_s"]
