"""The tentpole's correctness gate: the tiled/pallas aggregation backends
must match the scatter oracle — values AND gradients — standalone, under
vmap, and end-to-end through both trainers. (The shard_map leg lives in
test_dist_lowering.py, which needs forced host devices.)"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.edge_partition import partition_edges
from repro.core.vertex_partition import partition_vertices
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.minibatch import MiniBatchTrainer
from repro.gnn.models import GNNSpec
from repro.kernels import ops, ref


def _layout(dst, num_rows, **kw):
    order, ldst, _ = ops.prepare_tiled_edges(dst, num_rows, **kw)
    return jnp.asarray(order), jnp.asarray(ldst)


@pytest.mark.parametrize("backend", ["tiled", "pallas"])
@pytest.mark.parametrize("e,v,f", [(700, 300, 16), (257, 256, 128), (64, 1000, 8)])
def test_aggregate_matches_scatter(e, v, f, backend):
    rng = np.random.default_rng(e + v)
    dst = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    msgs = jnp.asarray(rng.normal(size=(e, f)).astype(np.float32))
    order, ldst = _layout(np.asarray(dst), v)
    expect = ops.aggregate(msgs, dst, v, backend="scatter")
    np.testing.assert_allclose(
        np.asarray(expect),
        np.asarray(ref.segment_sum_ref(msgs, dst, v)), rtol=1e-6, atol=1e-6)
    out = ops.aggregate(msgs, dst, v, edge_order=order, local_dst=ldst,
                        backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["tiled", "pallas"])
@pytest.mark.parametrize("e,v,f", [(700, 300, 16), (257, 256, 4), (64, 1000, 8)])
def test_aggregate_max_matches_scatter(e, v, f, backend):
    """reduce="max" through the tiled segment-reduce == the `at[].max`
    scatter oracle (rows with no edges are -inf under both)."""
    rng = np.random.default_rng(e + v)
    dst = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    msgs = jnp.asarray(rng.normal(size=(e, f)).astype(np.float32))
    order, ldst = _layout(np.asarray(dst), v)
    expect = ops.aggregate(msgs, dst, v, backend="scatter", reduce="max")
    np.testing.assert_allclose(
        np.asarray(expect),
        np.asarray(ref.segment_max_ref(msgs, dst, v)), rtol=1e-6, atol=1e-6)
    out = ops.aggregate(msgs, dst, v, edge_order=order, local_dst=ldst,
                        backend=backend, reduce="max")
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["tiled", "pallas"])
def test_aggregate_max_grads_match_scatter(backend):
    """The masked-argmax-gather vjp of the standalone segment-max == the
    scatter-max autodiff (every row covered, continuous data -> no ties,
    so the max is differentiable)."""
    rng = np.random.default_rng(0)
    e, v, f = 500, 200, 16
    dst = np.concatenate([np.arange(v), rng.integers(0, v, e - v)])
    dst = jnp.asarray(dst.astype(np.int32))
    msgs = jnp.asarray(rng.normal(size=(e, f)).astype(np.float32))
    order, ldst = _layout(np.asarray(dst), v)

    def loss(m, bk, **kw):
        return (ops.aggregate(m, dst, v, backend=bk, reduce="max",
                              **kw) ** 2).sum()

    g_ref = jax.grad(loss)(msgs, "scatter")
    g = jax.grad(loss)(msgs, backend, edge_order=order, local_dst=ldst)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["tiled", "pallas"])
def test_aggregate_max_tie_grads_split_like_scatter(backend):
    """On TIED maxima the vjp must follow the scatter oracle's even-split
    subgradient convention (regression: the tiled vjp used to hand every
    tied edge the full cotangent, doubling the gradient)."""
    dst = jnp.asarray(np.array([0, 0, 0, 1], np.int32))
    msgs = jnp.asarray(np.array(
        [[2.0], [2.0], [1.0], [5.0]], np.float32))  # edges 0,1 tie on row 0
    order, ldst = _layout(np.asarray(dst), 2)

    def loss(m, bk, **kw):
        return ops.aggregate(m, dst, 2, backend=bk, reduce="max", **kw).sum()

    g_ref = jax.grad(loss)(msgs, "scatter")
    g = jax.grad(loss)(msgs, backend, edge_order=order, local_dst=ldst)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(g),
                               [[0.5], [0.5], [0.0], [1.0]])


def test_aggregate_max_grad_ignores_dropped_tied_edge():
    """Regression: a `valid`-dropped edge whose message ties the surviving
    row max is NOT part of the computed max — it must get zero cotangent
    and must not deflate the survivors' tie split (the bwd used to compute
    the argmax mask over all edges but count ties over the layout only,
    leaking non-conservative gradient mass)."""
    dst = np.array([0, 0, 1], np.int32)
    msgs = jnp.asarray(np.array([[2.0], [2.0], [5.0]], np.float32))
    order, ldst, _ = ops.prepare_tiled_edges(
        dst, 2, valid=np.array([True, False, True]))

    def loss(m):
        return ops.aggregate(
            m, jnp.asarray(dst), 2, edge_order=jnp.asarray(order),
            local_dst=jnp.asarray(ldst), backend="tiled", reduce="max").sum()

    g = jax.grad(loss)(msgs)
    # edge 1 was dropped: the surviving argmax of row 0 is edge 0 alone
    np.testing.assert_allclose(np.asarray(g), [[1.0], [0.0], [1.0]])


def test_aggregate_max_under_vmap():
    rng = np.random.default_rng(1)
    k, e, v, f = 3, 400, 150, 8
    dst = rng.integers(0, v, (k, e)).astype(np.int32)
    msgs = rng.normal(size=(k, e, f)).astype(np.float32)
    per_tile = max(ops.prepare_tiled_edges(dst[p], v)[0].shape[0]
                   for p in range(k)) // ops.tiled_shape(v)[1]
    layouts = [ops.prepare_tiled_edges(dst[p], v, per_tile=per_tile)[:2]
               for p in range(k)]
    args = (jnp.asarray(msgs), jnp.asarray(dst),
            jnp.asarray(np.stack([o for o, _ in layouts])),
            jnp.asarray(np.stack([l for _, l in layouts])))
    expect = jax.vmap(lambda m, d: ops.aggregate(
        m, d, v, backend="scatter", reduce="max"))(args[0], args[1])
    out = jax.vmap(lambda m, d, o, l: ops.aggregate(
        m, d, v, edge_order=o, local_dst=l, backend="tiled", reduce="max"))(
        *args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["tiled", "pallas"])
def test_aggregate_grads_match_scatter(backend):
    rng = np.random.default_rng(0)
    e, v, f = 500, 200, 16
    dst = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    msgs = jnp.asarray(rng.normal(size=(e, f)).astype(np.float32))
    order, ldst = _layout(np.asarray(dst), v)

    def loss(m, bk, **kw):
        return (ops.aggregate(m, dst, v, backend=bk, **kw) ** 2).sum()

    g_ref = jax.grad(loss)(msgs, "scatter")
    g = jax.grad(loss)(msgs, backend, edge_order=order, local_dst=ldst)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-5)


def test_aggregate_under_vmap():
    rng = np.random.default_rng(1)
    k, e, v, f = 3, 400, 150, 8
    dst = rng.integers(0, v, (k, e)).astype(np.int32)
    msgs = rng.normal(size=(k, e, f)).astype(np.float32)
    orders, ldsts = [], []
    n_tiles = max(-(-v // ops.DEFAULT_TILE_V), 1)
    per_tile = 0
    for p in range(k):  # uniform static shape across the stacked layouts
        eo, _, _ = ops.prepare_tiled_edges(dst[p], v)
        per_tile = max(per_tile, eo.shape[0] // n_tiles)
    for p in range(k):
        eo, ld, _ = ops.prepare_tiled_edges(dst[p], v, per_tile=per_tile)
        orders.append(eo)
        ldsts.append(ld)

    def agg(bk):
        def fn(m, d, o, l):
            return ops.aggregate(m, d, v, edge_order=o, local_dst=l, backend=bk)
        return jax.vmap(fn)

    args = (jnp.asarray(msgs), jnp.asarray(dst),
            jnp.asarray(np.stack(orders)), jnp.asarray(np.stack(ldsts)))
    expect = jax.vmap(lambda m, d: ops.aggregate(m, d, v, backend="scatter"))(
        args[0], args[1])
    np.testing.assert_allclose(np.asarray(agg("tiled")(*args)),
                               np.asarray(expect), rtol=1e-5, atol=1e-5)

    # gradients under vmap
    def loss(bk):
        def fn(m, d, o, l):
            return (ops.aggregate(m, d, v, edge_order=o, local_dst=l,
                                  backend=bk) ** 2).sum()
        return jax.vmap(jax.grad(fn))
    g_ref = jax.vmap(jax.grad(
        lambda m, d: (ops.aggregate(m, d, v, backend="scatter") ** 2).sum()
    ))(args[0], args[1])
    np.testing.assert_allclose(np.asarray(loss("tiled")(*args)),
                               np.asarray(g_ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end: trainers with backend="tiled" == the scatter oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["sage", "gcn"])
@pytest.mark.parametrize("k", [1, 4])
def test_fullbatch_tiled_matches_scatter(or_graph, node_data, model, k):
    feats, labels, train = node_data
    spec = GNNSpec(model=model, feature_dim=16, hidden_dim=8, num_classes=5)
    asg = (np.zeros(or_graph.num_edges, np.int32) if k == 1
           else partition_edges(or_graph, k, "hdrf", seed=1))
    trainers = {}
    for backend in ("scatter", "tiled"):
        tr = FullBatchTrainer.build(
            or_graph, asg, k, dataclasses.replace(spec, agg_backend=backend),
            feats, labels, train, seed=7)
        losses = [tr.train_step() for _ in range(3)]
        trainers[backend] = (tr, losses)
    # training trajectories (loss after adam steps => gradients) must agree
    np.testing.assert_allclose(trainers["tiled"][1], trainers["scatter"][1],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        trainers["tiled"][0].forward_logits_global(),
        trainers["scatter"][0].forward_logits_global(),
        rtol=1e-5, atol=1e-5)


def test_fullbatch_gat_tiled_matches_scatter(or_graph, node_data):
    """GAT routes ALL its edge reductions — softmax num/den sums AND the
    stabilisation segment-max — through aggregate; the trajectories (loss
    after an adam step => gradients too) must match the scatter oracle."""
    feats, labels, train = node_data
    spec = GNNSpec(model="gat", feature_dim=16, hidden_dim=8, num_classes=5)
    asg = partition_edges(or_graph, 4, "hdrf", seed=1)
    logits, losses = {}, {}
    for backend in ("scatter", "tiled"):
        tr = FullBatchTrainer.build(
            or_graph, asg, 4, dataclasses.replace(spec, agg_backend=backend),
            feats, labels, train, seed=7)
        losses[backend] = [tr.train_step() for _ in range(2)]
        logits[backend] = tr.forward_logits_global()
    np.testing.assert_allclose(losses["tiled"], losses["scatter"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(logits["tiled"], logits["scatter"],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("model", ["sage", "gat"])
def test_fullbatch_pallas_backend_smoke(or_graph, node_data, model):
    """backend="pallas" (interpreted on CPU) stays numerically exact
    end-to-end (gat also runs the max kernel); one small forward keeps
    this affordable in CI."""
    feats, labels, train = node_data
    spec = GNNSpec(model=model, feature_dim=16, hidden_dim=8, num_classes=5)
    asg = np.zeros(or_graph.num_edges, np.int32)
    out = {}
    for backend in ("scatter", "pallas"):
        tr = FullBatchTrainer.build(
            or_graph, asg, 1, dataclasses.replace(spec, agg_backend=backend),
            feats, labels, train, seed=7)
        out[backend] = tr.forward_logits_global()
    np.testing.assert_allclose(out["pallas"], out["scatter"],
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# acceptance: no data-dependent scatter remains on the GAT hot path
# ---------------------------------------------------------------------------


def test_gat_forward_scatter_free_when_not_scatter(or_graph, node_data):
    """With agg_backend="pallas" the traced GAT forward contains NO
    data-dependent scatter-add/scatter-max — every O(E) edge reduction runs
    through the tiled kernel. Scope: the "tiled" backend off-TPU
    legitimately falls back to the jnp scatter oracle (on TPU it lowers to
    the same kernel as "pallas"), and with k>1 the replica sync still
    scatters into its bucket-sized halo buffers (O(replicas), the network
    path) — hence k=1/LocalSync here, which isolates the edge hot path.
    The walk + expectation live in `repro.analysis` (the gnn_lint
    no-scatter rule); this test pins the rule to these exact traces."""
    from repro.analysis import check_scatter
    from repro.gnn import models
    from repro.gnn.sync import LocalSync

    feats, labels, train = node_data
    spec = GNNSpec(model="gat", feature_dim=16, hidden_dim=8, num_classes=5,
                   agg_backend="pallas")
    tr = FullBatchTrainer.build(
        or_graph, np.zeros(or_graph.num_edges, np.int32), 1, spec,
        feats, labels, train, seed=7)
    blk = jax.tree.map(lambda a: a[0], tr.blocks)
    jaxpr = jax.make_jaxpr(
        lambda params, x: models.forward(spec, params, x, blk, LocalSync())
    )(tr.params, blk.x)
    assert check_scatter([jaxpr], expect_free=True) is None

    # the scatter oracle, traced the same way, DOES contain both — the
    # anchor direction (expect_free=False) holds, so the assertion above
    # is meaningful
    spec_sc = dataclasses.replace(spec, agg_backend="scatter")
    jaxpr_sc = jax.make_jaxpr(
        lambda params, x: models.forward(spec_sc, params, x, blk, LocalSync())
    )(tr.params, blk.x)
    assert check_scatter([jaxpr_sc], expect_free=False) is None
    # and the walker misreports neither direction
    assert check_scatter([jaxpr_sc], expect_free=True) is not None


def test_minibatch_gat_forward_scatter_free_when_not_scatter(
        or_graph, node_data):
    """Same acceptance gate for the mini-batch GAT layer stack."""
    from repro.analysis import check_scatter
    from repro.gnn.minibatch import minibatch_loss

    feats, labels, train = node_data
    owner = partition_vertices(or_graph, 4, "metis", seed=0)
    spec = GNNSpec(model="gat", feature_dim=16, hidden_dim=8, num_classes=5,
                   agg_backend="pallas")
    tr = MiniBatchTrainer.build(
        or_graph, owner, 4, spec, feats, labels, train,
        global_batch=64, seed=3)
    # pallas != scatter => the engine's preparer attaches the tiled layout
    pb = tr.engine.preparer.prepare()
    batch0 = jax.tree.map(lambda a: a[0], pb.stacked)
    sizes = tuple(tr._layer_sizes)
    jaxpr = jax.make_jaxpr(
        lambda params: minibatch_loss(spec, params, batch0, sizes, axis=None)
    )(tr.params)
    assert check_scatter([jaxpr], expect_free=True) is None


@pytest.mark.parametrize("model", ["sage", "gat"])
def test_minibatch_tiled_matches_scatter(or_graph, node_data, model):
    feats, labels, train = node_data
    owner = partition_vertices(or_graph, 4, "metis", seed=0)
    spec = GNNSpec(model=model, feature_dim=16, hidden_dim=8, num_classes=5)
    results = {}
    for backend in ("scatter", "tiled"):
        tr = MiniBatchTrainer.build(
            or_graph, owner, 4, dataclasses.replace(spec, agg_backend=backend),
            feats, labels, train, global_batch=64, seed=3)
        losses = [tr.train_step().loss for _ in range(3)]
        results[backend] = (losses, tr.params)
    np.testing.assert_allclose(results["tiled"][0], results["scatter"][0],
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(results["tiled"][1]),
                    jax.tree.leaves(results["scatter"][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
