"""Fallback for `hypothesis` so the property tests collect and run offline.

When hypothesis is installed, this module re-exports the real engine
unchanged. Otherwise it provides the tiny API surface the test suite uses
(`given`, `settings`, `st.integers`, `st.sampled_from`) with a deterministic
sampler: each test runs `max_examples` pseudo-random examples drawn from a
generator seeded by the test's qualified name — no shrinking, no database,
but the invariants still get exercised on every platform.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import zlib

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

    st = _Strategies()

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    drawn = {name: s.draw(rng) for name, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest follows __wrapped__ to the original signature and would
            # try to resolve the strategy parameters as fixtures — hide it.
            del wrapper.__wrapped__
            return wrapper

        return deco

    def settings(max_examples=10, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco
