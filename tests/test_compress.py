"""int8 gradient compression with error feedback (optim/compress.py).

Groundwork for the compressed-communication roadmap item: the quantiser's
per-tensor scale bounds the roundtrip error, and the error-feedback
accumulator carries the residual so repeated compression does not bias the
running gradient sum.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compress import (
    compress,
    compress_init,
    compressed_psum,
    decompress,
)


def _tree(rng, scale=1.0):
    return {
        "w": jnp.asarray(rng.normal(size=(32, 16)) * scale, jnp.float32),
        "b": jnp.asarray(rng.normal(size=(16,)) * scale, jnp.float32),
    }


def test_roundtrip_tolerance():
    """One compress/decompress roundtrip is within half a quantisation bin:
    |x - deq(q(x))| <= scale/2 = max|x| / 254 per tensor."""
    rng = np.random.default_rng(0)
    grads = _tree(rng)
    qs, scales, _ = compress(grads, compress_init(grads))
    deq = decompress(qs, scales)
    for key in grads:
        g = np.asarray(grads[key])
        bound = np.abs(g).max() / 127.0 / 2.0 + 1e-7
        err = np.abs(np.asarray(deq[key]) - g).max()
        assert err <= bound, (key, err, bound)


def test_roundtrip_dtypes_and_scale_positivity():
    rng = np.random.default_rng(1)
    grads = _tree(rng, scale=1e-3)
    qs, scales, state = compress(grads, compress_init(grads))
    for key in grads:
        assert np.asarray(qs[key]).dtype == np.int8
        assert float(np.asarray(scales[key])) > 0.0
        assert np.asarray(state.error[key]).shape == grads[key].shape


def test_zero_gradient_is_exact():
    grads = {"w": jnp.zeros((8, 8), jnp.float32)}
    qs, scales, state = compress(grads, compress_init(grads))
    deq = decompress(qs, scales)
    np.testing.assert_array_equal(np.asarray(deq["w"]), 0.0)
    np.testing.assert_array_equal(np.asarray(state.error["w"]), 0.0)


def test_error_feedback_accumulator_reduces_bias():
    """Feeding the SAME gradient repeatedly: with error feedback the running
    mean of dequantised outputs converges to the true gradient (residual is
    carried, not dropped), so the accumulated bias is strictly smaller than
    the no-feedback quantiser's."""
    rng = np.random.default_rng(2)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    steps = 32

    state = compress_init(g)
    total_fb = np.zeros(64)
    total_nofb = np.zeros(64)
    for _ in range(steps):
        qs, scales, state = compress(g, state)
        total_fb += np.asarray(decompress(qs, scales)["w"])
        qs0, scales0, _ = compress(g, compress_init(g))
        total_nofb += np.asarray(decompress(qs0, scales0)["w"])

    true = np.asarray(g["w"]) * steps
    err_fb = np.abs(total_fb - true).max()
    err_nofb = np.abs(total_nofb - true).max()
    # error feedback keeps the accumulated error bounded by ~one bin, while
    # the no-feedback error grows linearly in steps (same sign each step)
    one_bin = np.abs(np.asarray(g["w"])).max() / 127.0
    assert err_fb <= 2 * one_bin, (err_fb, one_bin)
    assert err_fb < err_nofb, (err_fb, err_nofb)


def test_error_state_carried_across_steps():
    """The residual of step t shows up in step t+1's quantisation input."""
    g = {"w": jnp.asarray([0.4, -0.7, 1.0], jnp.float32)}
    state0 = compress_init(g)
    qs, scales, state1 = compress(g, state0)
    resid = np.asarray(g["w"]) - (
        np.asarray(qs["w"]).astype(np.float32) * float(np.asarray(scales["w"]))
    )
    np.testing.assert_allclose(np.asarray(state1.error["w"]), resid,
                               rtol=1e-6, atol=1e-7)
    # second step quantises g + residual, so its residual differs unless the
    # residual was exactly zero
    _, _, state2 = compress(g, state1)
    assert not np.allclose(np.asarray(state2.error["w"]),
                           np.asarray(state1.error["w"]), atol=1e-9) or \
        np.allclose(resid, 0.0, atol=1e-9)


def test_compressed_psum_under_vmap():
    """compressed_psum == pmean of the dequantised views, per-worker error
    states kept independent — the data-parallel wiring the roadmap's
    cross-pod compression uses."""
    k = 4
    rng = np.random.default_rng(3)
    grads = {"w": jnp.asarray(rng.normal(size=(k, 16)), jnp.float32)}
    states = jax.vmap(lambda g: compress_init({"w": g}))(grads["w"])

    def per_worker(g, err):
        state = type(states)(error={"w": err})
        summed, new_state = compressed_psum({"w": g}, state, "dp")
        return summed["w"], new_state.error["w"]

    mean, new_err = jax.vmap(per_worker, axis_name="dp")(
        grads["w"], states.error["w"])
    # every worker holds the same mean
    np.testing.assert_allclose(np.asarray(mean[0]), np.asarray(mean[-1]),
                               rtol=1e-6, atol=1e-7)
    true_mean = np.asarray(grads["w"]).mean(axis=0)
    bin_bound = np.abs(np.asarray(grads["w"])).max() / 127.0
    assert np.abs(np.asarray(mean[0]) - true_mean).max() <= bin_bound
    # error states stay per-worker (not collectively reduced)
    assert np.asarray(new_err).shape == (k, 16)
