"""Deterministic synthetic token pipeline (LM training substrate).

Markov-chain corpus with a power-law unigram distribution — enough structure
that the loss demonstrably falls during the example runs, fully deterministic
per (seed, step) so restarts resume mid-epoch exactly (the iterator is
stateless: batch i is a pure function of (seed, i), the fault-tolerance
property a production data pipeline needs).
"""

from __future__ import annotations

import numpy as np


class SyntheticTokens:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, order_states: int = 512):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        rng = np.random.default_rng(seed)
        # hidden Markov transition over a reduced state space, projected to
        # the vocab with a power-law emission
        self.n_states = min(order_states, vocab_size)
        self.trans = rng.dirichlet(
            np.full(self.n_states, 0.1), size=self.n_states
        ).astype(np.float32)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        zipf = 1.0 / ranks ** 1.1
        self.emit_base = (zipf / zipf.sum()).astype(np.float64)

    def batch(self, step: int) -> dict:
        """Batch `step` as {tokens: [B, S] int32} — pure function of inputs."""
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        states = rng.integers(0, self.n_states, size=b)
        out = np.empty((b, s), dtype=np.int32)
        # vectorised over batch: one transition draw per position
        for t in range(s):
            u = rng.random(b)
            cdf = np.cumsum(self.trans[states], axis=1)
            states = (u[:, None] < cdf).argmax(axis=1)
            # emission: state biases a contiguous vocab bucket
            bucket = (states * (self.vocab_size // self.n_states)) % self.vocab_size
            offset = rng.choice(
                min(self.vocab_size, 1024), size=b, p=None
            )
            out[:, t] = (bucket + offset) % self.vocab_size
        return {"tokens": out}
