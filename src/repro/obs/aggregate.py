"""Shared span/metric aggregation helpers.

One home for the reductions that used to be re-implemented per consumer:
``core.study.host_phase_means``, ``benchmarks/fig19_phase_times.py`` and
``benchmarks/roofline.py --smoke`` all reduce per-step phase walls to the
same six-column summary — they now call :func:`phase_means` here, and the
serving row's queue-vs-service breakdown comes from
:func:`request_breakdown`.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np

from .trace import SpanEvent

__all__ = ["PHASES", "phase_means", "span_summary", "request_breakdown"]

#: canonical phase order: the four host phases of one mini-batch step
PHASES = ("sample", "fetch", "transfer", "compute")


def phase_means(metrics) -> dict:
    """Mean MEASURED host/device phase wall times over a list of
    `StepMetrics` — the `host_*` columns of a mini-batch row (this
    container's clock, unlike the modeled paper-cluster `*_time` columns).

    Each per-step value is a span duration (the phase spans recorded by
    the pipeline's `PhaseClock` plus the step/compute span), so every
    consumer of these columns reduces the same timing source."""
    return {
        "host_sample_time": float(np.mean([m.sample_time_host for m in metrics])),
        "host_fetch_time": float(np.mean([m.fetch_time_host for m in metrics])),
        "host_transfer_time": float(np.mean([m.transfer_time_host for m in metrics])),
        "host_compute_time": float(np.mean([m.compute_time_host for m in metrics])),
        "host_step_wall": float(np.mean([m.step_wall_host for m in metrics])),
        "overlap_efficiency": float(np.mean([m.overlap_efficiency for m in metrics])),
    }


def span_summary(spans: Iterable[SpanEvent]) -> Dict[str, dict]:
    """Per-name duration statistics over recorded spans:
    ``{name: {count, total_s, mean_s, p50_s, p99_s}}``."""
    by_name: Dict[str, List[float]] = {}
    for e in spans:
        by_name.setdefault(e.name, []).append(e.duration)
    out: Dict[str, dict] = {}
    for name, ds in sorted(by_name.items()):
        a = np.asarray(ds, dtype=np.float64)
        out[name] = {
            "count": int(a.size),
            "total_s": float(a.sum()),
            "mean_s": float(a.mean()),
            "p50_s": float(np.percentile(a, 50)),
            "p99_s": float(np.percentile(a, 99)),
        }
    return out


def request_breakdown(latency: np.ndarray,
                      queue_wait: Optional[np.ndarray]) -> dict:
    """Queue-wait vs service-time attribution over per-request serving
    latencies (both arrays come from the request spans: queue span =
    enqueue→dispatch, service span = dispatch→done, latency = their sum).

    ``p99_queue_share`` is the mean fraction of latency spent queueing
    among the slowest 1% of requests — the number that says whether a p99
    regression is a queueing problem or a compute problem."""
    lat = np.asarray(latency, dtype=np.float64)
    if queue_wait is None or lat.size == 0:
        return {}
    qw = np.asarray(queue_wait, dtype=np.float64)
    service = lat - qw
    p99 = np.percentile(lat, 99)
    tail = lat >= p99
    share = float(np.mean(qw[tail] / np.maximum(lat[tail], 1e-12)))
    return {
        "queue_wait_p50": float(np.percentile(qw, 50)),
        "queue_wait_p99": float(np.percentile(qw, 99)),
        "queue_wait_mean": float(qw.mean()),
        "service_p50": float(np.percentile(service, 50)),
        "service_p99": float(np.percentile(service, 99)),
        "service_mean_req": float(service.mean()),
        "p99_queue_share": share,
    }
