"""Chrome trace-event / Perfetto export for :mod:`repro.obs.trace`.

Schema tag: ``gnn-trace/v1`` (in ``otherData.schema``). The payload is
the standard JSON-object trace-event format, loadable by Perfetto
(https://ui.perfetto.dev) and ``chrome://tracing``:

  * one **process** per clock — pid 1 = ``host`` (wall-clock
    ``perf_counter`` spans), pid 2 = ``model`` (the serving simulator's
    virtual timeline) — so measured and modeled time never share an axis;
  * one **track** (tid) per thread or logical track, named via ``M``
    (metadata) events: the producer thread, each sampler-pool worker, the
    consumer, and per-worker serving queues each get their own row;
  * spans as paired ``B``/``E`` duration events (args on the ``B``);
  * counters (wire bytes, cache hit rate, queue depth, prefetch-queue
    occupancy) as ``C`` events on per-counter tracks.

Timestamps are microseconds relative to the earliest event per clock.
``load_trace`` is the exporter's own loader: it re-parses the JSON and
*validates* it (schema tag, every ``B`` paired with an ``E`` on its
track, per-track timestamps monotonically non-decreasing) — the
round-trip the CLI and the tests run.
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Tuple, Union

from .trace import CounterEvent, SpanEvent, Tracer

__all__ = ["TRACE_SCHEMA", "to_chrome_trace", "write_trace", "load_trace",
           "validate_chrome_trace"]

TRACE_SCHEMA = "gnn-trace/v1"

_PIDS = {"wall": 1, "model": 2}
_PROC_NAMES = {1: "host", 2: "model (simulated serving clock)"}


def _collect(tracers: Union[Tracer, Iterable[Tracer]]
             ) -> Tuple[List[SpanEvent], List[CounterEvent]]:
    if isinstance(tracers, Tracer):
        tracers = (tracers,)
    spans: List[SpanEvent] = []
    counters: List[CounterEvent] = []
    for tr in tracers:
        spans.extend(tr.spans())
        counters.extend(tr.counters())
    return spans, counters


def to_chrome_trace(tracers: Union[Tracer, Iterable[Tracer]]) -> dict:
    """Render recorded spans + counters as a Chrome trace-event object."""
    spans, counters = _collect(tracers)

    # microsecond timestamps relative to the earliest event *per clock*
    # (wall and model timelines have unrelated origins)
    t0: Dict[str, float] = {}
    for e in spans:
        t0[e.clock] = min(t0.get(e.clock, e.t0), e.t0)
    for c in counters:
        t0[c.clock] = min(t0.get(c.clock, c.t), c.t)

    def us(t: float, clock: str) -> float:
        return round((t - t0[clock]) * 1e6, 3)

    # stable tid assignment per (pid, track) in first-seen order; a span
    # without an explicit track lands on its recording thread's track
    tids: Dict[Tuple[int, str], int] = {}
    next_tid: Dict[int, int] = {}

    def tid_of(pid: int, track: str) -> int:
        key = (pid, track)
        if key not in tids:
            next_tid[pid] = next_tid.get(pid, 0) + 1
            tids[key] = next_tid[pid]
        return tids[key]

    events: List[dict] = []
    for e in spans:
        pid = _PIDS[e.clock]
        track = e.track if e.track is not None else e.thread
        tid = tid_of(pid, track)
        b = {"name": e.name, "cat": e.cat or "span", "ph": "B",
             "ts": us(e.t0, e.clock), "pid": pid, "tid": tid}
        if e.args:
            b["args"] = e.args
        events.append(b)
        events.append({"name": e.name, "cat": e.cat or "span", "ph": "E",
                       "ts": us(e.t1, e.clock), "pid": pid, "tid": tid})
    for c in counters:
        pid = _PIDS[c.clock]
        events.append({"name": c.name, "cat": "counter", "ph": "C",
                       "ts": us(c.t, c.clock), "pid": pid,
                       "tid": tid_of(pid, f"counter:{c.name}"),
                       "args": {"value": c.value}})

    # deterministic order: by timestamp, B before E at equal ts (keeps the
    # pairing stack non-negative for zero-duration spans), then pid/tid
    ph_rank = {"B": 0, "C": 1, "E": 2}
    events.sort(key=lambda ev: (ev["pid"], ev["tid"], ev["ts"],
                                ph_rank[ev["ph"]]))

    meta: List[dict] = []
    for pid in sorted({ev["pid"] for ev in events}):
        meta.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                     "args": {"name": _PROC_NAMES[pid]}})
    for (pid, track), tid in sorted(tids.items(), key=lambda kv: kv[1]):
        meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": tid, "args": {"name": track}})

    return {
        "traceEvents": meta + events,
        "displayTimeUnit": "ms",
        "otherData": {"schema": TRACE_SCHEMA},
    }


def write_trace(path: str,
                tracers: Union[Tracer, Iterable[Tracer]]) -> dict:
    """Write the Chrome trace JSON to ``path``; returns the payload."""
    payload = to_chrome_trace(tracers)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)
        fh.write("\n")
    return payload


def validate_chrome_trace(payload: dict) -> List[str]:
    """Structural validation; returns a list of problems (empty = valid).

    Checks: the schema tag, the event-list shape, every ``B`` paired with
    an ``E`` on the same (pid, tid), and per-(pid, tid) timestamps
    monotonically non-decreasing.
    """
    problems: List[str] = []
    schema = payload.get("otherData", {}).get("schema")
    if schema != TRACE_SCHEMA:
        problems.append(f"schema {schema!r} != {TRACE_SCHEMA!r}")
    events = payload.get("traceEvents")
    if not isinstance(events, list):
        return problems + ["traceEvents is not a list"]

    stacks: Dict[Tuple[int, int], List[str]] = {}
    last_ts: Dict[Tuple[int, int], float] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue
        key = (ev.get("pid"), ev.get("tid"))
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i}: non-numeric ts {ts!r}")
            continue
        if ts < last_ts.get(key, float("-inf")):
            problems.append(
                f"event {i}: ts {ts} < {last_ts[key]} on track {key}")
        last_ts[key] = ts
        if ph == "B":
            stacks.setdefault(key, []).append(ev.get("name", ""))
        elif ph == "E":
            # match by name, newest first: contiguous phases share their
            # boundary timestamp (one clock reading ends span A and starts
            # span B), and the B-before-E tiebreak then interleaves the
            # pairs — a strict LIFO pop would mispair them
            stack = stacks.get(key, [])
            name = ev.get("name", "")
            for j in range(len(stack) - 1, -1, -1):
                if stack[j] == name:
                    del stack[j]
                    break
            else:
                problems.append(
                    f"event {i}: E {name!r} with no open B on track {key}")
        elif ph != "C":
            problems.append(f"event {i}: unknown phase {ph!r}")
    for key, stack in stacks.items():
        if stack:
            problems.append(f"track {key}: {len(stack)} unclosed B event(s) "
                            f"({stack[:3]}...)")
    return problems


def load_trace(path: str) -> dict:
    """Parse and validate a trace written by :func:`write_trace`.

    Raises ``ValueError`` listing every structural problem; this is the
    loader half of the exporter round-trip the CI smoke exercises.
    """
    with open(path) as fh:
        payload = json.load(fh)
    problems = validate_chrome_trace(payload)
    if problems:
        raise ValueError(
            f"{path}: invalid gnn-trace payload: " + "; ".join(problems))
    return payload
