"""Unified tracing & telemetry: span timelines, counter tracks, and
measured-vs-model reconciliation.

  trace     — thread-aware span tracer (context-manager + decorator API,
              monotonic clocks, ring-buffered events; a disabled no-op
              singleton keeps the hot paths untouched by default)
  export    — Chrome trace-event / Perfetto JSON (schema gnn-trace/v1)
              with one track per worker/thread plus counter tracks
  aggregate — shared span/metric reductions (phase means, span stats,
              queue-vs-service request breakdown)
  reconcile — the runtime twin of the gnn-lint static gate: measured
              spans/counters held against the analytic cost model
"""

from .aggregate import PHASES, phase_means, request_breakdown, span_summary
from .export import (TRACE_SCHEMA, load_trace, to_chrome_trace,
                     validate_chrome_trace, write_trace)
from .trace import (CollectiveEvent, CounterEvent, PhaseClock, Span,
                    SpanEvent, Tracer, get_tracer, install, traced, tracing,
                    uninstall)

__all__ = [
    "PHASES", "phase_means", "request_breakdown", "span_summary",
    "TRACE_SCHEMA", "load_trace", "to_chrome_trace", "validate_chrome_trace",
    "write_trace",
    "CollectiveEvent", "CounterEvent", "PhaseClock", "Span", "SpanEvent",
    "Tracer", "get_tracer", "install", "traced", "tracing", "uninstall",
]
