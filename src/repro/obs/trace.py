"""Thread-aware span tracer: the single timing substrate for the repo.

Every host-side duration the repo reports — pipeline phase times, trainer
step walls, layer-wise inference times, serving gather/compute splits —
is derived from the spans recorded here, so there is exactly one timing
source of truth (``time.perf_counter``, a monotonic clock) instead of
ad-hoc ``perf_counter()`` pairs scattered per module.

Design constraints, in order:

  * **Disabled means untouched.** The module-level singleton starts
    disabled; every record method is a single attribute check away from a
    no-op, and the :class:`PhaseClock` used on the pipeline hot path
    takes exactly as many ``perf_counter()`` readings as the inline
    timestamps it replaced. The "four phases sum exactly to the step
    wall" invariant and the overlapped==serial bitwise tests hold with
    tracing on or off because the *timestamps themselves* are what feed
    ``StepMetrics`` — the spans are the same numbers, not a second clock.
  * **Thread-aware.** Spans capture the recording thread's name/ident at
    record time; the exporter lays producer, sampler-pool workers and the
    consumer out on separate tracks. A ``track=`` override places events
    on a logical track instead (e.g. per-worker serving queues), and
    ``clock="model"`` marks virtual-time spans from the serving simulator
    so they export under their own process and never mix timelines with
    wall-clock spans.
  * **Bounded.** Events land in ring buffers (``deque(maxlen=...)``), so
    a long traced run degrades to "most recent N events" instead of
    unbounded memory. Counter *totals* are kept separately and never
    truncate — reconciliation sums stay exact even if the event ring
    wrapped.

Byte accounting rides the same tracer: cumulative counters (``add``),
gauges (``gauge``) and trace-time collective records (``collective``, fed
by the sync strategies while jax traces the step function) are what
``obs.reconcile`` holds against the analytic cost model.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import threading
import time
from contextlib import contextmanager
from typing import Callable, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "SpanEvent", "CounterEvent", "CollectiveEvent", "Span", "PhaseClock",
    "Tracer", "get_tracer", "install", "uninstall", "tracing", "traced",
]

_DEFAULT_CAPACITY = 1 << 16


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One closed span: ``[t0, t1]`` on the recording thread's track."""

    name: str
    cat: str
    t0: float
    t1: float
    tid: int
    thread: str
    track: Optional[str] = None
    clock: str = "wall"          # "wall" (perf_counter) | "model" (sim time)
    args: Optional[dict] = None

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass(frozen=True)
class CounterEvent:
    """A counter sample: cumulative (``add``) or instantaneous (``gauge``)."""

    name: str
    t: float
    value: float
    track: Optional[str] = None
    clock: str = "wall"


@dataclasses.dataclass(frozen=True)
class CollectiveEvent:
    """One collective op recorded at jax trace time by a sync strategy.

    ``cluster_bytes`` follows the compiled-HLO output-shape convention the
    static gate's ``collective_budget`` uses (per-device output nbytes x
    k); ``wire_bytes`` follows the transport convention of
    ``sync_wire_bytes_per_round`` (k x per-device encoded payload+meta).
    ``wire_bytes`` is ``None`` where the transport formula intentionally
    diverges from what the op moves (DenseSync reduces *decoded* fp32).
    """

    kind: str
    cluster_bytes: int
    wire_bytes: Optional[int] = None
    layer: int = 0
    program: str = "sync"


class Span:
    """Context-manager span. Always measures (``duration`` is consumed by
    the call sites even when tracing is off); records only when enabled."""

    __slots__ = ("_tracer", "name", "cat", "track", "args", "t0", "t1")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 track: Optional[str], args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.track = track
        self.args = args
        self.t0 = 0.0
        self.t1 = 0.0

    def __enter__(self) -> "Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.t1 = time.perf_counter()
        tr = self._tracer
        if tr.enabled:
            tr.record_span(self.name, self.t0, self.t1, cat=self.cat,
                           track=self.track, args=self.args)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class PhaseClock:
    """Contiguous phase timer: each ``split`` closes the current phase at
    the exact instant the next one opens, so phase durations sum to the
    wall *bitwise* (the same ``perf_counter`` reading ends one span and
    starts the next — no gap, no overlap, and exactly one clock reading
    per boundary, matching the inline ``t0..t3`` code it replaced)."""

    __slots__ = ("_tracer", "cat", "track", "args", "_t")

    def __init__(self, tracer: "Tracer", cat: str, track: Optional[str],
                 args: Optional[dict]):
        self._tracer = tracer
        self.cat = cat
        self.track = track
        self.args = args
        self._t = time.perf_counter()

    def split(self, name: str) -> float:
        """Close the running phase as ``name``; return its duration."""
        t0, t1 = self._t, time.perf_counter()
        self._t = t1
        tr = self._tracer
        if tr.enabled:
            tr.record_span(name, t0, t1, cat=self.cat, track=self.track,
                           args=self.args)
        return t1 - t0


class Tracer:
    """Ring-buffered event sink. Thread-safe: spans/counters append from
    the producer thread, sampler pool and consumer concurrently (deque
    appends are atomic under the GIL; totals take a small lock)."""

    def __init__(self, enabled: bool = True,
                 capacity: int = _DEFAULT_CAPACITY):
        self.enabled = enabled
        self._spans: collections.deque = collections.deque(maxlen=capacity)
        self._counters: collections.deque = collections.deque(maxlen=capacity)
        self._collectives: collections.deque = collections.deque(
            maxlen=capacity)
        self._totals: Dict[str, float] = {}
        self._lock = threading.Lock()

    # -- recording ----------------------------------------------------------

    def span(self, name: str, *, cat: str = "span",
             track: Optional[str] = None,
             args: Optional[dict] = None) -> Span:
        return Span(self, name, cat, track, args)

    def phase_clock(self, *, cat: str = "phase",
                    track: Optional[str] = None,
                    args: Optional[dict] = None) -> PhaseClock:
        return PhaseClock(self, cat, track, args)

    def record_span(self, name: str, t0: float, t1: float, *,
                    cat: str = "span", track: Optional[str] = None,
                    clock: str = "wall", args: Optional[dict] = None) -> None:
        """Record a span from explicit timestamps (the migration path for
        call sites that already hold ``perf_counter`` readings, and the
        only path for virtual-time spans, which pass ``clock='model'``)."""
        if not self.enabled:
            return
        th = threading.current_thread()
        self._spans.append(SpanEvent(
            name=name, cat=cat, t0=t0, t1=t1, tid=th.ident or 0,
            thread=th.name, track=track, clock=clock, args=args))

    def add(self, name: str, delta: float, *, track: Optional[str] = None,
            t: Optional[float] = None, clock: str = "wall") -> None:
        """Cumulative counter (e.g. wire bytes): records the running total
        so the exported track is monotone and ``total(name)`` is exact."""
        if not self.enabled:
            return
        with self._lock:
            value = self._totals.get(name, 0.0) + delta
            self._totals[name] = value
        self._counters.append(CounterEvent(
            name=name, t=time.perf_counter() if t is None else t,
            value=value, track=track, clock=clock))

    def gauge(self, name: str, value: float, *, track: Optional[str] = None,
              t: Optional[float] = None, clock: str = "wall") -> None:
        """Instantaneous counter (e.g. queue depth, cache hit rate)."""
        if not self.enabled:
            return
        self._counters.append(CounterEvent(
            name=name, t=time.perf_counter() if t is None else t,
            value=float(value), track=track, clock=clock))

    def collective(self, kind: str, cluster_bytes: int, *,
                   wire_bytes: Optional[int] = None, layer: int = 0,
                   program: str = "sync") -> None:
        """Record one collective op (called by sync strategies at jax
        trace time, where shapes/dtypes are static even under vmap)."""
        if not self.enabled:
            return
        self._collectives.append(CollectiveEvent(
            kind=kind, cluster_bytes=int(cluster_bytes),
            wire_bytes=None if wire_bytes is None else int(wire_bytes),
            layer=layer, program=program))

    # -- reading ------------------------------------------------------------

    def spans(self, name: Optional[str] = None) -> List[SpanEvent]:
        evs = list(self._spans)
        return evs if name is None else [e for e in evs if e.name == name]

    def counters(self, name: Optional[str] = None) -> List[CounterEvent]:
        evs = list(self._counters)
        return evs if name is None else [e for e in evs if e.name == name]

    def collectives(self, program: Optional[str] = None
                    ) -> List[CollectiveEvent]:
        evs = list(self._collectives)
        if program is None:
            return evs
        return [e for e in evs if e.program == program]

    def total(self, name: str) -> Optional[float]:
        """Exact cumulative total for an ``add`` counter (``None`` if the
        counter never fired — distinguishes "measured zero" from "not
        instrumented / tracing was off")."""
        with self._lock:
            return self._totals.get(name)

    def totals(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._totals)

    def __len__(self) -> int:
        return len(self._spans) + len(self._counters) + len(self._collectives)

    def clear(self) -> None:
        self._spans.clear()
        self._counters.clear()
        self._collectives.clear()
        with self._lock:
            self._totals.clear()


# -- module-level singleton -------------------------------------------------

_NULL = Tracer(enabled=False, capacity=1)
_current: Tracer = _NULL


def get_tracer() -> Tracer:
    """The installed tracer (the disabled no-op singleton by default)."""
    return _current


def install(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process-wide sink; returns it."""
    global _current
    _current = tracer
    return tracer


def uninstall() -> None:
    """Restore the disabled no-op singleton."""
    global _current
    _current = _NULL


@contextmanager
def tracing(capacity: int = _DEFAULT_CAPACITY) -> Iterator[Tracer]:
    """Install a fresh enabled tracer for the block; restore on exit."""
    prev = _current
    tr = install(Tracer(enabled=True, capacity=capacity))
    try:
        yield tr
    finally:
        install(prev)


def traced(name: Optional[str] = None, *, cat: str = "fn",
           track: Optional[str] = None) -> Callable:
    """Decorator API: run the wrapped call under a span. Resolves the
    tracer at call time, so functions decorated at import time respect a
    later ``install()``."""
    def deco(fn: Callable) -> Callable:
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            with get_tracer().span(label, cat=cat, track=track):
                return fn(*a, **kw)
        return wrapper
    return deco
