"""Measured-vs-model reconciliation: the runtime twin of the gnn-lint gate.

Where the PR-8 static gate holds traced jaxprs and compiled HLO to the
analytic invariants, this module holds a REAL run's spans and counters to
the same predictions:

  * feature-fetch wire bytes measured at the encode site
    (`RowStore.gather` counts the actual encoded payload+meta nbytes)
    against `Codec.wire_bytes` per gather — exact for every codec;
  * fetch miss bytes against the logical miss·d·4 volume — exact;
  * full-batch collective counts and cluster bytes recorded at jax trace
    time by the sync strategies against `collective_budget`, and forward
    sync wire bytes against `sync_wire_bytes_per_round` — exact for fp32
    (int8 within its codec-width ratio);
  * per-epoch wire bytes against `FullBatchTrainer.wire_bytes_per_epoch`;
  * gradient all-reduce bytes against `cost_model.minibatch_step`'s
    parameter count — a model-granularity check (the analytic count drops
    biases/attention vectors), so it carries a documented 25% tolerance;
  * phase walls: sample+fetch+transfer+compute against the step wall;
  * fault accounting (`reconcile_recovery`): the tracer's fault.injected /
    fault.handled counters against the `FaultPlan`'s own books — exact —
    and the fault.recovery_time_model counter against the recomputed
    `RecoveryEstimate` sum, one recovery span per executed rescale.

Fetch-byte and phase checks apply to the serial engine; the pipelined
engine prefetches beyond the consumed steps and interleaves phases by
design, so those checks warn-skip there instead of faking a tolerance.

Tolerances are per quantity (see `README.md`'s reconciliation table).
``tol_rel == 0.0`` means a bitwise ``measured == predicted`` comparison —
fp32 byte counts must match exactly, not approximately.

The report (schema ``gnn-trace-report/v2``) mirrors the gnn-lint report:
programs, counts by level, exit_code (1 on any error), and one entry per
check with measured/predicted/tolerance detail.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .trace import Tracer, get_tracer

__all__ = ["REPORT_SCHEMA", "Check", "ReconcileReport", "make_check",
           "build_report", "reconcile_minibatch", "reconcile_fullbatch",
           "reconcile_serving", "reconcile_recovery"]

# v2: adds the recovery rule (fault.* counters/spans vs the FaultPlan's
# books and the cost model's RecoveryEstimate)
REPORT_SCHEMA = "gnn-trace-report/v2"


@dataclasses.dataclass
class Check:
    """One reconciled quantity. ``level`` is "ok" when it holds, "error"
    when it does not, "warn" for advisory-only findings (never exit 1)."""

    quantity: str
    program: str
    measured: float
    predicted: float
    tol_rel: float
    level: str
    message: str
    unit: str = "bytes"
    data: Optional[dict] = None

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if d["data"] is None:
            d.pop("data")
        return d


def make_check(quantity: str, program: str, measured, predicted, *,
               tol_rel: float = 0.0,
               bounds: Optional[Tuple[float, float]] = None,
               unit: str = "bytes", note: str = "",
               warn_only: bool = False,
               data: Optional[dict] = None) -> Check:
    """Compare one measured quantity against its prediction.

    ``tol_rel == 0.0`` is a bitwise equality check (the fp32 contract);
    ``bounds=(lo, hi)`` checks containment instead (collective op counts,
    phase-closure deviations).
    """
    measured = float(measured)
    if bounds is not None:
        lo, hi = float(bounds[0]), float(bounds[1])
        ok = lo <= measured <= hi
        predicted = hi
        detail = f"measured {measured:g} vs bounds [{lo:g}, {hi:g}]"
    else:
        predicted = float(predicted)
        if tol_rel == 0.0:
            ok = measured == predicted
            detail = f"measured {measured:g} vs predicted {predicted:g} (exact)"
        else:
            rel = abs(measured - predicted) / max(abs(predicted), 1e-12)
            ok = rel <= tol_rel
            detail = (f"measured {measured:g} vs predicted {predicted:g} "
                      f"(rel dev {rel:.3g}, tol {tol_rel:g})")
    if note:
        detail += f" — {note}"
    level = "ok" if ok else ("warn" if warn_only else "error")
    return Check(quantity=quantity, program=program, measured=measured,
                 predicted=float(predicted), tol_rel=float(tol_rel),
                 level=level, message=detail, unit=unit, data=data)


def _skip(quantity: str, program: str, why: str) -> Check:
    return Check(quantity=quantity, program=program, measured=float("nan"),
                 predicted=float("nan"), tol_rel=0.0, level="warn",
                 message=f"not reconciled: {why}", unit="")


@dataclasses.dataclass
class ReconcileReport:
    checks: List[Check]
    programs: List[str]
    elapsed_s: float = 0.0

    @property
    def counts(self) -> Dict[str, int]:
        c = {"error": 0, "warn": 0, "ok": 0}
        for ch in self.checks:
            c[ch.level] = c.get(ch.level, 0) + 1
        return c

    @property
    def exit_code(self) -> int:
        return 1 if self.counts.get("error") else 0

    def to_dict(self) -> dict:
        return {
            "schema": REPORT_SCHEMA,
            "programs": list(self.programs),
            "counts": self.counts,
            "exit_code": self.exit_code,
            "elapsed_s": self.elapsed_s,
            "checks": [c.to_dict() for c in self.checks],
        }


def build_report(checks: Sequence[Check],
                 elapsed_s: float = 0.0) -> ReconcileReport:
    programs = sorted({c.program for c in checks})
    return ReconcileReport(checks=list(checks), programs=programs,
                           elapsed_s=elapsed_s)


def _wb(codec, shape, layer: int = 0) -> int:
    try:
        return codec.wire_bytes(shape, layer=layer)
    except TypeError:  # fixed-ratio codecs take no layer kwarg
        return codec.wire_bytes(shape)


# ---------------------------------------------------------------------------
# mini-batch training (DistDGL regime)
# ---------------------------------------------------------------------------


def reconcile_minibatch(trainer, metrics, *, tracer: Optional[Tracer] = None,
                        program: str = "minibatch") -> List[Check]:
    """Reconcile a mini-batch run. ``metrics`` must hold the `StepMetrics`
    of EVERY step executed while ``tracer`` was installed (the fetch
    counters are cumulative over the whole traced run)."""
    from repro.core.cost_model import _wire_elem
    from repro.core.wire import as_codec

    tracer = tracer or get_tracer()
    codec = as_codec(trainer.codec)
    d = int(trainer.store.row_dim)
    k = int(trainer.book.k)
    checks: List[Check] = []

    miss_counts = [int(c) for m in metrics for c in m.remote_misses]
    pred_wire = sum(_wb(codec, (c, d)) for c in miss_counts)
    pred_miss = sum(c * d * 4 for c in miss_counts)

    meas_wire = tracer.total("fetch.wire_bytes")
    meas_miss = tracer.total("fetch.miss_bytes")
    if meas_wire is None:
        checks.append(_skip("fetch.wire_bytes", program,
                            "no fetch counters recorded (tracing was not "
                            "enabled during the steps)"))
    elif getattr(trainer, "overlap", False):
        # the prefetcher prepares batches AHEAD of consumption (and drops
        # queued ones at close), so the measured gather counters cover a
        # superset of the consumed steps' predictions
        checks.append(_skip("fetch.wire_bytes", program,
                            "pipelined engine: the prefetcher fetches "
                            "beyond the consumed steps by design"))
    else:
        checks.append(make_check(
            "fetch.wire_bytes", program, meas_wire, pred_wire,
            note="encoded payload+meta nbytes at the gather site vs "
                 "Codec.wire_bytes per gather"))
        checks.append(make_check(
            "fetch.miss_bytes", program, meas_miss or 0.0, pred_miss,
            note="logical f32 miss rows"))
        if not codec.lossless and pred_miss > 0:
            checks.append(make_check(
                "fetch.wire_ratio", program, meas_wire / pred_miss,
                codec.ratio(0), tol_rel=0.05, unit="ratio",
                note="codec width ratio; slack covers the O(1) per-gather "
                     "scale meta"))

    # gradient all-reduce: the live parameter tree vs the analytic count
    # (model granularity: cost_model drops biases/attention vectors)
    import jax

    leaf_wire = sum(_wb(codec, p.shape) for p in jax.tree.leaves(trainer.params))
    n_params_model = sum(din * dout for din, dout in trainer.spec.dims()) * 2
    checks.append(make_check(
        "allreduce.wire_bytes", program,
        2 * k * leaf_wire, 2 * k * n_params_model * _wire_elem(codec),
        tol_rel=0.25,
        note="2k x encoded param leaves vs the cost model's dense "
             "parameter count (biases excluded by design)"))

    # phase closure: the four phases must sum to the step wall (serial
    # engine; the pipelined engine overlaps phases across threads)
    if metrics:
        if getattr(trainer, "overlap", False):
            checks.append(_skip(
                "phase.closure", program,
                "pipelined engine: phases overlap across threads by design"))
        else:
            dev = max(
                abs(m.sample_time_host + m.fetch_time_host
                    + m.transfer_time_host + m.compute_time_host
                    - m.step_wall_host) / max(m.step_wall_host, 1e-12)
                for m in metrics)
            checks.append(make_check(
                "phase.closure", program, dev, 0.0, bounds=(0.0, 1e-9),
                unit="rel", note="max |sample+fetch+transfer+compute - "
                                 "wall| / wall over steps"))
    return checks


# ---------------------------------------------------------------------------
# full-batch training (sync-strategy collectives)
# ---------------------------------------------------------------------------


def reconcile_fullbatch(trainer, *, tracer: Optional[Tracer] = None,
                        program: str = "fullbatch") -> List[Check]:
    """Reconcile the collectives a full-batch trainer recorded at jax
    trace time against `collective_budget` / `sync_wire_bytes_per_round`.

    The tracer must have been installed BEFORE the first `train_step`
    (recording happens once, when jax traces the step). Predictions cover
    one forward pass, every aggregate priced at its true payload width
    (`GNNSpec.aggregate_dims`) — exact for fp32, every model.
    """
    from repro.core.wire import as_codec
    from repro.gnn.fullbatch import resolve_sync_mode
    from repro.gnn.sync import collective_budget, sync_wire_bytes_per_round

    tracer = tracer or get_tracer()
    book, spec = trainer.book, trainer.spec
    codec = as_codec(trainer.codec)
    mode = resolve_sync_mode(trainer.sync_mode, book.k)
    events = tracer.collectives()
    checks: List[Check] = []

    if mode == "local":
        checks.append(make_check(
            "sync.collective_count", program, len(events), 0, unit="ops",
            note="k=1 resolves to LocalSync: nothing may move"))
        return checks
    if not events:
        checks.append(_skip(
            "sync.collectives", program,
            "no collectives recorded — the tracer must be installed "
            "before the step function is first traced/compiled"))
        return checks

    pred: Dict[str, List[float]] = {}   # kind -> [lo, hi, cluster_bytes]
    pred_wire_fwd = 0
    ordinal = 0  # aggregate ordinal == the codec layer= the sync passes
    for layer_dims in spec.aggregate_dims(mode):
        for d in layer_dims:
            pred_wire_fwd += sync_wire_bytes_per_round(
                book, d, mode, codec, layer=ordinal)
            for kind, b in collective_budget(
                    book, d, mode, codec, layer=ordinal).items():
                lo, hi = b["count"]
                acc = pred.setdefault(kind, [0.0, 0.0, 0.0])
                acc[0] += lo
                acc[1] += hi
                acc[2] += b["cluster_bytes"]
            ordinal += 1

    meas: Dict[str, List[float]] = {}   # kind -> [count, cluster_bytes]
    meas_wire_fwd = 0
    for e in events:
        acc = meas.setdefault(e.kind, [0.0, 0.0])
        acc[0] += 1
        acc[1] += e.cluster_bytes
        if e.wire_bytes is not None:
            meas_wire_fwd += e.wire_bytes

    for kind in sorted(set(pred) | set(meas)):
        p = pred.get(kind, [0.0, 0.0, 0.0])
        m = meas.get(kind, [0.0, 0.0])
        checks.append(make_check(
            f"sync.count.{kind}", program, m[0], p[1],
            bounds=(p[0], p[1]), unit="ops",
            note="recorded ops of one traced forward vs collective_budget"))
        checks.append(make_check(
            f"sync.cluster_bytes.{kind}", program, m[1], p[2],
            note="HLO output-shape convention (per-device output x k)"))

    if mode in ("halo", "ring"):
        # the dense transport formula prices the quantised view while the
        # psum moves dequantised f32 — only halo/ring wire is reconcilable
        checks.append(make_check(
            "sync.wire_bytes.forward", program, meas_wire_fwd,
            pred_wire_fwd,
            note="encoded payload+meta x devices, one forward pass, vs "
                 "sum of sync_wire_bytes_per_round over aggregates"))

        import jax

        leaf_wire = sum(_wb(codec, p.shape)
                        for p in jax.tree.leaves(trainer.params))
        checks.append(make_check(
            "epoch.wire_bytes", program,
            2 * meas_wire_fwd + 2 * book.k * leaf_wire,
            trainer.wire_bytes_per_epoch(),
            note="2x traced forward sync wire + grad all-reduce vs "
                 "FullBatchTrainer.wire_bytes_per_epoch"))
    return checks


# ---------------------------------------------------------------------------
# online serving (embedding-store fetches + request lifecycle)
# ---------------------------------------------------------------------------


def reconcile_serving(report, store, *, tracer: Optional[Tracer] = None,
                      program: str = "serve") -> List[Check]:
    """Reconcile a serving-sim run: embedding-store wire bytes measured at
    the gather encode site vs the codec formula, the merged FetchStats
    accounting, and the request-latency closure (queue span + service
    span == latency span, on the simulator's virtual clock)."""
    from repro.core.wire import as_codec

    tracer = tracer or get_tracer()
    codec = as_codec(getattr(store, "codec", None))
    d = int(store.row_dim)
    checks: List[Check] = []

    batch_miss = getattr(report, "batch_miss", None)
    if batch_miss is None:
        return [_skip("serve.fetch.wire_bytes", program,
                      "report carries no per-batch miss counts")]
    pred_wire = sum(_wb(codec, (int(c), d)) for c in batch_miss)

    meas_wire = tracer.total("fetch.wire_bytes")
    if meas_wire is None:
        checks.append(_skip("serve.fetch.wire_bytes", program,
                            "no fetch counters recorded (tracing was not "
                            "enabled during the sim)"))
    else:
        checks.append(make_check(
            "serve.fetch.wire_bytes", program, meas_wire, pred_wire,
            note="encoded embedding rows at the gather site vs "
                 "Codec.wire_bytes per micro-batch"))
    checks.append(make_check(
        "serve.fetch.stats_wire_bytes", program, report.fetch.wire_bytes,
        pred_wire, note="merged FetchStats accounting vs per-batch sum"))
    checks.append(make_check(
        "serve.fetch.miss_bytes", program, report.fetch.miss_bytes,
        sum(int(c) * d * 4 for c in batch_miss),
        note="logical f32 embedding miss rows"))

    qw = getattr(report, "queue_wait", None)
    if qw is not None and report.latency.size:
        # each request's service share (latency minus its queue span) must
        # equal its batch's modeled service span
        service = report.latency - np.asarray(qw)
        by_batch = np.repeat(report.service_time, report.batch_size.astype(int))
        dev = float(np.max(np.abs(np.sort(service) - np.sort(by_batch))))
        checks.append(make_check(
            "serve.latency.closure", program, dev, 0.0,
            bounds=(0.0, 1e-9), unit="s",
            note="latency == queue span + its batch's service span, per "
                 "request (virtual clock)"))
    return checks


# ---------------------------------------------------------------------------
# fault injection + recovery (the chaos accounting)
# ---------------------------------------------------------------------------


def reconcile_recovery(plan, *, tracer: Optional[Tracer] = None,
                       estimates: Optional[Sequence] = None,
                       program: str = "recovery") -> List[Check]:
    """Reconcile a faulted run's trace against the `FaultPlan`'s own books.

    The plan counts what it injected and what the run reported handled;
    the tracer counted the same events from the run's side — the two
    stories must agree EXACTLY, or a fault was dropped/double-counted.
    With `estimates` (the `RecoveryEstimate`s of an elastic run) the traced
    `fault.recovery_time_model` counter must equal their recomputed sum and
    the run must have recorded exactly one recovery span per rescale.
    """
    tracer = tracer or get_tracer()
    checks: List[Check] = []

    injected = tracer.total("fault.injected")
    checks.append(make_check(
        "fault.injected", program, injected or 0.0,
        plan.injected_count, unit="ops",
        note="traced injection counter vs the plan's fired-event book"))
    handled = tracer.total("fault.handled")
    checks.append(make_check(
        "fault.handled", program, handled or 0.0,
        plan.handled_count, unit="ops",
        note="traced handled counter vs the plan's handled-event book"))

    if estimates is not None:
        pred_total = float(sum(e.recovery_time for e in estimates))
        meas_total = tracer.total("fault.recovery_time_model")
        checks.append(make_check(
            "fault.recovery_time_model", program, meas_total or 0.0,
            pred_total, unit="s",
            note="traced recovery-time counter vs the recomputed "
                 "RecoveryEstimate sum (restore + re-partition + "
                 "re-compile)"))
        checks.append(make_check(
            "fault.recovery_spans", program,
            len(tracer.spans("fault.recovery")), len(estimates), unit="ops",
            note="one fault.recovery span per executed rescale"))
    return checks
