"""Online GNN inference serving (the request path).

`repro.gnn.inference` is the offline half: a layer-wise pass materialises
per-layer embedding stores. This package is the online half: target-vertex
requests are micro-batched into padded MFGs (`batcher.py`), answered from
the embedding store plus a recompute of the final layers (`engine.py`),
and priced on the paper's cluster by `core.cost_model.serve_request`.
`launch/gnn_serve.py` is the driver; `benchmarks/fig_serving.py` the sweep.
"""

from repro.serve.batcher import MicroBatch, MicroBatcher  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    ServeEngine,
    ServingReport,
    build_serving,
    run_serving_sim,
)
