"""Micro-batching of target-vertex requests into padded MFGs.

An online GNN service answers "embed/classify vertex v" requests. Per-request
MFG construction would leave the device idle and recompile per shape;
production servers (and LM serving — see launch/serve.py's batched decode)
instead coalesce requests into micro-batches. Two properties matter here:

  * **static shapes**: every micro-batch is padded to one `SamplePlan`
    (`sampling.LayerPad`), whatever the request mix — 1 request or
    `max_batch`, duplicates or hubs — so the serve step compiles exactly
    once. `build_mfg` is the invariant's home (tested directly).
  * **bounded wait**: a batch dispatches when full OR when its oldest
    request has waited `max_wait` — the classic latency/throughput knob
    (`plan_dispatch` implements the policy as a pure function of arrival
    times so the simulator and tests share it).

The batcher is per-worker: requests are routed to the embedding store's
owner partition, where the target's rows (and most of its neighborhood,
if the partitioner did its job) live locally.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.core.graph import Graph
from repro.gnn.sampling import SamplePlan, SampledBatch, sample_blocks

__all__ = ["MicroBatch", "MicroBatcher", "plan_dispatch"]


class MicroBatch(NamedTuple):
    """One dispatched micro-batch: the padded MFG plus its request bookkeeping."""

    ids: np.ndarray          # [n] requested target vertices (n <= max_batch)
    arrivals: np.ndarray     # [n] request arrival times (seconds)
    dispatch_time: float     # when the batch left the queue
    batch: SampledBatch      # padded to the batcher's static plan


def plan_dispatch(
    arrivals: np.ndarray,
    start: int,
    t_free: float,
    max_batch: int,
    max_wait: float,
) -> tuple[int, float]:
    """Dispatch decision for the queue suffix `arrivals[start:]` (sorted).

    Returns (batch_size, dispatch_time). The worker serves batches serially
    and becomes free at `t_free`; the batch dispatches at the earliest
    moment it is full, OR when the oldest pending request has waited
    `max_wait` — whichever comes first — but never before the worker is
    free (requests that arrive while the worker is busy ride along for
    free, the standard continuous-batching win).
    """
    arrivals = np.asarray(arrivals)
    first = float(arrivals[start])
    t_ready = max(t_free, first)
    # everyone who has arrived by the time the worker could start
    j = int(np.searchsorted(arrivals, t_ready, side="right"))
    if j - start >= max_batch:
        # batch already full: dispatch as soon as its max_batch-th member
        # arrived (possibly earlier than t_ready... but never before t_free)
        return max_batch, max(t_free, float(arrivals[start + max_batch - 1]))
    # not full: hold until the deadline, admitting late arrivals
    deadline = max(t_ready, first + max_wait)
    j = int(np.searchsorted(arrivals, deadline, side="right"))
    if j - start >= max_batch:
        return max_batch, max(t_free, float(arrivals[start + max_batch - 1]))
    return j - start, deadline


@dataclasses.dataclass
class MicroBatcher:
    """Per-worker request coalescer + padded-MFG builder."""

    graph: Graph
    fanouts: tuple
    max_batch: int
    plan: SamplePlan
    owner: Optional[np.ndarray]
    worker: int
    tiled_layout: bool
    max_wait: float
    rng: np.random.Generator
    _labels: np.ndarray = dataclasses.field(default=None, repr=False)

    @classmethod
    def build(
        cls,
        graph: Graph,
        *,
        fanouts: Sequence[int],
        max_batch: int,
        owner: Optional[np.ndarray] = None,
        worker: int = 0,
        tiled_layout: bool = False,
        max_wait: float = 2e-3,
        seed: int = 0,
    ) -> "MicroBatcher":
        fanouts = tuple(int(f) for f in fanouts)
        return cls(
            graph=graph, fanouts=fanouts, max_batch=int(max_batch),
            plan=SamplePlan.build(int(max_batch), fanouts),
            owner=owner, worker=worker, tiled_layout=tiled_layout,
            max_wait=float(max_wait), rng=np.random.default_rng(seed),
            _labels=np.zeros(graph.num_vertices, dtype=np.int32),
        )

    def build_mfg(self, ids: np.ndarray) -> SampledBatch:
        """Pad `ids` (1 <= len <= max_batch, duplicates allowed) to the
        static plan. Every return value has identical array shapes."""
        ids = np.asarray(ids, dtype=np.int64)
        if not 0 < ids.shape[0] <= self.max_batch:
            raise ValueError(
                f"micro-batch size {ids.shape[0]} outside (0, {self.max_batch}]")
        return sample_blocks(
            self.graph, ids, self.fanouts, self.plan, self.rng,
            self._labels, owner=self.owner, worker=self.worker,
            tiled_layout=self.tiled_layout,
        )

    def dispatch(
        self, arrivals: np.ndarray, start: int, t_free: float
    ) -> tuple[int, float]:
        return plan_dispatch(arrivals, start, t_free,
                             self.max_batch, self.max_wait)
