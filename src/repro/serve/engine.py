"""The serving engine: embedding store + final-layer recompute, simulated QPS.

A request for vertex v's prediction is answered in three phases (the serving
mirror of the paper's §5.1 training phases, priced by
`core.cost_model.serve_request`):

  1. sample   — a `hops`-deep MFG rooted at the micro-batch's targets
                (host, `serve/batcher.py`; static `LayerPad` shapes)
  2. fetch    — the MFG's input frontier reads layer-(L-hops) embedding
                rows from the `RowStore` (gnn/inference.py); {local,
                cache-hit, remote-miss} accounting — only MISS bytes cross
                the network, exactly like the training feature store
  3. recompute— the last `hops` layers run over the MFG on device
                (`minibatch.mfg_forward`, through `ops.aggregate`, so the
                tiled/pallas backends serve scatter-free); compiled once
                per (spec, hops, plan) via an LRU'd jit.

Lower `hops` = cheaper serving but staler intermediate state; `hops = L`
degenerates to feature-store inference (no embedding reuse). The QPS
simulator (`run_serving_sim`) drives Poisson arrivals through per-worker
queues — each worker serves its micro-batches serially at the cost model's
service time — and reports per-worker p50/p99 latency and sustainable QPS,
which is where partitioning quality (fewer remote rows -> fewer miss bytes
-> shorter service) becomes user-visible latency.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.core.cost_model import PAPER_CLUSTER, ClusterSpec
from repro.gnn.feature_store import FetchStats, RowStore
from repro.gnn.minibatch import mfg_forward
from repro.gnn.models import GNNSpec
from repro.gnn.sampling import SampledBatch, SamplePlan
from repro.obs.trace import get_tracer
from repro.serve.batcher import MicroBatch, MicroBatcher

__all__ = ["ServeEngine", "ServingReport", "build_serving", "run_serving_sim"]


@functools.lru_cache(maxsize=64)
def _compiled_step(spec: GNNSpec, hops: int, sizes: tuple):
    """One jitted serve step per (spec, hops, plan) — shared across engines
    and workers, so a k-worker deployment compiles exactly once."""

    def fwd(layer_params, batch):
        return mfg_forward(spec, layer_params, batch, sizes)

    return jax.jit(fwd)


@dataclasses.dataclass
class ServeEngine:
    """Per-worker online engine: store reads + jitted last-layers recompute."""

    spec: GNNSpec
    params: Any                   # full model params (suffix sliced per step)
    store: RowStore               # layer-(L-hops) embedding rows
    plan: SamplePlan
    hops: int
    worker: int

    def __post_init__(self) -> None:
        if not 1 <= self.hops <= self.spec.num_layers:
            raise ValueError(
                f"hops={self.hops} outside [1, {self.spec.num_layers}]")
        expect = (self.spec.feature_dim if self.hops == self.spec.num_layers
                  else self.spec.hidden_dim)
        if self.store.row_dim != expect:
            raise ValueError(
                f"store row_dim {self.store.row_dim} != layer-"
                f"{self.spec.num_layers - self.hops} width {expect}")

    @property
    def _layer_params(self) -> tuple:
        return tuple(self.params["layers"][self.spec.num_layers - self.hops:])

    @property
    def _sizes(self) -> tuple:
        return tuple(p.n_dst for p in self.plan.layers)

    def device_batch(self, batch: SampledBatch, x: np.ndarray) -> dict:
        """Stage one padded MFG + input rows onto the device — the pytree the
        jitted step consumes. Public so the analysis subsystem can trace the
        exact serving forward (`mfg_forward` over this structure)."""
        layers = []
        for lay in batch.layers:
            d = {
                "esrc": jnp.asarray(lay.esrc),
                "edst": jnp.asarray(lay.edst),
                "emask": jnp.asarray(lay.emask),
                "deg": jnp.asarray(lay.sampled_deg),
            }
            if lay.agg_order is not None:
                d["agg_order"] = jnp.asarray(lay.agg_order)
                d["agg_ldst"] = jnp.asarray(lay.agg_ldst)
            layers.append(d)
        return {"x": jnp.asarray(x), "layers": layers}

    # back-compat alias (pre-analysis name)
    _device_batch = device_batch

    def answer(
        self, batch: SampledBatch
    ) -> tuple[np.ndarray, FetchStats, float]:
        """Serve one padded micro-batch MFG.

        Returns (logits [plan.seeds, C] — rows past the true request count
        are padding, mask with batch.seed_mask —, the embedding-store fetch
        accounting, and the measured host compute seconds)."""
        tracer = get_tracer()
        ids = batch.input_ids[batch.input_mask]
        with tracer.span("serve.gather", cat="serve",
                         args={"worker": self.worker}):
            rows, stats = self.store.gather(self.worker, ids)
        x = np.zeros((batch.input_ids.shape[0], self.store.row_dim),
                     dtype=np.float32)
        x[batch.input_mask] = rows
        step = _compiled_step(self.spec, self.hops, self._sizes)
        dev = self._device_batch(batch, x)
        # host compute = the compute span's duration (same two clock
        # readings the pre-tracer code took)
        with tracer.span("serve.compute", cat="serve",
                         args={"worker": self.worker}) as sp:
            out = step(self._layer_params, dev)
            out.block_until_ready()
        host_s = sp.duration
        return np.asarray(out[: self.plan.seeds]), stats, host_s

    def estimate(self, batch: SampledBatch,
                 stats: FetchStats,
                 cluster: ClusterSpec = PAPER_CLUSTER):
        """Cluster-model service time of one answered micro-batch (priced
        with whatever wire codec is installed on the embedding store)."""
        return cost_model.serve_request(
            stats.num_input, stats.num_remote, stats.num_remote_miss,
            batch.num_edges, self.spec,
            embed_dim=self.store.row_dim, hops=self.hops, cluster=cluster,
            codec=getattr(self.store, "codec", None),
        )


# ---------------------------------------------------------------------------
# QPS simulation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServingReport:
    """Outcome of one simulated serving run (all workers)."""

    k: int
    offered_qps: float
    latency: np.ndarray        # [n] modeled per-request latency (seconds)
    latency_worker: np.ndarray  # [n] worker that served each request
    host_time: np.ndarray      # [b] measured host compute per batch
    service_time: np.ndarray   # [b] modeled service time per batch
    batch_size: np.ndarray     # [b]
    batch_worker: np.ndarray   # [b]
    fetch: FetchStats          # merged over every batch
    duration: float            # arrival-window length (seconds)
    # per-request queue wait (dispatch - arrival; latency = queue_wait +
    # its batch's service span) and per-batch miss counts, both derived
    # from the request spans — None on reports built by older callers
    queue_wait: Optional[np.ndarray] = None   # [n]
    batch_miss: Optional[np.ndarray] = None   # [b]
    # fault-injection outcome (worker-death): every request is still
    # answered; the rerouted ones pay the detection delay + a colder store
    arrival: Optional[np.ndarray] = None      # [n] original arrival times
    fault_time: Optional[float] = None        # virtual death time tau
    dead_worker: int = -1
    rerouted: int = 0                         # requests failed over
    transition_end: Optional[float] = None    # last rerouted completion

    # -------------------------------------------------------------- metrics
    def _lat(self, worker: Optional[int]) -> np.ndarray:
        if worker is None:
            return self.latency
        return self.latency[self.latency_worker == worker]

    def p50(self, worker: Optional[int] = None) -> float:
        return float(np.percentile(self._lat(worker), 50))

    def p99(self, worker: Optional[int] = None) -> float:
        return float(np.percentile(self._lat(worker), 99))

    def sustainable_qps(self, worker: Optional[int] = None) -> float:
        """Throughput cap if the worker(s) were never idle: served requests
        per second of busy (service) time. Workers serve in PARALLEL, so
        the cluster cap (worker=None) is the SUM of per-worker rates."""
        if worker is None:
            rates = [self.sustainable_qps(w) for w in range(self.k)]
            finite = [r for r in rates if np.isfinite(r)]
            return float(sum(finite)) if finite else float("inf")
        sel = self.batch_worker == worker
        busy = float(self.service_time[sel].sum())
        served = float(self.batch_size[sel].sum())
        return served / busy if busy > 0 else float("inf")

    def served(self, worker: Optional[int] = None) -> int:
        return int(self._lat(worker).shape[0])

    def worker_rows(self) -> list:
        return [
            {
                "worker": w,
                "served": self.served(w),
                "p50": self.p50(w) if self.served(w) else float("nan"),
                "p99": self.p99(w) if self.served(w) else float("nan"),
                "qps_sustainable": self.sustainable_qps(w),
            }
            for w in range(self.k)
        ]

    def transition_stats(self) -> Optional[dict]:
        """Latency of the degraded window: requests COMPLETING between the
        death (tau) and the last rerouted request's completion. None when no
        fault was injected."""
        if self.fault_time is None:
            return None
        done = self.arrival + self.latency
        win = (done >= self.fault_time) & (done <= self.transition_end)
        lat = self.latency[win]
        return {
            "fault_time": float(self.fault_time),
            "transition_end": float(self.transition_end),
            "window": float(self.transition_end - self.fault_time),
            "requests": int(win.sum()),
            "rerouted": int(self.rerouted),
            "p50": float(np.percentile(lat, 50)) if lat.size else float("nan"),
            "p99": float(np.percentile(lat, 99)) if lat.size else float("nan"),
        }


def run_serving_sim(
    engines: list,
    batchers: list,
    owner: np.ndarray,
    request_ids: np.ndarray,
    arrivals: np.ndarray,
    *,
    cluster: ClusterSpec = PAPER_CLUSTER,
    fault_plan=None,
    failover_owner: Optional[np.ndarray] = None,
    detect_delay: float = 0.0,
) -> ServingReport:
    """Drive a request trace through per-worker queues.

    `request_ids`/`arrivals` are the global trace (arrivals sorted,
    seconds); each request is routed to the worker owning its target
    vertex. Every worker batches greedily (`plan_dispatch`) and serves
    serially at the cost model's service time; modeled per-request latency
    = (dispatch wait) + (batch service time). Host compute is measured too
    (real jitted step), reported separately — it validates the path runs,
    while the cost model supplies the paper-cluster numbers.

    Fault injection: a `fault_plan` with a `worker-death` event kills one
    worker at virtual time tau. Its unanswered requests fail over to
    `failover_owner` (see fault/recovery.failover_assignment) after
    `detect_delay` seconds; every request is STILL answered — the rerouted
    ones pay the delay plus the survivor's colder locality, which is the
    degraded-window latency `transition_stats()` reports. Latency and queue
    wait stay measured against the ORIGINAL arrival, so the per-request
    closure invariant (latency == queue_wait + service) survives the fault.
    """
    request_ids = np.asarray(request_ids, dtype=np.int64)
    arrivals = np.asarray(arrivals, dtype=np.float64)
    k = len(engines)
    tracer = get_tracer()
    latencies: list[np.ndarray] = []
    lat_worker: list[np.ndarray] = []
    queue_waits: list[np.ndarray] = []
    arrival_rec: list[np.ndarray] = []
    reroute_done: list[np.ndarray] = []  # completion times of rerouted reqs
    host_times, service_times, bsizes, bworkers, bmiss = [], [], [], [], []
    all_stats: list[FetchStats] = []

    def _drain(w, ids_w, eff_w, orig_w, flag_w, stop_at=None):
        """Serve worker w's stream serially; dispatch is planned from the
        EFFECTIVE arrivals, latency measured from the ORIGINAL ones.
        Returns the index the worker died at (== len when it drained)."""
        t_free = 0.0
        i = 0
        while i < ids_w.shape[0]:
            take, t_dispatch = batchers[w].dispatch(eff_w, i, t_free)
            if stop_at is not None and t_dispatch >= stop_at:
                break  # the worker is dead before this batch dispatches
            mb = MicroBatch(
                ids=ids_w[i:i + take],
                arrivals=orig_w[i:i + take],
                dispatch_time=t_dispatch,
                batch=batchers[w].build_mfg(ids_w[i:i + take]),
            )
            logits, stats, host_s = engines[w].answer(mb.batch)
            est = engines[w].estimate(mb.batch, stats, cluster)
            t_done = t_dispatch + est.service_time
            if stop_at is not None and t_done > stop_at:
                break  # died mid-batch: nothing of it was answered
            latencies.append(t_done - mb.arrivals)
            queue_waits.append(t_dispatch - mb.arrivals)
            arrival_rec.append(mb.arrivals)
            if flag_w is not None and flag_w[i:i + take].any():
                reroute_done.append(
                    np.full(int(flag_w[i:i + take].sum()), t_done))
            lat_worker.append(np.full(take, w, dtype=np.int64))
            host_times.append(host_s)
            service_times.append(est.service_time)
            bsizes.append(take)
            bworkers.append(w)
            bmiss.append(stats.num_remote_miss)
            all_stats.append(stats)
            if tracer.enabled:
                # the request lifecycle on the simulator's virtual clock:
                # enqueue→dispatch per request on the worker's queue
                # track, then the modeled gather/compute service phases
                for rid, arr in zip(mb.ids, mb.arrivals):
                    tracer.record_span(
                        "serve.queue", float(arr), float(t_dispatch),
                        cat="serve", clock="model",
                        track=f"serve.worker{w}.queue",
                        args={"rid": int(rid)})
                t_fetch = t_dispatch + est.sample_time + est.fetch_time
                tracer.record_span(
                    "serve.service.gather", float(t_dispatch),
                    float(t_fetch), cat="serve", clock="model",
                    track=f"serve.worker{w}", args={"size": int(take)})
                tracer.record_span(
                    "serve.service.compute", float(t_fetch), float(t_done),
                    cat="serve", clock="model",
                    track=f"serve.worker{w}", args={"size": int(take)})
            t_free = t_done
            i += take
        return i

    # ----------------------------------------------------- fault resolution
    route = np.asarray(owner)[request_ids] if request_ids.size else \
        np.zeros(0, np.int64)
    death_ev, dead, fault_time = None, -1, None
    if fault_plan is not None:
        deaths = fault_plan.pending("worker-death")
        if deaths:
            death_ev = deaths[0]
            dead = fault_plan.resolve_worker(death_ev, k)
            fault_time = (float(death_ev.at) if death_ev.at >= 0 else
                          0.5 * float(arrivals.max() if arrivals.size else 0.0))
            if failover_owner is None:
                raise ValueError(
                    "worker-death injection requires failover_owner "
                    "(see fault.recovery.failover_assignment)")

    rerouted_n = 0
    extra = {w: None for w in range(k)}  # survivor -> rerouted (ids, orig)
    if death_ev is not None:
        sel = route == dead
        ids_d, orig_d = request_ids[sel], arrivals[sel]
        served = _drain(dead, ids_d, orig_d, orig_d, None,
                        stop_at=fault_time)
        fault_plan.fire(death_ev, worker=int(dead), at=fault_time)
        left_ids, left_orig = ids_d[served:], orig_d[served:]
        rerouted_n = int(left_ids.shape[0])
        tracer.add("fault.rerouted", rerouted_n)
        new_owner = np.asarray(failover_owner)
        targets = new_owner[left_ids]
        if (targets == dead).any():
            raise ValueError(
                f"failover_owner still routes to dead worker {dead}")
        for w in range(k):
            pick = targets == w
            if pick.any():
                extra[w] = (left_ids[pick], left_orig[pick])

    # ------------------------------------------------------------ the drain
    for w in range(k):
        if w == dead:
            continue
        sel = route == w
        ids_w, orig_w = request_ids[sel], arrivals[sel]
        flag_w = None
        if extra[w] is not None:
            re_ids, re_orig = extra[w]
            # rerouted requests become visible to the survivor only after
            # the death is detected
            re_eff = np.maximum(re_orig, fault_time + detect_delay)
            ids_w = np.concatenate([ids_w, re_ids])
            eff_w = np.concatenate([orig_w, re_eff])
            orig_w = np.concatenate([orig_w, re_orig])
            flag_w = np.zeros(ids_w.shape[0], dtype=bool)
            flag_w[-re_ids.shape[0]:] = True
            order = np.argsort(eff_w, kind="stable")
            ids_w, eff_w = ids_w[order], eff_w[order]
            orig_w, flag_w = orig_w[order], flag_w[order]
        else:
            eff_w = orig_w
        _drain(w, ids_w, eff_w, orig_w, flag_w)

    transition_end = None
    if death_ev is not None:
        transition_end = (float(np.max(np.concatenate(reroute_done)))
                          if reroute_done else float(fault_time))
        if tracer.enabled:
            tracer.record_span(
                "serve.worker_death", float(fault_time), transition_end,
                cat="fault", clock="model", track=f"serve.worker{dead}",
                args={"worker": int(dead), "rerouted": rerouted_n})
        fault_plan.mark_handled(death_ev)  # every rerouted request answered

    return ServingReport(
        k=k,
        offered_qps=(request_ids.shape[0] / max(float(arrivals.max()), 1e-9)
                     if request_ids.size else 0.0),
        latency=(np.concatenate(latencies) if latencies
                 else np.zeros(0)),
        latency_worker=(np.concatenate(lat_worker) if lat_worker
                        else np.zeros(0, np.int64)),
        host_time=np.asarray(host_times),
        service_time=np.asarray(service_times),
        batch_size=np.asarray(bsizes, dtype=np.int64),
        batch_worker=np.asarray(bworkers, dtype=np.int64),
        fetch=FetchStats.merge(all_stats),
        duration=float(arrivals.max()) if arrivals.size else 0.0,
        queue_wait=(np.concatenate(queue_waits) if queue_waits
                    else np.zeros(0)),
        batch_miss=np.asarray(bmiss, dtype=np.int64),
        arrival=(np.concatenate(arrival_rec) if arrival_rec
                 else np.zeros(0)),
        fault_time=fault_time,
        dead_worker=dead,
        rerouted=rerouted_n,
        transition_end=transition_end,
    )


def build_serving(
    graph,
    vbook,
    spec: GNNSpec,
    params: Any,
    embeddings: list,
    *,
    hops: int = 1,
    fanout: int = 10,
    max_batch: int = 32,
    max_wait: float = 2e-3,
    cache_policy: str = "none",
    cache_budget: int = 0,
    seed: int = 0,
    codec=None,
) -> tuple[list, list, RowStore]:
    """Wire per-worker (engines, batchers) over one embedding store.

    `embeddings` is the `LayerwiseInference.run()` output (layer outputs,
    input side first); serving with `hops` recompute layers reads the
    layer-(L-1-hops) store. The single store serving reads is built here so
    callers cannot desync cache policy/budget across workers.
    """
    from repro.gnn.inference import build_embedding_stores

    L = spec.num_layers
    if hops == L:
        raise ValueError(
            "hops == num_layers is feature-store inference — use the "
            "mini-batch path (gnn/minibatch.py); serving reads embeddings")
    source = embeddings[L - 1 - hops]
    store = build_embedding_stores(
        graph, vbook, [source], policy=cache_policy, budget=cache_budget,
        seed=seed, codec=codec,
    )[0]
    fanouts = (fanout,) * hops
    tiled = spec.agg_backend != "scatter"
    engines, batchers = [], []
    for w in range(vbook.k):
        batchers.append(MicroBatcher.build(
            graph, fanouts=fanouts, max_batch=max_batch, owner=vbook.owner,
            worker=w, tiled_layout=tiled, max_wait=max_wait, seed=seed + w,
        ))
        engines.append(ServeEngine(
            spec=spec, params=params, store=store,
            plan=batchers[w].plan, hops=hops, worker=w,
        ))
    return engines, batchers, store
