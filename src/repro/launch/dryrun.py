import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, WITHOUT allocating any real model:
  * compiled.memory_analysis()   -> bytes per device (fits-in-HBM proof)
  * compiled.cost_analysis()     -> HLO flops / bytes     (roofline terms)
  * collective bytes parsed from the compiled HLO text    (roofline term 3)

Results are cached incrementally in dryrun_results.json so interrupted runs
resume. Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-cell ...]
"""

import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs.base import SHAPES, get_config, list_archs, shape_cells
from repro.dist import steps as steps_lib
from repro.dist.sharding import ShardingPolicy
from repro.launch.hlo import collective_bytes_from_hlo  # noqa: F401 (re-export)
from repro.launch.mesh import TPU_V5E, make_production_mesh

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "dryrun_results.json")
RESULTS_PATH = os.path.abspath(
    os.environ.get("DRYRUN_RESULTS", "/root/repo/dryrun_results.json")
)

def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D for inference."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


# Per-cell gradient-accumulation overrides: archs whose attention heads do
# not divide the TP degree (hymba: 25) can't shard attention interiors; the
# standard production lever is microbatching the global batch.
MICROBATCH_OVERRIDES = {
    ("hymba-1.5b", "train_4k"): 2,
}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             policy=None, remat: bool = True, quiet: bool = False,
             microbatches: int = 0, strategy: str = "tp_sp") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    if not microbatches:
        microbatches = MICROBATCH_OVERRIDES.get((arch, shape_name), 1)
    if policy is None and strategy != "tp_sp":
        policy = ShardingPolicy(strategy=strategy)
    t0 = time.time()
    cell = steps_lib.build_cell(cfg, shape, mesh, policy=policy, remat=remat,
                                microbatches=microbatches)
    lowered = cell.lower(mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    flops_total = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # memory_analysis is per-device on SPMD executables
        "bytes_per_device": {
            "argument": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak": int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0)
            ),
        },
        "hlo_flops_per_device": flops_total,
        "hlo_bytes_per_device": bytes_accessed,
        "collectives": coll,
        "model_flops_global": model_flops(cfg, shape),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    # roofline terms — two sources:
    #  * hlo_*: from the compiled artifact. CAVEAT: HloCostAnalysis visits
    #    while-loop bodies ONCE, so scan-over-layers flops/bytes are
    #    under-counted ~L-fold. Kept as the compiled cross-check (and the
    #    collective schedule is real).
    #  * analytic: repro.dist.costs — exact matmul accounting per cell;
    #    these are the §Roofline numbers.
    peak = TPU_V5E["peak_flops_bf16"]
    hbm = TPU_V5E["hbm_bandwidth"]
    ici = TPU_V5E["ici_link_bandwidth"]
    out["roofline_hlo"] = {
        "compute_s": flops_total / peak,
        "memory_s": bytes_accessed / hbm,
        "collective_s": coll["total_bytes"] / ici,
    }
    from repro.dist.costs import cell_costs

    costs = cell_costs(cfg, shape, dict(mesh.shape), strategy=strategy)
    rf = costs.roofline()
    out["roofline"] = {
        "compute_s": rf["compute_s"],
        "memory_s": rf["memory_s"],
        "collective_s": rf["collective_s"],
        "dominant": rf["dominant"],
        "bound_s": rf["bound_s"],
        "mfu_bound": rf["mfu_bound"],
        "useful_flops_ratio": costs.model_flops_global
        / max(costs.flops * n_dev, 1.0),
    }
    out["analytic"] = {
        "flops_per_device": costs.flops,
        "hbm_bytes_per_device": costs.hbm_bytes,
        "collective_bytes_per_device": costs.collective_bytes,
    }
    if not quiet:
        hbm_ok = out["bytes_per_device"]["peak"] <= TPU_V5E["hbm_bytes"]
        r = out["roofline"]
        print(
            f"[dryrun] {arch} x {shape_name} x {out['mesh']}: "
            f"compile {t_compile:.0f}s, peak/dev "
            f"{out['bytes_per_device']['peak']/2**30:.2f} GiB "
            f"({'fits' if hbm_ok else 'OVER'}), dominant={r['dominant']}, "
            f"terms c/m/n = {r['compute_s']*1e3:.2f}/"
            f"{r['memory_s']*1e3:.2f}/"
            f"{r['collective_s']*1e3:.2f} ms, mfu_bound={r['mfu_bound']:.3f}"
        )
    return out


def load_results() -> dict:
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            return json.load(f)
    return {}


def save_result(key: str, value: dict) -> None:
    results = load_results()
    results[key] = value
    tmp = RESULTS_PATH + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, RESULTS_PATH)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 (512-chip) mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--strategy", default="tp_sp", choices=["tp_sp", "fsdp"],
                    help="sharding strategy (fsdp = the §Perf-winning ZeRO-3)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        "dry-run needs the 512 placeholder devices; do not import jax before "
        "this module sets XLA_FLAGS"
    )

    cells: list[tuple[str, str, bool]] = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        shapes = shape_cells(arch) if (args.all or not args.shape) else [args.shape]
        for sh in shapes:
            if args.both_meshes:
                cells.append((arch, sh, False))
                cells.append((arch, sh, True))
            else:
                cells.append((arch, sh, args.multi_pod))

    failures = 0
    for arch, sh, mp in cells:
        key = f"{arch}|{sh}|{'2x16x16' if mp else '16x16'}"
        if not args.force and key in load_results():
            print(f"[dryrun] cached: {key}")
            continue
        try:
            res = run_cell(arch, sh, multi_pod=mp)
            save_result(key, res)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"[dryrun] FAIL {key}: {type(e).__name__}: {e}")
            traceback.print_exc()
            save_result(key, {"error": f"{type(e).__name__}: {e}"[:500],
                              "arch": arch, "shape": sh})
    print(f"[dryrun] done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
