"""gnn_trace: record a traced run per regime and reconcile it against the
analytic cost model — the runtime twin of the gnn_lint static gate.

Runs small representative programs with the tracer installed:

  fullbatch-halo / fullbatch-ring : one traced training step per sync
      strategy; the strategies report every collective (kind + bytes) at
      jax trace time, reconciled against `collective_budget` and
      `sync_wire_bytes_per_round`, and the per-epoch wire bytes against
      `FullBatchTrainer.wire_bytes_per_epoch`.
  minibatch : serial mini-batch steps; feature-fetch wire/miss bytes are
      measured at the gather encode site and reconciled against
      `Codec.wire_bytes`, phases against the step wall, the gradient
      all-reduce against `cost_model.minibatch_step`'s parameter count.
  serve : layer-wise inference + the micro-batched serving sim; embedding
      wire bytes and the request-latency closure are reconciled.

Outputs a merged Chrome trace-event timeline (schema gnn-trace/v1, loadable
in https://ui.perfetto.dev or chrome://tracing) which is round-tripped
through the exporter's own loader, plus a JSON reconciliation report
(schema "gnn-trace-report/v2", the gnn-lint report shape). Run from the
repo root:

    PYTHONPATH=src python -m repro.launch.gnn_trace --smoke \
        --out-trace trace.json --out-json gnn_trace_report.json

Exit code 0 = every check holds; 1 = at least one reconciliation
violation. `--inject-violation` adds one stray byte to the measured
mini-batch fetch counter — a deliberate byte mismatch proving the gate
exits non-zero (fp32 checks are EXACT: one byte is enough).
"""

# pin the backend before anything imports jax (same pin gnn_lint uses);
# every program here runs in sim mode (vmap), so no forced device count
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import sys
import time


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="gnn_trace",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    p.add_argument("--smoke", action="store_true",
                   help="run the CI-sized programs (default sizes are a "
                        "seconds-fast cross-section)")
    p.add_argument("--codec", default="fp32",
                   help="wire codec for every program (fp32 reconciles "
                        "exactly; int8 within its codec-width ratio)")
    p.add_argument("--out-trace", default="trace.json", metavar="PATH",
                   help="write the merged Chrome trace-event JSON here")
    p.add_argument("--out-json", default=None, metavar="PATH",
                   help="write the reconciliation report here "
                        "('-' for stdout)")
    p.add_argument("--inject-violation", action="store_true",
                   help="corrupt the measured mini-batch fetch counter by "
                        "one byte — proves the gate exits 1")
    p.add_argument("--scale", type=float, default=None,
                   help="graph scale (default 0.01; --smoke 0.02)")
    p.add_argument("--k", type=int, default=None,
                   help="partitions/devices (default 2; --smoke 4)")
    p.add_argument("--steps", type=int, default=None,
                   help="mini-batch steps resp. full-batch epochs "
                        "(default 2; --smoke 3)")
    p.add_argument("--requests", type=int, default=None,
                   help="serving request-trace length "
                        "(default 60; --smoke 160)")
    p.add_argument("--seed", type=int, default=0)
    return p


def _spec(feature: int = 32, hidden: int = 32):
    from repro.gnn.models import GNNSpec

    return GNNSpec(model="sage", feature_dim=feature, hidden_dim=hidden,
                   num_classes=8, num_layers=2)


def _node_data(g, spec, seed: int):
    import numpy as np

    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(g.num_vertices, spec.feature_dim)).astype(
        np.float32)
    labels = rng.integers(0, spec.num_classes, g.num_vertices).astype(
        np.int32)
    train = rng.random(g.num_vertices) < 0.3
    return feats, labels, train


def _run_fullbatch(g, spec, args, sync_mode: str):
    """One traced full-batch program; returns (tracer, checks)."""
    from repro.core.edge_partition import partition_edges
    from repro.gnn.fullbatch import FullBatchTrainer
    from repro.obs import Tracer, install, reconcile, uninstall

    feats, labels, train = _node_data(g, spec, args.seed)
    part = "blockrow" if sync_mode == "ring" else "hep100"
    assignment = partition_edges(g, args.k, part, seed=args.seed)
    program = f"fullbatch-{sync_mode}"
    tracer = install(Tracer())
    try:
        # the tracer must be live BEFORE the step compiles: collectives
        # are recorded when jax traces the step function
        tr = FullBatchTrainer.build(
            g, assignment, args.k, spec, feats, labels, train,
            sync_mode=sync_mode, mode="sim", seed=args.seed,
            codec=args.codec)
        for _ in range(args.steps):
            tr.train_step()
        checks = reconcile.reconcile_fullbatch(tr, tracer=tracer,
                                               program=program)
    finally:
        uninstall()
    return tracer, checks


def _run_minibatch(g, spec, args):
    from repro.core.vertex_partition import partition_vertices
    from repro.gnn.minibatch import MiniBatchTrainer
    from repro.obs import Tracer, install, reconcile, uninstall

    feats, labels, train = _node_data(g, spec, args.seed)
    owner = partition_vertices(g, args.k, "metis", seed=args.seed,
                               train_mask=train)
    tracer = install(Tracer())
    try:
        tr = MiniBatchTrainer.build(
            g, owner, args.k, spec, feats, labels, train,
            global_batch=64, seed=args.seed, codec=args.codec)
        sms = [tr.train_step() for _ in range(args.steps)]
        tr.close()
        if args.inject_violation:
            # the seeded red path: one stray byte through the REAL
            # measured counter — the fp32 checks are exact, so this must
            # surface as an error-level finding
            tracer.add("fetch.wire_bytes", 1)
        checks = reconcile.reconcile_minibatch(tr, sms, tracer=tracer,
                                               program="minibatch")
    finally:
        uninstall()
    return tracer, checks


def _run_serving(g, spec, args):
    import numpy as np

    from repro.core.edge_partition import partition_edges
    from repro.core.partition_book import build_vertex_book
    from repro.gnn.inference import LayerwiseInference
    from repro.gnn.models import init_params
    from repro.obs import Tracer, install, reconcile, uninstall
    from repro.serve import build_serving, run_serving_sim

    feats, _, _ = _node_data(g, spec, args.seed)
    params = init_params(spec, seed=args.seed)
    assignment = partition_edges(g, args.k, "hep100", seed=args.seed)
    tracer = install(Tracer())
    try:
        eng = LayerwiseInference.build(g, assignment, args.k, spec, params,
                                       feats)
        embeddings = eng.run()
        owner = eng.book.master_assignment()
        vbook = build_vertex_book(g, owner, args.k)
        engines, batchers, store = build_serving(
            g, vbook, spec, params, embeddings, hops=1, fanout=8,
            max_batch=16, max_wait=5e-4, seed=args.seed, codec=args.codec)
        rng = np.random.default_rng(args.seed)
        request_ids = rng.integers(0, g.num_vertices, args.requests)
        arrivals = np.sort(rng.uniform(0.0, args.requests / 200.0,
                                       args.requests))
        report = run_serving_sim(engines, batchers, owner, request_ids,
                                 arrivals)
        checks = reconcile.reconcile_serving(report, store, tracer=tracer,
                                             program="serve")
    finally:
        uninstall()
    return tracer, checks


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    if args.scale is None:
        args.scale = 0.02 if args.smoke else 0.01
    if args.k is None:
        args.k = 4 if args.smoke else 2
    if args.steps is None:
        args.steps = 3 if args.smoke else 2
    if args.requests is None:
        args.requests = 160 if args.smoke else 60

    from repro.core.graph import paper_graph
    from repro.obs import load_trace, reconcile, write_trace

    t_start = time.perf_counter()
    g = paper_graph("OR", scale=args.scale, seed=0)
    spec = _spec()
    print(f"[trace] graph OR x{args.scale}: {g.num_vertices} vertices, "
          f"{g.num_edges} edges; k={args.k}, codec={args.codec}")

    tracers, checks = [], []
    for sync_mode in ("halo", "ring"):
        tr, cs = _run_fullbatch(g, spec, args, sync_mode)
        tracers.append(tr)
        checks.extend(cs)
        print(f"[trace] fullbatch-{sync_mode}: {len(tr)} events, "
              f"{len(cs)} checks")
    tr, cs = _run_minibatch(g, spec, args)
    tracers.append(tr)
    checks.extend(cs)
    print(f"[trace] minibatch: {len(tr)} events, {len(cs)} checks")
    tr, cs = _run_serving(g, spec, args)
    tracers.append(tr)
    checks.extend(cs)
    print(f"[trace] serve: {len(tr)} events, {len(cs)} checks")

    payload = write_trace(args.out_trace, tracers)
    # the exporter's own loader re-parses and validates the file (schema,
    # B/E pairing, per-track monotonic timestamps) — the round-trip gate
    load_trace(args.out_trace)
    print(f"[trace] timeline -> {args.out_trace} "
          f"({len(payload['traceEvents'])} events, round-trip ok)")

    report = reconcile.build_report(
        checks, elapsed_s=time.perf_counter() - t_start)
    out = json.dumps(report.to_dict(), indent=2)
    if args.out_json == "-":
        print(out)
    elif args.out_json:
        with open(args.out_json, "w") as fh:
            fh.write(out + "\n")

    c = report.counts
    print(f"gnn_trace: {len(report.programs)} programs, "
          f"{len(report.checks)} checks in {report.elapsed_s:.1f}s — "
          f"{c.get('ok', 0)} ok, {c.get('warn', 0)} warn, "
          f"{c.get('error', 0)} error(s)")
    for ch in report.checks:
        if ch.level != "ok":
            print(f"  [{ch.level}] {ch.quantity} :: {ch.program}: "
                  f"{ch.message}")
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
