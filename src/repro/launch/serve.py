"""Serving driver: batched prefill + greedy decode with a KV cache.

Smoke-scale on this container; the same decode_step is what the decode_32k /
long_500k dry-run cells lower on the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_config
from repro.models import lm


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 64, gen: int = 32, seed: int = 0):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    lm.set_activation_sharding(None)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    max_len = prompt_len + gen

    batch_in = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    if cfg.encoder_decoder:
        batch_in["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "vlm":
        p = min(cfg.num_patches, 8)
        batch_in["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, p, cfg.d_model)), jnp.bfloat16)
        total = p + prompt_len
        batch_in["pos3"] = jnp.broadcast_to(
            jnp.arange(total)[None, None], (3, batch, total)).astype(jnp.int32)
        prompt_len = total

    prefill = jax.jit(lambda pr, b: lm.prefill(cfg, pr, b, max_len=max_len))
    t0 = time.perf_counter()
    logits, caches = prefill(params, batch_in)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(
        lambda pr, t, c, i, p3: lm.decode_step(cfg, pr, t, c, i, pos3=p3))
    tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tokens]
    t0 = time.perf_counter()
    for step in range(gen - 1):
        idx = jnp.asarray(prompt_len + step, jnp.int32)
        pos3 = None
        if cfg.family == "vlm":
            pos3 = jnp.broadcast_to(idx, (3, batch, 1)).astype(jnp.int32)
        logits, caches = decode(params, tokens, caches, idx, pos3)
        tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0
    seqs = jnp.concatenate(out_tokens, axis=1)
    return seqs, t_prefill, t_decode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    seqs, t_prefill, t_decode = serve(
        args.arch, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)
    per_tok = t_decode / max(args.gen - 1, 1) / args.batch * 1e3
    print(f"[serve] generated {seqs.shape} tokens; prefill {t_prefill:.2f}s, "
          f"decode {t_decode:.2f}s ({per_tok:.1f} ms/token/seq)")
    print("[serve] sample:", np.asarray(seqs[0])[:16].tolist())


if __name__ == "__main__":
    main()
