"""The serving driver: partition, layer-wise-infer, then serve traffic.

The full serving stack on one command line: partition a graph (edge OR
vertex partitioner — the embedding store shards by masters resp. owners),
run the distributed layer-wise inference engine to materialise the
per-layer embedding stores (gnn/inference.py), then drive a Poisson request
trace through the micro-batched online path (repro.serve) and report
per-worker p50/p99 latency and sustainable QPS on the paper's cluster.

  PYTHONPATH=src python -m repro.launch.gnn_serve --graph OR --scale 0.05 \
      --partitioner hep100 --k 4 --model sage --qps 100 --smoke
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import study
from repro.core.edge_partition import EDGE_PARTITIONERS, partition_edges
from repro.core.graph import paper_graph
from repro.core.metrics import edge_partition_metrics, vertex_partition_metrics
from repro.core.partition_book import build_vertex_book
from repro.core.vertex_partition import VERTEX_PARTITIONERS, partition_vertices
from repro.core.wire import CODECS
from repro.gnn.feature_store import CACHE_POLICIES
from repro.gnn.inference import (
    LayerwiseInference,
    edge_assignment_from_vertex,
)
from repro.gnn.models import GNNSpec, init_params
from repro.serve import build_serving, run_serving_sim


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="OR", choices=["HO", "DI", "EN", "EU", "OR"])
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--partitioner", default="hep100",
                    help="edge partitioner (store shards by masters) or "
                         "vertex partitioner (store shards by owners)")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--model", default="sage", choices=["sage", "gcn", "gat"])
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--features", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--agg-backend", default="scatter",
                    choices=["scatter", "tiled", "pallas"])
    ap.add_argument("--qps", type=float, default=100.0,
                    help="offered load (Poisson arrivals, whole cluster)")
    ap.add_argument("--requests", type=int, default=1000,
                    help="length of the simulated request trace")
    ap.add_argument("--hops", type=int, default=1,
                    help="final layers recomputed per request (1..layers-1); "
                         "the rest is read from the embedding store")
    ap.add_argument("--fanout", type=int, default=10)
    ap.add_argument("--batch", type=int, default=32,
                    help="micro-batch size cap")
    ap.add_argument("--max-wait", type=float, default=5e-4,
                    help="seconds a request may wait for its micro-batch")
    ap.add_argument("--codec", default="fp32", choices=list(CODECS),
                    help="wire codec (core/wire.py) on the embedding store: "
                         "remote-miss rows are shipped encoded and decoded "
                         "at the reader; service time is priced from "
                         "encoded bytes")
    ap.add_argument("--cache-policy", default="none",
                    choices=list(CACHE_POLICIES))
    ap.add_argument("--cache-budget", type=int, default=0,
                    help="cached remote embedding rows per worker")
    ap.add_argument("--out-json", default="",
                    help="write the study-format serving row here")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="record the span/counter timeline to PATH (Chrome "
                         "trace-event JSON, schema gnn-trace/v1: inference "
                         "layers + real gather/compute spans on the host "
                         "process, the request lifecycle on the simulated "
                         "clock) and write the reconciliation report to "
                         "PATH.report.json")
    ap.add_argument("--inject-fault", action="append", default=[],
                    metavar="SPEC",
                    help="deterministic fault injection (repeatable): "
                         "worker-death@t:0.5,worker:1 kills a serving "
                         "worker at virtual time t; its requests fail over "
                         "to surviving workers (replica-aware "
                         "master_assignment re-derivation) and EVERY "
                         "request is still answered")
    ap.add_argument("--detect-delay", type=float, default=0.0,
                    help="seconds before a death is detected (rerouted "
                         "requests become visible to survivors after it)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-fast: trim the request trace")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 200)

    plan = None
    if args.inject_fault:
        from repro.fault import FaultPlan, FaultSpecError
        try:
            plan = FaultPlan.parse(args.inject_fault, seed=args.seed)
        except FaultSpecError as e:
            print(f"[serve] bad --inject-fault: {e}")
            sys.exit(1)
        print(f"[serve] fault plan: "
              f"{'; '.join(ev.describe() for ev in plan.events)}")

    tracer = None
    if args.trace:
        from repro.obs import Tracer, install
        tracer = install(Tracer())

    g = paper_graph(args.graph, scale=args.scale, seed=0)
    print(f"[serve] graph {args.graph}: {g.num_vertices} vertices, "
          f"{g.num_edges} edges")
    spec = GNNSpec(model=args.model, feature_dim=args.features,
                   hidden_dim=args.hidden, num_classes=args.classes,
                   num_layers=args.layers, agg_backend=args.agg_backend)
    rng = np.random.default_rng(args.seed)
    feats = rng.normal(size=(g.num_vertices, args.features)).astype(np.float32)
    params = init_params(spec, seed=args.seed)

    # ---------------------------------------------------------- partition
    t0 = time.perf_counter()
    if args.partitioner in EDGE_PARTITIONERS:
        edge_assignment = partition_edges(g, args.k, args.partitioner,
                                          seed=args.seed)
        pt = time.perf_counter() - t0
        m = edge_partition_metrics(g, edge_assignment, args.k)
        quality = m.replication_factor
        print(f"[serve] edge-partitioned in {pt:.2f}s: "
              f"rf={m.replication_factor:.2f} edge_bal={m.edge_balance:.2f}")
        owner = None  # derived from masters below
    else:
        assert args.partitioner in VERTEX_PARTITIONERS, (
            f"unknown partitioner {args.partitioner!r}; edge options "
            f"{sorted(EDGE_PARTITIONERS)}, vertex options "
            f"{sorted(VERTEX_PARTITIONERS)}")
        owner = partition_vertices(g, args.k, args.partitioner, seed=args.seed)
        pt = time.perf_counter() - t0
        m = vertex_partition_metrics(g, owner, args.k)
        quality = m.edge_cut
        print(f"[serve] vertex-partitioned in {pt:.2f}s: "
              f"edge_cut={m.edge_cut:.3f} vertex_bal={m.vertex_balance:.2f}")
        edge_assignment = edge_assignment_from_vertex(g, owner)

    # ------------------------------------------- layer-wise embedding pass
    engine = LayerwiseInference.build(
        g, edge_assignment, args.k, spec, params, feats)
    embeddings = engine.run()
    if owner is None:
        owner = engine.book.master_assignment()
    vbook = build_vertex_book(g, owner, args.k)
    dims = "/".join(str(e.shape[1]) for e in embeddings)
    print(f"[serve] layer-wise inference: {len(embeddings)} layers "
          f"(dims {dims}) in {sum(engine.layer_times):.2f}s host, "
          f"halo traffic {engine.sync_bytes()/2**20:.1f} MiB/pass")

    # ------------------------------------------------------- online serving
    engines, batchers, store = build_serving(
        g, vbook, spec, params, embeddings,
        hops=args.hops, fanout=args.fanout, max_batch=args.batch,
        max_wait=args.max_wait, cache_policy=args.cache_policy,
        cache_budget=args.cache_budget, seed=args.seed, codec=args.codec,
    )
    if args.cache_budget:
        print(f"[serve] embedding cache: policy={args.cache_policy} "
              f"budget={args.cache_budget}/worker "
              f"(filled {store.cache_sizes.tolist()})")
    request_ids = rng.integers(0, g.num_vertices, args.requests)
    arrivals = np.sort(rng.uniform(0.0, args.requests / args.qps,
                                   args.requests))
    failover = None
    if plan is not None and plan.events_of("worker-death"):
        from repro.fault import recovery as fault_recovery
        ev = plan.events_of("worker-death")[0]
        dead = plan.resolve_worker(ev, args.k)
        # replica-aware only for edge partitions: mirrors already hold the
        # dead master's vertices; vertex partitions spread deterministically
        book = engine.book if args.partitioner in EDGE_PARTITIONERS else None
        failover = fault_recovery.failover_assignment(
            owner, dead, args.k, book=book)
        moved = int((np.asarray(owner) == dead).sum())
        print(f"[serve] failover map: worker {dead} dies, {moved} vertices "
              f"re-mastered ({'replica-aware' if book is not None else 'spread'})")
    report = run_serving_sim(engines, batchers, owner, request_ids, arrivals,
                             fault_plan=plan, failover_owner=failover,
                             detect_delay=args.detect_delay)

    for row in report.worker_rows():
        print(f"[serve] worker {row['worker']}: served {row['served']:5d}  "
              f"p50 {row['p50']*1e3:7.2f} ms  p99 {row['p99']*1e3:7.2f} ms  "
              f"sustainable {row['qps_sustainable']:8.0f} qps")
    print(f"[serve] cluster: offered {args.qps:.0f} qps, served "
          f"{report.served()} requests in {report.duration:.2f}s  "
          f"p50 {report.p50()*1e3:.2f} ms  p99 {report.p99()*1e3:.2f} ms  "
          f"sustainable {report.sustainable_qps():.0f} qps/cluster")
    print(f"[serve] store traffic: hit_rate {report.fetch.hit_rate:.2f}  "
          f"miss {report.fetch.miss_bytes/2**20:.2f} MiB  "
          f"wire {report.fetch.wire_bytes/2**20:.2f} MiB ({args.codec})  "
          f"host compute p50 {np.percentile(report.host_time, 50)*1e3:.2f} "
          f"ms/batch")
    if report.fault_time is not None:
        ts = report.transition_stats()
        answered = report.served() == args.requests
        print(f"[serve] worker-death: worker {report.dead_worker} died at "
              f"t={ts['fault_time']:.3f}s, {ts['rerouted']} requests "
              f"rerouted, transition window {ts['window']*1e3:.1f} ms "
              f"({ts['requests']} requests, p50 {ts['p50']*1e3:.2f} ms, "
              f"p99 {ts['p99']*1e3:.2f} ms)")
        print(f"[serve] every request answered: {answered} "
              f"({report.served()}/{args.requests})")
        if not answered:
            sys.exit(1)

    if args.out_json:
        row = study.serve_result_row(
            args.graph, args.partitioner, args.k, spec, report,
            qps=args.qps, hops=args.hops, fanout=args.fanout,
            max_batch=args.batch, max_wait=args.max_wait,
            cache_policy=args.cache_policy, cache_budget=args.cache_budget,
            partition_time=pt, partition_quality=quality, codec=args.codec,
        )
        study.write_rows([row], args.out_json)
        print(f"[serve] wrote study row -> {args.out_json}")

    if tracer is not None:
        import json

        from repro.obs import reconcile, write_trace

        checks = reconcile.reconcile_serving(report, store, tracer=tracer)
        if plan is not None:
            checks += reconcile.reconcile_recovery(plan, tracer=tracer)
        rep = reconcile.build_report(checks)
        write_trace(args.trace, tracer)
        with open(args.trace + ".report.json", "w") as fh:
            json.dump(rep.to_dict(), fh, indent=2)
            fh.write("\n")
        c = rep.counts
        print(f"[serve] trace -> {args.trace} "
              f"(report {args.trace}.report.json: {c.get('ok', 0)} ok, "
              f"{c.get('warn', 0)} warn, {c.get('error', 0)} error)")
        for ch in rep.checks:
            if ch.level == "error":
                print(f"  [error] {ch.quantity}: {ch.message}")


if __name__ == "__main__":
    main()
