"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state. Single pod = (data=16, model=16) = 256 chips (one TPU v5e pod slice);
multi-pod adds a leading 'pod' axis: (pod=2, data=16, model=16) = 512 chips.

The `pod` axis is the slow (DCN/inter-pod) dimension: only data-parallel
gradient reduction crosses it; `model` stays inside a pod (ICI).
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n: int) -> dict:
    # jax >= 0.5 wants explicit axis_types; jax 0.4.x has neither the
    # parameter nor jax.sharding.AxisType. Auto is the 0.4.x behavior.
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests use small ones, e.g. (2, 2))."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
TPU_V5E = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bandwidth": 819e9,      # bytes/s
    "hbm_bytes": 16 * 2**30,
    "ici_link_bandwidth": 50e9,  # bytes/s per link
}
