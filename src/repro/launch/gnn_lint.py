"""gnn_lint: the distributed-invariant static-analysis gate.

Builds one representative program per (entry point x model x aggregation
backend x sync strategy x wire codec) cell — full-batch and mini-batch
training steps, the layer-wise inference pass and the online serving
forward — and runs every registered rule over their traced jaxprs and
compiled HLO. Run from the repo root:

    PYTHONPATH=src python -m repro.launch.gnn_lint --smoke \
        --out-json gnn_lint_report.json

Exit code 0 = no error-level findings; 1 = at least one violation.

The JSON report (schema "gnn-lint-report/v1"):

    {
      "schema":   "gnn-lint-report/v1",
      "programs": [name, ...],            # every program analyzed
      "rules":    [name, ...],            # every rule run
      "counts":   {"error": n, "warn": n, "info": n},
      "exit_code": 0 | 1,
      "elapsed_s": float,
      "findings": [
        {"rule": str, "program": str,
         "level": "error" | "warn" | "info",
         "message": str, "data": {...}},  # data is rule-specific detail
        ...
      ]
    }
"""

# XLA device count is fixed at backend init: force the host devices the
# compiled-HLO programs shard over BEFORE anything imports jax.
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import sys


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="gnn_lint",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    p.add_argument("--smoke", action="store_true",
                   help="run the full smoke grid (same as --grid smoke; "
                        "the CI gate)")
    p.add_argument("--grid", choices=("tiny", "smoke"), default=None,
                   help="program grid: 'tiny' is a seconds-fast "
                        "cross-section (trace-only), 'smoke' is the full "
                        "gate incl. compiled-HLO budgets and retrace "
                        "sweeps (default: tiny)")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule subset (default: all); "
                        "known rules are listed by --list-rules")
    p.add_argument("--out-json", default=None, metavar="PATH",
                   help="write the JSON report here ('-' for stdout)")
    p.add_argument("--inject-violation", default=None, metavar="RULE",
                   help="append a program deliberately violating RULE — "
                        "proves the gate exits non-zero")
    p.add_argument("--deadcode", action="store_true",
                   help="also run the advisory dead-export sweep "
                        "(warn-level findings; never affects exit code)")
    p.add_argument("--list-programs", action="store_true",
                   help="print the grid's program names and exit")
    p.add_argument("--list-rules", action="store_true",
                   help="print registered rules and exit")
    return p


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    grid = args.grid or ("smoke" if args.smoke else "tiny")

    from repro.analysis import (
        RULES, Finding, build_programs, run_rules, violation_program,
    )

    if args.list_rules:
        for name in sorted(RULES):
            print(f"{name:20s} {RULES[name].doc}")
        return 0

    programs = build_programs(grid)
    if args.inject_violation:
        programs.append(violation_program(args.inject_violation))
    if args.list_programs:
        for prog in programs:
            print(f"{prog.kind:10s} {prog.name}")
        return 0

    rules = args.rules.split(",") if args.rules else None
    if rules:
        unknown = sorted(set(rules) - set(RULES))
        if unknown:
            print(f"unknown rules: {unknown}; known: {sorted(RULES)}",
                  file=sys.stderr)
            return 2

    report = run_rules(programs, rules)

    if args.deadcode:
        from repro.analysis.deadcode import dead_exports

        for name, files in dead_exports(os.getcwd()):
            report.findings.append(Finding(
                rule="dead-code", program=files[0], level="warn",
                message=f"public export {name!r} is referenced nowhere "
                        "outside its definition",
                data={"symbol": name, "defined_in": files}))
        report.rules_run.append("dead-code")

    payload = json.dumps(report.to_dict(), indent=2)
    if args.out_json == "-":
        print(payload)
    elif args.out_json:
        with open(args.out_json, "w") as fh:
            fh.write(payload + "\n")

    by_level = {"error": [], "warn": [], "info": []}
    for f in report.findings:
        by_level.setdefault(f.level, []).append(f)
    print(f"gnn_lint: {len(report.programs_run)} programs x "
          f"{len(report.rules_run)} rules in {report.elapsed_s:.1f}s — "
          f"{len(by_level['error'])} error(s), "
          f"{len(by_level['warn'])} warning(s)")
    for f in by_level["error"] + by_level["warn"]:
        print(f"  [{f.level}] {f.rule} :: {f.program}: {f.message}")
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
