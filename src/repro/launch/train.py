"""LM training driver: config-selected arch, fault-tolerant loop.

Runs on anything from this 1-CPU container (smoke configs) to a multi-pod
mesh (full configs; same code path the dry-run lowers). Features:

  * --arch <id> selects any of the ten assigned architectures
  * checkpoint/restart: atomic keep-k checkpoints, auto-resume, deterministic
    data pipeline (batch i is a function of (seed, i) — restart-exact)
  * per-step retry: a transient device failure re-runs the step from the
    last good state; repeated failure restores the last checkpoint
  * --simulate-failure N injects a failure at step N (used by tests)

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 30 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs.base import get_config, smoke_config
from repro.data.tokens import SyntheticTokens
from repro.dist import steps as steps_lib
from repro.models import lm
from repro.optim import adam_init


class TransientFailure(RuntimeError):
    pass


def train(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 30,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    ckpt_dir: str | None = None,
    ckpt_every: int = 10,
    seed: int = 0,
    simulate_failure_at: int = -1,
    log_every: int = 5,
) -> list[float]:
    cfg = smoke_config(arch) if smoke else get_config(arch)
    lm.set_activation_sharding(None)  # single-host path: no pins
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = adam_init(params)
    data = SyntheticTokens(cfg.vocab_size, seq, batch, seed=seed)
    step_fn = jax.jit(steps_lib.make_train_fn(cfg, lr=lr, remat=False))

    manager = CheckpointManager(ckpt_dir, every=ckpt_every) if ckpt_dir else None
    start = 0
    if manager is not None:
        restored_step, (params, opt_state) = manager.restore((params, opt_state))
        if restored_step is not None:
            start = restored_step + 1
            print(f"[train] resumed from step {restored_step}")

    losses: list[float] = []
    failed_once = False
    i = start
    while i < steps:
        t0 = time.perf_counter()
        raw = data.batch(i)
        b = {"tokens": jax.numpy.asarray(raw["tokens"])}
        if cfg.encoder_decoder:
            rng = np.random.default_rng((seed, i, 7))
            b["frames"] = jax.numpy.asarray(
                rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model))
                .astype(np.float32), jax.numpy.bfloat16)
        if cfg.family == "vlm":
            rng = np.random.default_rng((seed, i, 8))
            p = min(cfg.num_patches, 8)
            b["patch_embeds"] = jax.numpy.asarray(
                rng.normal(size=(batch, p, cfg.d_model)).astype(np.float32),
                jax.numpy.bfloat16)
            total = p + seq
            b["pos3"] = jax.numpy.broadcast_to(
                jax.numpy.arange(total)[None, None], (3, batch, total)
            ).astype(jax.numpy.int32)
        try:
            if i == simulate_failure_at and not failed_once:
                failed_once = True
                raise TransientFailure(f"injected failure at step {i}")
            loss, gnorm, params, opt_state = step_fn(params, opt_state, b)
        except TransientFailure as e:
            print(f"[train] step {i} failed ({e}); retrying from last state")
            continue  # params/opt_state unchanged -> pure retry
        loss = float(loss)
        losses.append(loss)
        if manager is not None:
            manager.maybe_save(i, (params, opt_state), {"loss": loss})
        if i % log_every == 0:
            print(f"[train] step {i:5d} loss {loss:.4f} "
                  f"gnorm {float(gnorm):.3f} {time.perf_counter()-t0:.2f}s")
        i += 1
    if manager is not None and steps > 0:
        manager.maybe_save(steps - 1, (params, opt_state), force=True)
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--simulate-failure", type=int, default=-1)
    args = ap.parse_args()
    losses = train(
        args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, seed=args.seed,
        simulate_failure_at=args.simulate_failure,
    )
    print(f"[train] done; first loss {losses[0]:.4f}, last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
