"""The paper's training driver: partition a graph, train a GNN distributed.

Both regimes:
  --regime fullbatch  : DistGNN-style (edge partitioning, replica sync)
  --regime minibatch  : DistDGL-style (vertex partitioning, sampling+fetch)

Usage:
  PYTHONPATH=src python -m repro.launch.gnn_train --graph OR --scale 0.05 \
      --partitioner hep100 --k 8 --model sage --regime fullbatch --epochs 5
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import cost_model, study
from repro.core.edge_partition import EDGE_PARTITIONERS, partition_edges
from repro.core.wire import CODECS
from repro.core.graph import paper_graph
from repro.core.metrics import edge_partition_metrics, vertex_partition_metrics
from repro.core.vertex_partition import VERTEX_PARTITIONERS, partition_vertices
from repro.fault import (FAULT_KINDS, FaultInjector, FaultPlan,
                         FaultSpecError, WorkerCrash,
                         corrupt_latest_checkpoint)
from repro.gnn.feature_store import CACHE_POLICIES
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.minibatch import MiniBatchTrainer
from repro.gnn.models import GNNSpec

CRASH_EXIT = 3  # injected worker crash (distinct from real failures)


def _crash_exit(e: WorkerCrash, args) -> None:
    print(f"[gnn] FATAL: {e}")
    if args.ckpt_dir:
        print(f"[gnn] resume: re-run with --resume "
              f"(checkpoints in {args.ckpt_dir})")
    sys.exit(CRASH_EXIT)


def _mark_corrupt_handled(plan) -> None:
    """A corrupt-ckpt fault is handled once restore fell back gracefully."""
    if plan is None:
        return
    for ev in plan.fired_events():
        if ev.kind == "corrupt-ckpt":
            plan.mark_handled(ev)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="OR", choices=["HO", "DI", "EN", "EU", "OR"])
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--partitioner", default="hep100")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--model", default="sage", choices=["sage", "gcn", "gat"])
    ap.add_argument("--regime", default="fullbatch",
                    choices=["fullbatch", "minibatch"])
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--features", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--sync-mode", "--sync", dest="sync_mode", default="halo",
                    choices=["halo", "dense", "ring"],
                    help="full-batch sync strategy (gnn/sync.py): halo = "
                         "static-routed replica exchange, dense = global "
                         "psum baseline, ring = 1.5D ppermute block "
                         "rotation (ignores --partitioner: the blockrow "
                         "layout needs no partitioning pass)")
    ap.add_argument("--agg-backend", default="scatter",
                    choices=["scatter", "tiled", "pallas"],
                    help="aggregation backend (kernels.ops.aggregate): "
                         "data-dependent scatter, tiled segment-SpMM layout, "
                         "or the Pallas kernel (interpreted off-TPU)")
    ap.add_argument("--rebalance", action="store_true",
                    help="dynamic seed rebalancing (straggler mitigation)")
    ap.add_argument("--overlap", action="store_true",
                    help="pipelined mini-batch execution (gnn/pipeline.py): "
                         "sampling + feature prefetch for step t+1 run on a "
                         "producer thread while the device computes step t; "
                         "same batches as serial given the same seed")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="batches prepared ahead of the device step "
                         "(bounded queue; only read with --overlap)")
    ap.add_argument("--codec", default="fp32", choices=list(CODECS),
                    help="wire codec (core/wire.py) for the byte-moving "
                         "paths: replica sync + gradient all-reduce "
                         "(fullbatch) resp. feature fetch + gradient "
                         "all-reduce (minibatch). fp32 is exact; int8 adds "
                         "error feedback on gradients; variable ramps the "
                         "ratio by layer and epoch")
    ap.add_argument("--cache-policy", default="none",
                    choices=list(CACHE_POLICIES),
                    help="per-worker remote-feature cache policy (minibatch)")
    ap.add_argument("--cache-budget", type=int, default=0,
                    help="cached remote vertices per worker (minibatch)")
    ap.add_argument("--out-json", default="",
                    help="write the run's study-format row(s) here "
                         "(core/study.py serializers — same format the "
                         "benchmark drivers emit)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="record the run's span/counter timeline to PATH "
                         "(Chrome trace-event JSON, schema gnn-trace/v1; "
                         "open in https://ui.perfetto.dev or "
                         "chrome://tracing) and write the measured-vs-"
                         "model reconciliation report to PATH.report.json")
    ap.add_argument("--ckpt-dir", default="",
                    help="checkpoint directory (ckpt/checkpoint.py: atomic "
                         "step_<n>/ dirs, keep-last-k). Saves params + "
                         "optimizer + codec EF carry + run coordinates")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="checkpoint cadence: epochs (fullbatch) resp. "
                         "global steps (minibatch) between saves")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="complete checkpoints retained (older ones GC'd)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest complete checkpoint in "
                         "--ckpt-dir and continue from the step after it; "
                         "fp32 resume is bitwise (tests/test_fault.py)")
    ap.add_argument("--inject-fault", action="append", default=[],
                    metavar="SPEC",
                    help="deterministic fault injection (repeatable), "
                         "kind@key:value[,key:value...] — e.g. "
                         "crash@step:3, sample-error@step:2,worker:1, "
                         "straggler@step:1,delay:0.05, corrupt-ckpt. "
                         f"Kinds: {', '.join(FAULT_KINDS)}")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    plan, injector = None, None
    if args.inject_fault:
        try:
            plan = FaultPlan.parse(args.inject_fault, seed=args.seed)
        except FaultSpecError as e:
            print(f"[gnn] bad --inject-fault: {e}")
            sys.exit(1)
        injector = FaultInjector(plan)
        print(f"[gnn] fault plan: "
              f"{'; '.join(ev.describe() for ev in plan.events)}")

    tracer = None
    if args.trace:
        # install BEFORE anything compiles: the sync strategies report
        # their collectives when jax first traces the step function
        from repro.obs import Tracer, install
        tracer = install(Tracer())

    g = paper_graph(args.graph, scale=args.scale, seed=0)
    print(f"[gnn] graph {args.graph}: {g.num_vertices} vertices, "
          f"{g.num_edges} edges")
    rng = np.random.default_rng(args.seed)
    feats = rng.normal(size=(g.num_vertices, args.features)).astype(np.float32)
    labels = rng.integers(0, args.classes, g.num_vertices).astype(np.int32)
    train_mask = rng.random(g.num_vertices) < 0.3
    spec = GNNSpec(model=args.model, feature_dim=args.features,
                   hidden_dim=args.hidden, num_classes=args.classes,
                   num_layers=args.layers, agg_backend=args.agg_backend)

    manager = None
    if args.ckpt_dir:
        from repro.ckpt.checkpoint import CheckpointManager
        manager = CheckpointManager(args.ckpt_dir, keep=args.ckpt_keep,
                                    every=args.ckpt_every)
        if plan is not None and args.resume:
            # corrupt-ckpt: break the newest checkpoint BEFORE restore reads
            # it — restore must fall back to the previous complete one
            for ev in plan.pending("corrupt-ckpt"):
                if plan.fire(ev):
                    path = corrupt_latest_checkpoint(args.ckpt_dir)
                    print(f"[gnn] injected checkpoint corruption -> {path}")

    t0 = time.perf_counter()
    if args.regime == "fullbatch":
        partitioner = args.partitioner
        if args.sync_mode == "ring":
            # 1.5D: contiguous blockrow layout, no partitioning heuristic —
            # the near-zero partition time IS the regime's selling point
            partitioner = "blockrow"
        assert partitioner in EDGE_PARTITIONERS, (
            f"full-batch (DistGNN) uses edge partitioners: "
            f"{sorted(EDGE_PARTITIONERS)}")
        assignment = partition_edges(g, args.k, partitioner, seed=args.seed)
        pt = time.perf_counter() - t0
        m = edge_partition_metrics(g, assignment, args.k)
        print(f"[gnn] partitioned in {pt:.2f}s ({partitioner}): "
              f"rf={m.replication_factor:.2f} "
              f"edge_bal={m.edge_balance:.2f} vertex_bal={m.vertex_balance:.2f}")
        tr = FullBatchTrainer.build(
            g, assignment, args.k, spec, feats, labels, train_mask,
            sync_mode=args.sync_mode, mode="sim", seed=args.seed,
            codec=args.codec,
        )
        est = cost_model.fullbatch_epoch(tr.book, spec, codec=args.codec)
        print(f"[gnn] paper-cluster epoch estimate: {est.epoch_time*1e3:.1f} ms, "
              f"comm {est.comm_bytes.sum()/2**20:.1f} MiB "
              f"(wire {est.wire_bytes.sum()/2**20:.1f} MiB, {args.codec}), "
              f"mem max {est.memory.max()/2**20:.1f} MiB"
              + (" (OOM!)" if est.oom else ""))
        start_epoch = 0
        if manager is not None and args.resume:
            from repro.ckpt.checkpoint import checkpoint_extra
            _, extra = checkpoint_extra(args.ckpt_dir)
            tree = {"params": tr.params, "opt_state": tr.opt_state}
            if extra.get("has_ef"):
                tr.ef_state = tr._init_ef()
                tree["ef"] = tr.ef_state
            step_r, restored = manager.restore(tree)
            _mark_corrupt_handled(plan)
            if step_r is not None:
                tr.params = restored["params"]
                tr.opt_state = restored["opt_state"]
                if "ef" in restored:
                    tr.ef_state = restored["ef"]
                start_epoch = int(extra.get("epoch", step_r)) + 1
                print(f"[gnn] resumed from checkpoint epoch {step_r} "
                      f"-> continuing at epoch {start_epoch}")
            else:
                print("[gnn] --resume: no complete checkpoint found, "
                      "starting fresh")
        loss = float("nan")
        try:
            for epoch in range(start_epoch, args.epochs):
                t1 = time.perf_counter()
                if injector is not None:
                    injector.at_epoch(epoch)
                tr.set_epoch(epoch)
                loss = tr.train_step()
                print(f"[gnn] epoch {epoch:3d} loss {loss:.4f} "
                      f"({time.perf_counter()-t1:.2f}s)")
                if manager is not None:
                    tree = {"params": tr.params, "opt_state": tr.opt_state}
                    if tr.ef_state is not None:
                        tree["ef"] = tr.ef_state
                    manager.maybe_save(
                        epoch, tree,
                        extra={"epoch": epoch,
                               "has_ef": tr.ef_state is not None})
        except WorkerCrash as e:
            _crash_exit(e, args)
        if args.out_json:
            row = study.fullbatch_result_row(
                args.graph, partitioner, args.k, spec,
                metrics=m, partition_time=pt, est=est,
                sync_mode=args.sync_mode, codec=args.codec)
            row["loss"] = loss
            study.write_rows([row], args.out_json)
            print(f"[gnn] wrote study row -> {args.out_json}")
    else:
        assert args.partitioner in VERTEX_PARTITIONERS, (
            f"mini-batch (DistDGL) uses vertex partitioners: "
            f"{sorted(VERTEX_PARTITIONERS)}")
        assignment = partition_vertices(
            g, args.k, args.partitioner, seed=args.seed, train_mask=train_mask)
        pt = time.perf_counter() - t0
        m = vertex_partition_metrics(g, assignment, args.k, train_mask)
        print(f"[gnn] partitioned in {pt:.2f}s: edge_cut={m.edge_cut:.3f} "
              f"vertex_bal={m.vertex_balance:.2f}")
        steps_per_epoch = max(int(train_mask.sum()) // args.batch, 1)
        start_epoch, step_offset, next_step = 0, 0, 0
        resume_extra = None
        if manager is not None and args.resume:
            from repro.ckpt.checkpoint import checkpoint_extra
            gstep, resume_extra = checkpoint_extra(args.ckpt_dir)
            if gstep is not None:
                next_step = gstep + 1          # first global step to draw
                start_epoch = next_step // steps_per_epoch
                step_offset = next_step % steps_per_epoch
        tr = MiniBatchTrainer.build(
            g, assignment, args.k, spec, feats, labels, train_mask,
            global_batch=args.batch, seed=args.seed, rebalance=args.rebalance,
            cache_policy=args.cache_policy, cache_budget=args.cache_budget,
            overlap=args.overlap, prefetch_depth=args.prefetch_depth,
            codec=args.codec, start_step=next_step, injector=injector,
        )
        if manager is not None and args.resume:
            tree = {"params": tr.params, "opt_state": tr.opt_state}
            if resume_extra and resume_extra.get("has_ef"):
                tr.ef_state = tr._init_ef()
                tree["ef"] = tr.ef_state
            step_r, restored = manager.restore(tree)
            _mark_corrupt_handled(plan)
            if step_r is not None:
                tr.params = restored["params"]
                tr.opt_state = restored["opt_state"]
                if "ef" in restored:
                    tr.ef_state = restored["ef"]
                print(f"[gnn] resumed from checkpoint step {step_r} -> "
                      f"continuing at global step {next_step} "
                      f"(epoch {start_epoch}, step {step_offset})")
            else:
                print("[gnn] --resume: no complete checkpoint found, "
                      "starting fresh")
        if args.cache_budget:
            print(f"[gnn] feature cache: policy={args.cache_policy} "
                  f"budget={args.cache_budget}/worker "
                  f"(filled {tr.store.cache_sizes.tolist()})")
        sms, losses = [], []
        all_sms = []  # every traced step (the fetch counters span all epochs)
        gstep = next_step
        try:
            for epoch in range(start_epoch, args.epochs):
                t1 = time.perf_counter()
                tr.set_epoch(epoch)
                losses, remotes, hit_rates = [], [], []
                sms = []
                first = step_offset if epoch == start_epoch else 0
                for step in range(first, steps_per_epoch):
                    sm = tr.train_step()
                    sms.append(sm)
                    all_sms.append(sm)
                    losses.append(sm.loss)
                    remotes.append(sm.remote_vertices.sum())
                    hit_rates.append(sm.hit_rate)
                    if manager is not None:
                        tree = {"params": tr.params,
                                "opt_state": tr.opt_state}
                        if tr.ef_state is not None:
                            tree["ef"] = tr.ef_state
                        manager.maybe_save(
                            gstep, tree,
                            extra={"epoch": epoch, "step": step,
                                   "has_ef": tr.ef_state is not None})
                    gstep += 1
                est = cost_model.minibatch_step(
                    sm.input_vertices, sm.remote_vertices, sm.edges,
                    tr.book.sizes, spec,
                    remote_miss_vertices=sm.remote_misses,
                    cached_vertices=tr.store.cache_sizes, codec=args.codec)
                overlap_note = ""
                if args.overlap:
                    eff = np.mean([s.overlap_efficiency for s in sms])
                    overlap_note = f"overlap_eff {eff:.2f} "
                print(f"[gnn] epoch {epoch:3d} loss {np.mean(losses):.4f} "
                      f"remote/step {np.mean(remotes):.0f} "
                      f"hit_rate {np.mean(hit_rates):.2f} "
                      f"{overlap_note}"
                      f"cluster step est {est.step_time*1e3:.1f} ms "
                      f"({time.perf_counter()-t1:.2f}s)")
        except WorkerCrash as e:
            tr.close()
            _crash_exit(e, args)
        tr.close()
        if args.out_json and not sms:
            print("[gnn] --out-json needs at least one trained epoch; "
                  "no row written")
        elif args.out_json:
            # average the LAST epoch's measured per-worker metrics (same
            # aggregation as study.minibatch_row) and re-estimate from them
            inputs = np.stack([s.input_vertices for s in sms]).mean(axis=0)
            remote = np.stack([s.remote_vertices for s in sms]).mean(axis=0)
            edges = np.stack([s.edges for s in sms]).mean(axis=0)
            hits = np.stack([s.cache_hits for s in sms]).mean(axis=0)
            misses = np.stack([s.remote_misses for s in sms]).mean(axis=0)
            est = cost_model.minibatch_step(
                inputs, remote, edges, tr.book.sizes, spec,
                seeds_per_worker=max(args.batch // args.k, 1),
                remote_miss_vertices=misses,
                cached_vertices=tr.store.cache_sizes, codec=args.codec)
            row = study.minibatch_result_row(
                args.graph, args.partitioner, args.k, spec,
                metrics=m, partition_time=pt, batch=args.batch,
                inputs=inputs, remote=remote, hits=hits, misses=misses,
                est=est, steps_per_epoch=steps_per_epoch,
                cache_policy=args.cache_policy,
                cache_budget=args.cache_budget,
                overlap=args.overlap, prefetch_depth=args.prefetch_depth,
                host_times=study.host_phase_means(sms), codec=args.codec)
            row["loss"] = float(np.mean(losses))
            study.write_rows([row], args.out_json)
            print(f"[gnn] wrote study row -> {args.out_json}")

    if tracer is not None:
        import json

        from repro.obs import reconcile, write_trace

        if args.regime == "fullbatch":
            checks = reconcile.reconcile_fullbatch(tr, tracer=tracer)
        else:
            checks = reconcile.reconcile_minibatch(tr, all_sms,
                                                   tracer=tracer)
        if plan is not None:
            checks += reconcile.reconcile_recovery(plan, tracer=tracer)
        report = reconcile.build_report(checks)
        write_trace(args.trace, tracer)
        with open(args.trace + ".report.json", "w") as fh:
            json.dump(report.to_dict(), fh, indent=2)
            fh.write("\n")
        c = report.counts
        print(f"[gnn] trace -> {args.trace} "
              f"(report {args.trace}.report.json: {c.get('ok', 0)} ok, "
              f"{c.get('warn', 0)} warn, {c.get('error', 0)} error)")
        for ch in report.checks:
            if ch.level == "error":
                print(f"  [error] {ch.quantity}: {ch.message}")


if __name__ == "__main__":
    main()
