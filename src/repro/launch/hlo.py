"""Compat shim: the HLO parser moved to `repro.analysis.hlo`.

The dry-run harness and older tests import `collective_bytes_from_hlo`
from here; the canonical implementation (plus the richer `analyze_hlo`)
now lives in the analysis subsystem so the lint rules and the dry-run
cross-check share one parser.
"""

from repro.analysis.hlo import (  # noqa: F401 (re-exports)
    analyze_hlo,
    collective_bytes_from_hlo,
)

__all__ = ["analyze_hlo", "collective_bytes_from_hlo"]
