"""Compiled-HLO text analysis helpers (no repro.dist dependency).

`collective_bytes_from_hlo` is used by the dry-run harness to cross-check
analytic communication models against what XLA actually emitted, and by the
GNN tests to pin `sync_bytes_per_round` to the compiled halo exchange.
"""

from __future__ import annotations

import re

_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the compiled HLO.

    Uses the *output* shape of each collective instruction (for all-gather
    that is the gathered size; for reduce-scatter the scattered size; a
    reasonable, consistent proxy for payload per device).
    """
    per_kind: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        m = _COLLECTIVE_RE.search(rhs)
        if not m:
            continue
        kind = m.group(1)
        # output shape(s) sit between '=' and the op name, e.g.
        #   %ar = (f32[1024], f32[64]) all-reduce(...)
        shape_region = rhs[: m.start()]
        total = 0
        for dt, dims in _SHAPE_RE.findall(shape_region):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for tok in dims.split(","):
                if tok:
                    n *= int(tok)
            total += n * _DTYPE_BYTES[dt]
        per_kind[kind] = per_kind.get(kind, 0) + total
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_per_kind": per_kind, "count_per_kind": count,
            "total_bytes": int(sum(per_kind.values()))}
