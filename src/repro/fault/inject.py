"""Fault injection hooks + retry-with-backoff for the host phases.

The injector is the bridge between a declarative `FaultPlan` and the
execution seams the plan addresses:

  * `at_step`    — the pipeline's step entry (`BatchPreparer.prepare`, and
                   the full-batch epoch loop): fatal `crash` events raise
                   `WorkerCrash`, which in overlap mode travels through the
                   producer's poison token to the consumer.
  * `on_sample`  — per-(step, worker) sampling: `straggler` events sleep
                   (delay absorbed, handled on the spot); `sample-error`
                   events raise a retryable `TransientSampleFault`.
  * `on_fetch`   — per-(step, worker) feature gather: `fetch-error` events
                   raise a retryable `TransientFetchFault`.
  * `RowStore.gather` additionally consults the module-level fetch hook
    (`install_fetch_hook`) — the generic seam for paths that don't thread
    an injector (serving, ad-hoc gathers); exceptions raised there are
    caught by the same caller-side retry.

`retry_call` is the recovery half: bounded attempts with exponential
backoff under a per-phase deadline. A retried phase re-derives its RNG from
the (step, worker) `SeedSequence`, so the retried batch is bitwise-
identical to the first attempt (pinned in tests/test_fault.py). Transient
exceptions carry their plan + event; `retry_call` marks them handled on
the first subsequent success, keeping the plan's books exact.
"""

from __future__ import annotations

import os
import shutil
import time
from typing import Callable, Optional

from repro.obs.trace import get_tracer

__all__ = ["FaultEscalation", "FaultInjector", "InjectedFault",
           "TransientFault", "TransientFetchFault", "TransientSampleFault",
           "WorkerCrash", "corrupt_latest_checkpoint", "install_fetch_hook",
           "clear_fetch_hook", "fetch_hook", "retry_call"]


class InjectedFault(RuntimeError):
    """Base class for every injected failure (carries its plan + event)."""

    def __init__(self, message: str, *, event=None, plan=None) -> None:
        super().__init__(message)
        self.event = event
        self.plan = plan


class WorkerCrash(InjectedFault):
    """Fatal: the worker process is gone. Not retryable — recovery is
    checkpoint restore (--resume) or elastic shrink."""


class TransientFault(InjectedFault):
    """Retryable: the next attempt of the same phase may succeed."""


class TransientSampleFault(TransientFault):
    """Transient sampler failure (remote adjacency RPC dropped)."""


class TransientFetchFault(TransientFault):
    """Transient feature/embedding fetch failure (store RPC dropped)."""


class FaultEscalation(RuntimeError):
    """A retried phase exhausted its attempts/deadline — now fatal."""


# ---------------------------------------------------------------------------
# generic RowStore.gather seam (module-level so stores need no plumbing)
# ---------------------------------------------------------------------------

_FETCH_HOOK: Optional[Callable] = None


def install_fetch_hook(fn: Callable) -> None:
    """Install `fn(worker, ids)` to run at the top of every
    `RowStore.gather`; it may raise a `TransientFetchFault`."""
    global _FETCH_HOOK
    _FETCH_HOOK = fn


def clear_fetch_hook() -> None:
    global _FETCH_HOOK
    _FETCH_HOOK = None


def fetch_hook() -> Optional[Callable]:
    return _FETCH_HOOK


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------


class FaultInjector:
    """Probes a `FaultPlan` at the execution seams (see module docstring).

    `k` (the worker count) lets events with an unspecified worker resolve
    to a seeded choice; the `BatchPreparer` sets it on first use when the
    caller didn't."""

    def __init__(self, plan, k: Optional[int] = None) -> None:
        self.plan = plan
        self.k = k

    def _worker_of(self, ev) -> int:
        if ev.worker >= 0 or self.k is None:
            return ev.worker
        return self.plan.resolve_worker(ev, self.k)

    # ------------------------------------------------------------ step seam
    def at_step(self, step: int) -> None:
        """Raise `WorkerCrash` if a crash is scheduled for this step."""
        for ev in self.plan.pending("crash", step=step):
            if self.plan.fire(ev, step=step):
                raise WorkerCrash(
                    f"injected worker crash at step {step}",
                    event=ev, plan=self.plan)

    def at_epoch(self, epoch: int) -> None:
        """Epoch-addressed alias of `at_step` for the full-batch loop (one
        step per epoch: `crash@step:N` means epoch N there)."""
        self.at_step(epoch)

    # -------------------------------------------------------- sampling seam
    def on_sample(self, step: int, worker: int) -> None:
        for ev in self.plan.pending("straggler", step=step, worker=worker):
            if self._worker_of(ev) not in (-1, worker):
                continue
            if self.plan.fire(ev, step=step, worker=worker):
                time.sleep(max(ev.delay, 0.0))
                self.plan.mark_handled(ev)  # the delay IS the fault, absorbed
        for ev in self.plan.pending("sample-error", step=step, worker=worker):
            if self._worker_of(ev) not in (-1, worker):
                continue
            if self.plan.fire(ev, step=step, worker=worker):
                raise TransientSampleFault(
                    f"injected sampler fault at step {step} worker {worker}",
                    event=ev, plan=self.plan)

    # ----------------------------------------------------------- fetch seam
    def on_fetch(self, step: int, worker: int) -> None:
        for ev in self.plan.pending("fetch-error", step=step, worker=worker):
            if self._worker_of(ev) not in (-1, worker):
                continue
            if self.plan.fire(ev, step=step, worker=worker):
                raise TransientFetchFault(
                    f"injected fetch fault at step {step} worker {worker}",
                    event=ev, plan=self.plan)

    def gather_hook(self) -> Callable:
        """A `(worker, ids)` closure for `install_fetch_hook` that fires
        this plan's step-agnostic fetch-error events at the store seam."""

        def hook(worker: int, ids) -> None:
            for ev in self.plan.pending("fetch-error", worker=worker):
                if ev.step >= 0:  # step-addressed events belong to on_fetch
                    continue
                if self.plan.fire(ev, worker=int(worker)):
                    raise TransientFetchFault(
                        f"injected fetch fault at gather (worker {worker})",
                        event=ev, plan=self.plan)

        return hook


# ---------------------------------------------------------------------------
# retry-with-backoff
# ---------------------------------------------------------------------------


def retry_call(fn: Callable, *, phase: str, attempts: int = 3,
               backoff: float = 0.005, timeout: float = 5.0):
    """Run `fn()` retrying `TransientFault`s: exponential backoff, at most
    `attempts` tries, all within a `timeout`-second phase deadline.

    Deterministic contract: `fn` must re-derive any randomness from its
    own (step, worker) SeedSequence so attempt N is bitwise attempt 1.
    On the first success after failures, every distinct fault retried is
    marked handled on its plan; exhausting the budget raises
    `FaultEscalation` chained to the last fault.
    """
    tracer = get_tracer()
    t_start = time.perf_counter()
    delay = backoff
    seen = []
    while True:
        t_attempt = time.perf_counter()
        try:
            out = fn()
        except TransientFault as e:
            seen.append(e)
            tracer.add("fault.retries", 1)
            if tracer.enabled:
                tracer.record_span(
                    f"fault.retry.{phase}", t_attempt, time.perf_counter(),
                    cat="fault", args={"attempt": len(seen),
                                       "error": str(e)})
            elapsed = time.perf_counter() - t_start
            if len(seen) >= attempts or elapsed + delay > timeout:
                raise FaultEscalation(
                    f"phase {phase!r} still failing after {len(seen)} "
                    f"attempt(s) in {elapsed:.3f}s (attempts={attempts}, "
                    f"timeout={timeout:g}s)") from e
            time.sleep(delay)
            delay *= 2
            continue
        for e in seen:
            if e.plan is not None and e.event is not None:
                e.plan.mark_handled(e.event)
        return out


# ---------------------------------------------------------------------------
# checkpoint corruption (the corrupt-ckpt fault)
# ---------------------------------------------------------------------------


def corrupt_latest_checkpoint(directory: str, mode: str = "manifest") -> Optional[str]:
    """Corrupt the NEWEST complete checkpoint under `directory`.

    mode="manifest": delete its manifest.json (the half-written-directory
    signature — restore must skip it and fall back to the previous one).
    mode="truncate": truncate its first leaf file (np.load then fails).
    Returns the corrupted path, or None if there was nothing to corrupt.
    """
    from repro.ckpt.checkpoint import _complete_checkpoints

    ckpts = _complete_checkpoints(directory)
    if not ckpts:
        return None
    _, path = ckpts[-1]
    if mode == "manifest":
        os.remove(os.path.join(path, "manifest.json"))
    elif mode == "truncate":
        leaves = sorted(n for n in os.listdir(path) if n.endswith(".npy"))
        if not leaves:
            shutil.rmtree(path)
        else:
            with open(os.path.join(path, leaves[0]), "wb") as fh:
                fh.write(b"\x00")
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return path
