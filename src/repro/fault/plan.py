"""Deterministic fault plans: what breaks, where, and when.

A `FaultPlan` is a seeded, declarative list of faults to inject into a run
— the chaos-engineering twin of the study grids: every fault is addressed
by the SAME coordinates the deterministic execution uses (step index,
worker id, virtual arrival time), so a faulted run is exactly reproducible
and a retried/resumed run can be held bitwise against the unfaulted oracle.

Spec grammar (the `--inject-fault` CLI argument, repeatable)::

    kind@key:value[,key:value...]

    crash@step:3              kill the run at training step 3
    sample-error@step:2,worker:1   transient sampler exception (retried)
    fetch-error@step:4,worker:0    transient feature-fetch exception
    straggler@step:1,worker:2,delay:0.05   slow worker (seconds)
    corrupt-ckpt              corrupt the newest checkpoint before resume
    worker-death@t:0.5,worker:1    serving worker dies at virtual time t
    worker-loss@epoch:2,worker:1   elastic: shrink k -> k-1 at epoch 2
    worker-join@epoch:4            elastic: grow back to the original k

An unknown kind (or malformed spec) raises `FaultSpecError` whose message
lists the valid kinds — the CLIs turn that into an exit-1 diagnosis.

Every injection and every successful handling is recorded in the PR-9
tracer (`fault.injected` / `fault.handled` counters plus a `fault.inject`
span per event), and the plan keeps its own authoritative counts — the
reconciliation gate (obs/reconcile.reconcile_recovery) holds the two
stories against each other EXACTLY.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Iterable, List, Optional

import numpy as np

from repro.obs.trace import get_tracer

__all__ = ["FAULT_KINDS", "FaultEvent", "FaultPlan", "FaultSpecError",
           "parse_fault_spec"]

FAULT_KINDS = (
    "crash",          # fatal worker crash at a training step
    "sample-error",   # transient sampler exception (retry-recoverable)
    "fetch-error",    # transient feature/embedding fetch exception
    "straggler",      # slow worker: injected host delay
    "corrupt-ckpt",   # corrupted/partial newest checkpoint directory
    "worker-death",   # serving worker dies at virtual time t
    "worker-loss",    # elastic training: lose a worker at an epoch
    "worker-join",    # elastic training: a worker (re)joins at an epoch
)


class FaultSpecError(ValueError):
    """Malformed/unknown `--inject-fault` spec (message lists valid kinds)."""


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault. Unused coordinates stay at their sentinels;
    `worker=-1` means "let the seeded plan pick one" (resolve_worker)."""

    kind: str
    step: int = -1       # training step (crash/sample-error/fetch-error/straggler)
    epoch: int = -1      # epoch (worker-loss / worker-join)
    worker: int = -1     # worker id; -1 = seeded choice
    at: float = -1.0     # virtual time, seconds (worker-death)
    delay: float = 0.0   # injected host delay, seconds (straggler)

    def describe(self) -> str:
        parts = [self.kind]
        if self.step >= 0:
            parts.append(f"step={self.step}")
        if self.epoch >= 0:
            parts.append(f"epoch={self.epoch}")
        if self.worker >= 0:
            parts.append(f"worker={self.worker}")
        if self.at >= 0:
            parts.append(f"t={self.at:g}")
        if self.delay:
            parts.append(f"delay={self.delay:g}")
        return " ".join(parts)


_INT_KEYS = {"step": "step", "epoch": "epoch", "worker": "worker"}
_FLOAT_KEYS = {"t": "at", "at": "at", "delay": "delay"}


def parse_fault_spec(spec: str) -> FaultEvent:
    """Parse one `kind@key:value[,key:value...]` spec string."""
    kind, _, rest = spec.partition("@")
    kind = kind.strip()
    if kind not in FAULT_KINDS:
        raise FaultSpecError(
            f"unknown fault kind {kind!r} in spec {spec!r}; "
            f"valid kinds: {', '.join(FAULT_KINDS)}")
    ev = FaultEvent(kind=kind)
    if not rest:
        return ev
    for part in rest.split(","):
        key, sep, val = part.partition(":")
        key = key.strip()
        if not sep or not val:
            raise FaultSpecError(
                f"malformed parameter {part!r} in spec {spec!r} "
                f"(expected key:value); valid kinds: {', '.join(FAULT_KINDS)}")
        try:
            if key in _INT_KEYS:
                setattr(ev, _INT_KEYS[key], int(val))
            elif key in _FLOAT_KEYS:
                setattr(ev, _FLOAT_KEYS[key], float(val))
            else:
                raise FaultSpecError(
                    f"unknown parameter {key!r} in spec {spec!r}; valid "
                    f"parameters: step, epoch, worker, t, delay")
        except ValueError as e:
            if isinstance(e, FaultSpecError):
                raise
            raise FaultSpecError(
                f"non-numeric value {val!r} for {key!r} in spec {spec!r}"
            ) from e
    return ev


class FaultPlan:
    """A seeded set of `FaultEvent`s with fire-once semantics.

    Thread-safe: the pipeline's producer/sampler threads probe the plan
    concurrently; each event fires exactly once (`fire` is check-and-set
    under one lock). `injected_count`/`handled_count` are the plan's own
    books; the tracer counters tell the same story from the run's side.
    """

    def __init__(self, events: Iterable[FaultEvent], seed: int = 0) -> None:
        self.events: List[FaultEvent] = list(events)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._fired: set = set()
        self._handled: set = set()
        self._resolved_workers: dict = {}

    @classmethod
    def parse(cls, specs: Iterable[str], seed: int = 0) -> "FaultPlan":
        return cls([parse_fault_spec(s) for s in specs], seed=seed)

    # ------------------------------------------------------------- queries
    def pending(self, kind: str, *, step: Optional[int] = None,
                epoch: Optional[int] = None,
                worker: Optional[int] = None) -> List[FaultEvent]:
        """Unfired events of `kind` matching the given coordinates. A
        coordinate the event left unspecified (-1) matches anything."""
        out = []
        with self._lock:
            for i, ev in enumerate(self.events):
                if ev.kind != kind or i in self._fired:
                    continue
                if step is not None and ev.step >= 0 and ev.step != step:
                    continue
                if epoch is not None and ev.epoch >= 0 and ev.epoch != epoch:
                    continue
                if worker is not None and ev.worker >= 0 and ev.worker != worker:
                    continue
                out.append(ev)
        return out

    def events_of(self, kind: str) -> List[FaultEvent]:
        return [ev for ev in self.events if ev.kind == kind]

    def resolve_worker(self, ev: FaultEvent, k: int) -> int:
        """The event's worker id, drawing one deterministically from the
        plan seed when the spec left it open (stable across calls)."""
        if ev.worker >= 0:
            return ev.worker
        idx = self.events.index(ev)
        with self._lock:
            if idx not in self._resolved_workers:
                rng = np.random.default_rng((self.seed, idx))
                self._resolved_workers[idx] = int(rng.integers(0, k))
        return self._resolved_workers[idx]

    # ------------------------------------------------------------ recording
    def fire(self, ev: FaultEvent, **ctx) -> bool:
        """Mark `ev` injected (once); False if it already fired. Records the
        `fault.injected` counter and a `fault.inject` span."""
        idx = self.events.index(ev)
        with self._lock:
            if idx in self._fired:
                return False
            self._fired.add(idx)
        tracer = get_tracer()
        if tracer.enabled:
            now = time.perf_counter()
            args = {"kind": ev.kind, "event": ev.describe()}
            args.update({k: v for k, v in ctx.items()})
            tracer.record_span("fault.inject", now, now, cat="fault",
                               args=args)
        tracer.add("fault.injected", 1)
        return True

    def mark_handled(self, ev: FaultEvent) -> bool:
        """Mark a fired event as successfully handled (retry succeeded,
        delay absorbed, failover completed, checkpoint fallback worked)."""
        idx = self.events.index(ev)
        with self._lock:
            if idx not in self._fired or idx in self._handled:
                return False
            self._handled.add(idx)
        get_tracer().add("fault.handled", 1)
        return True

    # ------------------------------------------------------------- accounts
    @property
    def injected_count(self) -> int:
        with self._lock:
            return len(self._fired)

    @property
    def handled_count(self) -> int:
        with self._lock:
            return len(self._handled)

    def fired_events(self) -> List[FaultEvent]:
        with self._lock:
            return [self.events[i] for i in sorted(self._fired)]

    def __len__(self) -> int:
        return len(self.events)
