"""Recovery strategies: elastic degrade-and-recover + serving failover.

Training (full-batch): `run_elastic_fullbatch` is a supervised driver over
`FullBatchTrainer` that reacts to the plan's `worker-loss` events by
shrinking k -> k-1 (re-partition, rebuild device blocks, carry model +
optimizer + codec state through the fixed `ckpt.elastic.rescale_fullbatch`)
and to `worker-join` events by growing back. Model state is partition-
independent (the tested distributed==single invariant), so the rescale is
exact; what it COSTS is the point — every rescale is priced with
`cost_model.recovery_time` (checkpoint restore + re-partition + re-compile)
and recorded as `fault.restore` / `fault.repartition` / `fault.recompile` /
`fault.recovery` spans plus the `fault.recovery_time_model` counter the
reconciliation gate holds against the recomputed estimates exactly.

Serving: `failover_assignment` re-derives vertex ownership with one worker
dead — the `master_assignment` re-derivation: for an edge partition book,
each vertex mastered on the dead worker moves to the first surviving
partition that holds a REPLICA of it (mirrors already have the data);
vertices with no surviving replica (and all vertices under replica-free
vertex partitions) fall back to a deterministic spread over survivors.
`run_serving_sim` re-routes with this map mid-trace (see serve/engine.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional

import jax
import numpy as np

from repro.core import cost_model
from repro.core.cost_model import PAPER_CLUSTER, ClusterSpec
from repro.core.edge_partition import partition_edges
from repro.obs.trace import get_tracer

__all__ = ["ElasticEvent", "ElasticRunResult", "failover_assignment",
           "run_elastic_fullbatch"]


# ---------------------------------------------------------------------------
# serving failover
# ---------------------------------------------------------------------------


def failover_assignment(owner: np.ndarray, dead: int, k: int, *,
                        book=None) -> np.ndarray:
    """Ownership array with worker `dead` removed.

    `book` (an `EdgePartitionBook`, optional) enables the replica-aware
    re-derivation; without it (vertex partitions hold no replicas) the dead
    worker's vertices spread deterministically over the survivors.
    """
    owner = np.asarray(owner)
    new = owner.copy()
    moved = np.where(owner == dead)[0]
    if moved.size == 0:
        return new
    survivors = np.array([w for w in range(k) if w != dead], dtype=owner.dtype)
    if survivors.size == 0:
        raise ValueError("cannot fail over: no surviving workers")
    fallback = survivors[moved % survivors.size]
    if book is None:
        new[moved] = fallback
        return new
    # replica map: has[p, v] — partition p holds a copy of vertex v
    has = np.zeros((k, owner.shape[0]), dtype=bool)
    for p in range(k):
        ids = book.vglobal[p][book.vmask[p]]
        has[p, ids] = True
    cand = has[survivors][:, moved]            # [k-1, moved]
    replicated = cand.any(axis=0)
    first_replica = survivors[np.argmax(cand, axis=0)]
    new[moved] = np.where(replicated, first_replica, fallback)
    return new


# ---------------------------------------------------------------------------
# elastic full-batch training
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ElasticEvent:
    """One executed rescale (shrink or grow)."""

    epoch: int
    action: str                  # "shrink" | "grow"
    old_k: int
    new_k: int
    estimate: Any                # cost_model.RecoveryEstimate
    repartition_s: float         # measured host re-partition + rebuild wall
    compile_s: float = 0.0       # measured first-step wall post-rescale


@dataclasses.dataclass
class ElasticRunResult:
    losses: List[float]
    k_history: List[int]
    events: List[ElasticEvent]
    trainer: Any                 # the final FullBatchTrainer

    @property
    def recovery_estimates(self) -> list:
        return [e.estimate for e in self.events]

    @property
    def recovery_time_total(self) -> float:
        return float(sum(e.estimate.recovery_time for e in self.events))


def _state_bytes(trainer) -> int:
    """Checkpointable state volume: what a restore must read back."""
    tree = {"params": trainer.params, "opt_state": trainer.opt_state}
    if trainer.ef_state is not None:
        tree["ef"] = trainer.ef_state
    return int(sum(np.asarray(jax.device_get(leaf)).nbytes
                   for leaf in jax.tree.leaves(tree)))


def _rescale(trainer, new_k: int, epoch: int, action: str, graph, features,
             labels, train_mask, *, partitioner: str, seed: int,
             cluster: ClusterSpec) -> tuple:
    from repro.ckpt.elastic import rescale_fullbatch

    tracer = get_tracer()
    t_rec0 = time.perf_counter()
    # restore phase: snapshot the state a real peer would read from the
    # checkpoint (measured here as the host gather; priced from its bytes)
    with tracer.span("fault.restore", cat="fault",
                     args={"epoch": epoch, "action": action}):
        ckpt_bytes = _state_bytes(trainer)
    t_p0 = time.perf_counter()
    with tracer.span("fault.repartition", cat="fault",
                     args={"old_k": trainer.book.k, "new_k": new_k}):
        new = rescale_fullbatch(
            trainer, graph, new_k, features, labels, train_mask,
            partitioner=partitioner, seed=seed)
    repartition_s = time.perf_counter() - t_p0
    est = cost_model.recovery_time(ckpt_bytes, repartition_s, cluster=cluster)
    tracer.add("fault.recovery_time_model", est.recovery_time)
    if tracer.enabled:
        tracer.record_span(
            "fault.recovery", t_rec0, time.perf_counter(), cat="fault",
            args={"epoch": epoch, "action": action, "old_k": trainer.book.k,
                  "new_k": new_k, "recovery_time_model": est.recovery_time})
    event = ElasticEvent(epoch=epoch, action=action, old_k=trainer.book.k,
                         new_k=new_k, estimate=est,
                         repartition_s=repartition_s)
    return new, event


def run_elastic_fullbatch(
    graph,
    features: np.ndarray,
    labels: np.ndarray,
    train_mask: np.ndarray,
    spec,
    *,
    k: int,
    epochs: int,
    plan=None,
    partitioner: str = "hep100",
    seed: int = 0,
    sync_mode: str = "halo",
    codec=None,
    lr: float = 1e-2,
    cluster: ClusterSpec = PAPER_CLUSTER,
) -> ElasticRunResult:
    """Train full-batch for `epochs`, executing the plan's worker-loss /
    worker-join events: shrink to k-1 when a worker dies, grow back toward
    the original k when one rejoins. Returns the loss trajectory, the k in
    effect at every epoch, and one priced `ElasticEvent` per rescale."""
    from repro.gnn.fullbatch import FullBatchTrainer

    tracer = get_tracer()
    assignment = partition_edges(graph, k, partitioner, seed=seed)
    trainer = FullBatchTrainer.build(
        graph, assignment, k, spec, features, labels, train_mask,
        sync_mode=sync_mode, seed=seed, lr=lr, codec=codec)
    base_k = k
    losses: List[float] = []
    k_history: List[int] = []
    events: List[ElasticEvent] = []
    just_rescaled = False
    for epoch in range(epochs):
        if plan is not None:
            for ev in plan.pending("worker-loss", epoch=epoch):
                cur_k = trainer.book.k
                if cur_k <= 1:
                    continue  # nothing left to lose a worker from
                lost = plan.resolve_worker(ev, cur_k)
                if plan.fire(ev, epoch=epoch, worker=lost):
                    trainer, event = _rescale(
                        trainer, cur_k - 1, epoch, "shrink", graph, features,
                        labels, train_mask, partitioner=partitioner,
                        seed=seed, cluster=cluster)
                    events.append(event)
                    plan.mark_handled(ev)
                    just_rescaled = True
            for ev in plan.pending("worker-join", epoch=epoch):
                cur_k = trainer.book.k
                if cur_k >= base_k:
                    continue  # already at full strength
                if plan.fire(ev, epoch=epoch):
                    trainer, event = _rescale(
                        trainer, cur_k + 1, epoch, "grow", graph, features,
                        labels, train_mask, partitioner=partitioner,
                        seed=seed, cluster=cluster)
                    events.append(event)
                    plan.mark_handled(ev)
                    just_rescaled = True
        trainer.set_epoch(epoch)
        t0 = time.perf_counter()
        losses.append(float(trainer.train_step()))
        wall = time.perf_counter() - t0
        if just_rescaled:
            # the first step after a rescale pays the re-compile (new k =>
            # new static shapes); record it against the estimate's term
            if tracer.enabled:
                tracer.record_span("fault.recompile", t0,
                                   time.perf_counter(), cat="fault",
                                   args={"epoch": epoch,
                                         "k": trainer.book.k})
            events[-1].compile_s = wall
            just_rescaled = False
        k_history.append(trainer.book.k)
    return ElasticRunResult(losses=losses, k_history=k_history,
                            events=events, trainer=trainer)
