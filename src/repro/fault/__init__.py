"""Deterministic fault injection + recovery (see plan.py for the taxonomy).

Kept import-light: `recovery` pulls the trainers, so import it as a
submodule (`from repro.fault import recovery`) only where needed.
"""

from repro.fault.inject import (
    FaultEscalation,
    FaultInjector,
    InjectedFault,
    TransientFault,
    TransientFetchFault,
    TransientSampleFault,
    WorkerCrash,
    clear_fetch_hook,
    corrupt_latest_checkpoint,
    install_fetch_hook,
    retry_call,
)
from repro.fault.plan import (
    FAULT_KINDS,
    FaultEvent,
    FaultPlan,
    FaultSpecError,
    parse_fault_spec,
)

__all__ = [
    "FAULT_KINDS",
    "FaultEscalation",
    "FaultEvent",
    "FaultInjector",
    "FaultPlan",
    "FaultSpecError",
    "InjectedFault",
    "TransientFault",
    "TransientFetchFault",
    "TransientSampleFault",
    "WorkerCrash",
    "clear_fetch_hook",
    "corrupt_latest_checkpoint",
    "install_fetch_hook",
    "parse_fault_spec",
    "retry_call",
]
