"""The paper's experiment harness (RQ-1 .. RQ-5) + the serving study.

Runs the full grid — graphs x partitioners x k x GNN hyper-parameters — and
emits rows that the per-figure benchmarks aggregate. Partitions and books
are cached per (graph, partitioner, k, seed) because the GNN-parameter grid
reuses them (exactly how the paper amortises partitioning across runs).

The `*_result_row` functions are the ONE serializer per regime: the study
grid, the CLI drivers (`launch/gnn_train.py --out-json`,
`launch/gnn_serve.py --out-json`) and the benchmark figures all build their
JSON rows through them, so a row means the same thing wherever it was
produced. `write_rows` is the shared file emitter.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import time
from typing import Iterable, Optional

import numpy as np

from repro.core import cost_model
from repro.core.cost_model import PAPER_CLUSTER, ClusterSpec
from repro.core.edge_partition import partition_edges
from repro.core.graph import Graph, paper_graph
from repro.core.metrics import (
    edge_partition_metrics,
    vertex_partition_metrics,
)
from repro.core.partition_book import (
    build_blockrow_book,
    build_edge_book,
    build_vertex_book,
)
from repro.core.vertex_partition import partition_vertices
from repro.gnn.models import GNNSpec
from repro.gnn.minibatch import MiniBatchTrainer
from repro.gnn.sampling import PAPER_FANOUTS
from repro.obs import aggregate as obs_aggregate

# Paper Table 2 grid.
PAPER_GRID = {  # lint: keep — documents the paper's model-size sweep
    "hidden_dim": (16, 64, 512),
    "feature_size": (16, 64, 512),
    "num_layers": (2, 3, 4),
}

EDGE_METHODS = ("random", "dbh", "hdrf", "2ps-l", "hep10", "hep100")
VERTEX_METHODS = ("random", "ldg", "spinner", "bytegnn", "metis", "kahip")


@dataclasses.dataclass
class PartitionRecord:
    method: str
    k: int
    assignment: np.ndarray
    partition_time: float
    metrics: object
    book: object = None


class StudyCache:
    """Memoises partitions/books across the hyper-parameter grid."""

    def __init__(self) -> None:
        self._graphs: dict = {}
        self._edge: dict = {}
        self._vertex: dict = {}
        self._blockrow: dict = {}

    def graph(self, key: str, scale: float, seed: int = 0) -> Graph:
        gk = (key, scale, seed)
        if gk not in self._graphs:
            self._graphs[gk] = paper_graph(key, scale=scale, seed=seed)
        return self._graphs[gk]

    def edge_partition(
        self, graph: Graph, method: str, k: int, seed: int = 0
    ) -> PartitionRecord:
        pk = (id(graph), method, k, seed)
        if pk not in self._edge:
            t0 = time.perf_counter()
            a = partition_edges(graph, k, method, seed=seed)
            dt = time.perf_counter() - t0
            rec = PartitionRecord(
                method=method, k=k, assignment=a, partition_time=dt,
                metrics=edge_partition_metrics(graph, a, k),
                book=build_edge_book(graph, a, k),
            )
            self._edge[pk] = rec
        return self._edge[pk]

    def blockrow_partition(self, graph: Graph, k: int) -> PartitionRecord:
        """1.5D layout record (sync_mode="ring"): the "partitioner" is the
        contiguous block split — near-zero partition time by construction,
        which is exactly what tab3's amortization question needs."""
        pk = (id(graph), "blockrow", k)
        if pk not in self._blockrow:
            # time only the partitioning decision (the contiguous split),
            # matching edge_partition: runtime books are built outside the
            # window for every method
            t0 = time.perf_counter()
            a = partition_edges(graph, k, "blockrow")
            dt = time.perf_counter() - t0
            book = build_blockrow_book(graph, k)
            self._blockrow[pk] = PartitionRecord(
                method="blockrow", k=k, assignment=a, partition_time=dt,
                metrics=edge_partition_metrics(graph, a, k),
                book=book,
            )
        return self._blockrow[pk]

    def vertex_partition(
        self, graph: Graph, method: str, k: int, seed: int = 0,
        train_mask: Optional[np.ndarray] = None,
    ) -> PartitionRecord:
        pk = (id(graph), method, k, seed)
        if pk not in self._vertex:
            t0 = time.perf_counter()
            a = partition_vertices(graph, k, method, seed=seed, train_mask=train_mask)
            dt = time.perf_counter() - t0
            rec = PartitionRecord(
                method=method, k=k, assignment=a, partition_time=dt,
                metrics=vertex_partition_metrics(graph, a, k, train_mask),
                book=build_vertex_book(graph, a, k),
            )
            self._vertex[pk] = rec
        return self._vertex[pk]


_GLOBAL_CACHE = StudyCache()


def _json_default(o):
    if hasattr(o, "item"):  # numpy scalars
        return o.item()
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def _json_clean(v):
    """Strict-JSON value: non-finite floats -> null (e.g. an idle serving
    worker's NaN p99 / infinite sustainable QPS), containers recursed."""
    if isinstance(v, dict):
        return {k: _json_clean(x) for k, x in v.items()}
    if isinstance(v, (list, tuple, np.ndarray)):
        return [_json_clean(x) for x in v]
    if isinstance(v, np.generic):
        v = v.item()
    if isinstance(v, float) and not np.isfinite(v):
        return None
    return v


def write_rows(rows: Iterable[dict], path: str) -> None:
    """The one JSON emitter: a list of study-format rows, one strict-JSON
    file (jq/JSON.parse-safe: no bare NaN/Infinity tokens)."""
    with open(path, "w") as f:
        json.dump(_json_clean(list(rows)), f, indent=1,
                  default=_json_default)
        f.write("\n")


# ---------------------------------------------------------------------------
# DistGNN-side study rows (full-batch / edge partitioning)
# ---------------------------------------------------------------------------


def fullbatch_result_row(
    graph_key: str,
    method: str,
    k: int,
    spec: GNNSpec,
    *,
    metrics,
    partition_time: float,
    est,
    sync_mode: str = "halo",
    codec: str = "fp32",
    recovery=None,
) -> dict:
    """Serialize one DistGNN result (shared by the study grid and the CLI).

    `comm_bytes` is the logical (f32) replica-sync volume; `wire_bytes` is
    what actually crosses the network under `codec` (equal under fp32).
    `recovery` (a `cost_model.RecoveryEstimate`, optional) adds the priced
    cost of one worker-loss recovery — restore + re-partition + re-compile
    — which is how a partitioner's quality advantage gets taxed by churn."""
    wire = est.comm_bytes if getattr(est, "wire_bytes", None) is None else est.wire_bytes
    rec_cols = {}
    if recovery is not None:
        rec_cols = {
            "recovery_time": float(recovery.recovery_time),
            "recovery_restore_time": float(recovery.restore_time),
            "recovery_repartition_time": float(recovery.repartition_time),
            "recovery_recompile_time": float(recovery.recompile_time),
        }
    return {
        "graph": graph_key, "method": method, "k": k,
        "sync_mode": sync_mode, "codec": codec,
        "model": spec.model, "feature": spec.feature_dim,
        "hidden": spec.hidden_dim, "layers": spec.num_layers,
        "rf": metrics.replication_factor,
        "edge_balance": metrics.edge_balance,
        "vertex_balance": metrics.vertex_balance,
        "partition_time": partition_time,
        "epoch_time": est.epoch_time,
        "comm_bytes": float(est.comm_bytes.sum()),
        "wire_bytes": float(wire.sum()),
        "memory_total": float(est.memory.sum()),
        "memory_max": float(est.memory.max()),
        "memory_balance": float(est.memory.max() / est.memory.mean()),
        "oom": est.oom,
        **rec_cols,
    }


def fullbatch_row(
    graph_key: str,
    method: str,
    k: int,
    spec: GNNSpec,
    *,
    scale: float = 0.03,
    seed: int = 0,
    cluster: ClusterSpec = PAPER_CLUSTER,
    cache: Optional[StudyCache] = None,
    sync_mode: str = "halo",
    codec=None,
) -> dict:
    """One DistGNN study row. sync_mode="ring" prices the 1.5D regime: the
    blockrow layout replaces the edge partitioner (which is then only a
    label) and the estimate runs through the overlap-aware ring model.
    `codec` (a name or `repro.core.wire.Codec`) prices the replica-sync
    traffic at its wire width; the row keeps both byte columns."""
    from repro.core.wire import as_codec

    cache = cache or _GLOBAL_CACHE
    g = cache.graph(graph_key, scale, 0)
    if sync_mode == "ring":
        rec = cache.blockrow_partition(g, k)
        method = rec.method
    else:
        rec = cache.edge_partition(g, method, k, seed)
    est = cost_model.fullbatch_epoch(rec.book, spec, cluster, codec=codec)
    return fullbatch_result_row(
        graph_key, method, k, spec, metrics=rec.metrics,
        partition_time=rec.partition_time, est=est, sync_mode=sync_mode,
        codec=as_codec(codec).name,
    )


def fullbatch_speedup(rows: Iterable[dict]) -> list[dict]:
    """Attach speedup/memory ratios vs the random baseline per config."""
    rows = list(rows)
    base = {}
    for r in rows:
        if r["method"] == "random":
            key = (r["graph"], r["k"], r["model"], r["feature"], r["hidden"], r["layers"])
            base[key] = r
    out = []
    for r in rows:
        key = (r["graph"], r["k"], r["model"], r["feature"], r["hidden"], r["layers"])
        b = base.get(key)
        if b is None:
            continue
        r = dict(r)
        r["speedup"] = b["epoch_time"] / r["epoch_time"]
        r["memory_pct_random"] = 100.0 * r["memory_total"] / b["memory_total"]
        r["amortize_epochs"] = (
            r["partition_time"] / max(b["epoch_time"] - r["epoch_time"], 1e-12)
            if r["epoch_time"] < b["epoch_time"] else float("inf")
        )
        out.append(r)
    return out


# ---------------------------------------------------------------------------
# DistDGL-side study rows (mini-batch / vertex partitioning)
# ---------------------------------------------------------------------------


def minibatch_row(
    graph_key: str,
    method: str,
    k: int,
    spec: GNNSpec,
    *,
    scale: float = 0.03,
    seed: int = 0,
    global_batch: int = 256,
    steps: int = 4,
    cluster: ClusterSpec = PAPER_CLUSTER,
    cache: Optional[StudyCache] = None,
    train_frac: float = 0.3,
    run_device_step: bool = False,
    cache_policy: str = "none",
    cache_budget: int = 0,
    overlap: bool = False,
    prefetch_depth: int = 2,
    codec=None,
) -> dict:
    """One DistDGL study row: REAL sampling on the real partition, cost-model
    cluster times. `run_device_step=True` additionally runs the jitted
    data-parallel train step (slower; used by integration tests) — then
    `overlap`/`prefetch_depth` select the pipelined execution engine
    (gnn/pipeline.py) and the row carries its measured host phase times.
    `cache_policy`/`cache_budget` configure the per-worker feature cache
    (gnn/feature_store.py); network fetch is then priced from cache misses.
    `codec` compresses miss rows + gradient all-reduce on the wire: the
    device step (if run) trains through it, and the cost model prices fetch
    and all-reduce at its wire width."""
    from repro.core.wire import as_codec
    from repro.gnn.feature_store import FeatureStore

    cache = cache or _GLOBAL_CACHE
    g = cache.graph(graph_key, scale, 0)
    rng = np.random.default_rng(1234)
    train_mask = rng.random(g.num_vertices) < train_frac
    rec = cache.vertex_partition(g, method, k, seed, train_mask)

    host_times = None
    if run_device_step:
        feats = rng.normal(size=(g.num_vertices, spec.feature_dim)).astype(np.float32)
        labels = rng.integers(0, spec.num_classes, g.num_vertices).astype(np.int32)
        tr = MiniBatchTrainer.build(
            g, rec.assignment, k, spec, feats, labels, train_mask,
            global_batch=global_batch, seed=seed,
            cache_policy=cache_policy, cache_budget=cache_budget,
            overlap=overlap, prefetch_depth=prefetch_depth, codec=codec,
        )
        store = tr.store
        ms = [tr.train_step() for _ in range(steps)]
        tr.close()
        inputs = np.stack([m.input_vertices for m in ms]).mean(axis=0)
        remote = np.stack([m.remote_vertices for m in ms]).mean(axis=0)
        edges = np.stack([m.edges for m in ms]).mean(axis=0)
        hits = np.stack([m.cache_hits for m in ms]).mean(axis=0)
        misses = np.stack([m.remote_misses for m in ms]).mean(axis=0)
        host_times = host_phase_means(ms)
    else:
        # sampling only (fast path): identical metrics, no device compute
        from repro.gnn.sampling import SamplePlan, sample_blocks

        store = FeatureStore.build(
            g, rec.book, policy=cache_policy, budget=cache_budget,
            feature_dim=spec.feature_dim, seed=seed, codec=codec,
        )
        fanouts = PAPER_FANOUTS[spec.num_layers]
        spw = max(global_batch // k, 1)
        plan = SamplePlan.build(spw, fanouts)
        labels = np.zeros(g.num_vertices, np.int32)
        per = [[], [], [], [], []]
        srng = np.random.default_rng(seed)
        train_ids = np.where(train_mask)[0]
        pools = [train_ids[rec.assignment[train_ids] == w] for w in range(k)]
        for _ in range(steps):
            for w in range(k):
                pool = pools[w]
                if pool.shape[0] == 0:
                    for lst in per:
                        lst.append(0)
                    continue
                s = srng.choice(pool, size=min(spw, pool.shape[0]), replace=False)
                b = sample_blocks(g, s.astype(np.int64), fanouts, plan, srng,
                                  labels, owner=rec.assignment, worker=w)
                fs = store.stats(w, b.input_ids[b.input_mask])
                per[0].append(b.num_input)
                per[1].append(b.num_remote)
                per[2].append(b.num_edges)
                per[3].append(fs.num_cache_hit)
                per[4].append(fs.num_remote_miss)
        inputs = np.array(per[0], dtype=np.float64).reshape(steps, k).mean(axis=0)
        remote = np.array(per[1], dtype=np.float64).reshape(steps, k).mean(axis=0)
        edges = np.array(per[2], dtype=np.float64).reshape(steps, k).mean(axis=0)
        hits = np.array(per[3], dtype=np.float64).reshape(steps, k).mean(axis=0)
        misses = np.array(per[4], dtype=np.float64).reshape(steps, k).mean(axis=0)

    owned = rec.book.sizes.astype(np.float64)
    est = cost_model.minibatch_step(
        inputs, remote, edges, owned, spec, cluster,
        seeds_per_worker=max(global_batch // k, 1),
        remote_miss_vertices=misses, cached_vertices=store.cache_sizes,
        codec=codec,
    )
    steps_per_epoch = max(int(train_mask.sum()) // global_batch, 1)
    return minibatch_result_row(
        graph_key, method, k, spec, metrics=rec.metrics,
        partition_time=rec.partition_time, batch=global_batch,
        inputs=inputs, remote=remote, hits=hits, misses=misses,
        est=est, steps_per_epoch=steps_per_epoch,
        cache_policy=cache_policy, cache_budget=cache_budget,
        codec=as_codec(codec).name,
        # the overlap column means "the pipelined engine actually ran" —
        # the sampling-only fast path executes nothing, so it stays serial
        overlap=overlap and run_device_step, prefetch_depth=prefetch_depth,
        host_times=host_times,
    )


# the reduction itself lives in the observability layer now, shared with
# benchmarks/fig19_phase_times.py and roofline.py --smoke; this name stays
# as the study-side entry point
host_phase_means = obs_aggregate.phase_means


def minibatch_result_row(
    graph_key: str,
    method: str,
    k: int,
    spec: GNNSpec,
    *,
    metrics,
    partition_time: float,
    batch: int,
    inputs: np.ndarray,
    remote: np.ndarray,
    hits: np.ndarray,
    misses: np.ndarray,
    est,
    steps_per_epoch: int,
    cache_policy: str = "none",
    cache_budget: int = 0,
    overlap: bool = False,
    prefetch_depth: int = 0,
    host_times: Optional[dict] = None,
    codec: str = "fp32",
) -> dict:
    """Serialize one DistDGL result (shared by the study grid and the CLI).

    `step_time` models the serial phase structure, `step_time_overlap` the
    pipelined one (cost_model.overlapped_step_time); `host_times` — from
    `host_phase_means` when a device step actually ran — adds this
    container's measured wall times next to the modeled cluster times.
    `fetch_bytes` is the logical (f32) miss volume, `wire_bytes` the
    encoded volume under `codec` (equal under fp32)."""
    wire = est.fetch_bytes if getattr(est, "wire_bytes", None) is None else est.wire_bytes
    row = {
        "graph": graph_key, "method": method, "k": k,
        "codec": codec,
        "model": spec.model, "feature": spec.feature_dim,
        "hidden": spec.hidden_dim, "layers": spec.num_layers,
        "batch": batch,
        "edge_cut": metrics.edge_cut,
        "vertex_balance": metrics.vertex_balance,
        "train_vertex_balance": metrics.train_vertex_balance,
        "partition_time": partition_time,
        "input_vertices": float(inputs.mean()),
        "input_vertex_balance": float(inputs.max() / max(inputs.mean(), 1e-9)),
        "remote_vertices": float(remote.sum()),
        "cache_policy": cache_policy,
        "cache_budget": int(cache_budget),
        "cache_hits": float(hits.sum()),
        "remote_misses": float(misses.sum()),
        "hit_rate": float(hits.sum() / remote.sum()) if remote.sum() else 1.0,
        "fetch_bytes": float(est.fetch_bytes.sum()),
        "wire_bytes": float(np.asarray(wire).sum()),
        "step_time": est.step_time,
        "step_time_overlap": cost_model.overlapped_step_time(est),
        "epoch_time": est.step_time * steps_per_epoch,
        "sample_time": float(est.sample_time.max()),
        "fetch_time": float(est.fetch_time.max()),
        "compute_time": float(est.compute_time.max()),
        "memory_total": float(est.memory.sum()),
        "time_balance": float(
            (est.sample_time + est.fetch_time + est.compute_time).max()
            / max((est.sample_time + est.fetch_time + est.compute_time).mean(), 1e-12)
        ),
        "overlap": bool(overlap),
        # serial rows carry depth 0 (same convention as fig19's overlap
        # rows): the knob only means something when the pipeline is on
        "prefetch_depth": int(prefetch_depth) if overlap else 0,
    }
    if host_times is not None:
        row.update(host_times)
    return row


# ---------------------------------------------------------------------------
# Serving-side study rows (layer-wise inference + micro-batched requests)
# ---------------------------------------------------------------------------


def serve_result_row(
    graph_key: str,
    method: str,
    k: int,
    spec: GNNSpec,
    report,
    *,
    qps: float,
    hops: int,
    fanout: int,
    max_batch: int,
    max_wait: float,
    cache_policy: str = "none",
    cache_budget: int = 0,
    partition_time: float = 0.0,
    partition_quality: Optional[float] = None,
    codec: str = "fp32",
) -> dict:
    """Serialize one serving run (shared by `launch/gnn_serve.py --out-json`
    and `benchmarks/fig_serving.py`). `report` is a
    `repro.serve.ServingReport`; `partition_quality` is the regime's scalar
    (edge-cut for vertex partitions, replication factor for edge
    partitions). `miss_bytes` is the logical (f32) miss volume; `wire_bytes`
    is the encoded volume measured by the embedding store under `codec`."""
    fetch = report.fetch
    return {
        "graph": graph_key, "method": method, "k": k,
        "codec": codec,
        "model": spec.model, "feature": spec.feature_dim,
        "hidden": spec.hidden_dim, "layers": spec.num_layers,
        "regime": "serve",
        "qps_offered": float(qps),
        "hops": int(hops), "fanout": int(fanout),
        "max_batch": int(max_batch), "max_wait": float(max_wait),
        "cache_policy": cache_policy, "cache_budget": int(cache_budget),
        "partition_time": partition_time,
        "partition_quality": partition_quality,
        "requests": report.served(),
        "batches": int(report.batch_size.shape[0]),
        "latency_p50": report.p50(),
        "latency_p99": report.p99(),
        "latency_mean": float(report.latency.mean()),
        "service_mean": float(report.service_time.mean()),
        "host_mean": float(report.host_time.mean()),
        "qps_sustainable": report.sustainable_qps(),
        "qps_per_worker": [report.sustainable_qps(w) for w in range(report.k)],
        "p99_per_worker": [r["p99"] for r in report.worker_rows()],
        "remote_vertices": fetch.num_remote,
        "cache_hits": fetch.num_cache_hit,
        "remote_misses": fetch.num_remote_miss,
        "hit_rate": fetch.hit_rate,
        "miss_bytes": fetch.miss_bytes,
        "wire_bytes": fetch.wire_bytes,
        # queue-wait vs service-time attribution from the request spans
        # (queue span = enqueue→dispatch, service span = dispatch→done):
        # lets fig_serving attribute a p99 to queueing vs compute
        **obs_aggregate.request_breakdown(
            report.latency, getattr(report, "queue_wait", None)),
        **_serve_fault_cols(report),
    }


def _serve_fault_cols(report) -> dict:
    """Degraded-window columns of a faulted serving run (worker-death)."""
    if getattr(report, "fault_time", None) is None:
        return {}
    ts = report.transition_stats()
    return {
        "fault_time": ts["fault_time"],
        "dead_worker": int(report.dead_worker),
        "rerouted": ts["rerouted"],
        "transition_window": ts["window"],
        "transition_requests": ts["requests"],
        "transition_p50": ts["p50"],
        "transition_p99": ts["p99"],
    }


def serve_row(
    graph_key: str,
    method: str,
    k: int,
    spec: GNNSpec,
    *,
    scale: float = 0.03,
    seed: int = 0,
    qps: float = 200.0,
    n_requests: int = 240,
    hops: int = 1,
    fanout: int = 10,
    max_batch: int = 32,
    max_wait: float = 5e-4,
    cache_policy: str = "none",
    cache_budget: int = 0,
    cluster: ClusterSpec = PAPER_CLUSTER,
    cache: Optional[StudyCache] = None,
    codec=None,
    fault_plan=None,
    detect_delay: float = 0.0,
) -> dict:
    """One serving study row: REAL layer-wise inference + request simulation
    on the real partition, cost-model cluster latencies. `codec` installs a
    wire codec on the embedding store: miss rows are decoded from their
    encoded form (lossy codecs perturb served embeddings) and the service
    time is priced from encoded bytes.

    `fault_plan` (a `repro.fault.FaultPlan` with a worker-death event) kills
    one worker mid-trace; the failover map is derived here (replica-aware
    for edge partitions) and the row gains the degraded-window columns.

    `method` may be a vertex partitioner (the embedding store shards by it
    directly) or an edge partitioner (the store shards by the edge book's
    masters). Layer-wise embeddings are memoised per (graph, method, k,
    spec, seed) — the policy x budget x qps grid reuses them, exactly like
    partitions are reused across the training grid.
    """
    from repro.core.partition_book import build_vertex_book
    from repro.core.wire import as_codec
    from repro.gnn.inference import (
        LayerwiseInference,
        edge_assignment_from_vertex,
    )
    from repro.gnn.models import init_params
    from repro.serve import build_serving, run_serving_sim

    cache = cache or _GLOBAL_CACHE
    g = cache.graph(graph_key, scale, 0)
    # names shared by both regimes (e.g. "random") resolve as VERTEX
    # partitioners — the embedding store shards by vertex ownership
    if method in VERTEX_METHODS or method not in EDGE_METHODS:
        rec = cache.vertex_partition(g, method, k, seed)
        owner = rec.assignment
        edge_assignment = edge_assignment_from_vertex(g, owner)
        quality = rec.metrics.edge_cut
        edge_book = None  # vertex partitions hold no replicas
    else:
        rec = cache.edge_partition(g, method, k, seed)
        edge_assignment = rec.assignment
        owner = rec.book.master_assignment()
        quality = rec.metrics.replication_factor
        edge_book = rec.book

    memo = getattr(cache, "_serve_embeddings", None)
    if memo is None:
        memo = cache._serve_embeddings = {}
    # layer-wise inference == the full-batch forward for ANY partition
    # (tested per backend), so the embeddings are partition-invariant:
    # one pass per (graph, spec, seed) serves every (method, k) cell
    key = (id(g), spec, seed)
    if key not in memo:
        rng = np.random.default_rng(seed)
        feats = rng.normal(size=(g.num_vertices, spec.feature_dim))
        params = init_params(spec, seed=seed)
        eng = LayerwiseInference.build(
            g, edge_assignment, k, spec, params, feats.astype(np.float32))
        memo[key] = (params, eng.run())
    params, embeddings = memo[key]

    vbook = build_vertex_book(g, owner, k)
    engines, batchers, _ = build_serving(
        g, vbook, spec, params, embeddings,
        hops=hops, fanout=fanout, max_batch=max_batch, max_wait=max_wait,
        cache_policy=cache_policy, cache_budget=cache_budget, seed=seed,
        codec=codec,
    )
    rng = np.random.default_rng(seed + 99)
    request_ids = rng.integers(0, g.num_vertices, n_requests)
    arrivals = np.sort(rng.uniform(0.0, n_requests / qps, n_requests))
    failover = None
    if fault_plan is not None and fault_plan.events_of("worker-death"):
        from repro.fault.recovery import failover_assignment

        ev = fault_plan.events_of("worker-death")[0]
        dead = fault_plan.resolve_worker(ev, k)
        failover = failover_assignment(owner, dead, k, book=edge_book)
    report = run_serving_sim(engines, batchers, owner, request_ids, arrivals,
                             cluster=cluster, fault_plan=fault_plan,
                             failover_owner=failover,
                             detect_delay=detect_delay)
    return serve_result_row(
        graph_key, method, k, spec, report,
        qps=qps, hops=hops, fanout=fanout, max_batch=max_batch,
        max_wait=max_wait, cache_policy=cache_policy,
        cache_budget=cache_budget, partition_time=rec.partition_time,
        partition_quality=quality, codec=as_codec(codec).name,
    )


def minibatch_speedup(rows: Iterable[dict]) -> list[dict]:
    rows = list(rows)
    base = {}
    for r in rows:
        if r["method"] == "random":
            key = (r["graph"], r["k"], r["model"], r["feature"], r["hidden"],
                   r["layers"], r["batch"])
            base[key] = r
    out = []
    for r in rows:
        key = (r["graph"], r["k"], r["model"], r["feature"], r["hidden"],
               r["layers"], r["batch"])
        b = base.get(key)
        if b is None:
            continue
        r = dict(r)
        r["speedup"] = b["epoch_time"] / r["epoch_time"]
        r["net_pct_random"] = 100.0 * r["fetch_bytes"] / max(b["fetch_bytes"], 1e-9)
        r["remote_pct_random"] = 100.0 * r["remote_vertices"] / max(b["remote_vertices"], 1e-9)
        r["memory_pct_random"] = 100.0 * r["memory_total"] / b["memory_total"]
        r["amortize_epochs"] = (
            r["partition_time"] / max(b["epoch_time"] - r["epoch_time"], 1e-12)
            if r["epoch_time"] < b["epoch_time"] else float("inf")
        )
        out.append(r)
    return out
