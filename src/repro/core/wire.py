"""The wire layer: pluggable codecs for every byte-moving path.

The paper's mechanism is that partitioning quality governs how many bytes
cross the network; this module is the complementary lever the follow-up
literature (SAR, the DistGNN-compression line) pulls on the SAME bytes:
compress the payload instead of (or on top of) partitioning it better.
Every communication path in the repo routes its payload through one
`Codec`:

  gnn/sync.py          halo all_to_all buffers + ring ppermute blocks
                       (encode BEFORE the collective, decode after — the
                       compiled HLO moves the compressed dtype, pinned in
                       tests/test_dist_lowering.py)
  gnn/feature_store.py remote-miss rows (the DistDGL fetch phase)
  gnn/fullbatch.py +   gradient all-reduce via the error-feedback pmean
  gnn/minibatch.py     (`codec_grad_reduce`, composing optim/compress.py)
  core/cost_model.py   analytic `wire_bytes` next to every logical bytes
                       term

A codec is three functions:

  encode(x)                -> (payload, meta)   payload is what crosses the
                                                wire; meta (scale) rides
                                                along or is None
  decode(payload, meta)    -> x'                f32 reconstruction
  wire_bytes(shape, dtype) -> int               bytes on the wire for one
                                                encoded tensor, payload +
                                                meta (== payload.nbytes +
                                                meta.nbytes, property-
                                                tested in tests/test_wire.py)

`Fp32Codec` is the default and is the IDENTITY — encode/decode return their
input untouched, so every default path is bitwise-identical to the
pre-codec code (no astype, no extra ops in the jaxpr). Encode/decode accept
numpy arrays (the host-side feature store path) and jax arrays/tracers (the
device collectives) alike.

Error feedback: lossy gradient reduction carries the quantisation residual
to the next step (Seide et al. / Karimireddy et al.) so compression error
acts like a delayed gradient instead of a bias. `codec_grad_reduce` is the
trainer-facing wrapper: lossless codecs take the plain pmean; int8 routes
through `optim/compress.py`'s quantiser (the same compress/decompress pair
`compressed_psum` composes); other lossy codecs run the identical
EF recipe with their own encode/decode. The EF state is an explicit carry
(same tree as the grads), jit-stable, donated alongside opt_state.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CODECS",
    "Bf16Codec",
    "Codec",
    "Fp32Codec",
    "Int8EFCodec",
    "VariableRatioCodec",
    "as_codec",
    "codec_grad_reduce",
    "ef_init",
    "make_codec",
    "narrow_wire_dtypes",
    "roundtrip",
]

CODECS = ("fp32", "bf16", "int8", "variable")


def _xp(x):
    """numpy for host arrays, jnp for device arrays/tracers."""
    return np if isinstance(x, np.ndarray) else jnp


def _nelems(shape) -> int:
    return int(math.prod(int(s) for s in shape))


@runtime_checkable
class Codec(Protocol):
    """What every wire codec implements (see module docstring)."""

    name: str
    lossless: bool

    def encode(self, x, *, layer: int = 0): ...

    def decode(self, payload, meta): ...

    def wire_bytes(self, shape, dtype=np.float32) -> int: ...

    def wire_dtype(self, layer: int = 0): ...

    def ratio(self, layer: int = 0) -> float: ...


@dataclasses.dataclass(frozen=True)
class Fp32Codec:
    """Identity codec: the wire carries the raw f32 payload (today's bytes).

    encode/decode return their argument UNCHANGED (same object, no astype),
    which is what makes `codec="fp32"` bitwise-identical to the pre-wire
    code paths — the refactor is behaviour-preserving by default.
    """

    name = "fp32"
    lossless = True

    def encode(self, x, *, layer: int = 0):
        return x, None

    def decode(self, payload, meta):
        return payload

    def wire_bytes(self, shape, dtype=np.float32) -> int:
        n = _nelems(shape)
        return n * np.dtype(dtype).itemsize if n else 0

    def wire_dtype(self, layer: int = 0):
        return jnp.float32

    def ratio(self, layer: int = 0) -> float:
        return 1.0


@dataclasses.dataclass(frozen=True)
class Bf16Codec:
    """Round-to-bfloat16 payload: 2 bytes/element, ~3 significand bits lost.

    No meta crosses the wire; relative roundtrip error is bounded by
    2^-8 (half a ulp of the 8-bit bf16 significand).
    """

    name = "bf16"
    lossless = False

    def encode(self, x, *, layer: int = 0):
        return x.astype(jnp.bfloat16), None

    def decode(self, payload, meta):
        return payload.astype(jnp.float32)

    def wire_bytes(self, shape, dtype=np.float32) -> int:
        n = _nelems(shape)
        return n * 2 if n else 0

    def wire_dtype(self, layer: int = 0):
        return jnp.bfloat16

    def ratio(self, layer: int = 0) -> float:
        return 0.5


@dataclasses.dataclass(frozen=True)
class Int8EFCodec:
    """Per-tensor int8 uniform quantisation (optim/compress.py's scheme).

    scale = max|x| / 127 rides along as one f32 meta scalar per encoded
    tensor — both the int8 payload and the scale cross the wire, which is
    exactly what the ring-HLO byte pin measures. The "EF" in the name is
    the gradient-reduce contract: `codec_grad_reduce` threads this codec
    through the error-feedback accumulator so quantisation error never
    biases convergence; activation exchanges (halo/ring) re-encode fresh
    payloads each sync and need no carried state.
    """

    name = "int8"
    lossless = False
    meta_bytes = 4  # one f32 scale per encoded tensor

    def encode(self, x, *, layer: int = 0):
        xp = _xp(x)
        if x.size == 0:
            return x.astype(xp.int8), xp.float32(1.0)
        x = x.astype(xp.float32)
        scale = xp.maximum(xp.max(xp.abs(x)), 1e-12) / 127.0
        q = xp.clip(xp.round(x / scale), -127, 127).astype(xp.int8)
        return q, scale

    def decode(self, payload, meta):
        xp = _xp(payload)
        return payload.astype(xp.float32) * meta

    def wire_bytes(self, shape, dtype=np.float32) -> int:
        n = _nelems(shape)
        return n + self.meta_bytes if n else 0

    def wire_dtype(self, layer: int = 0):
        return jnp.int8

    def ratio(self, layer: int = 0) -> float:
        return 0.25


@dataclasses.dataclass(frozen=True)
class VariableRatioCodec:
    """Ratio ramps with depth and training progress (SAR's
    `--enable_cr --compression_type variable` policy).

    The first aggregate of a forward pass carries the widest payload (the
    feature-width block) and tolerates compression best, so it quantises
    hardest; deeper aggregates — closer to the loss — get progressively
    more precision. Early epochs (`epoch < warmup_epochs`) soften the whole
    schedule one notch, protecting the noisy initial steps:

        layer 0:   int8  (bf16 during warmup)
        layer >=1: bf16  (fp32 during warmup)

    `layer` is the aggregate ordinal within one forward pass (sync
    strategies count their aggregates; GAT's three layer-0 syncs are
    ordinals 0..2). Swapping `epoch` builds a NEW codec — the step function
    re-traces, so ramp at epoch granularity, not per step.
    """

    name = "variable"
    lossless = False
    epoch: int = 0
    warmup_epochs: int = 2

    def _sub(self, layer: int):
        hard = self.epoch >= self.warmup_epochs
        if layer == 0:
            return _INT8 if hard else _BF16
        return _BF16 if hard else _FP32

    def at_epoch(self, epoch: int) -> "VariableRatioCodec":
        return dataclasses.replace(self, epoch=int(epoch))

    def encode(self, x, *, layer: int = 0):
        return self._sub(layer).encode(x)

    def decode(self, payload, meta):
        # dispatch on the payload dtype — each sub-codec is recognisable
        if payload.dtype == jnp.int8:
            return _INT8.decode(payload, meta)
        if payload.dtype == jnp.bfloat16:
            return _BF16.decode(payload, meta)
        return _FP32.decode(payload, meta)

    def wire_bytes(self, shape, dtype=np.float32, *, layer: int = 0) -> int:
        return self._sub(layer).wire_bytes(shape, dtype)

    def wire_dtype(self, layer: int = 0):
        return self._sub(layer).wire_dtype()

    def ratio(self, layer: int = 0) -> float:
        return self._sub(layer).ratio()


_FP32 = Fp32Codec()
_BF16 = Bf16Codec()
_INT8 = Int8EFCodec()
_REGISTRY = {"fp32": _FP32, "bf16": _BF16, "int8": _INT8,
             "variable": VariableRatioCodec()}


def make_codec(name: str) -> Codec:
    """Codec instance by CLI name (`--codec {fp32,bf16,int8,variable}`)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}: options are {', '.join(CODECS)}")


def as_codec(codec: "Optional[str | Codec]") -> Codec:
    """Normalise None / a name / an instance to a Codec (None -> fp32)."""
    if codec is None:
        return _FP32
    if isinstance(codec, str):
        return make_codec(codec)
    return codec


def roundtrip(codec: Codec, x, *, layer: int = 0):
    """decode(encode(x)) — the locally-observable effect of the wire."""
    payload, meta = codec.encode(x, layer=layer)
    return codec.decode(payload, meta)


def narrow_wire_dtypes(codec: "Optional[str | Codec]",
                       max_layers: int = 4) -> frozenset:
    """Dtype NAMES this codec may narrow f32 payloads to on the wire.

    The dtype-policy rule (repro.analysis) compares the narrowing
    `convert_element_type`s it finds in a traced program against this set:
    the fp32 codec returns an EMPTY set (any narrowing convert on an
    fp32-default path is a violation), int8 returns {"int8"}, and the
    variable-ratio codec returns the union over its per-layer schedule at
    its current epoch — exactly where `core/wire.py` says the trace may
    narrow, and nowhere else.
    """
    codec = as_codec(codec)
    dts = set()
    for layer in range(max_layers):
        dt = np.dtype(codec.wire_dtype(layer=layer))
        if dt.itemsize < 4:
            dts.add(dt.name)
    return frozenset(dts)


# ---------------------------------------------------------------------------
# Error-feedback gradient reduction (the trainers' allreduce path)
# ---------------------------------------------------------------------------


def ef_init(grads_like) -> Any:
    """Zero error-feedback accumulator, same tree/shapes as the grads."""
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def codec_grad_reduce(codec: Codec, grads, ef, axis: Optional[str]):
    """Data-parallel gradient mean through the codec, with error feedback.

    Returns (mean_grads, new_ef). Lossless codecs take the plain pmean and
    the EF state passes through untouched (zero forever). Lossy codecs run
    the compressed_psum recipe — quantise (corrected = g + e), reduce the
    dequantised views, keep the residual local — with int8 literally routed
    through `optim/compress.py`'s compress/decompress pair so the trainer
    allreduce and the cross-pod `compressed_psum` cannot drift apart.
    `axis=None` (k == 1) skips the collective; the quantisation + EF still
    applies, so the k=1 oracle sees the same arithmetic as each worker.
    """
    def pmean(g):
        return jax.lax.pmean(g, axis) if axis is not None else g

    if codec.lossless:
        return jax.tree.map(pmean, grads), ef

    if codec.name == "int8":
        from repro.optim.compress import CompressionState, compress, decompress

        qs, scales, new_state = compress(grads, CompressionState(error=ef))
        deq = decompress(qs, scales)
        return jax.tree.map(pmean, deq), new_state.error

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        deq = roundtrip(codec, corrected)
        return deq, corrected - deq

    flat, treedef = jax.tree.flatten(grads)
    eflat = treedef.flatten_up_to(ef)
    pairs = [one(g, e) for g, e in zip(flat, eflat)]
    mean = treedef.unflatten([pmean(d) for d, _ in pairs])
    new_ef = treedef.unflatten([r for _, r in pairs])
    return mean, new_ef
