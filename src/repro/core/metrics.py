"""Partitioning-quality metrics, exactly as defined in the paper §2.1.

Edge partitioning (vertex-cut): replication factor RF(P), edge balance EB(P),
vertex balance VB(P).

Vertex partitioning (edge-cut): edge-cut ratio lambda, vertex balance, plus
the paper's GNN-specific metrics (training-vertex balance §5.1, input-vertex
balance §5.2).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph

__all__ = [
    "EdgePartitionMetrics",
    "VertexPartitionMetrics",
    "edge_partition_metrics",
    "vertex_partition_metrics",
    "replication_factor",
]


@dataclasses.dataclass(frozen=True)
class EdgePartitionMetrics:
    num_partitions: int
    replication_factor: float  # RF(P) = (1/|V|) sum_i |V(p_i)|
    edge_balance: float        # max(|p_i|) / mean(|p_i|)
    vertex_balance: float      # max(|V(p_i)|) / mean(|V(p_i)|)
    vertices_per_partition: np.ndarray  # |V(p_i)|, int64 [k]
    edges_per_partition: np.ndarray     # |p_i|,   int64 [k]

    def as_row(self) -> dict:
        return {
            "k": self.num_partitions,
            "rf": round(self.replication_factor, 4),
            "edge_balance": round(self.edge_balance, 4),
            "vertex_balance": round(self.vertex_balance, 4),
        }


@dataclasses.dataclass(frozen=True)
class VertexPartitionMetrics:
    num_partitions: int
    edge_cut: float            # lambda = |E_cut| / |E|
    vertex_balance: float      # max(|p_i|) / mean(|p_i|)
    train_vertex_balance: float  # same over the training-vertex subset
    vertices_per_partition: np.ndarray
    cut_edges: int

    def as_row(self) -> dict:
        return {
            "k": self.num_partitions,
            "edge_cut": round(self.edge_cut, 4),
            "vertex_balance": round(self.vertex_balance, 4),
            "train_vertex_balance": round(self.train_vertex_balance, 4),
        }


def _balance(counts: np.ndarray) -> float:
    counts = np.asarray(counts, dtype=np.float64)
    mean = counts.mean()
    if mean == 0:
        return 1.0
    return float(counts.max() / mean)


def partition_vertex_cover(graph: Graph, edge_assignment: np.ndarray, k: int) -> np.ndarray:
    """|V(p_i)| for each partition: vertices covered by partition i's edges.

    Returns an int64 [k] array. Vectorised: build (partition, vertex) pairs
    for both endpoints, unique them.
    """
    part = np.asarray(edge_assignment, dtype=np.int64)
    pairs_src = part * graph.num_vertices + graph.src.astype(np.int64)
    pairs_dst = part * graph.num_vertices + graph.dst.astype(np.int64)
    uniq = np.unique(np.concatenate([pairs_src, pairs_dst]))
    owners = (uniq // graph.num_vertices).astype(np.int64)
    return np.bincount(owners, minlength=k)


def replication_factor(graph: Graph, edge_assignment: np.ndarray, k: int) -> float:
    cover = partition_vertex_cover(graph, edge_assignment, k)
    # Vertices with degree 0 are not covered anywhere; the paper's RF
    # denominator is |V| of the graph as loaded (all covered in practice).
    covered_any = np.unique(np.concatenate([graph.src, graph.dst])).shape[0]
    denom = max(covered_any, 1)
    return float(cover.sum() / denom)


def edge_partition_metrics(graph: Graph, edge_assignment: np.ndarray, k: int) -> EdgePartitionMetrics:
    assert edge_assignment.shape[0] == graph.num_edges
    assert edge_assignment.min(initial=0) >= 0 and edge_assignment.max(initial=0) < k
    edges_per = np.bincount(edge_assignment, minlength=k).astype(np.int64)
    cover = partition_vertex_cover(graph, edge_assignment, k)
    covered_any = np.unique(np.concatenate([graph.src, graph.dst])).shape[0]
    return EdgePartitionMetrics(
        num_partitions=k,
        replication_factor=float(cover.sum() / max(covered_any, 1)),
        edge_balance=_balance(edges_per),
        vertex_balance=_balance(cover),
        vertices_per_partition=cover,
        edges_per_partition=edges_per,
    )


def vertex_partition_metrics(
    graph: Graph,
    vertex_assignment: np.ndarray,
    k: int,
    train_mask: np.ndarray | None = None,
) -> VertexPartitionMetrics:
    assert vertex_assignment.shape[0] == graph.num_vertices
    assert vertex_assignment.min(initial=0) >= 0 and vertex_assignment.max(initial=0) < k
    per = np.bincount(vertex_assignment, minlength=k).astype(np.int64)
    cut = int((vertex_assignment[graph.src] != vertex_assignment[graph.dst]).sum())
    if train_mask is not None:
        train_per = np.bincount(vertex_assignment[train_mask], minlength=k).astype(np.int64)
        tvb = _balance(train_per)
    else:
        tvb = _balance(per)
    return VertexPartitionMetrics(
        num_partitions=k,
        edge_cut=float(cut / max(graph.num_edges, 1)),
        vertex_balance=_balance(per),
        train_vertex_balance=tvb,
        vertices_per_partition=per,
        cut_edges=cut,
    )


def input_vertex_balance(input_counts: np.ndarray) -> float:
    """Paper §5.2: per-step balance of mini-batch *input vertices* —
    max(input vertices of any worker) / mean(...)."""
    return _balance(input_counts)
