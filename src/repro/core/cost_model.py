"""Cluster cost model — maps measured partition/sampling metrics to the
paper's 32-machine cluster (§3: 8-core Haswell 2.4 GHz, 64 GB RAM).

Why a model: this container has one CPU, but the paper's findings are about
*cluster* wall-time, which is max-over-machines(compute) + network/bw. Both
inputs are measurable exactly here: per-partition compute load (edges,
vertices, flops) comes from the real partition books; per-partition
communication volume comes from the real replica lists / sampled batches.
Only the hardware constants are assumed, and they are stated below. The
same accounting doubles as the TPU-pod collective model used in §Roofline
(with TPU constants), where it is cross-checked against compiled HLO.

Conventions: times in seconds, sizes in bytes, rates in bytes/s or flop/s.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.partition_book import BlockRowBook, EdgePartitionBook
from repro.core.wire import as_codec
from repro.gnn.models import GNNSpec

__all__ = [
    "ClusterSpec",
    "PAPER_CLUSTER",
    "collective_budget",
    "fullbatch_epoch",
    "minibatch_step",
    "overlapped_step_time",
    "RecoveryEstimate",
    "recovery_time",
    "ring_bytes_per_round",
    "serve_request",
]


def collective_budget(book, d: int, mode: str, codec=None,
                      layer: int = 0) -> dict:
    """Predicted compiled-HLO collective budget of one aggregate — the
    hook the analysis subsystem's collective-budget rule prices programs
    with. Canonical implementation sits next to the byte formulas in
    `gnn.sync`; re-exported here so model-side consumers get every
    analytic communication quantity from one module.

    Returns {hlo_kind: {"count": (lo, hi), "cluster_bytes": int}}.
    """
    from repro.gnn.sync import collective_budget as _impl

    return _impl(book, d, mode, codec=codec, layer=layer)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Hardware constants for one machine + interconnect."""

    name: str
    flops: float          # effective dense flop/s per machine
    mem_bw: float         # bytes/s effective memory bandwidth (sparse agg)
    net_bw: float         # bytes/s per-machine network bandwidth
    net_latency: float    # seconds per collective round
    memory: float         # bytes of RAM per machine
    sample_rate: float    # sampled edges/s per machine (host sampler)
    remote_adj_cost: float  # seconds per remote vertex adjacency access
    sample_hop_overhead: float = 5e-4  # fixed per-hop cost (RPC round, batching)
    # recovery constants (fault/recovery.py): checkpoint-restore read
    # bandwidth (shared FS) and the XLA re-compile a mesh-shape change pays
    disk_bw: float = 500e6      # bytes/s checkpoint restore read bandwidth
    recompile_s: float = 30.0   # seconds to re-trace + re-compile the step


# Paper cluster: 8-core 2.4 GHz Haswell. Dense f32 peak would be
# ~614 GFLOP/s; GNN kernels on DGL reach a few percent of peak, so we use an
# effective 40 GFLOP/s. 10 GbE assumed (not stated in the paper): 1.25 GB/s.
# sample_rate: DGL's CPU sampler does tens of millions of sampled edges/s
# per machine (~50 ns/edge); remote adjacency accesses add a small batched
# per-vertex RPC overhead on top.
PAPER_CLUSTER = ClusterSpec(
    name="paper-32x-haswell",
    flops=40e9,
    mem_bw=12e9,
    net_bw=1.25e9,
    net_latency=150e-6,
    memory=64e9,
    sample_rate=2e7,
    remote_adj_cost=2e-7,
    sample_hop_overhead=5e-4,
)


def _flops_per_vertex_dims(model: str, dims) -> float:
    """Dense NN flops per vertex for one forward pass over `dims` layers."""
    total = 0.0
    for din, dout in dims:
        if model == "sage":
            total += 2.0 * din * dout * 2  # self + neigh matmuls
        elif model == "gcn":
            total += 2.0 * din * dout
        else:  # gat
            total += 2.0 * din * dout + 8.0 * dout
    return total


def _model_flops_per_vertex(spec: GNNSpec) -> float:
    """Dense NN flops per vertex for one forward pass (all layers)."""
    return _flops_per_vertex_dims(spec.model, spec.dims())


def _agg_bytes_per_edge(spec: GNNSpec) -> float:
    """Bytes moved per edge per layer for the aggregation (read msg + write)."""
    dims = [spec.feature_dim] + [spec.hidden_dim] * (spec.num_layers - 1)
    return float(sum(3 * 4 * d for d in dims))


def _wire_elem(codec, layer: int = 0) -> float:
    """Per-element wire bytes under `codec` (f32 logical elements).

    Analytic-model granularity: the O(1) per-tensor metadata (one f32 scale
    for int8) is dropped here; the exact per-tensor accounting lives in
    `Codec.wire_bytes` / `gnn.sync.sync_wire_bytes_per_round`. With the
    default codec (fp32) this is exactly 4.0, so every estimate below is
    float-identical to the pre-codec model.
    """
    return 4.0 * as_codec(codec).ratio(layer)


@dataclasses.dataclass(frozen=True)
class FullBatchEstimate:
    epoch_time: float
    compute_time: np.ndarray     # [k] per machine
    comm_time: np.ndarray        # [k]
    comm_bytes: np.ndarray       # [k] true (unpadded) replica-sync traffic
    memory: np.ndarray           # [k] bytes
    oom: bool
    # [k] encoded bytes actually crossing the network under the codec the
    # estimate was priced with; == comm_bytes for lossless/fp32 codecs.
    wire_bytes: Optional[np.ndarray] = None


def ring_bytes_per_round(book: BlockRowBook, d: int) -> int:
    """Cluster-wide `ppermute` bytes of ONE ring aggregate at width d.

    k−1 stages, each device shipping its [Vb+1, d] f32 payload block:
    k·(k−1)·(Vb+1)·d·4 bytes. Independent of graph structure — the 1.5D
    regime trades the replication-factor sensitivity of halo for a fixed
    (k−1)/k · V·d volume (< dense's 2·V·d at every k). Matches
    `gnn.sync.sync_bytes_per_round(book, d, "ring")` and is pinned against
    the compiled collective-permute HLO in tests/test_dist_lowering.py.
    """
    return book.k * (book.k - 1) * (book.v_block + 1) * d * 4


def _ring_epoch(
    book: BlockRowBook,
    spec: GNNSpec,
    cluster: ClusterSpec,
    codec=None,
) -> FullBatchEstimate:
    """Overlap-aware 1.5D ring epoch estimate.

    Each aggregate is k stages of per-chunk segment-SpMM with the next
    block's `ppermute` in flight: a stage's transfer is hidden when the
    chunk compute covers it, so per aggregate
        time = k·c_stage + (k−1)·max(0, t_stage − c_stage)
    and only the uncovered remainder shows up as comm_time.
    """
    k = book.k
    edges = book.chunk_emask.sum(axis=(1, 2)).astype(np.float64)
    verts = book.vmask.sum(axis=1).astype(np.float64)

    # chunk_emask already counts BOTH directions of every stored edge, while
    # _agg_bytes_per_edge prices a stored (bidirectional) edge — halve.
    agg_bytes = edges / 2.0 * _agg_bytes_per_edge(spec) * 3.0
    nn_flops = verts * _model_flops_per_vertex(spec) * 3.0
    compute = agg_bytes / cluster.mem_bw + nn_flops / cluster.flops

    dims = [dout for _, dout in spec.dims()]
    aggs_per_layer = 3 if spec.model == "gat" else 1
    syncs = aggs_per_layer * 2  # per layer, fwd+bwd
    stage_rows = float(book.v_block + 1)
    comm_bytes = np.full(k, (k - 1) * stage_rows * 4 * sum(dims) * syncs)
    wire_bytes = np.zeros(k)
    for li, d in enumerate(dims):
        eb = _wire_elem(codec, li * aggs_per_layer)
        wire_bytes += (k - 1) * stage_rows * eb * d * syncs
    comm = np.zeros(k)
    if k > 1:
        for li, d in enumerate(dims):
            eb = _wire_elem(codec, li * aggs_per_layer)
            t_stage = (stage_rows * d * eb / cluster.net_bw
                       + cluster.net_latency)
            # per-stage chunk compute: this layer's aggregation share of the
            # memory-bound traffic, spread over the k chunks
            layer_frac = 3 * 4 * d / _agg_bytes_per_edge(spec)
            c_stage = agg_bytes * layer_frac / cluster.mem_bw / k
            exposed = np.maximum(0.0, t_stage - c_stage) * (k - 1)
            comm += exposed * syncs
    f, h, L = spec.feature_dim, spec.hidden_dim, spec.num_layers
    memory = (
        verts * f * 4
        + verts * h * 4 * L * 2
        + edges * 4
        + 2 * stage_rows * max(f, h) * 4  # double-buffered rotation payload
    )
    epoch = float((compute + comm).max())
    return FullBatchEstimate(
        epoch_time=epoch,
        compute_time=compute,
        comm_time=comm,
        comm_bytes=comm_bytes,
        memory=memory,
        oom=bool((memory > cluster.memory).any()),
        wire_bytes=wire_bytes,
    )


def fullbatch_epoch(
    book,
    spec: GNNSpec,
    cluster: ClusterSpec = PAPER_CLUSTER,
    codec=None,
) -> FullBatchEstimate:
    """Full-batch epoch estimate from a real partition book.

    EdgePartitionBook (DistGNN/halo regime) —
    Compute: aggregation is memory-bound over local edges; vertex updates are
    dense flops over local (replicated!) vertices — so *vertex imbalance*
    directly skews compute, exactly the paper's §4.2(2) observation.
    Communication: true per-partition replica-sync volume (alltoallv on the
    paper's cluster — no bucket padding), reduce + broadcast per layer,
    forward + backward.

    BlockRowBook (1.5D ring regime) — see `_ring_epoch`: fixed rotation
    volume with the transfer overlapped against per-chunk compute.
    """
    if isinstance(book, BlockRowBook):
        return _ring_epoch(book, spec, cluster, codec)
    k = book.k
    edges = book.emask.sum(axis=1).astype(np.float64)
    verts = book.vmask.sum(axis=1).astype(np.float64)

    # fwd + bwd ~ 3x forward cost (standard rule of thumb)
    agg_bytes = edges * _agg_bytes_per_edge(spec) * 3.0
    nn_flops = verts * _model_flops_per_vertex(spec) * 3.0
    compute = agg_bytes / cluster.mem_bw + nn_flops / cluster.flops

    # per-partition sync volume: rows it sends (as mirror) + rows it returns
    # (as master) = send_mask + recv_mask true counts, per layer/round.
    send_rows = book.send_mask.sum(axis=(1, 2)).astype(np.float64)
    recv_rows = book.recv_mask.sum(axis=(1, 2)).astype(np.float64)
    dims = [dout for _, dout in spec.dims()]
    aggs_per_layer = 3 if spec.model == "gat" else 1
    syncs = aggs_per_layer * 2  # per layer, fwd+bwd
    rows = send_rows + recv_rows
    comm_bytes = np.zeros(k)
    wire_bytes = np.zeros(k)
    for li, d in enumerate(dims):
        comm_bytes += rows * d * 4 * syncs
        wire_bytes += rows * d * _wire_elem(codec, li * aggs_per_layer) * syncs
    comm = wire_bytes / cluster.net_bw + cluster.net_latency * 2 * len(dims) * syncs

    # memory: features + per-layer activations (kept for backward) + graph
    f, h, L = spec.feature_dim, spec.hidden_dim, spec.num_layers
    memory = (
        verts * f * 4
        + verts * h * 4 * L * 2
        + edges * 8
        + rows * max(f, h) * 4
    )
    epoch = float((compute + comm).max())
    return FullBatchEstimate(
        epoch_time=epoch,
        compute_time=compute,
        comm_time=comm,
        comm_bytes=comm_bytes,
        memory=memory,
        oom=bool((memory > cluster.memory).any()),
        wire_bytes=wire_bytes,
    )


@dataclasses.dataclass(frozen=True)
class MiniBatchEstimate:
    step_time: float          # serial phases: straggler host+compute + allreduce
    sample_time: np.ndarray   # [k]
    fetch_time: np.ndarray    # [k]
    compute_time: np.ndarray  # [k]
    fetch_bytes: np.ndarray   # [k]
    straggler: int            # argmax worker
    memory: np.ndarray        # [k]
    allreduce_time: float = 0.0  # gradient all-reduce (shared by both modes)
    # [k] encoded feature-fetch bytes on the wire under the pricing codec;
    # == fetch_bytes for lossless/fp32 codecs.
    wire_bytes: Optional[np.ndarray] = None


def minibatch_step(
    input_vertices: np.ndarray,
    remote_vertices: np.ndarray,
    edges: np.ndarray,
    owned_vertices: np.ndarray,
    spec: GNNSpec,
    cluster: ClusterSpec = PAPER_CLUSTER,
    seeds_per_worker: int = 64,
    *,
    remote_miss_vertices: Optional[np.ndarray] = None,
    cached_vertices: Optional[np.ndarray] = None,
    codec=None,
) -> MiniBatchEstimate:
    """DistDGL step estimate from real per-worker sampled-batch metrics.

    The paper's phase structure: sampling (host; remote adjacency accesses
    cost network latency), feature loading (remote vertices cross the
    network), forward+backward (dense flops on the sampled block), update
    (negligible). Step time = slowest worker (straggler) + gradient
    all-reduce.

    With a per-worker feature cache (gnn/feature_store.py), only cache
    *misses* cross the network: pass `remote_miss_vertices` [k] to price the
    fetch phase from missed bytes (default: every remote vertex misses, the
    uncached DistDGL behavior) and `cached_vertices` [k] to charge the cache
    copies to worker memory. Sampling still pays `remote_vertices` adjacency
    costs — the cache holds features, not adjacency.
    """
    input_vertices = input_vertices.astype(np.float64)
    remote = remote_vertices.astype(np.float64)
    edges = edges.astype(np.float64)
    miss = (remote if remote_miss_vertices is None
            else remote_miss_vertices.astype(np.float64))

    sample = (edges / cluster.sample_rate + remote * cluster.remote_adj_cost
              + cluster.sample_hop_overhead * spec.num_layers)
    fetch_bytes = miss * spec.feature_dim * 4
    wire_bytes = miss * spec.feature_dim * _wire_elem(codec)
    fetch = wire_bytes / cluster.net_bw + cluster.net_latency

    # dense flops: each sampled edge moves a d-dim message once per layer;
    # each block vertex gets the per-vertex NN update.
    nn = input_vertices * _model_flops_per_vertex(spec) * 3.0
    agg = edges * 2.0 * max(spec.feature_dim, spec.hidden_dim) * 3.0
    compute = (nn + agg) / cluster.flops

    per_worker = sample + fetch + compute
    straggler = int(np.argmax(per_worker))

    n_params = sum(din * dout for din, dout in spec.dims()) * 2
    allreduce = (2 * n_params * _wire_elem(codec) / cluster.net_bw
                 + cluster.net_latency)

    f = spec.feature_dim
    memory = (
        owned_vertices.astype(np.float64) * f * 4          # local feature shard
        + input_vertices * f * 4                            # fetched cache
        + input_vertices * spec.hidden_dim * 4 * spec.num_layers * 2
    )
    if cached_vertices is not None:                        # static feature cache
        memory = memory + cached_vertices.astype(np.float64) * f * 4
    return MiniBatchEstimate(
        step_time=float(per_worker.max() + allreduce),
        sample_time=sample,
        fetch_time=fetch,
        compute_time=compute,
        fetch_bytes=fetch_bytes,
        straggler=straggler,
        memory=memory,
        allreduce_time=float(allreduce),
        wire_bytes=wire_bytes,
    )


def overlapped_step_time(est: MiniBatchEstimate) -> float:
    """Pipelined step time from a serial `minibatch_step` estimate.

    DistDGL's sampler processes (and gnn/pipeline.py's prefetch engine)
    hide the host phases behind device compute, so in steady state each
    worker's step costs max(sample + fetch, compute) instead of their sum;
    the cluster step is still gated by the slowest worker plus the gradient
    all-reduce, which no amount of prefetch hides. This is the model-side
    twin of the measured `StepMetrics.overlap_efficiency` accounting — the
    fig19 phase tables report both."""
    host = est.sample_time + est.fetch_time
    return float(np.maximum(host, est.compute_time).max() + est.allreduce_time)


# ---------------------------------------------------------------------------
# Online serving (repro.serve): one micro-batch of target-vertex requests
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeEstimate:
    """Cluster service time of ONE micro-batch at one worker."""

    service_time: float   # sample + fetch + compute (serial per worker)
    sample_time: float
    fetch_time: float
    compute_time: float
    fetch_bytes: int      # embedding-store MISS bytes, logical (f32) size
    wire_bytes: int = 0   # encoded MISS bytes; == fetch_bytes under fp32


def serve_request(
    num_input: float,
    num_remote: float,
    num_miss: float,
    edges: float,
    spec: GNNSpec,
    *,
    embed_dim: int,
    hops: int,
    cluster: ClusterSpec = PAPER_CLUSTER,
    codec=None,
) -> ServeEstimate:
    """Price one serving micro-batch from its measured MFG + store metrics.

    The serving phase structure mirrors `minibatch_step`'s, forward-only
    (inference has no backward, so no 3x): sampling the `hops`-deep MFG
    (remote adjacency accesses cost network latency), fetching the input
    frontier's layer-(L-hops) embedding rows — where, exactly like the
    training feature store, only cache-MISS bytes cross the network
    (`num_miss` of `num_remote` remote vertices, `embed_dim` * 4 bytes
    each) — and recomputing the last `hops` layers. Per-request latency =
    queue wait + this service time; better partitioning => fewer remote
    rows => fewer miss bytes => lower modeled service time, the paper's
    mechanism carried to serving.
    """
    num_input = float(num_input)
    edges = float(edges)
    sample = (edges / cluster.sample_rate
              + float(num_remote) * cluster.remote_adj_cost
              + cluster.sample_hop_overhead * hops)
    fetch_bytes = int(num_miss) * embed_dim * 4
    wire_bytes = int(round(int(num_miss) * embed_dim * _wire_elem(codec)))
    fetch = wire_bytes / cluster.net_bw + cluster.net_latency

    # forward-only dense flops over the recomputed layer suffix
    dims = spec.dims()[spec.num_layers - hops:]
    nn = num_input * _flops_per_vertex_dims(spec.model, dims)
    width = max([embed_dim] + [dout for _, dout in dims])
    agg = edges * 2.0 * width
    compute = (nn + agg) / cluster.flops

    return ServeEstimate(
        service_time=sample + fetch + compute,
        sample_time=sample,
        fetch_time=fetch,
        compute_time=compute,
        fetch_bytes=fetch_bytes,
        wire_bytes=wire_bytes,
    )


# ---------------------------------------------------------------------------
# failure recovery
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecoveryEstimate:
    """Cluster cost of one recovery: restore + re-partition + re-compile.

    The three terms are the paper-cluster price of what elastic recovery
    actually does (fault/recovery.py): read the checkpoint back from the
    shared filesystem, re-run the partitioner for the new worker count, and
    re-trace/re-compile the step function for the new mesh shape. This is
    the amortization question (tab3) extended to failures: a high-quality
    partitioner's epoch-time advantage must now also pay back its
    re-partition cost every time recovery forces one.
    """

    restore_time: float       # checkpoint read: bytes / disk_bw + latency
    repartition_time: float   # measured host partitioner wall (real data)
    recompile_time: float     # XLA re-trace + re-compile for the new mesh

    @property
    def recovery_time(self) -> float:
        return self.restore_time + self.repartition_time + self.recompile_time


def recovery_time(
    ckpt_bytes: float,
    partition_time: float,
    *,
    cluster: ClusterSpec = PAPER_CLUSTER,
    compile_time: Optional[float] = None,
) -> RecoveryEstimate:
    """Price one recovery. `ckpt_bytes` is the checkpointable state volume
    (params + opt state + EF carry); `partition_time` is the MEASURED
    re-partition wall (the partitioners run for real here, exactly like the
    partition_time column of every study row); `compile_time` overrides the
    cluster's re-compile constant when a measured value exists."""
    restore = cluster.net_latency + float(ckpt_bytes) / cluster.disk_bw
    return RecoveryEstimate(
        restore_time=restore,
        repartition_time=float(partition_time),
        recompile_time=(cluster.recompile_s if compile_time is None
                        else float(compile_time)),
    )
