"""Graph container + synthetic generators for the five paper graph categories.

The paper (Table 1) uses five graphs: Hollywood-2011 (collaboration),
Dimacs9-USA (road), Enwiki-2021 (wiki), Eu-2015-tpd (web), Orkut (social).
They range 58M-234M edges — far beyond a CPU container — so we provide
generators that reproduce each category's *structural signature* (degree-law
exponent, clustering style, directedness) at a configurable scale. All
generators are deterministic given a seed.

Everything here is NumPy on purpose: graph loading/partitioning is host-side
preprocessing in every real system (DistDGL, DistGNN, METIS); the device
compute starts after partitioning.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Graph",
    "generate_graph",
    "GRAPH_CATEGORIES",
    "paper_graph",
    "PAPER_GRAPHS",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable graph in COO + CSR form.

    Edges are stored once (canonical direction). ``directed=False`` means each
    stored edge represents both directions; the CSR adjacency then contains
    both. Vertex ids are dense ``[0, num_vertices)``.
    """

    num_vertices: int
    src: np.ndarray  # int32 [E]
    dst: np.ndarray  # int32 [E]
    directed: bool
    name: str = "graph"
    # CSR over the *message* direction (in-neighbors of each vertex),
    # built lazily via `csr()`; cached in __dict__ despite frozen dataclass.

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    # -- degree utilities ---------------------------------------------------
    def out_degrees(self) -> np.ndarray:
        deg = np.bincount(self.src, minlength=self.num_vertices)
        if not self.directed:
            deg = deg + np.bincount(self.dst, minlength=self.num_vertices)
        return deg.astype(np.int64)

    def in_degrees(self) -> np.ndarray:
        deg = np.bincount(self.dst, minlength=self.num_vertices)
        if not self.directed:
            deg = deg + np.bincount(self.src, minlength=self.num_vertices)
        return deg.astype(np.int64)

    def degrees(self) -> np.ndarray:
        """Total degree (used by degree-based partitioners like DBH)."""
        d = np.bincount(self.src, minlength=self.num_vertices) + np.bincount(
            self.dst, minlength=self.num_vertices
        )
        return d.astype(np.int64)

    # -- CSR (both directions; neighbors for sampling/aggregation) ----------
    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (indptr, indices) of the symmetrised adjacency.

        GNN aggregation and neighbor sampling in DGL operate on the
        message graph; like the paper's systems we symmetrise directed
        graphs for neighborhood computation.
        """
        cached = self.__dict__.get("_csr")
        if cached is not None:
            return cached
        s = np.concatenate([self.src, self.dst])
        d = np.concatenate([self.dst, self.src])
        order = np.argsort(s, kind="stable")
        s_sorted = s[order]
        d_sorted = d[order]
        indptr = np.zeros(self.num_vertices + 1, dtype=np.int64)
        counts = np.bincount(s_sorted, minlength=self.num_vertices)
        np.cumsum(counts, out=indptr[1:])
        object.__setattr__(self, "_csr", (indptr, d_sorted.astype(np.int32)))
        return self.__dict__["_csr"]

    def neighbors(self, v: int) -> np.ndarray:
        indptr, indices = self.csr()
        return indices[indptr[v] : indptr[v + 1]]

    def validate(self) -> None:
        assert self.src.dtype == np.int32 and self.dst.dtype == np.int32
        assert self.src.shape == self.dst.shape
        assert self.src.min(initial=0) >= 0 and self.dst.min(initial=0) >= 0
        if self.num_edges:
            assert int(self.src.max()) < self.num_vertices
            assert int(self.dst.max()) < self.num_vertices


def _dedupe(src: np.ndarray, dst: np.ndarray, directed: bool) -> tuple[np.ndarray, np.ndarray]:
    """Remove self-loops and duplicate edges (canonicalised if undirected)."""
    mask = src != dst
    src, dst = src[mask], dst[mask]
    if not directed:
        lo = np.minimum(src, dst)
        hi = np.maximum(src, dst)
        src, dst = lo, hi
    key = src.astype(np.int64) * (int(max(src.max(initial=0), dst.max(initial=0))) + 1) + dst
    _, idx = np.unique(key, return_index=True)
    idx.sort()
    return src[idx], dst[idx]


# ---------------------------------------------------------------------------
# Generators, one per paper category.
# ---------------------------------------------------------------------------


def _rmat(
    num_vertices: int,
    num_edges: int,
    rng: np.random.Generator,
    a: float,
    b: float,
    c: float,
) -> tuple[np.ndarray, np.ndarray]:
    """R-MAT/Kronecker generator — standard power-law graph model.

    Vectorised: every bit of every edge endpoint is drawn at once.
    """
    scale = int(np.ceil(np.log2(max(num_vertices, 2))))
    n = 1 << scale
    # Oversample to survive dedupe.
    m = int(num_edges * 1.35) + 16
    d = 1.0 - a - b - c
    probs = np.array([a, b, c, d])
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        quad = rng.choice(4, size=m, p=probs)
        src = (src << 1) | (quad >> 1)
        dst = (dst << 1) | (quad & 1)
    # Permute ids so the power-law isn't aligned with id order (realistic).
    perm = rng.permutation(n)
    src = perm[src] % num_vertices
    dst = perm[dst] % num_vertices
    return src.astype(np.int32), dst.astype(np.int32)


def _with_communities(
    n: int,
    m: int,
    rng: np.random.Generator,
    rmat_params: tuple[float, float, float],
    intra_frac: float,
) -> tuple[np.ndarray, np.ndarray]:
    """Power-law graph with planted community structure.

    Real social/web/wiki graphs combine a heavy-tailed degree law with strong
    locality (communities / host-local links) — that locality is exactly what
    in-memory partitioners (METIS/KaHIP/HEP) exploit and what pure R-MAT
    lacks. We draw `intra_frac` of the edges inside power-law-sized
    communities and the rest from a global R-MAT.
    """
    m_intra = int(m * intra_frac)
    m_global = m - m_intra
    a, b, c = rmat_params
    gs, gd = _rmat(n, m_global, rng, a=a, b=b, c=c)

    # Power-law community sizes laid out contiguously in a *hidden* order.
    sizes = np.clip((rng.pareto(1.3, size=max(n // 40, 8)) + 1.0) * 30, 8, n // 4)
    sizes = sizes.astype(np.int64)
    bounds = np.cumsum(sizes)
    bounds = bounds[bounds < n]
    starts = np.concatenate([[0], bounds])
    ends = np.concatenate([bounds, [n]])
    widths = ends - starts
    # Sample intra edges proportional to community size (degree-balanced-ish).
    comm = rng.choice(starts.shape[0], size=m_intra, p=widths / widths.sum())
    lo = starts[comm]
    w = widths[comm]
    # Within a community, prefer low offsets (local hubs): squared trick.
    u = lo + (rng.random(m_intra) ** 2 * w).astype(np.int64)
    v = lo + (rng.random(m_intra) * w).astype(np.int64)
    # Hide the contiguous layout behind a random permutation.
    perm = rng.permutation(n)
    src = np.concatenate([perm[u], gs.astype(np.int64)]).astype(np.int32)
    dst = np.concatenate([perm[v], gd.astype(np.int64)]).astype(np.int32)
    return src, dst


def _social(n: int, m: int, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray, bool]:
    """Orkut-like: undirected, heavy-tailed, strong community structure."""
    src, dst = _with_communities(n, m, rng, (0.57, 0.19, 0.19), intra_frac=0.75)
    return src, dst, False


def _web(n: int, m: int, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray, bool]:
    """Eu-2015-like: directed, very skewed, host-local link blocks."""
    src, dst = _with_communities(n, m, rng, (0.65, 0.15, 0.15), intra_frac=0.85)
    return src, dst, True


def _wiki(n: int, m: int, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray, bool]:
    """Enwiki-like: directed, skewed in-degree, topic-cluster locality."""
    src, dst = _with_communities(n, m, rng, (0.6, 0.2, 0.1), intra_frac=0.65)
    return src, dst, True


def _collab(n: int, m: int, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray, bool]:
    """Hollywood-like: undirected, dense clique-ish collaboration cliques.

    Model: sample "movies" (cliques) with power-law cast sizes and connect
    cast pairwise, which matches how Hollywood-2011 is built.
    """
    src_list = []
    dst_list = []
    total = 0
    while total < m:
        size = min(2 + int(rng.pareto(1.6) * 3), 60)
        cast = rng.integers(0, n, size=size)
        iu, ju = np.triu_indices(size, k=1)
        src_list.append(cast[iu])
        dst_list.append(cast[ju])
        total += iu.shape[0]
    return (
        np.concatenate(src_list).astype(np.int32),
        np.concatenate(dst_list).astype(np.int32),
        False,
    )


def _road(n: int, m: int, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray, bool]:
    """Dimacs9-USA-like: directed, near-planar grid with low max degree,
    huge diameter, |E| ≈ 2.4 |V|."""
    side = int(np.ceil(np.sqrt(n)))
    n = side * side
    v = np.arange(n, dtype=np.int64)
    right = v + 1
    down = v + side
    ok_r = (v % side) != side - 1
    ok_d = down < n
    src = np.concatenate([v[ok_r], v[ok_d]])
    dst = np.concatenate([right[ok_r], down[ok_d]])
    # Random long-ish "highway" shortcuts, few of them.
    extra = max(int(0.03 * src.shape[0]), 1)
    es = rng.integers(0, n, size=extra)
    ed = np.clip(es + rng.integers(-3 * side, 3 * side, size=extra), 0, n - 1)
    src = np.concatenate([src, es])
    dst = np.concatenate([dst, ed])
    # Both directions exist in DIMACS (directed representation).
    return src.astype(np.int32), dst.astype(np.int32), True


GRAPH_CATEGORIES = {
    "social": _social,
    "web": _web,
    "wiki": _wiki,
    "collab": _collab,
    "road": _road,
}

# Scaled-down stand-ins for the paper's Table 1 (same |E|/|V| ratio shape).
# name: (category, |V| at scale=1.0, |E| target at scale=1.0)
PAPER_GRAPHS: dict[str, tuple[str, int, int]] = {
    "HO": ("collab", 8_000, 900_000),   # Hollywood-2011: 2M V / 229M E (dense)
    "DI": ("road", 120_000, 290_000),   # Dimacs9-USA: 24M V / 58M E (sparse)
    "EN": ("wiki", 40_000, 1_000_000),  # Enwiki-2021: 6M V / 150M E
    "EU": ("web", 45_000, 1_050_000),   # Eu-2015-tpd: 7M V / 166M E
    "OR": ("social", 25_000, 1_900_000),  # Orkut: 3M V / 234M E (dense)
}


def generate_graph(
    category: str,
    num_vertices: int,
    num_edges: int,
    *,
    seed: int = 0,
    name: Optional[str] = None,
) -> Graph:
    if category not in GRAPH_CATEGORIES:
        raise ValueError(f"unknown category {category!r}; options: {sorted(GRAPH_CATEGORIES)}")
    rng = np.random.default_rng(seed)
    src, dst, directed = GRAPH_CATEGORIES[category](num_vertices, num_edges, rng)
    src, dst = _dedupe(src, dst, directed)
    # Trim to the requested edge budget deterministically.
    if src.shape[0] > num_edges:
        keep = rng.permutation(src.shape[0])[:num_edges]
        keep.sort()
        src, dst = src[keep], dst[keep]
    # Honor the requested vertex count for every category: keep isolated
    # vertices (ids past the max referenced id) instead of silently shrinking
    # |V|, which would skew vertex-balance metrics. Road grids may exceed the
    # request because the generator rounds |V| up to a full square.
    n = int(max(src.max(initial=0), dst.max(initial=0))) + 1 if src.size else num_vertices
    g = Graph(
        num_vertices=max(n, num_vertices),
        src=src.astype(np.int32),
        dst=dst.astype(np.int32),
        directed=directed,
        name=name or f"{category}-{num_vertices}v",
    )
    g.validate()
    return g


def paper_graph(key: str, *, scale: float = 0.1, seed: int = 0) -> Graph:
    """One of the five paper graphs (HO/DI/EN/EU/OR) at a size scale.

    ``scale=1.0`` is already the CPU-tractable stand-in (~1M edges); the
    paper-size originals are 50-250x larger and meant for real clusters.
    """
    cat, nv, ne = PAPER_GRAPHS[key]
    return generate_graph(
        cat,
        max(int(nv * scale), 64),
        max(int(ne * scale), 128),
        seed=seed,
        name=key,
    )
