"""Partition books: static device-side layouts + halo routing tables.

This is the bridge between host-side partitioning (NumPy, data-dependent) and
device-side SPMD training (JAX, static shapes). Everything data-dependent is
resolved here, *before* tracing, so the compiled program contains only static
gathers/scatters and fixed-size collectives.

EdgePartitionBook (vertex-cut / DistGNN regime)
  * every edge lives on exactly one partition; cut vertices are replicated
  * each vertex has a unique *master* partition (the replica with the most
    incident edges) — mirrors hold copies
  * replica synchronisation = two static-routed all_to_all rounds:
      reduce:    mirror partials -> master (scatter-add)
      broadcast: master totals  -> mirrors (scatter-set)
    bucket size B = max over ordered partition pairs of the replica list —
    collective bytes therefore scale with the replication factor, which is
    the paper's central mechanism.

VertexPartitionBook (edge-cut / DistDGL regime)
  * every vertex (and its features) lives on exactly one partition
  * mini-batch sampling computes, per step, which remote vertices each
    worker must fetch — the paper's "remote vertices" metric.

BlockRowBook (1.5D block partitioning / CAGNET regime)
  * process row p owns the contiguous vertex block [p*Vb, (p+1)*Vb) — no
    partitioning heuristic, no replicas, every vertex has exactly one home
  * the symmetrised directed edge list is tiled into k x k block-column
    chunks: chunk (p, s) holds the directed edges with dst in block p and
    src in block (p+s) mod k, stored PRE-ROTATED in ring-stage order so
    `RingSync` stage s reads chunk s with a static index
  * replica synchronisation disappears: a `lax.ppermute` ring rotates the
    feature blocks instead (k-1 stages of (Vb+1)*d elements per device),
    each stage's local segment-SpMM over one chunk overlapping the next
    block's transfer (gnn/sync.py:RingSync).

TPU adaptation (DESIGN.md §2): DistGNN's MPI alltoallv becomes a fixed-bucket
`lax.all_to_all` because XLA SPMD requires static shapes; the partition is
known before tracing so the routing is static. Padding waste = (B * k / true
pair volume) is reported by `EdgePartitionBook.padding_waste()`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph
from repro.kernels.tiling import (
    prepare_tiled_edges,
    tiled_need_per_tile,
    tiled_shape,
)

__all__ = [
    "BlockRowBook",
    "EdgePartitionBook",
    "VertexPartitionBook",
    "build_blockrow_book",
    "build_edge_book",
    "build_vertex_book",
]


@dataclasses.dataclass(frozen=True)
class EdgePartitionBook:
    k: int
    num_vertices: int
    v_max: int  # max local vertices (excl. dummy row)
    e_max: int
    bucket: int  # B: all_to_all bucket (max replica list over ordered pairs)

    # [k, v_max+1]: global id per local slot (pad/dummy -> -1)
    vglobal: np.ndarray
    # [k, v_max+1] bool: local slot holds a real vertex
    vmask: np.ndarray
    # [k, v_max+1] bool: this partition is the master of the local vertex
    master: np.ndarray
    # [k, v_max+1] float32: *global* degree of the local vertex (for GCN/mean)
    degree: np.ndarray
    # [k, e_max] int32 local endpoint indices; pad -> v_max (dummy row)
    esrc: np.ndarray
    edst: np.ndarray
    # [k, e_max] bool
    emask: np.ndarray

    # routing — reduce phase: device i sends h[A[i, j]] to j; j scatters into
    # C[j, i]. broadcast phase is the exact transpose.
    # [k, k, bucket] int32 local indices (pad -> v_max) and bool masks
    send_idx: np.ndarray   # A
    send_mask: np.ndarray
    recv_idx: np.ndarray   # C
    recv_mask: np.ndarray

    replicas_total: int  # sum over pairs of true replica-list lengths

    # tiled aggregation layout (kernels.tiling.prepare_tiled_edges, built
    # with the DEFAULT_TILE_V/DEFAULT_BLOCK_E tiling `ops.aggregate` expects)
    # over the SYMMETRISED edge list — dst sequence [edst | esrc], one layout
    # per partition, padded to a uniform per-tile edge count so the stacked
    # [k, ...] arrays share one static shape. Masked (padding) edges are
    # dropped: their messages are identically zero. Empty [k, 0] unless the
    # book was built with tiled_layout=True.
    # [k, E_tiled] gather indices into the 2*e_max message list (pad -> 2*e_max)
    agg_order: np.ndarray
    # [k, E_tiled] row id within the edge's row tile (pad -> DEFAULT_TILE_V)
    agg_ldst: np.ndarray

    def padding_waste(self) -> float:
        """Fraction of all_to_all payload that is padding (0 = perfect)."""
        payload = self.k * self.k * self.bucket
        if payload == 0:
            return 0.0
        return 1.0 - self.replicas_total / payload

    def master_assignment(self) -> np.ndarray:
        """Per-vertex master partition as an int32 [V] ownership array.

        This is the vertex-partition view of an edge partition: exactly one
        master per vertex, so the result is a valid `VertexPartitionBook`
        assignment — how the inference serving path shards its embedding
        stores when the graph was partitioned by edges.
        """
        owner = np.zeros(self.num_vertices, dtype=np.int32)
        sel = self.master & self.vmask
        part_of = np.broadcast_to(
            np.arange(self.k, dtype=np.int32)[:, None], self.master.shape)
        owner[self.vglobal[sel]] = part_of[sel]
        return owner

    def local_features(self, features: np.ndarray) -> np.ndarray:
        """Replicate global features [V, F] into [k, v_max+1, F] device layout."""
        f = np.zeros((self.k, self.v_max + 1, features.shape[1]), dtype=features.dtype)
        safe = np.where(self.vglobal >= 0, self.vglobal, 0)
        f[:] = features[safe]
        f[~self.vmask] = 0
        return f

    def local_labels(self, labels: np.ndarray, fill: int = -1) -> np.ndarray:
        out = np.full((self.k, self.v_max + 1), fill, dtype=np.int32)
        safe = np.where(self.vglobal >= 0, self.vglobal, 0)
        out[:] = labels[safe]
        out[~self.vmask] = fill
        return out

    def scatter_to_global(self, local: np.ndarray) -> np.ndarray:
        """Collect master rows back into a global [V, ...] array (host-side)."""
        out_shape = (self.num_vertices,) + local.shape[2:]
        out = np.zeros(out_shape, dtype=local.dtype)
        sel = self.master & self.vmask
        out[self.vglobal[sel]] = local[sel]
        return out


def build_edge_book(
    graph: Graph,
    edge_assignment: np.ndarray,
    k: int,
    *,
    tiled_layout: bool = False,
) -> EdgePartitionBook:
    """`tiled_layout` additionally builds the per-partition tiled aggregation
    layout (agg_order/agg_ldst) — only the tiled/pallas backends read it, so
    the default scatter path skips the host sort and the device residency
    (the fields are then empty [k, 0] arrays)."""
    assignment = np.asarray(edge_assignment, dtype=np.int64)
    V = graph.num_vertices
    src = graph.src.astype(np.int64)
    dst = graph.dst.astype(np.int64)

    # --- cover pairs (p, v), with incident-edge counts for master election --
    pv = np.concatenate([assignment * V + src, assignment * V + dst])
    pv_unique, counts = np.unique(pv, return_counts=True)
    pp = (pv_unique // V).astype(np.int64)
    vv = (pv_unique % V).astype(np.int64)

    # local index of each (p, v): rank within its partition
    part_sizes = np.bincount(pp, minlength=k)
    v_max = int(part_sizes.max()) if part_sizes.size else 0
    part_starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(part_sizes, out=part_starts[1:])
    local_idx = np.arange(pv_unique.shape[0]) - part_starts[pp]

    vglobal = np.full((k, v_max + 1), -1, dtype=np.int64)
    vglobal[pp, local_idx] = vv
    vmask = vglobal >= 0

    # --- master election: replica with most incident edges, tie -> lowest p -
    # sort by (v, -count, p); first row per v wins
    order = np.lexsort((pp, -counts, vv))
    v_sorted = vv[order]
    first = np.ones(v_sorted.shape[0], dtype=bool)
    first[1:] = v_sorted[1:] != v_sorted[:-1]
    master_of = np.full(V, -1, dtype=np.int64)
    master_of[v_sorted[first]] = pp[order][first]

    master = np.zeros((k, v_max + 1), dtype=bool)
    is_master_pair = master_of[vv] == pp
    master[pp[is_master_pair], local_idx[is_master_pair]] = True

    # --- degrees (global, for normalisation on device) ----------------------
    # GNN aggregation runs over the symmetrised adjacency (DGL semantics on
    # undirected training graphs), so the normaliser is the symmetric degree.
    deg_global = graph.degrees().astype(np.float32)
    degree = np.zeros((k, v_max + 1), dtype=np.float32)
    degree[pp, local_idx] = deg_global[vv]

    # --- edge endpoint local indices ----------------------------------------
    # lookup (p, v) -> local via searchsorted on the sorted pv_unique keys
    def lookup(p: np.ndarray, v: np.ndarray) -> np.ndarray:
        keys = p * V + v
        pos = np.searchsorted(pv_unique, keys)
        return local_idx[pos]

    e_sizes = np.bincount(assignment, minlength=k)
    e_max = int(e_sizes.max()) if e_sizes.size else 0
    e_starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(e_sizes, out=e_starts[1:])
    e_order = np.argsort(assignment, kind="stable")
    e_local = np.arange(graph.num_edges) - e_starts[assignment[e_order]]

    esrc = np.full((k, e_max), v_max, dtype=np.int64)
    edst = np.full((k, e_max), v_max, dtype=np.int64)
    emask = np.zeros((k, e_max), dtype=bool)
    pe = assignment[e_order]
    esrc[pe, e_local] = lookup(pe, src[e_order])
    edst[pe, e_local] = lookup(pe, dst[e_order])
    emask[pe, e_local] = True

    # --- halo routing: mirrors -> masters ------------------------------------
    mirror_pairs = ~is_master_pair  # (p, v) where p is a mirror
    mi = pp[mirror_pairs]                 # sender (mirror) partition
    mv = vv[mirror_pairs]                 # vertex
    mj = master_of[mv]                    # receiver (master) partition
    m_local_send = local_idx[mirror_pairs]          # local idx at sender
    m_local_recv = lookup(mj, mv)                   # local idx at master

    # group by (i, j)
    pair_key = mi * k + mj
    order2 = np.argsort(pair_key, kind="stable")
    pk_sorted = pair_key[order2]
    pair_sizes = np.bincount(pk_sorted, minlength=k * k)
    bucket = int(pair_sizes.max()) if pair_sizes.size and pair_sizes.max() > 0 else 1
    pair_starts = np.zeros(k * k + 1, dtype=np.int64)
    np.cumsum(pair_sizes, out=pair_starts[1:])
    within = np.arange(pk_sorted.shape[0]) - pair_starts[pk_sorted]

    send_idx = np.full((k, k, bucket), v_max, dtype=np.int64)
    send_mask = np.zeros((k, k, bucket), dtype=bool)
    recv_idx = np.full((k, k, bucket), v_max, dtype=np.int64)
    recv_mask = np.zeros((k, k, bucket), dtype=bool)

    si = pk_sorted // k
    sj = pk_sorted % k
    send_idx[si, sj, within] = m_local_send[order2]
    send_mask[si, sj, within] = True
    recv_idx[sj, si, within] = m_local_recv[order2]
    recv_mask[sj, si, within] = True

    # --- tiled aggregation layout (one per partition, uniform shape) --------
    # The device aggregates over the symmetrised edge list: messages are
    # [values_src | values_dst] with destinations [edst | esrc]. Masked edges
    # carry zero messages and are dropped from the layout.
    if tiled_layout:
        dst2 = np.concatenate([edst, esrc], axis=1)
        valid2 = np.concatenate([emask, emask], axis=1)
        _, n_tiles = tiled_shape(v_max + 1)
        per_tile = max(
            tiled_need_per_tile(dst2[p], v_max + 1, valid=valid2[p])
            for p in range(k)
        )
        agg_order = np.empty((k, per_tile * n_tiles), dtype=np.int64)
        agg_ldst = np.empty((k, per_tile * n_tiles), dtype=np.int32)
        for p in range(k):
            agg_order[p], agg_ldst[p], _ = prepare_tiled_edges(
                dst2[p], v_max + 1, per_tile=per_tile, valid=valid2[p],
            )
    else:
        agg_order = np.zeros((k, 0), dtype=np.int64)
        agg_ldst = np.zeros((k, 0), dtype=np.int32)

    return EdgePartitionBook(
        k=k,
        num_vertices=V,
        v_max=v_max,
        e_max=e_max,
        bucket=bucket,
        vglobal=vglobal,
        vmask=vmask,
        master=master,
        degree=degree,
        esrc=esrc.astype(np.int32),
        edst=edst.astype(np.int32),
        emask=emask,
        send_idx=send_idx.astype(np.int32),
        send_mask=send_mask,
        recv_idx=recv_idx.astype(np.int32),
        recv_mask=recv_mask,
        replicas_total=int(mirror_pairs.sum()),
        agg_order=agg_order.astype(np.int32),
        agg_ldst=agg_ldst,
    )


# ---------------------------------------------------------------------------
# Vertex partition book (DistDGL regime)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VertexPartitionBook:
    k: int
    num_vertices: int
    owner: np.ndarray          # int32 [V]
    v_max: int                 # max owned vertices per partition
    vglobal: np.ndarray        # [k, v_max] global ids of owned vertices (pad -1)
    local_of: np.ndarray       # int64 [V]: local slot of each vertex at owner
    sizes: np.ndarray          # int64 [k]

    def feature_shards(self, features: np.ndarray) -> np.ndarray:
        """[k, v_max, F] owner-sharded features (DistDGL KV-store layout)."""
        out = np.zeros((self.k, self.v_max, features.shape[1]), dtype=features.dtype)
        safe = np.where(self.vglobal >= 0, self.vglobal, 0)
        out[:] = features[safe]
        out[self.vglobal < 0] = 0
        return out


# ---------------------------------------------------------------------------
# Block-row book (1.5D / CAGNET regime)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockRowBook:
    """Static 1.5D layout: contiguous vertex blocks + ring-ordered edge chunks.

    Row layout mirrors `EdgePartitionBook`'s device block (dummy row at index
    `v_block`), so the same model code runs on both; the halo routing tables
    are replaced by the chunk arrays `RingSync` consumes.
    """

    k: int
    num_vertices: int
    v_block: int   # rows per block, ceil(V / k); local row v_block = dummy
    c_max: int     # uniform per-chunk edge capacity (max over k*k chunks)

    # [k, v_block+1]: global id per local slot (pad/dummy -> -1)
    vglobal: np.ndarray
    vmask: np.ndarray    # [k, v_block+1] bool
    degree: np.ndarray   # [k, v_block+1] float32 global symmetric degree

    # ring chunks over the SYMMETRISED directed edge list (each stored edge
    # (u, v) contributes u->v and v->u; 2E directed edges total), pre-rotated:
    # chunk (p, s) holds the directed edges with dst in block p and src in
    # block (p+s) mod k. chunk_esrc indexes the VISITING payload block's rows,
    # chunk_edst the local (own) rows; pad -> v_block (dummy row).
    chunk_esrc: np.ndarray   # [k, k, c_max] int32
    chunk_edst: np.ndarray   # [k, k, c_max] int32
    chunk_emask: np.ndarray  # [k, k, c_max] bool

    # per-chunk tiled aggregation layouts (kernels.tiling.prepare_tiled_edges
    # over chunk_edst with valid=chunk_emask, one shared per_tile so all k*k
    # chunks stack to one static shape). Empty [k, k, 0] unless the book was
    # built with tiled_layout=True.
    chunk_agg_order: np.ndarray  # [k, k, E_tiled] int32 (pad -> c_max)
    chunk_agg_ldst: np.ndarray   # [k, k, E_tiled] int32 (pad -> tile_v)

    # masters == vmask: every vertex lives exactly once, on its block row
    @property
    def master(self) -> np.ndarray:
        return self.vmask

    def local_features(self, features: np.ndarray) -> np.ndarray:
        """Block global features [V, F] into [k, v_block+1, F] device layout."""
        f = np.zeros((self.k, self.v_block + 1, features.shape[1]),
                     dtype=features.dtype)
        safe = np.where(self.vglobal >= 0, self.vglobal, 0)
        f[:] = features[safe]
        f[~self.vmask] = 0
        return f

    def local_labels(self, labels: np.ndarray, fill: int = -1) -> np.ndarray:
        out = np.full((self.k, self.v_block + 1), fill, dtype=np.int32)
        safe = np.where(self.vglobal >= 0, self.vglobal, 0)
        out[:] = labels[safe]
        out[~self.vmask] = fill
        return out

    def scatter_to_global(self, local: np.ndarray) -> np.ndarray:
        """Collect block rows back into a global [V, ...] array (host-side)."""
        out_shape = (self.num_vertices,) + local.shape[2:]
        out = np.zeros(out_shape, dtype=local.dtype)
        out[self.vglobal[self.vmask]] = local[self.vmask]
        return out


def build_blockrow_book(
    graph: Graph,
    k: int,
    *,
    tiled_layout: bool = False,
) -> BlockRowBook:
    """1.5D book: contiguous vertex blocks, symmetrised edges chunked by
    (dst block, ring stage). `tiled_layout` additionally builds one
    `prepare_tiled_edges` layout per chunk (shared per_tile, so the stacked
    [k, k, ...] arrays have one static shape) for the tiled/pallas backends."""
    V = graph.num_vertices
    v_block = -(-max(V, 1) // k)  # ceil(V / k)

    vglobal = np.full((k, v_block + 1), -1, dtype=np.int64)
    ids = np.arange(V, dtype=np.int64)
    vglobal[ids // v_block, ids % v_block] = ids
    vmask = vglobal >= 0

    deg_global = graph.degrees().astype(np.float32)
    degree = np.zeros((k, v_block + 1), dtype=np.float32)
    degree[ids // v_block, ids % v_block] = deg_global

    # symmetrised directed edge list: u->v and v->u per stored edge
    ssrc = np.concatenate([graph.src, graph.dst]).astype(np.int64)
    sdst = np.concatenate([graph.dst, graph.src]).astype(np.int64)
    own = sdst // v_block            # owning block row (by destination)
    sblk = ssrc // v_block           # source block (the visiting payload)
    stage = (sblk - own) % k         # ring stage that sees this edge

    key = own * k + stage
    order = np.argsort(key, kind="stable")
    key_sorted = key[order]
    sizes = np.bincount(key_sorted, minlength=k * k)
    c_max = int(max(sizes.max() if sizes.size else 0, 1))
    starts = np.zeros(k * k + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    within = np.arange(key_sorted.shape[0]) - starts[key_sorted]

    chunk_esrc = np.full((k, k, c_max), v_block, dtype=np.int32)
    chunk_edst = np.full((k, k, c_max), v_block, dtype=np.int32)
    chunk_emask = np.zeros((k, k, c_max), dtype=bool)
    cp = key_sorted // k
    cs = key_sorted % k
    chunk_esrc[cp, cs, within] = (ssrc[order] % v_block).astype(np.int32)
    chunk_edst[cp, cs, within] = (sdst[order] % v_block).astype(np.int32)
    chunk_emask[cp, cs, within] = True

    if tiled_layout:
        n_rows = v_block + 1
        _, n_tiles = tiled_shape(n_rows)
        per_tile = max(
            tiled_need_per_tile(chunk_edst[p, s], n_rows,
                                valid=chunk_emask[p, s])
            for p in range(k) for s in range(k)
        )
        e_tiled = per_tile * n_tiles
        chunk_agg_order = np.empty((k, k, e_tiled), dtype=np.int64)
        chunk_agg_ldst = np.empty((k, k, e_tiled), dtype=np.int32)
        for p in range(k):
            for s in range(k):
                chunk_agg_order[p, s], chunk_agg_ldst[p, s], _ = (
                    prepare_tiled_edges(
                        chunk_edst[p, s], n_rows, per_tile=per_tile,
                        valid=chunk_emask[p, s],
                    ))
    else:
        chunk_agg_order = np.zeros((k, k, 0), dtype=np.int64)
        chunk_agg_ldst = np.zeros((k, k, 0), dtype=np.int32)

    return BlockRowBook(
        k=k,
        num_vertices=V,
        v_block=v_block,
        c_max=c_max,
        vglobal=vglobal,
        vmask=vmask,
        degree=degree,
        chunk_esrc=chunk_esrc,
        chunk_edst=chunk_edst,
        chunk_emask=chunk_emask,
        chunk_agg_order=chunk_agg_order.astype(np.int32),
        chunk_agg_ldst=chunk_agg_ldst,
    )


def build_vertex_book(graph: Graph, vertex_assignment: np.ndarray, k: int) -> VertexPartitionBook:
    owner = np.asarray(vertex_assignment, dtype=np.int32)
    sizes = np.bincount(owner, minlength=k).astype(np.int64)
    v_max = int(sizes.max()) if sizes.size else 0
    order = np.argsort(owner, kind="stable")
    starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    local = np.arange(graph.num_vertices, dtype=np.int64) - starts[owner[order]]
    local_of = np.empty(graph.num_vertices, dtype=np.int64)
    local_of[order] = local
    vglobal = np.full((k, v_max), -1, dtype=np.int64)
    vglobal[owner[order], local] = order
    return VertexPartitionBook(
        k=k,
        num_vertices=graph.num_vertices,
        owner=owner,
        v_max=v_max,
        vglobal=vglobal,
        local_of=local_of,
        sizes=sizes,
    )
