"""Edge partitioners (vertex-cut) — the six used in the paper's DistGNN study.

  random  — stateless streaming baseline
  dbh     — Degree-Based Hashing (Xie et al., NIPS'14): hash the
            lower-degree endpoint
  hdrf    — Highest-Degree Replicated First (Petroni et al., CIKM'15):
            stateful streaming, replication+balance score
  2ps-l   — Two-Phase Streaming, linear (Mayer et al., ICDE'22):
            streaming clustering phase + cluster-aware assignment phase
  hep10 / hep100 — Hybrid Edge Partitioner (Mayer & Jacobsen, SIGMOD'21):
            NE++-style in-memory partitioning of low-degree vertices,
            HDRF-style streaming of high-degree ones; tau = 10 / 100

All partitioners return an int32[E] edge→partition assignment. Everything is
deterministic given `seed`. These run on the host (NumPy): partitioning is
preprocessing, not device compute.
"""

from __future__ import annotations

import heapq
from typing import Callable

import numpy as np

from repro.core.graph import Graph

__all__ = ["EDGE_PARTITIONERS", "partition_edges"]


# ---------------------------------------------------------------------------
# Stateless streaming
# ---------------------------------------------------------------------------


def random_edge(graph: Graph, k: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, size=graph.num_edges, dtype=np.int32)


def dbh(graph: Graph, k: int, seed: int = 0) -> np.ndarray:
    """Assign each edge by hashing its lower-degree endpoint.

    Power-law insight: cutting hubs (replicating high-degree vertices) is
    cheaper in aggregate than cutting low-degree vertices.
    """
    deg = graph.degrees()
    pick_src = deg[graph.src] <= deg[graph.dst]
    chosen = np.where(pick_src, graph.src, graph.dst).astype(np.uint64)
    # Splittable integer hash (fmix64-ish) so assignment isn't id-correlated.
    x = chosen + np.uint64((seed * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xFF51AFD7ED558CCD)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xC4CEB9FE1A85EC53)
    x ^= x >> np.uint64(33)
    return (x % np.uint64(k)).astype(np.int32)


# ---------------------------------------------------------------------------
# HDRF — stateful streaming
# ---------------------------------------------------------------------------


def hdrf(graph: Graph, k: int, seed: int = 0, lam: float = 1.0) -> np.ndarray:
    """HDRF: score(e=(u,v), p) = C_rep(u,v,p) + lam * C_bal(p).

    C_rep favours partitions already holding a replica of u or v, weighted so
    the *lower*-degree endpoint pulls harder (replicate hubs first). Uses
    partial (streamed) degrees, as in the original.
    """
    rng = np.random.default_rng(seed)
    order = rng.permutation(graph.num_edges)
    replicas = np.zeros((graph.num_vertices, k), dtype=bool)
    sizes = np.zeros(k, dtype=np.int64)
    pdeg = np.zeros(graph.num_vertices, dtype=np.int64)  # partial degrees
    out = np.empty(graph.num_edges, dtype=np.int32)
    eps = 1.0
    src, dst = graph.src, graph.dst
    for e in order:
        u = int(src[e])
        v = int(dst[e])
        pdeg[u] += 1
        pdeg[v] += 1
        du, dv = pdeg[u], pdeg[v]
        theta_u = du / (du + dv)
        theta_v = 1.0 - theta_u
        g_u = replicas[u] * (2.0 - theta_u)  # 1 + (1 - theta_u)
        g_v = replicas[v] * (2.0 - theta_v)
        maxsize = sizes.max()
        minsize = sizes.min()
        c_bal = (maxsize - sizes) / (eps + maxsize - minsize)
        score = g_u + g_v + lam * c_bal
        p = int(np.argmax(score))
        out[e] = p
        sizes[p] += 1
        replicas[u, p] = True
        replicas[v, p] = True
    return out


# ---------------------------------------------------------------------------
# 2PS-L — two-phase streaming (linear)
# ---------------------------------------------------------------------------


class _UnionFind:
    __slots__ = ("parent", "volume")

    def __init__(self, n: int, volume: np.ndarray):
        self.parent = np.arange(n, dtype=np.int64)
        self.volume = volume.astype(np.int64).copy()

    def find(self, x: int) -> int:
        parent = self.parent
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:  # path compression
            parent[x], x = root, parent[x]
        return root

    def union_into(self, small: int, large: int) -> None:
        self.parent[small] = large
        self.volume[large] += self.volume[small]


def two_ps_l(graph: Graph, k: int, seed: int = 0) -> np.ndarray:
    """2PS-L: (1) streaming clustering by volume-bounded merging,
    (2) map clusters to partitions (largest-first bin packing), then stream
    edges to the partition of the lighter-loaded endpoint cluster.

    Linear run-time; known trade-off (reproduced in the paper): decent
    replication factor but noticeable *vertex imbalance*, because clusters
    are packed whole.
    """
    rng = np.random.default_rng(seed)
    deg = graph.degrees()
    uf = _UnionFind(graph.num_vertices, deg)
    max_vol = max(int(2 * graph.num_edges / k), 1)

    order = rng.permutation(graph.num_edges)
    src, dst = graph.src, graph.dst
    # Phase 1: clustering stream.
    for e in order:
        cu = uf.find(int(src[e]))
        cv = uf.find(int(dst[e]))
        if cu == cv:
            continue
        if uf.volume[cu] > uf.volume[cv]:
            cu, cv = cv, cu  # cu = smaller
        if uf.volume[cu] + uf.volume[cv] <= max_vol:
            uf.union_into(cu, cv)

    roots = np.array([uf.find(i) for i in range(graph.num_vertices)], dtype=np.int64)
    cluster_ids, cluster_of = np.unique(roots, return_inverse=True)
    num_clusters = cluster_ids.shape[0]
    # Cluster edge volume estimate: sum of member degrees / 2.
    cvol = np.zeros(num_clusters, dtype=np.int64)
    np.add.at(cvol, cluster_of, deg)

    # Phase 2a: largest-first packing of clusters onto partitions.
    part_of_cluster = np.empty(num_clusters, dtype=np.int32)
    loads = np.zeros(k, dtype=np.int64)
    for c in np.argsort(-cvol):
        p = int(np.argmin(loads))
        part_of_cluster[c] = p
        loads[p] += cvol[c]

    # Phase 2b: stream edges; intra-cluster edges follow their cluster,
    # inter-cluster edges go to the less-loaded of the two candidates.
    pu = part_of_cluster[cluster_of[src]]
    pv = part_of_cluster[cluster_of[dst]]
    out = np.empty(graph.num_edges, dtype=np.int32)
    edge_loads = np.zeros(k, dtype=np.int64)
    for e in order:
        a, b = int(pu[e]), int(pv[e])
        p = a if (a == b or edge_loads[a] <= edge_loads[b]) else b
        out[e] = p
        edge_loads[p] += 1
    return out


# ---------------------------------------------------------------------------
# HEP — hybrid (NE++ in memory + streaming for high-degree vertices)
# ---------------------------------------------------------------------------


def _neighborhood_expansion(
    graph: Graph,
    eligible_edge: np.ndarray,
    capacity: int,
    k: int,
) -> np.ndarray:
    """NE/NE++ core: grow partitions one at a time, repeatedly absorbing the
    boundary vertex with the fewest *unassigned external* neighbors, so cut
    vertices are minimised. Returns int32[E] with -1 for untouched edges.

    `eligible_edge`: bool[E] mask of edges this phase may assign.
    """
    indptr, indices, eid = _csr_with_eids(graph)
    assigned = np.full(graph.num_edges, -1, dtype=np.int32)
    edge_free = eligible_edge.copy()
    vert_done = np.zeros(graph.num_vertices, dtype=bool)  # in core of some part
    free_deg = np.zeros(graph.num_vertices, dtype=np.int64)
    np.add.at(free_deg, graph.src[eligible_edge], 1)
    np.add.at(free_deg, graph.dst[eligible_edge], 1)

    # Seeds in ascending degree order (NE heuristic: start at the fringe).
    seed_order = iter(np.argsort(free_deg, kind="stable"))

    for p in range(k):
        size = 0
        heap: list[tuple[int, int]] = []  # (ext_estimate, vertex)

        def push_seed() -> bool:
            for s in seed_order:  # noqa: B023 — same iterator across partitions
                s = int(s)
                if not vert_done[s] and free_deg[s] > 0:
                    heapq.heappush(heap, (int(free_deg[s]), s))
                    return True
            return False

        if not push_seed():
            break
        while size < capacity:
            if not heap:
                if not push_seed():
                    break
                continue
            _, x = heapq.heappop(heap)
            if vert_done[x]:
                continue
            vert_done[x] = True
            lo, hi = indptr[x], indptr[x + 1]
            nbrs = indices[lo:hi]
            eids = eid[lo:hi]
            take = edge_free[eids]
            take_eids = eids[take]
            n_take = int(take_eids.shape[0])
            if n_take:
                assigned[take_eids] = p
                edge_free[take_eids] = False
                size += n_take
                touched = nbrs[take]
                np.subtract.at(free_deg, touched, 1)
                free_deg[x] = 0
                for y in touched:
                    y = int(y)
                    if not vert_done[y] and free_deg[y] > 0:
                        heapq.heappush(heap, (int(free_deg[y]), y))
    return assigned


def _csr_with_eids(graph: Graph) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Symmetrised CSR that also carries the originating edge id per entry."""
    cached = graph.__dict__.get("_csr_eid")
    if cached is not None:
        return cached
    e = np.arange(graph.num_edges, dtype=np.int64)
    s = np.concatenate([graph.src, graph.dst]).astype(np.int64)
    d = np.concatenate([graph.dst, graph.src]).astype(np.int64)
    ee = np.concatenate([e, e])
    order = np.argsort(s, kind="stable")
    indptr = np.zeros(graph.num_vertices + 1, dtype=np.int64)
    np.cumsum(np.bincount(s[order], minlength=graph.num_vertices), out=indptr[1:])
    out = (indptr, d[order].astype(np.int32), ee[order])
    object.__setattr__(graph, "_csr_eid", out)
    return out


def _hdrf_stream(
    graph: Graph,
    assigned: np.ndarray,
    k: int,
    capacity: int,
    rng: np.random.Generator,
    deg: np.ndarray,
) -> None:
    """HEP's second phase: stream the still-unassigned edges HDRF-style
    (greedy replica/balance score), respecting `capacity`. In-place.

    When every partition is at capacity the capacity-gated score is all
    -inf — `argmax` would then silently dump the edge on partition 0, so we
    fall back to the least-loaded partition instead (capacity is a soft
    balance target, not a hard invariant, once the graph overflows it).
    """
    rest = np.where(assigned < 0)[0]
    if not rest.shape[0]:
        return
    replicas = np.zeros((graph.num_vertices, k), dtype=bool)
    done = assigned >= 0
    np.logical_or.at(replicas, (graph.src[done], assigned[done]), True)
    np.logical_or.at(replicas, (graph.dst[done], assigned[done]), True)
    sizes = np.bincount(assigned[done], minlength=k).astype(np.int64)
    order = rng.permutation(rest)
    src, dst = graph.src, graph.dst
    for e in order:
        u, v = int(src[e]), int(dst[e])
        du, dv = int(deg[u]), int(deg[v])
        theta_u = du / max(du + dv, 1)
        g = replicas[u] * (2.0 - theta_u) + replicas[v] * (1.0 + theta_u)
        has_room = sizes < capacity
        if has_room.any():
            maxs, mins = sizes.max(), sizes.min()
            bal = (maxs - sizes) / (1.0 + maxs - mins)
            score = np.where(has_room, g + bal, -np.inf)
            p = int(np.argmax(score))
        else:
            p = int(np.argmin(sizes))
        assigned[e] = p
        sizes[p] += 1
        replicas[u, p] = True
        replicas[v, p] = True


def _hep(graph: Graph, k: int, seed: int, tau: float) -> np.ndarray:
    rng = np.random.default_rng(seed)
    deg = graph.degrees()
    threshold = tau * max(deg.mean(), 1.0)
    high = deg > threshold
    # Edge is streamed iff it touches a high-degree vertex.
    streamed = high[graph.src] | high[graph.dst]
    in_memory = ~streamed
    capacity = int(np.ceil(1.02 * graph.num_edges / k))

    assigned = _neighborhood_expansion(graph, in_memory, capacity, k)
    _hdrf_stream(graph, assigned, k, capacity, rng, deg)
    return assigned.astype(np.int32)


def hep10(graph: Graph, k: int, seed: int = 0) -> np.ndarray:
    return _hep(graph, k, seed, tau=10.0)


def hep100(graph: Graph, k: int, seed: int = 0) -> np.ndarray:
    return _hep(graph, k, seed, tau=100.0)


def blockrow(graph: Graph, k: int, seed: int = 0) -> np.ndarray:
    """1.5D block-row assignment (CAGNET-style): vertex v's contiguous block
    owns every edge whose DESTINATION is v. Needs no heuristic pass at all —
    the near-zero partitioning-time end of the amortization trade-off — and
    is the layout `BlockRowBook` / `RingSync` pipeline around. Usable as a
    plain edge partitioner too (halo/dense run on it), which is what makes
    partition layout and sync strategy independent axes."""
    del seed  # deterministic: blocks are contiguous vertex ranges
    v_block = -(-graph.num_vertices // k)  # ceil(V / k)
    return (graph.dst.astype(np.int64) // v_block).astype(np.int32)


EDGE_PARTITIONERS: dict[str, Callable[..., np.ndarray]] = {
    "random": random_edge,
    "dbh": dbh,
    "hdrf": hdrf,
    "2ps-l": two_ps_l,
    "hep10": hep10,
    "hep100": hep100,
    "blockrow": blockrow,
}


def partition_edges(graph: Graph, k: int, method: str, seed: int = 0, **kw) -> np.ndarray:
    if method not in EDGE_PARTITIONERS:
        raise ValueError(f"unknown edge partitioner {method!r}; options: {sorted(EDGE_PARTITIONERS)}")
    out = EDGE_PARTITIONERS[method](graph, k, seed=seed, **kw)
    assert out.shape == (graph.num_edges,)
    return out.astype(np.int32)
