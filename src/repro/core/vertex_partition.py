"""Vertex partitioners (edge-cut) — the six used in the paper's DistDGL study.

  random  — stateless streaming baseline
  ldg     — Linear Deterministic Greedy (Stanton & Kliot, KDD'12)
  spinner — label-propagation partitioning (Martella et al., ICDE'17)
  bytegnn — BFS-block partitioning with training-vertex balance
            (Zheng et al., VLDB'22)
  metis   — multilevel k-way (heavy-edge-matching coarsening, greedy-growing
            initial partition, boundary-FM refinement) — faithful multilevel
            reimplementation of the METIS scheme
  kahip   — same multilevel machinery with stronger local search and V-cycles
            (KaHIP 'strong social' flavour)

All return int32[V] vertex→partition assignments, deterministic given seed.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.graph import Graph

__all__ = ["VERTEX_PARTITIONERS", "partition_vertices"]


# ---------------------------------------------------------------------------
# Streaming
# ---------------------------------------------------------------------------


def random_vertex(graph: Graph, k: int, seed: int = 0, **_) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, size=graph.num_vertices, dtype=np.int32)


def ldg(graph: Graph, k: int, seed: int = 0, **_) -> np.ndarray:
    """LDG: stream vertices; send v to argmax_i |N(v) ∩ P_i| (1 - |P_i|/C)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(graph.num_vertices)
    indptr, indices = graph.csr()
    out = np.full(graph.num_vertices, -1, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.int64)
    capacity = max(graph.num_vertices / k, 1.0)
    for v in order:
        v = int(v)
        nbrs = indices[indptr[v] : indptr[v + 1]]
        placed = out[nbrs]
        placed = placed[placed >= 0]
        counts = np.bincount(placed, minlength=k) if placed.size else np.zeros(k)
        score = counts * (1.0 - sizes / capacity)
        # Tie-break to the least-loaded partition (Stanton & Kliot).
        p = int(np.lexsort((sizes, -score))[0])
        out[v] = p
        sizes[p] += 1
    return out


# ---------------------------------------------------------------------------
# Spinner — label propagation with load penalty
# ---------------------------------------------------------------------------


def spinner(
    graph: Graph,
    k: int,
    seed: int = 0,
    iterations: int = 20,
    balance_slack: float = 0.05,
    **_,
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, k, size=graph.num_vertices, dtype=np.int64)
    capacity = (1.0 + balance_slack) * graph.num_edges * 2.0 / k  # edge-capacity
    deg = graph.degrees().astype(np.int64)
    src = np.concatenate([graph.src, graph.dst]).astype(np.int64)
    dst = np.concatenate([graph.dst, graph.src]).astype(np.int64)
    for _ in range(iterations):
        # counts[v, l] = #neighbors of v with label l
        counts = np.zeros((graph.num_vertices, k), dtype=np.float32)
        np.add.at(counts, (src, labels[dst]), 1.0)
        load = np.zeros(k, dtype=np.float64)
        np.add.at(load, labels, deg)
        penalty = np.maximum(1.0 - load / capacity, 0.0)  # remaining headroom
        score = counts * penalty[None, :].astype(np.float32)
        new_labels = np.asarray(np.argmax(score, axis=1), dtype=np.int64)
        # Probabilistic adoption (Spinner flips with prob to avoid oscillation)
        flip = rng.random(graph.num_vertices) < 0.5
        changed = (new_labels != labels) & flip
        if not changed.any():
            break
        labels = np.where(changed, new_labels, labels)
    return labels.astype(np.int32)


# ---------------------------------------------------------------------------
# ByteGNN — BFS blocks + greedy multi-objective block assignment
# ---------------------------------------------------------------------------


def bytegnn(
    graph: Graph,
    k: int,
    seed: int = 0,
    train_mask: Optional[np.ndarray] = None,
    block_hops: int = 2,
    **_,
) -> np.ndarray:
    """ByteGNN partitioning: grow small BFS blocks from training vertices
    (matching the sampling locality of mini-batch GNN training), then greedily
    assign blocks to machines balancing training vertices first and total
    vertices second.
    """
    rng = np.random.default_rng(seed)
    n = graph.num_vertices
    if train_mask is None:
        train_mask = np.ones(n, dtype=bool)
    indptr, indices = graph.csr()
    block_of = np.full(n, -1, dtype=np.int64)
    seeds = np.where(train_mask)[0]
    rng.shuffle(seeds)
    # Block size target keeps ~4k blocks so packing has freedom.
    num_blocks = 0
    budget = max(n // max(4 * k, 1), 8)
    for s in seeds:
        if block_of[s] >= 0:
            continue
        bid = num_blocks
        num_blocks += 1
        frontier = [int(s)]
        block_of[s] = bid
        size = 1
        for _ in range(block_hops):
            nxt: list[int] = []
            for u in frontier:
                nbrs = indices[indptr[u] : indptr[u + 1]]
                free = nbrs[block_of[nbrs] < 0]
                take = free[: max(budget - size, 0)]
                block_of[take] = bid
                size += take.shape[0]
                nxt.extend(int(t) for t in take)
                if size >= budget:
                    break
            frontier = nxt
            if size >= budget or not frontier:
                break
    # Orphans (unreached vertices) become singleton blocks.
    orphans = np.where(block_of < 0)[0]
    block_of[orphans] = num_blocks + np.arange(orphans.shape[0])
    num_blocks += orphans.shape[0]

    # Greedy assignment, largest block first; lexicographic objective
    # (train balance, vertex balance).
    train_per_block = np.zeros(num_blocks, dtype=np.int64)
    np.add.at(train_per_block, block_of[train_mask], 1)
    size_per_block = np.bincount(block_of, minlength=num_blocks).astype(np.int64)
    out = np.empty(n, dtype=np.int32)
    part_train = np.zeros(k, dtype=np.int64)
    part_size = np.zeros(k, dtype=np.int64)
    block_part = np.empty(num_blocks, dtype=np.int32)
    for b in np.argsort(-(train_per_block * n + size_per_block)):
        p = int(np.lexsort((part_size, part_train))[0])
        block_part[b] = p
        part_train[p] += train_per_block[b]
        part_size[p] += size_per_block[b]
    out = block_part[block_of]
    return out.astype(np.int32)


# ---------------------------------------------------------------------------
# Multilevel k-way (METIS / KaHIP style)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Level:
    """A coarsened weighted graph plus the projection map to the finer one."""

    num_vertices: int
    indptr: np.ndarray
    indices: np.ndarray
    eweights: np.ndarray
    vweights: np.ndarray
    fine_to_coarse: Optional[np.ndarray]  # None at the finest level


def _build_weighted_csr(
    n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Symmetrised weighted CSR with duplicate edges merged (weights summed)."""
    s = np.concatenate([src, dst]).astype(np.int64)
    d = np.concatenate([dst, src]).astype(np.int64)
    ww = np.concatenate([w, w]).astype(np.int64)
    key = s * n + d
    uniq, inv = np.unique(key, return_inverse=True)
    wsum = np.zeros(uniq.shape[0], dtype=np.int64)
    np.add.at(wsum, inv, ww)
    us = (uniq // n).astype(np.int64)
    ud = (uniq % n).astype(np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(us, minlength=n), out=indptr[1:])
    return indptr, ud.astype(np.int32), wsum


def _heavy_edge_matching(level: _Level, rng: np.random.Generator) -> np.ndarray:
    """Heavy-edge matching: visit vertices in random order, match each with
    its unmatched neighbor of maximum edge weight. Returns match[] with the
    partner (or self)."""
    n = level.num_vertices
    match = np.full(n, -1, dtype=np.int64)
    order = rng.permutation(n)
    indptr, indices, ew = level.indptr, level.indices, level.eweights
    for v in order:
        v = int(v)
        if match[v] >= 0:
            continue
        lo, hi = indptr[v], indptr[v + 1]
        nbrs = indices[lo:hi]
        free = match[nbrs] < 0
        cand = nbrs[free]
        if cand.shape[0] == 0:
            match[v] = v
            continue
        wts = ew[lo:hi][free]
        u = int(cand[np.argmax(wts)])
        if u == v:
            match[v] = v
        else:
            match[v] = u
            match[u] = v
    return match


def _coarsen(level: _Level, rng: np.random.Generator) -> _Level:
    match = _heavy_edge_matching(level, rng)
    n = level.num_vertices
    rep = np.minimum(np.arange(n), match)  # representative of each pair
    _, coarse_id = np.unique(rep, return_inverse=True)
    nc = int(coarse_id.max()) + 1
    vw = np.zeros(nc, dtype=np.int64)
    np.add.at(vw, coarse_id, level.vweights)
    # Contract edges, dropping the ones internal to a matched pair.
    cs = coarse_id[_csr_expand_src(level)]
    cd = coarse_id[level.indices]
    keep = cs < cd  # upper triangle (csr already symmetric), drops self-loops
    indptr, indices, ew = _build_weighted_csr(nc, cs[keep], cd[keep], level.eweights[keep])
    return _Level(nc, indptr, indices, ew, vw, fine_to_coarse=coarse_id)


def _csr_expand_src(level: _Level) -> np.ndarray:
    cached = getattr(level, "_src_cache", None)
    if cached is None:
        cached = np.repeat(
            np.arange(level.num_vertices, dtype=np.int64), np.diff(level.indptr)
        )
        level._src_cache = cached  # type: ignore[attr-defined]
    return cached


def _lp_initial_partition(
    level: _Level, k: int, rng: np.random.Generator, iterations: int = 12
) -> np.ndarray:
    """Label-propagation initial partition + balance repair.

    LP finds the community structure (what makes dense social graphs
    partitionable at all); the repair step then moves lowest-connectivity
    vertices out of overloaded labels until balance holds. Mirrors the
    LP-based initialisation of modern multilevel partitioners.
    """
    n = level.num_vertices
    indptr, indices, ew = level.indptr, level.indices, level.eweights
    esrc = _csr_expand_src(level)
    vw = level.vweights.astype(np.float64)
    labels = rng.integers(0, k, size=n, dtype=np.int64)
    total = vw.sum()
    cap = 1.02 * total / k
    for _ in range(iterations):
        conn = np.zeros((n, k), dtype=np.int64)
        np.add.at(conn, (esrc, labels[indices]), ew)
        load = np.zeros(k)
        np.add.at(load, labels, vw)
        headroom = np.maximum(1.0 - load / cap, 0.05)
        new = np.argmax(conn * headroom[None, :], axis=1)
        flip = rng.random(n) < 0.7
        labels = np.where(flip, new, labels)
    # balance repair: evict lowest-attachment vertices from overloaded labels
    conn = np.zeros((n, k), dtype=np.int64)
    np.add.at(conn, (esrc, labels[indices]), ew)
    load = np.zeros(k)
    np.add.at(load, labels, vw)
    max_load = 1.05 * total / k
    for p in range(k):
        if load[p] <= max_load:
            continue
        members = np.where(labels == p)[0]
        # weakest attachment to p first
        order = members[np.argsort(conn[members, p])]
        for v in order:
            if load[p] <= max_load:
                break
            alt = conn[v].copy()
            alt[p] = -1
            loads_ok = load + vw[v] <= max_load
            loads_ok[p] = False
            if not loads_ok.any():
                t = int(np.argmin(load + (~loads_ok) * 1e18))
            else:
                alt[~loads_ok] = -1
                t = int(np.argmax(alt))
            load[p] -= vw[v]
            load[t] += vw[v]
            labels[v] = t
    return labels.astype(np.int32)


def _initial_partition(level: _Level, k: int, rng: np.random.Generator) -> np.ndarray:
    """Greedy graph growing: BFS-grow each partition to ~total_weight/k."""
    n = level.num_vertices
    target = level.vweights.sum() / k
    out = np.full(n, -1, dtype=np.int32)
    indptr, indices = level.indptr, level.indices
    order = iter(rng.permutation(n))
    for p in range(k - 1):
        w = 0.0
        frontier: list[int] = []
        while w < target:
            if not frontier:
                s = next((int(x) for x in order if out[int(x)] < 0), None)
                if s is None:
                    break
                frontier = [s]
                out[s] = p
                w += level.vweights[s]
            u = frontier.pop()
            for x in indices[indptr[u] : indptr[u + 1]]:
                x = int(x)
                if out[x] < 0 and w < target:
                    out[x] = p
                    w += level.vweights[x]
                    frontier.append(x)
    out[out < 0] = k - 1
    return out


def _fm_refine(
    level: _Level,
    part: np.ndarray,
    k: int,
    rng: np.random.Generator,
    passes: int,
    allow_zero_gain: bool,
    slack: float = 0.05,
) -> np.ndarray:
    """Greedy boundary refinement (FM-flavoured, vectorised per pass).

    Per pass: compute, for every vertex, its connectivity to each partition;
    move boundary vertices with positive (or zero, for the KaHIP flavour)
    gain to their best partition when balance allows, in random order with
    sequentially-updated load accounting.
    """
    n = level.num_vertices
    indptr, indices, ew = level.indptr, level.indices, level.eweights
    esrc = _csr_expand_src(level)
    vw = level.vweights
    max_load = (1.0 + slack) * vw.sum() / k
    part = part.astype(np.int64).copy()
    for _ in range(passes):
        conn = np.zeros((n, k), dtype=np.int64)
        np.add.at(conn, (esrc, part[indices]), ew)
        internal = conn[np.arange(n), part]
        best_other = conn.copy()
        best_other[np.arange(n), part] = -1
        target = np.argmax(best_other, axis=1)
        gain = best_other[np.arange(n), target] - internal
        thresh = -1 if allow_zero_gain else 0
        movable = np.where(gain > thresh)[0]
        if movable.shape[0] == 0:
            break
        load = np.zeros(k, dtype=np.float64)
        np.add.at(load, part, vw)
        moved = 0
        for v in rng.permutation(movable):
            v = int(v)
            t = int(target[v])
            if gain[v] <= thresh or t == part[v]:
                continue
            if load[t] + vw[v] > max_load:
                continue
            load[part[v]] -= vw[v]
            load[t] += vw[v]
            part[v] = t
            moved += 1
        if moved == 0:
            break
    return part.astype(np.int32)


def _finest_level(graph: Graph) -> _Level:
    w = np.ones(graph.num_edges, dtype=np.int64)
    indptr, indices, ew = _build_weighted_csr(
        graph.num_vertices, graph.src.astype(np.int64), graph.dst.astype(np.int64), w
    )
    return _Level(
        graph.num_vertices, indptr, indices, ew,
        np.ones(graph.num_vertices, dtype=np.int64), None,
    )


def _multilevel(
    graph: Graph,
    k: int,
    seed: int,
    refine_passes: int,
    vcycles: int,
    allow_zero_gain: bool,
    coarsen_to: int = 256,
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    finest = _finest_level(graph)
    levels = [finest]
    while levels[-1].num_vertices > max(coarsen_to, 4 * k):
        nxt = _coarsen(levels[-1], rng)
        if nxt.num_vertices >= 0.95 * levels[-1].num_vertices:
            break  # matching stalled (e.g. star graphs)
        levels.append(nxt)

    # Several initial partitions on the coarsest level; keep the best cut
    # after refinement (METIS does multiple initial bisection attempts).
    coarsest = levels[-1]
    esrc_c = _csr_expand_src(coarsest)
    best_part, best_cut = None, np.inf
    for attempt in range(4):
        if attempt % 2 == 0:
            cand = _lp_initial_partition(coarsest, k, rng)
        else:
            cand = _initial_partition(coarsest, k, rng)
        cand = _fm_refine(coarsest, cand, k, rng, refine_passes, allow_zero_gain)
        cut = float(
            (coarsest.eweights * (cand[esrc_c] != cand[coarsest.indices])).sum()
        )
        if cut < best_cut:
            best_part, best_cut = cand, cut
    part = best_part
    for fine, coarse in zip(reversed(levels[:-1]), reversed(levels[1:])):
        part = part[coarse.fine_to_coarse]
        part = _fm_refine(fine, part, k, rng, refine_passes, allow_zero_gain)

    for _ in range(vcycles):  # KaHIP-style V-cycles on the finest level
        part = _fm_refine(finest, part, k, rng, refine_passes, allow_zero_gain=True)
        # Positive-gain cleanup counters zero-gain drift.
        part = _fm_refine(finest, part, k, rng, 2, allow_zero_gain=False)
    return part


def metis_like(graph: Graph, k: int, seed: int = 0, **_) -> np.ndarray:
    return _multilevel(graph, k, seed, refine_passes=4, vcycles=0, allow_zero_gain=False)


def kahip_like(graph: Graph, k: int, seed: int = 0, repeats: int = 3, **_) -> np.ndarray:
    """KaHIP 'strong' flavour: repeated multilevel runs with deeper
    refinement and V-cycles; keep the best cut. Slowest partitioner,
    best cut — exactly its profile in the paper (Fig. 13/15)."""
    best: Optional[np.ndarray] = None
    best_cut = np.inf
    finest = _finest_level(graph)
    for r in range(repeats):
        part = _multilevel(
            graph, k, seed + 1000 * r, refine_passes=8, vcycles=1, allow_zero_gain=True
        )
        # One final positive-gain-only cleanup pass counters zero-gain drift.
        rng = np.random.default_rng(seed + 1000 * r + 17)
        part = _fm_refine(finest, part, k, rng, 2, allow_zero_gain=False)
        cut = float((part[graph.src] != part[graph.dst]).sum())
        if cut < best_cut:
            best_cut = cut
            best = part
    assert best is not None
    return best


VERTEX_PARTITIONERS: dict[str, Callable[..., np.ndarray]] = {
    "random": random_vertex,
    "ldg": ldg,
    "spinner": spinner,
    "bytegnn": bytegnn,
    "metis": metis_like,
    "kahip": kahip_like,
}


def partition_vertices(
    graph: Graph,
    k: int,
    method: str,
    seed: int = 0,
    train_mask: Optional[np.ndarray] = None,
    **kw,
) -> np.ndarray:
    if method not in VERTEX_PARTITIONERS:
        raise ValueError(
            f"unknown vertex partitioner {method!r}; options: {sorted(VERTEX_PARTITIONERS)}"
        )
    if method == "bytegnn":
        kw["train_mask"] = train_mask
    out = VERTEX_PARTITIONERS[method](graph, k, seed=seed, **kw)
    assert out.shape == (graph.num_vertices,)
    return out.astype(np.int32)
