"""Optimizers as pure pytree transforms (no external deps).

AdamW and SGD-momentum, plus global-norm clipping. State and update are
plain pytrees so they shard transparently under pjit (optimizer state
inherits the parameter sharding, or a ZeRO-style sharded spec — see
repro.dist.sharding).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class AdamState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    mu: Params
    nu: Params


def adam_init(params: Params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.zeros_like, zeros))


def adam_update(
    grads: Params,
    state: AdamState,
    params: Params,
    *,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> tuple[Params, AdamState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g32
        v = b2 * v + (1.0 - b2) * jnp.square(g32)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p - lr * delta.astype(p.dtype)), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, mu=new_m, nu=new_v)


class SGDState(NamedTuple):
    velocity: Params


def sgd_init(params: Params) -> SGDState:
    return SGDState(velocity=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))


def sgd_update(
    grads: Params,
    state: SGDState,
    params: Params,
    *,
    lr: float = 1e-2,
    momentum: float = 0.9,
) -> tuple[Params, SGDState]:
    def upd(g, v, p):
        v = momentum * v + g.astype(jnp.float32)
        return (p - lr * v.astype(p.dtype)), v

    pairs = jax.tree.map(upd, grads, state.velocity, params)
    new_p = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, SGDState(velocity=new_v)


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, jnp.ndarray]:
    sq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads),
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm
