"""Gradient compression with error feedback (cross-pod DP traffic).

int8 uniform quantisation per tensor with an error-feedback accumulator
(Seide et al. / Karimireddy et al.): the quantisation residual is carried to
the next step, so compression error does not bias convergence — it acts like
a delayed gradient. Used on the slowest links — the data-parallel gradient
reduce, via `core/wire.py`'s `Int8EFCodec`/`codec_grad_reduce` wrappers;
payload shrinks 4x vs f32 / 2x vs bf16.

The transform is collective-agnostic: compress -> (all-reduce happens on the
int8 payload's dequantised view in the caller) -> decompress. For the
simulated data-parallel trainers it wraps the psum; on real pods the same
pair brackets the cross-pod reduce.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class CompressionState(NamedTuple):
    error: Params  # error-feedback accumulator, same tree as grads (f32)


def compress_init(grads_like: Params) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )
    )


def compress(grads: Params, state: CompressionState):
    """Returns (quantised int8 tree, per-leaf scales, new state)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_err = corrected - q.astype(jnp.float32) * scale
        return q, scale, new_err

    flat, treedef = jax.tree.flatten(grads)
    err = treedef.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat, err)]
    qs = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    new_state = CompressionState(error=treedef.unflatten([o[2] for o in out]))
    return qs, scales, new_state


def decompress(qs: Params, scales: Params, dtype=jnp.float32) -> Params:
    return jax.tree.map(
        lambda q, s: (q.astype(jnp.float32) * s).astype(dtype), qs, scales
    )


def compressed_psum(grads: Params, state: CompressionState, axis: str):
    """Data-parallel gradient mean with int8 error-feedback compression.

    Each worker quantises its local gradient (int8 + f32 scale), the
    collective reduces the dequantised views (on TPU pods the int8 payload is
    what crosses the slow links), and the quantisation error stays local in
    the error-feedback state.
    """
    qs, scales, new_state = compress(grads, state)
    deq = decompress(qs, scales)
    summed = jax.tree.map(lambda g: jax.lax.pmean(g, axis), deq)
    return summed, new_state
