from repro.optim.adam import adam_init, adam_update, sgd_init, sgd_update, clip_by_global_norm  # noqa: F401
