"""jit'd public wrappers for the Pallas kernels, with platform dispatch.

On TPU the Pallas kernels run natively; elsewhere (this CPU container) the
wrappers dispatch to the pure-jnp oracle so the rest of the system never
cares. `interpret=True` forces the kernel body through the Pallas
interpreter (tests validate kernels this way, per-shape/dtype, against the
oracles in ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.segment_spmm import segment_spmm as _spmm_pallas
from repro.kernels.tiling import (  # noqa: F401 (re-exported host-layout API)
    DEFAULT_BLOCK_E,
    DEFAULT_TILE_F,
    DEFAULT_TILE_V,
    prepare_tiled_edges,
    tiled_need_per_tile,
    tiled_shape,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# segment spmm (GNN aggregation)
# ---------------------------------------------------------------------------


def _pick_tile_f(f: int) -> int:
    """Lane tiling: the MXU-friendly 128 when it divides f, else f itself
    (small feature dims; Pallas pads lanes internally)."""
    return DEFAULT_TILE_F if f % DEFAULT_TILE_F == 0 else f


@functools.partial(jax.jit, static_argnames=(
    "num_rows", "combiner", "tile_v", "block_e", "use_pallas", "interpret"))
def segment_spmm(
    messages: jnp.ndarray,
    local_dst: jnp.ndarray,
    num_rows: int,
    *,
    combiner: str = "sum",
    tile_v: int = DEFAULT_TILE_V,
    block_e: int = DEFAULT_BLOCK_E,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled segment-reduce (`combiner` in {"sum", "max"}; see
    kernels/segment_spmm.py for the combiner semantics — init 0 vs -inf,
    MXU one-hot matmul vs VPU masked max). `messages`/`local_dst` must come
    from a `prepare_tiled_edges` layout built with the SAME (tile_v,
    block_e); non-TPU backends use the oracle. `num_rows` may be unpadded —
    both paths derive the tile grid from `tiled_shape` and return
    [num_rows, F]."""
    # Derive the grid the layout was built with (the ONE padding rule,
    # tiling.tiled_shape) — floor-dividing num_rows here would mis-bin every
    # edge of the trailing tiles when num_rows is unpadded.
    e = messages.shape[0]
    rows_padded, n_tiles = tiled_shape(num_rows, tile_v)
    assert e % n_tiles == 0, (
        f"tiled layout mismatch: {e} edges do not split over {n_tiles} row "
        f"tiles (num_rows={num_rows}, tile_v={tile_v}); was the layout built "
        f"with a different (num_rows, tile_v)?")
    use = _on_tpu() if use_pallas is None else use_pallas
    if use or interpret:
        out = _spmm_pallas(
            messages, local_dst, rows_padded,
            combiner=combiner, block_e=block_e, tile_v=tile_v,
            tile_f=_pick_tile_f(messages.shape[1]),
            interpret=interpret or not _on_tpu(),
        )
        return out[:num_rows]
    # oracle path: local_dst is tile-relative; rebuild global ids
    per_tile = e // n_tiles
    tile_idx = jnp.arange(e) // per_tile
    gdst = jnp.where(
        local_dst >= tile_v, num_rows, tile_idx * tile_v + local_dst
    ).astype(jnp.int32)
    if combiner == "max":
        return ref.segment_max_ref(messages, gdst, num_rows)
    return ref.segment_sum_ref(messages, gdst, num_rows)


# ---------------------------------------------------------------------------
# aggregate — the GNN aggregation primitive (scatter | tiled | pallas)
# ---------------------------------------------------------------------------

AGG_BACKENDS = ("scatter", "tiled", "pallas")

# The data-dependent scatter primitives the no-scatter rule hunts for in
# traced programs (repro.analysis). Plain "scatter" (static-index
# `at[].set`, e.g. zeroing the dummy row) is deliberately NOT listed: its
# indices are compile-time constants, so it lowers to a cheap in-place
# update, not the O(E) data-dependent scatter the tiled backends exist to
# eliminate.
SCATTER_PRIMITIVES = ("scatter-add", "scatter-max")


def scatter_free_traced(backend: str) -> bool:
    """Whether `aggregate(backend=...)` traces WITHOUT data-dependent
    scatter primitives on this host.

    "pallas" always forces the kernel (interpreted off-TPU), so its trace
    is scatter-free everywhere. "tiled" lowers to the same kernel on TPU
    but falls back to the jnp scatter ORACLE off-TPU (numerics over speed
    on hosts with no tiled advantage) — so off-TPU its trace legitimately
    contains scatter-add/scatter-max. "scatter" is the oracle by
    definition. The analysis no-scatter rule derives each program's
    expectation from this single predicate.
    """
    if backend == "pallas":
        return True
    return backend == "tiled" and _on_tpu()


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _tiled_aggregate(num_rows, tile_v, block_e, use_pallas, interpret,
                     messages, dst, edge_order, local_dst):
    """Tiled segment-sum of `messages` into `num_rows` rows.

    Forward runs the pre-sorted / pre-blocked layout (gather by `edge_order`,
    then the tiled kernel). Backward exploits that a segment-sum's transpose
    is a plain gather: grad_messages = g[dst] — cheap and Pallas-free.
    """
    del dst  # forward uses the tiled layout only; dst feeds the backward
    e, f = messages.shape
    msg_pad = jnp.concatenate(
        [messages, jnp.zeros((1, f), messages.dtype)], axis=0)
    return segment_spmm(
        msg_pad[edge_order], local_dst, num_rows,
        tile_v=tile_v, block_e=block_e,
        use_pallas=use_pallas, interpret=interpret,
    )


def _tiled_aggregate_fwd(num_rows, tile_v, block_e, use_pallas, interpret,
                         messages, dst, edge_order, local_dst):
    out = _tiled_aggregate(num_rows, tile_v, block_e, use_pallas, interpret,
                           messages, dst, edge_order, local_dst)
    return out, dst


def _tiled_aggregate_bwd(num_rows, tile_v, block_e, use_pallas, interpret,
                         dst, g):
    # transpose of the pre-sorted scatter-add: a gather (pad dst -> zero row)
    g_pad = jnp.concatenate([g, jnp.zeros((1, g.shape[1]), g.dtype)], axis=0)
    grad_messages = g_pad[jnp.minimum(dst, num_rows)]
    return grad_messages, None, None, None


_tiled_aggregate.defvjp(_tiled_aggregate_fwd, _tiled_aggregate_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _tiled_aggregate_max(num_rows, tile_v, block_e, use_pallas, interpret,
                         messages, dst, edge_order, local_dst):
    """Tiled segment-max of `messages` into `num_rows` rows.

    Forward runs the same pre-sorted / pre-blocked layout as the sum, with
    combiner="max" (init -inf). Backward is a masked-argmax gather: the
    cotangent of row r flows to the layout-present edges whose message
    EQUALS the row max (exact — the kernel takes maxes without arithmetic,
    so the comparison reproduces the forward selection), split evenly among
    ties — the same even-subgradient convention as the `at[].max` scatter
    oracle, so gradients match it on any data the layout kept in full; an
    edge the layout dropped (`valid`-masked) is not part of the computed
    max and gets zero cotangent even if its message ties the surviving row
    max (the scatter oracle, which still sees that edge, would hand it a
    tie share — gradient parity on dropped edges needs them strictly below
    the surviving max). The GAT hot path wraps this in lax.stop_gradient
    and never runs the backward — the vjp exists for standalone use of
    aggregate(reduce="max").
    """
    del dst  # forward uses the tiled layout only; dst feeds the backward
    e, f = messages.shape
    msg_pad = jnp.concatenate(
        [messages, jnp.full((1, f), -jnp.inf, messages.dtype)], axis=0)
    return segment_spmm(
        msg_pad[edge_order], local_dst, num_rows,
        combiner="max", tile_v=tile_v, block_e=block_e,
        use_pallas=use_pallas, interpret=interpret,
    )


def _tiled_aggregate_max_fwd(num_rows, tile_v, block_e, use_pallas, interpret,
                             messages, dst, edge_order, local_dst):
    out = _tiled_aggregate_max(num_rows, tile_v, block_e, use_pallas,
                               interpret, messages, dst, edge_order, local_dst)
    return out, (messages, dst, out, edge_order, local_dst)


def _tiled_aggregate_max_bwd(num_rows, tile_v, block_e, use_pallas, interpret,
                             res, g):
    messages, dst, out, edge_order, local_dst = res
    e, f = messages.shape
    dstc = jnp.minimum(dst, num_rows)
    # The forward maxes over the edges PRESENT in the layout; a dropped
    # (`valid`-masked) edge is not part of the computed function even when
    # its message happens to tie the surviving row max, so it must get zero
    # cotangent (and stay out of the tie denominator — which the layouted
    # tie count below already guarantees).
    in_layout = jnp.zeros((e + 1,), jnp.bool_).at[edge_order].set(True)[:e]
    # sink row compares against +inf (never the max) and carries zero grad
    out_pad = jnp.concatenate(
        [out, jnp.full((1, f), jnp.inf, out.dtype)], axis=0)
    g_pad = jnp.concatenate([g, jnp.zeros((1, f), g.dtype)], axis=0)
    is_max = (messages == out_pad[dstc]) & in_layout[:, None]
    # even split among ties (the scatter oracle's subgradient convention):
    # per-row tie counts via the tiled segment-sum of the argmax mask
    ties = _tiled_aggregate(num_rows, tile_v, block_e, use_pallas, interpret,
                            is_max.astype(g.dtype), dst, edge_order, local_dst)
    ties_pad = jnp.concatenate([ties, jnp.ones((1, f), g.dtype)], axis=0)
    share = g_pad[dstc] / jnp.maximum(ties_pad[dstc], 1.0)
    grad_messages = jnp.where(is_max, share, 0.0).astype(messages.dtype)
    return grad_messages, None, None, None


_tiled_aggregate_max.defvjp(_tiled_aggregate_max_fwd, _tiled_aggregate_max_bwd)


AGG_REDUCES = ("sum", "max")


def aggregate(
    messages: jnp.ndarray,    # [E, F] per-edge messages (original edge order)
    dst: jnp.ndarray,         # [E] int32 destination row per edge (< num_rows)
    num_rows: int,
    *,
    edge_order: jnp.ndarray | None = None,  # from prepare_tiled_edges
    local_dst: jnp.ndarray | None = None,
    backend: str = "scatter",
    reduce: str = "sum",
    tile_v: int = DEFAULT_TILE_V,
    block_e: int = DEFAULT_BLOCK_E,
    interpret: bool = False,
) -> jnp.ndarray:
    """Segment-reduce `messages` into `[num_rows, F]` vertex rows.

    backend:
      scatter — data-dependent `at[].add` / `at[].max` (the oracle; XLA
                scatter)
      tiled   — `prepare_tiled_edges` layout through the tiled segment-reduce
                (jnp oracle off-TPU, Pallas kernel on TPU); custom_vjp
                backward
      pallas  — like tiled but forces the Pallas kernel (interpreted on CPU;
                tests use this)

    reduce:
      sum — the segment-SpMM. Backward is a plain gather g[dst] (the
            transpose of a pre-sorted scatter-add).
      max — segment-max (init -inf: rows no edge reaches come back as -inf;
            clamp with jnp.maximum against a finite floor before exp/log).
            Backward is a masked-argmax gather, split evenly among tied
            edges (the scatter oracle's convention, so gradients match it
            even on ties). GNN softmax stabilisation — the one max
            on the GAT hot path — does NOT need it: softmax is
            shift-invariant, so the stabilisation max is wrapped in
            lax.stop_gradient at the call sites (gnn/models.py,
            gnn/minibatch.py), which is exact and keeps the backward free of
            any scatter/argmax transpose.

    The tiled layout may drop `valid`-masked edges — forward values still
    match the scatter oracle as long as dropped messages carry the reduce
    identity's certainty: identically zero for sum, at or below every
    surviving score for max (the GAT layers mask scores to -1e30 > -inf,
    and clamp the aggregate before use, so both backends agree after
    clamping). Gradients match too, except that a dropped edge exactly
    TYING the surviving row max gets zero cotangent here (it is not part of
    the computed max) while the scatter oracle — which still sees it —
    hands it a tie share; strict inequality on dropped edges restores full
    parity.
    """
    if reduce not in AGG_REDUCES:
        raise ValueError(f"unknown aggregate reduce {reduce!r}; "
                         f"options: {AGG_REDUCES}")
    if backend == "scatter":
        if reduce == "max":
            out = jnp.full((num_rows + 1, messages.shape[-1]), -jnp.inf,
                           messages.dtype)
            return out.at[jnp.minimum(dst, num_rows)].max(messages)[:num_rows]
        out = jnp.zeros((num_rows + 1, messages.shape[-1]), messages.dtype)
        return out.at[jnp.minimum(dst, num_rows)].add(messages)[:num_rows]
    if backend not in AGG_BACKENDS:
        raise ValueError(f"unknown aggregate backend {backend!r}; "
                         f"options: {AGG_BACKENDS}")
    assert edge_order is not None and local_dst is not None, (
        "tiled/pallas backends need the prepare_tiled_edges layout")
    if edge_order.shape[-1] == 0 and messages.shape[0] > 0:
        raise ValueError(
            "empty tiled layout: the partition book / sample plan was built "
            "without tiled_layout=True but a tiled backend was requested")
    use_pallas = None if backend == "tiled" else True
    fn = _tiled_aggregate_max if reduce == "max" else _tiled_aggregate
    return fn(
        num_rows, tile_v, block_e, use_pallas, interpret,
        messages, dst, edge_order, local_dst,
    )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # [B, H, Sq, D]
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    use = _on_tpu() if use_pallas is None else use_pallas
    b, h, sq, d = q.shape
    if use or interpret:
        fold = lambda x: x.reshape(b * h, x.shape[2], d)
        out = _flash_pallas(
            fold(q), fold(k), fold(v), causal=causal,
            interpret=interpret or not _on_tpu(),
        )
        return out.reshape(b, h, sq, d)
    return ref.flash_attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(
    q: jnp.ndarray,  # [B, H, D]
    k: jnp.ndarray,  # [B, H, S, D]
    v: jnp.ndarray,
    valid_len: jnp.ndarray,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    use = _on_tpu() if use_pallas is None else use_pallas
    b, h, s, d = k.shape
    if use or interpret:
        out = _decode_pallas(
            q.reshape(b * h, d), k.reshape(b * h, s, d), v.reshape(b * h, s, d),
            valid_len, interpret=interpret or not _on_tpu(),
        )
        return out.reshape(b, h, d)
    return ref.decode_attention_ref(q, k, v, valid_len)
