"""jit'd public wrappers for the Pallas kernels, with platform dispatch.

On TPU the Pallas kernels run natively; elsewhere (this CPU container) the
wrappers dispatch to the pure-jnp oracle so the rest of the system never
cares. `interpret=True` forces the kernel body through the Pallas
interpreter (tests validate kernels this way, per-shape/dtype, against the
oracles in ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.segment_spmm import segment_spmm as _spmm_pallas
from repro.kernels.tiling import (  # noqa: F401 (re-exported host-layout API)
    DEFAULT_BLOCK_E,
    DEFAULT_TILE_F,
    DEFAULT_TILE_V,
    prepare_tiled_edges,
    tiled_need_per_tile,
    tiled_shape,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# segment spmm (GNN aggregation)
# ---------------------------------------------------------------------------


def _pick_tile_f(f: int) -> int:
    """Lane tiling: the MXU-friendly 128 when it divides f, else f itself
    (small feature dims; Pallas pads lanes internally)."""
    return DEFAULT_TILE_F if f % DEFAULT_TILE_F == 0 else f


@functools.partial(jax.jit, static_argnames=(
    "num_rows", "tile_v", "block_e", "use_pallas", "interpret"))
def segment_spmm(
    messages: jnp.ndarray,
    local_dst: jnp.ndarray,
    num_rows: int,
    *,
    tile_v: int = DEFAULT_TILE_V,
    block_e: int = DEFAULT_BLOCK_E,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled segment-sum. `messages`/`local_dst` must come from a
    `prepare_tiled_edges` layout built with the SAME (tile_v, block_e);
    non-TPU backends use the oracle."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use or interpret:
        return _spmm_pallas(
            messages, local_dst, num_rows,
            block_e=block_e, tile_v=tile_v,
            tile_f=_pick_tile_f(messages.shape[1]),
            interpret=interpret or not _on_tpu(),
        )
    # oracle path: local_dst is tile-relative; rebuild global ids
    e = messages.shape[0]
    n_tiles = max(num_rows // tile_v, 1)
    per_tile = e // n_tiles
    tile_idx = jnp.arange(e) // per_tile
    gdst = jnp.where(
        local_dst >= tile_v, num_rows, tile_idx * tile_v + local_dst
    )
    return ref.segment_sum_ref(messages, gdst.astype(jnp.int32), num_rows)


# ---------------------------------------------------------------------------
# aggregate — the GNN aggregation primitive (scatter | tiled | pallas)
# ---------------------------------------------------------------------------

AGG_BACKENDS = ("scatter", "tiled", "pallas")


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _tiled_aggregate(num_rows, tile_v, block_e, use_pallas, interpret,
                     messages, dst, edge_order, local_dst):
    """Tiled segment-sum of `messages` into `num_rows` rows.

    Forward runs the pre-sorted / pre-blocked layout (gather by `edge_order`,
    then the tiled kernel). Backward exploits that a segment-sum's transpose
    is a plain gather: grad_messages = g[dst] — cheap and Pallas-free.
    """
    del dst  # forward uses the tiled layout only; dst feeds the backward
    e, f = messages.shape
    msg_pad = jnp.concatenate(
        [messages, jnp.zeros((1, f), messages.dtype)], axis=0)
    out = segment_spmm(
        msg_pad[edge_order], local_dst, tiled_shape(num_rows, tile_v)[0],
        tile_v=tile_v, block_e=block_e,
        use_pallas=use_pallas, interpret=interpret,
    )
    return out[:num_rows]


def _tiled_aggregate_fwd(num_rows, tile_v, block_e, use_pallas, interpret,
                         messages, dst, edge_order, local_dst):
    out = _tiled_aggregate(num_rows, tile_v, block_e, use_pallas, interpret,
                           messages, dst, edge_order, local_dst)
    return out, dst


def _tiled_aggregate_bwd(num_rows, tile_v, block_e, use_pallas, interpret,
                         dst, g):
    # transpose of the pre-sorted scatter-add: a gather (pad dst -> zero row)
    g_pad = jnp.concatenate([g, jnp.zeros((1, g.shape[1]), g.dtype)], axis=0)
    grad_messages = g_pad[jnp.minimum(dst, num_rows)]
    return grad_messages, None, None, None


_tiled_aggregate.defvjp(_tiled_aggregate_fwd, _tiled_aggregate_bwd)


def aggregate(
    messages: jnp.ndarray,    # [E, F] per-edge messages (original edge order)
    dst: jnp.ndarray,         # [E] int32 destination row per edge (< num_rows)
    num_rows: int,
    *,
    edge_order: jnp.ndarray | None = None,  # from prepare_tiled_edges
    local_dst: jnp.ndarray | None = None,
    backend: str = "scatter",
    tile_v: int = DEFAULT_TILE_V,
    block_e: int = DEFAULT_BLOCK_E,
    interpret: bool = False,
) -> jnp.ndarray:
    """Segment-sum `messages` into `[num_rows, F]` vertex rows.

    backend:
      scatter — data-dependent `at[].add` (the oracle; XLA scatter)
      tiled   — `prepare_tiled_edges` layout through the tiled segment-sum
                (jnp oracle off-TPU, Pallas kernel on TPU); custom_vjp gather
                backward
      pallas  — like tiled but forces the Pallas kernel (interpreted on CPU;
                tests use this)

    The tiled layout may drop edges whose messages are identically zero
    (padding edges) — forward values and gradients still match the scatter
    oracle, because a zero message contributes nothing and the backward
    gather `g[dst]` is the same linear transpose either way.
    """
    if backend == "scatter":
        out = jnp.zeros((num_rows + 1, messages.shape[-1]), messages.dtype)
        return out.at[jnp.minimum(dst, num_rows)].add(messages)[:num_rows]
    if backend not in AGG_BACKENDS:
        raise ValueError(f"unknown aggregate backend {backend!r}; "
                         f"options: {AGG_BACKENDS}")
    assert edge_order is not None and local_dst is not None, (
        "tiled/pallas backends need the prepare_tiled_edges layout")
    if edge_order.shape[-1] == 0 and messages.shape[0] > 0:
        raise ValueError(
            "empty tiled layout: the partition book / sample plan was built "
            "without tiled_layout=True but a tiled backend was requested")
    use_pallas = None if backend == "tiled" else True
    return _tiled_aggregate(
        num_rows, tile_v, block_e, use_pallas, interpret,
        messages, dst, edge_order, local_dst,
    )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # [B, H, Sq, D]
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    use = _on_tpu() if use_pallas is None else use_pallas
    b, h, sq, d = q.shape
    if use or interpret:
        fold = lambda x: x.reshape(b * h, x.shape[2], d)
        out = _flash_pallas(
            fold(q), fold(k), fold(v), causal=causal,
            interpret=interpret or not _on_tpu(),
        )
        return out.reshape(b, h, sq, d)
    return ref.flash_attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(
    q: jnp.ndarray,  # [B, H, D]
    k: jnp.ndarray,  # [B, H, S, D]
    v: jnp.ndarray,
    valid_len: jnp.ndarray,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    use = _on_tpu() if use_pallas is None else use_pallas
    b, h, s, d = k.shape
    if use or interpret:
        out = _decode_pallas(
            q.reshape(b * h, d), k.reshape(b * h, s, d), v.reshape(b * h, s, d),
            valid_len, interpret=interpret or not _on_tpu(),
        )
        return out.reshape(b, h, d)
    return ref.decode_attention_ref(q, k, v, valid_len)
