"""jit'd public wrappers for the Pallas kernels, with platform dispatch.

On TPU the Pallas kernels run natively; elsewhere (this CPU container) the
wrappers dispatch to the pure-jnp oracle so the rest of the system never
cares. `interpret=True` forces the kernel body through the Pallas
interpreter (tests validate kernels this way, per-shape/dtype, against the
oracles in ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.segment_spmm import (
    DEFAULT_BLOCK_E,
    DEFAULT_TILE_V,
    segment_spmm as _spmm_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# segment spmm (GNN aggregation)
# ---------------------------------------------------------------------------


def prepare_tiled_edges(
    dst: np.ndarray,
    num_rows: int,
    *,
    tile_v: int = DEFAULT_TILE_V,
    block_e: int = DEFAULT_BLOCK_E,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Host-side layout pass (once per graph/partition): sort edges by row
    tile and pad each tile's edge list to a multiple of block_e.

    Returns (edge_order, local_dst, rows_padded):
      edge_order [E_padded] — gather indices into the original edge list
                              (padding -> E, caller appends a zero message row)
      local_dst  [E_padded] — row id within the edge's tile (padding -> tile_v)
    """
    e = dst.shape[0]
    rows_padded = int(np.ceil(max(num_rows, 1) / tile_v) * tile_v)
    n_tiles = rows_padded // tile_v
    tile_of = dst // tile_v
    order = np.argsort(tile_of, kind="stable")
    counts = np.bincount(tile_of, minlength=n_tiles)
    padded_counts = np.maximum(np.ceil(counts / block_e).astype(int), 1) * block_e
    total = int(padded_counts.sum())
    # make every tile have the same number of edge blocks (grid uniformity)
    per_tile = int(padded_counts.max())
    total = per_tile * n_tiles
    edge_order = np.full(total, e, dtype=np.int64)
    local_dst = np.full(total, tile_v, dtype=np.int32)
    starts = np.cumsum(counts) - counts
    for t in range(n_tiles):
        seg = order[starts[t]: starts[t] + counts[t]]
        edge_order[t * per_tile: t * per_tile + counts[t]] = seg
        local_dst[t * per_tile: t * per_tile + counts[t]] = (
            dst[seg] - t * tile_v
        ).astype(np.int32)
    return edge_order, local_dst, rows_padded


@functools.partial(jax.jit, static_argnames=("num_rows", "use_pallas", "interpret"))
def segment_spmm(
    messages: jnp.ndarray,
    local_dst: jnp.ndarray,
    num_rows: int,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled segment-sum. `messages`/`local_dst` must come from
    `prepare_tiled_edges` layout; non-TPU backends use the oracle."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use or interpret:
        return _spmm_pallas(
            messages, local_dst, num_rows, interpret=interpret or not _on_tpu()
        )
    # oracle path: local_dst is tile-relative; rebuild global ids
    e = messages.shape[0]
    n_tiles = max(num_rows // DEFAULT_TILE_V, 1)
    per_tile = e // n_tiles
    tile_idx = jnp.arange(e) // per_tile
    gdst = jnp.where(
        local_dst >= DEFAULT_TILE_V, num_rows, tile_idx * DEFAULT_TILE_V + local_dst
    )
    return ref.segment_sum_ref(messages, gdst.astype(jnp.int32), num_rows)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # [B, H, Sq, D]
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    use = _on_tpu() if use_pallas is None else use_pallas
    b, h, sq, d = q.shape
    if use or interpret:
        fold = lambda x: x.reshape(b * h, x.shape[2], d)
        out = _flash_pallas(
            fold(q), fold(k), fold(v), causal=causal,
            interpret=interpret or not _on_tpu(),
        )
        return out.reshape(b, h, sq, d)
    return ref.flash_attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(
    q: jnp.ndarray,  # [B, H, D]
    k: jnp.ndarray,  # [B, H, S, D]
    v: jnp.ndarray,
    valid_len: jnp.ndarray,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    use = _on_tpu() if use_pallas is None else use_pallas
    b, h, s, d = k.shape
    if use or interpret:
        out = _decode_pallas(
            q.reshape(b * h, d), k.reshape(b * h, s, d), v.reshape(b * h, s, d),
            valid_len, interpret=interpret or not _on_tpu(),
        )
        return out.reshape(b, h, d)
    return ref.decode_attention_ref(q, k, v, valid_len)
