"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def segment_sum_ref(messages: jnp.ndarray, seg_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """Reference for the GNN aggregation kernel: sum messages[e] into rows
    seg_ids[e]. messages [E, F], seg_ids [E] int32 (may contain
    num_segments = padding sink). Returns [num_segments, F]."""
    out = jnp.zeros((num_segments + 1, messages.shape[1]), messages.dtype)
    out = out.at[seg_ids].add(messages)
    return out[:num_segments]


def segment_max_ref(messages: jnp.ndarray, seg_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """Reference for the segment-max kernel (combiner="max"): per-row max of
    messages[e] over rows seg_ids[e]. Same contract as segment_sum_ref —
    seg_ids may contain num_segments as a padding sink — but the reduction
    identity is -inf, so rows no edge reaches come back as -inf (callers
    clamp against a finite floor before use; see ops.aggregate)."""
    out = jnp.full((num_segments + 1, messages.shape[1]), -jnp.inf,
                   messages.dtype)
    out = out.at[seg_ids].max(messages)
    return out[:num_segments]


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None) -> jnp.ndarray:
    """Reference attention. q [B, H, Sq, D]; k, v [B, H, Skv, D]."""
    d = q.shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(d)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * s
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.arange(sq)[:, None] + (sk - sq) >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def decode_attention_ref(q, k, v, valid_len) -> jnp.ndarray:
    """Single-token decode attention. q [B, H, D]; k, v [B, H, S, D];
    valid_len scalar — cache slots >= valid_len are masked out."""
    d = q.shape[-1]
    scores = jnp.einsum("bhd,bhkd->bhk", q, k).astype(jnp.float32) / np.sqrt(d)
    mask = jnp.arange(k.shape[2])[None, None, :] < valid_len
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhk,bhkd->bhd", p, v)
