"""Pallas TPU kernel: tiled segment-reduce (sum | max) for GNN aggregation.

The paper's compute hot spot is sparse neighbor aggregation (SpMM over the
partition-local edge list). TPU adaptation of the insight (DESIGN.md §2):
data-dependent scatters are hostile to the MXU/VPU, but a scatter whose
segment ids are PRE-SORTED and PRE-TILED becomes a dense tile operation. The
host (partition book) sorts edges by destination once per graph and blocks
them so one edge block touches one row tile:

  grid = (row_tiles, edge_blocks_per_tile, feature_tiles)
  kernel: P[r, e] = one_hot(local_dst)          (VPU compare on iota)
  sum:    acc    += P @ messages                (MXU matmul)
  max:    acc     = max(acc, masked-max over edge chunks)   (VPU)

The same one-hot layout serves both combiners; only the init value (0 vs
-inf) and the accumulation differ. Max has no matmul form (it is a reduction
over the tropical semiring, which the MXU does not implement), so the kernel
sweeps the edge block in chunks sized to a VMEM budget and takes a masked
`jnp.max` per chunk — still fully dense and data-independent.

VMEM per step = BLOCK_E x TILE_F messages + TILE_V x TILE_F accumulator +
TILE_V x BLOCK_E one-hot (+ TILE_V x CHUNK_E x TILE_F for the max sweep) —
all tiled to multiples of (8, 128) lanes.

The jit'd wrapper (ops.py) validates shapes and falls back to the pure-jnp
oracle (ref.py) on non-TPU backends; interpret=True is used by the tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import (  # noqa: F401 (canonical tile constants)
    DEFAULT_BLOCK_E,
    DEFAULT_TILE_F,
    DEFAULT_TILE_V,
)

COMBINERS = ("sum", "max")

# VMEM budget for the max sweep's [tile_v, chunk_e, tile_f] intermediate
_MAX_SWEEP_BYTES = 2 << 20


def _max_chunk_e(block_e: int, tile_v: int, tile_f: int) -> int:
    """Largest chunk of the edge block whose masked-max intermediate
    [tile_v, chunk_e, tile_f] fits the VMEM budget (chunk divides block_e)."""
    chunk = block_e
    while (chunk > 8 and chunk % 2 == 0
           and tile_v * chunk * tile_f * 4 > _MAX_SWEEP_BYTES):
        chunk //= 2
    return chunk


def _segment_reduce_kernel(dst_ref, msg_ref, out_ref, *, block_e, tile_v,
                           combiner, chunk_e):
    """One grid step: fold one edge block into its row tile.

    dst_ref: [block_e]        int32 — LOCAL row ids within this row tile
                               (pad edges -> tile_v, i.e. out of range)
    msg_ref: [block_e, tile_f] message block
    out_ref: [tile_v, tile_f]  row-tile accumulator (same tile for all edge
                               blocks of this row tile; initialised at step 0
                               to the combiner identity: 0 for sum, -inf for
                               max)
    """
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        if combiner == "sum":
            out_ref[...] = jnp.zeros_like(out_ref)
        else:
            out_ref[...] = jnp.full_like(out_ref, -jnp.inf)

    dst = dst_ref[...]
    # one-hot [tile_v, block_e] via iota comparison (VPU)
    rows = jax.lax.broadcasted_iota(jnp.int32, (tile_v, block_e), 0)
    hits = rows == dst[None, :]
    if combiner == "sum":
        # MXU matmul: out-of-range (padding) dst rows vanish in the one-hot
        out_ref[...] += jax.lax.dot(
            hits.astype(msg_ref.dtype), msg_ref[...],
            preferred_element_type=out_ref.dtype,
        )
    else:
        # masked max, swept in chunks so the [tile_v, chunk_e, tile_f]
        # broadcast stays within the VMEM budget; padding edges hit no row
        # and contribute -inf (the max identity)
        msg = msg_ref[...].astype(out_ref.dtype)
        neg_inf = jnp.asarray(-jnp.inf, out_ref.dtype)

        def body(i, acc):
            m = jax.lax.dynamic_slice_in_dim(msg, i * chunk_e, chunk_e, 0)
            h = jax.lax.dynamic_slice_in_dim(hits, i * chunk_e, chunk_e, 1)
            cand = jnp.max(
                jnp.where(h[:, :, None], m[None, :, :], neg_inf), axis=1)
            return jnp.maximum(acc, cand)

        out_ref[...] = jax.lax.fori_loop(
            0, block_e // chunk_e, body, out_ref[...])


def segment_spmm(
    messages: jnp.ndarray,   # [E, F] edge messages, pre-sorted by dst tile
    local_dst: jnp.ndarray,  # [E] int32 row id WITHIN the edge's row tile
    num_rows: int,
    *,
    combiner: str = "sum",
    block_e: int = DEFAULT_BLOCK_E,
    tile_v: int = DEFAULT_TILE_V,
    tile_f: int = DEFAULT_TILE_F,
    interpret: bool = False,
) -> jnp.ndarray:
    """Segment reduce with the tiling contract described in the module
    docstring. `combiner` is static: "sum" (init 0, MXU one-hot matmul) or
    "max" (init -inf, VPU masked max). Rows no edge reaches come back as the
    combiner identity (0 / -inf).

    E must be row-tile-blocked: edges of row tile r occupy the contiguous
    range [r * epr, (r+1) * epr) where epr = E // num_row_tiles, padded with
    local_dst == tile_v (an out-of-range row hits nothing under either
    combiner). `prepare_tiled_edges` (ops.py) produces this layout from raw
    (dst, msg).
    """
    assert combiner in COMBINERS, combiner
    e, f = messages.shape
    assert num_rows % tile_v == 0, (num_rows, tile_v)
    assert f % tile_f == 0, (f, tile_f)
    n_tiles = num_rows // tile_v
    assert e % (n_tiles * block_e) == 0, (e, n_tiles, block_e)
    blocks_per_tile = e // n_tiles // block_e

    grid = (n_tiles, blocks_per_tile, f // tile_f)
    kernel = functools.partial(
        _segment_reduce_kernel, block_e=block_e, tile_v=tile_v,
        combiner=combiner, chunk_e=_max_chunk_e(block_e, tile_v, tile_f),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda r, eb, ft: (r * blocks_per_tile + eb,)),
            pl.BlockSpec(
                (block_e, tile_f),
                lambda r, eb, ft: (r * blocks_per_tile + eb, ft),
            ),
        ],
        out_specs=pl.BlockSpec((tile_v, tile_f), lambda r, eb, ft: (r, ft)),
        out_shape=jax.ShapeDtypeStruct((num_rows, f), messages.dtype),
        interpret=interpret,
    )(local_dst, messages)
