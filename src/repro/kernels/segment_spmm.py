"""Pallas TPU kernel: tiled segment-sum for GNN neighbor aggregation.

The paper's compute hot spot is sparse neighbor aggregation (SpMM over the
partition-local edge list). TPU adaptation of the insight (DESIGN.md §2):
data-dependent scatters are hostile to the MXU/VPU, but a scatter whose
segment ids are PRE-SORTED and PRE-TILED becomes a *one-hot matmul* — an MXU
operation. The host (partition book) sorts edges by destination once per
graph and blocks them so one edge block touches one row tile:

  grid = (row_tiles, edge_blocks_per_tile, feature_tiles)
  kernel: P[r, e] = one_hot(local_dst)          (VPU compare on iota)
          acc    += P^T-free: out_tile += P @ messages      (MXU)

VMEM per step = BLOCK_E x TILE_F messages + TILE_V x TILE_F accumulator +
TILE_V x BLOCK_E one-hot — all tiled to multiples of (8, 128) lanes.

The jit'd wrapper (ops.py) validates shapes and falls back to the pure-jnp
oracle (ref.py) on non-TPU backends; interpret=True is used by the tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import (  # noqa: F401 (canonical tile constants)
    DEFAULT_BLOCK_E,
    DEFAULT_TILE_F,
    DEFAULT_TILE_V,
)


def _segment_spmm_kernel(dst_ref, msg_ref, out_ref, *, block_e, tile_v):
    """One grid step: accumulate one edge block into its row tile.

    dst_ref: [block_e]        int32 — LOCAL row ids within this row tile
                               (pad edges -> tile_v, i.e. out of range)
    msg_ref: [block_e, tile_f] message block
    out_ref: [tile_v, tile_f]  row-tile accumulator (same tile for all edge
                               blocks of this row tile; zeroed at step 0)
    """
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = dst_ref[...]
    # one-hot [tile_v, block_e] via iota comparison (VPU), then MXU matmul
    rows = jax.lax.broadcasted_iota(jnp.int32, (tile_v, block_e), 0)
    onehot = (rows == dst[None, :]).astype(msg_ref.dtype)
    out_ref[...] += jax.lax.dot(
        onehot, msg_ref[...], preferred_element_type=out_ref.dtype
    )


def segment_spmm(
    messages: jnp.ndarray,   # [E, F] edge messages, pre-sorted by dst tile
    local_dst: jnp.ndarray,  # [E] int32 row id WITHIN the edge's row tile
    num_rows: int,
    *,
    block_e: int = DEFAULT_BLOCK_E,
    tile_v: int = DEFAULT_TILE_V,
    tile_f: int = DEFAULT_TILE_F,
    interpret: bool = False,
) -> jnp.ndarray:
    """Segment sum with the tiling contract described in the module docstring.

    E must be row-tile-blocked: edges of row tile r occupy the contiguous
    range [r * epr, (r+1) * epr) where epr = E // num_row_tiles, padded with
    local_dst == tile_v (one-hot of an out-of-range row vanishes).
    `prepare_tiled_edges` (ops.py) produces this layout from raw (dst, msg).
    """
    e, f = messages.shape
    assert num_rows % tile_v == 0, (num_rows, tile_v)
    assert f % tile_f == 0, (f, tile_f)
    n_tiles = num_rows // tile_v
    assert e % (n_tiles * block_e) == 0, (e, n_tiles, block_e)
    blocks_per_tile = e // n_tiles // block_e

    grid = (n_tiles, blocks_per_tile, f // tile_f)
    kernel = functools.partial(
        _segment_spmm_kernel, block_e=block_e, tile_v=tile_v
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda r, eb, ft: (r * blocks_per_tile + eb,)),
            pl.BlockSpec(
                (block_e, tile_f),
                lambda r, eb, ft: (r * blocks_per_tile + eb, ft),
            ),
        ],
        out_specs=pl.BlockSpec((tile_v, tile_f), lambda r, eb, ft: (r, ft)),
        out_shape=jax.ShapeDtypeStruct((num_rows, f), messages.dtype),
        interpret=interpret,
    )(local_dst, messages)
