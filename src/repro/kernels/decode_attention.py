"""Pallas TPU kernel: single-token decode attention over a long KV cache.

Decode attention is purely memory-bound (stream S x D keys/values per new
token); the kernel's job is to saturate HBM: grid over kv blocks, online
softmax in VMEM scratch, masked by the cache's valid length (scalar
prefetch). Head-batched: q [BH, D] vs cache [BH, S, D].

Blocks of 1024 cache rows x D lanes stream through VMEM; one [8-padded, D]
accumulator per head. Contract matches ref.decode_attention_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 1024
NEG_INF = -1e30


def _decode_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale, block_k, n_kv):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                       # [1, d] (q row padded to sublane)
    k = k_ref[0]                       # [block_k, d]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                          # [1, block_k]
    idx = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    s = jnp.where(idx < valid_ref[0], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


def decode_attention(
    q: jnp.ndarray,        # [BH, D]
    k: jnp.ndarray,        # [BH, S, D]
    v: jnp.ndarray,        # [BH, S, D]
    valid_len: jnp.ndarray,  # scalar int32
    *,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, s, d = k.shape
    block_k = min(block_k, s)
    assert s % block_k == 0, (s, block_k)
    n_kv = s // block_k
    scale = 1.0 / float(np.sqrt(d))
    valid = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (1,))

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, n_kv=n_kv
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, ki, valid: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ki, valid: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ki, valid: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, ki, valid: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        interpret=interpret,
    )(valid, q[:, None, :], k, v)
    return out[:, 0, :]
