"""Pallas TPU kernel: causal flash attention (online softmax).

Grid: (batch*heads, q_blocks, kv_blocks) with kv innermost. Running max /
normaliser / accumulator live in VMEM scratch across kv steps; the output
tile is written once at the last kv step. Causal masking is an iota compare
inside the kernel — no S x S mask tensor ever exists.

Blocks default to (128, 512): q tile rows are a multiple of 8 sublanes, the
head dim and kv tile a multiple of 128 lanes — MXU-aligned per TPU v5e.
VMEM per step ~ Bq*D + 2*Bk*D + Bq*Bk floats, well under the 128 MiB VMEM.

Contract matches ref.flash_attention_ref; tests sweep shapes/dtypes in
interpret mode. The pure-jnp blockwise path (models.layers.attention) is the
XLA fallback on non-TPU backends.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale, block_q, block_k, n_kv, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # [block_q, d]
    k = k_ref[0]  # [block_k, d]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [block_q, block_k]

    if causal:
        q_idx = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        k_idx = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        s = jnp.where(q_idx >= k_idx, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


def flash_attention(
    q: jnp.ndarray,  # [BH, Sq, D]
    k: jnp.ndarray,  # [BH, Skv, D]
    v: jnp.ndarray,  # [BH, Skv, D]
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    n_q = sq // block_q
    n_kv = skv // block_k
    scale = 1.0 / float(np.sqrt(d))

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv=n_kv, causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running normaliser
            pltpu.VMEM((block_q, d), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
