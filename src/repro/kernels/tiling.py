"""Host-side tiled-edge layout for the segment-SpMM kernel (pure NumPy).

Kept jax-free on purpose: the partition books and the mini-batch sampler run
in the host/preprocessing layer (core/, gnn/sampling.py), which must not pay
the jax import just to sort edge lists. The device-side wrappers
(kernels/ops.py) re-export everything here.
"""

from __future__ import annotations

import numpy as np

DEFAULT_BLOCK_E = 512
DEFAULT_TILE_V = 256
DEFAULT_TILE_F = 128


def tiled_shape(num_rows: int, tile_v: int = DEFAULT_TILE_V) -> tuple[int, int]:
    """(rows_padded, n_tiles) of a tiled layout over `num_rows` rows — the
    ONE place this padding rule lives; every consumer (layout pass, kernel
    wrapper, partition book, sample plan) derives shapes from here."""
    rows_padded = int(np.ceil(max(num_rows, 1) / tile_v) * tile_v)
    return rows_padded, rows_padded // tile_v


def _check_dst_range(vdst: np.ndarray, num_rows: int, rows_padded: int) -> None:
    """Every (valid) destination must land inside the padded row range —
    edges past it would fall into row tiles the kernel grid never visits and
    silently vanish from the aggregate."""
    if vdst.size == 0:
        return
    lo, hi = int(vdst.min()), int(vdst.max())
    if lo < 0 or hi >= rows_padded:
        raise ValueError(
            f"tiled layout: dst out of range [0, {rows_padded}) "
            f"(num_rows={num_rows} padded to {rows_padded}); got "
            f"min={lo}, max={hi}. Edges aimed past the padded row range "
            f"would be silently dropped — mask them out via `valid` or "
            f"route them to an in-range padding sink row."
        )


def tiled_need_per_tile(
    dst: np.ndarray,
    num_rows: int,
    *,
    tile_v: int = DEFAULT_TILE_V,
    block_e: int = DEFAULT_BLOCK_E,
    valid: np.ndarray | None = None,
) -> int:
    """Smallest legal `per_tile` for this edge list — the block-rounded max
    per-tile edge count — without building the layout (O(E) bincount)."""
    rows_padded, n_tiles = tiled_shape(num_rows, tile_v)
    vdst = np.asarray(dst if valid is None else dst[valid], dtype=np.int64)
    _check_dst_range(vdst, num_rows, rows_padded)
    counts = np.bincount(vdst // tile_v, minlength=n_tiles)
    blocks = int(np.ceil(counts.max() / block_e)) if counts.size else 0
    return max(blocks, 1) * block_e


def prepare_tiled_edges(
    dst: np.ndarray,
    num_rows: int,
    *,
    tile_v: int = DEFAULT_TILE_V,
    block_e: int = DEFAULT_BLOCK_E,
    per_tile: int | None = None,
    valid: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Host-side layout pass (once per graph/partition): sort edges by row
    tile and pad each tile's edge list to a multiple of block_e.

    Returns (edge_order, local_dst, rows_padded):
      edge_order [E_padded] — gather indices into the original edge list
                              (padding -> E, caller appends a zero message row)
      local_dst  [E_padded] — row id within the edge's tile (padding -> tile_v)

    `valid` (bool[E]) drops edges from the layout entirely; only edges whose
    messages carry the combiner identity (zero for sum, <= any real score for
    max) may be dropped (the aggregate stays exact). `per_tile` forces every
    tile's padded edge count, so several partitions / batches can share one
    static device shape; it must be a multiple of block_e and at least the
    largest per-tile edge count (`tiled_need_per_tile`).

    Every valid dst must lie in [0, rows_padded) — anything past the padded
    row range raises ValueError rather than silently vanishing from the
    aggregate (its row tile would sit outside the kernel grid).
    """
    e = dst.shape[0]
    rows_padded, n_tiles = tiled_shape(num_rows, tile_v)
    if valid is None:
        idx = np.arange(e, dtype=np.int64)
        vdst = np.asarray(dst, dtype=np.int64)
    else:
        idx = np.where(valid)[0].astype(np.int64)
        vdst = np.asarray(dst, dtype=np.int64)[idx]
    _check_dst_range(vdst, num_rows, rows_padded)
    tile_of = vdst // tile_v
    order = np.argsort(tile_of, kind="stable")
    counts = np.bincount(tile_of, minlength=n_tiles)
    # every tile gets the same number of edge blocks (grid uniformity)
    need = int(max(int(np.ceil(counts.max() / block_e)) if counts.size else 0, 1))
    need *= block_e
    if per_tile is None:
        per_tile = need
    else:
        assert per_tile % block_e == 0 and per_tile >= need, (per_tile, need)
    total = per_tile * n_tiles
    edge_order = np.full(total, e, dtype=np.int64)
    local_dst = np.full(total, tile_v, dtype=np.int32)
    starts = np.cumsum(counts) - counts
    for t in range(n_tiles):
        seg = order[starts[t]: starts[t] + counts[t]]
        edge_order[t * per_tile: t * per_tile + counts[t]] = idx[seg]
        local_dst[t * per_tile: t * per_tile + counts[t]] = (
            vdst[seg] - t * tile_v
        ).astype(np.int32)
    return edge_order, local_dst, rows_padded
