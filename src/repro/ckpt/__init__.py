from repro.ckpt.checkpoint import (  # noqa: F401
    CheckpointManager,
    restore_latest,
    save_checkpoint,
)
