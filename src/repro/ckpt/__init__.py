from repro.ckpt.checkpoint import (  # noqa: F401
    CheckpointManager,
    checkpoint_extra,
    restore_latest,
    save_checkpoint,
)
