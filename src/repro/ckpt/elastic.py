"""Elastic scaling utilities.

LM side: checkpoints are saved unsharded (ckpt/checkpoint.py), so restoring
onto a different mesh is just re-placement with the new shardings —
`reshard_tree` below is the helper the launcher calls after building the new
mesh. GNN side: scaling from k to k' machines re-partitions the graph (the
partition is preprocessing state, not model state) and rebuilds the device
blocks; model parameters transfer unchanged because they are
partition-independent (the tested distributed==single invariant).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.core.graph import Graph
from repro.core.edge_partition import partition_edges
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.models import GNNSpec


def reshard_tree(tree: Any, shardings: Any) -> Any:  # lint: keep — LM-build hook
    """Re-place every leaf for a new mesh (LM elastic restart)."""
    return jax.tree.map(
        lambda leaf, sh: jax.device_put(np.asarray(jax.device_get(leaf)), sh),
        tree,
        shardings,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, jax.sharding.Sharding),
    )


def rescale_fullbatch(
    trainer: FullBatchTrainer,
    graph: Graph,
    new_k: int,
    features: np.ndarray,
    labels: np.ndarray,
    train_mask: np.ndarray,
    *,
    partitioner: str = "hep100",
    seed: int = 0,
) -> FullBatchTrainer:
    """Scale a full-batch GNN trainer from k to new_k machines: re-partition
    the graph, rebuild device blocks, carry ALL run state over — model and
    optimizer (partition-independent), the learning rate and wire codec
    (including the tier a VariableRatioCodec's epoch schedule has advanced
    to, since `trainer.codec` holds the advanced instance), and the lossy
    codec's error-feedback carry, re-stacked for the new device count."""
    assignment = partition_edges(graph, new_k, partitioner, seed=seed)
    new = FullBatchTrainer.build(
        graph, assignment, new_k, trainer.spec, features, labels, train_mask,
        sync_mode=trainer.sync_mode, mode=trainer.mode, seed=seed,
        lr=trainer.lr, codec=trainer.codec,
    )
    new.params = trainer.params        # model state is partition-independent
    new.opt_state = trainer.opt_state
    if trainer.ef_state is not None:
        # EF residuals are per-device [k, ...] (unstacked when k == 1): the
        # device mean is the state the gradient all-reduce would have folded
        # in, so replicate it across the new device count
        old_k = trainer.book.k
        mean = (trainer.ef_state if old_k == 1 else
                jax.tree.map(lambda e: e.mean(axis=0), trainer.ef_state))
        new.ef_state = (mean if new_k == 1 else jax.tree.map(
            lambda z: jax.numpy.broadcast_to(z, (new_k,) + z.shape), mean))
    return new
