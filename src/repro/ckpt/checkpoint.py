"""Fault-tolerant checkpointing: atomic, versioned, keep-last-k, resumable.

Design (matches what production JAX frameworks do, npz-backed so it stays
dependency-free):

  * every checkpoint is a directory  step_<n>/  with one .npy per leaf plus
    a manifest.json (tree structure, shapes, dtypes, step, mesh shape)
  * writes go to  step_<n>.tmp/  and are os.rename'd — a crash mid-write
    can never corrupt the latest checkpoint (restart-safe)
  * restore_latest scans for the highest complete manifest — a half-written
    directory from a killed process is ignored and garbage-collected
  * elastic restart: leaves are saved UNSHARDED (gathered); on restore the
    caller passes target shardings for the (possibly different) new mesh and
    leaves are re-placed with jax.device_put — checkpoints survive mesh-shape
    changes (scale up/down), which is the elastic-training contract.

For multi-host deployments the same layout maps onto a parallel filesystem
with per-host shard files; the manifest format already records shardings.
"""

from __future__ import annotations

import json
import os
import shutil
import warnings
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [
        "/".join(
            str(getattr(p, "key", getattr(p, "idx", p)))
            for p in path
        )
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]
    return leaves, paths, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomically write `tree` as checkpoint `step` under `directory`."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, paths, treedef = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": [],
    }
    for i, (leaf, path) in enumerate(zip(leaves, paths)):
        arr = np.asarray(jax.device_get(leaf))
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in orig_dtype:
            # numpy can't serialise ml_dtypes (bf16/fp8): widen to f32 and
            # record the original dtype for the restore-side cast
            arr = arr.astype(np.float32)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape),
             "dtype": orig_dtype}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def _complete_checkpoints(directory: str) -> list[tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        full = os.path.join(directory, name)
        if name.endswith(".tmp"):
            continue
        if name.startswith("step_") and os.path.exists(
            os.path.join(full, "manifest.json")
        ):
            try:
                step = int(name[5:])
            except ValueError:
                # a stray directory (step_final/, step_backup/, ...) must not
                # kill restore — skip it loudly instead
                warnings.warn(
                    f"ignoring non-checkpoint entry {name!r} in {directory!r}"
                    " (step_<n> suffix is not an integer)",
                    stacklevel=2)
                continue
            out.append((step, full))
    return sorted(out)


def restore_latest(directory: str, target_tree: Any,
                   shardings: Any = None) -> tuple[Optional[int], Any]:
    """Restore the newest complete checkpoint into target_tree's structure.

    `shardings` (optional pytree of jax.sharding.Sharding) re-places every
    leaf for the CURRENT mesh — this is what makes restarts elastic: the
    saved arrays are unsharded, so any new mesh shape works as long as the
    logical shapes still divide.
    Returns (step or None, tree).
    """
    ckpts = _complete_checkpoints(directory)
    if not ckpts:
        return None, target_tree
    step, path = ckpts[-1]
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, paths, treedef = _flatten_with_paths(target_tree)
    assert len(leaves) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"target tree has {len(leaves)}"
    )
    # the zip below is positional — guard it: a target tree with the same
    # leaf count but different structure must fail by NAME, not by silently
    # loading leaf i into the wrong slot (or by a shape assert if lucky)
    for path_t, rec in zip(paths, manifest["leaves"]):
        if path_t != rec["path"]:
            raise ValueError(
                f"checkpoint/target tree mismatch at leaf {rec['path']!r}: "
                f"target tree has {path_t!r} in that position")
    new_leaves = []
    shard_leaves = (
        jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )[0]
        if shardings is not None
        else [None] * len(leaves)
    )
    for leaf, rec, sh in zip(leaves, manifest["leaves"], shard_leaves):
        arr = np.load(os.path.join(path, rec["file"]))
        assert tuple(arr.shape) == tuple(np.shape(leaf)), (
            rec["path"], arr.shape, np.shape(leaf)
        )
        target_dtype = getattr(leaf, "dtype", None)
        if sh is not None:
            val = jax.numpy.asarray(arr)
            if target_dtype is not None:
                val = val.astype(target_dtype)
            new_leaves.append(jax.device_put(val, sh))
        else:
            new_leaves.append(
                jax.numpy.asarray(arr).astype(target_dtype)
                if target_dtype is not None else arr
            )
    return step, jax.tree_util.tree_unflatten(treedef, new_leaves)


def checkpoint_extra(directory: str) -> tuple[Optional[int], dict]:
    """The (step, extra-metadata) of the newest complete checkpoint, read
    without touching any leaf file — resume logic needs the run coordinates
    (epoch, step, has_ef) BEFORE it can build the target tree to restore
    into. Returns (None, {}) when no checkpoint exists."""
    ckpts = _complete_checkpoints(directory)
    if not ckpts:
        return None, {}
    step, path = ckpts[-1]
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    return step, manifest.get("extra", {}) or {}


class CheckpointManager:
    """Keep-last-k manager with garbage collection of stale/partial dirs."""

    def __init__(self, directory: str, keep: int = 3, every: int = 50):
        self.directory = directory
        self.keep = keep
        self.every = every
        os.makedirs(directory, exist_ok=True)
        self._gc_partial()

    def _gc_partial(self) -> None:
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    def maybe_save(self, step: int, tree: Any, extra: Optional[dict] = None,
                   force: bool = False) -> Optional[str]:
        if not force and (step % self.every) != 0:
            return None
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc_old()
        return path

    def _gc_old(self) -> None:
        ckpts = _complete_checkpoints(self.directory)
        for _, path in ckpts[: -self.keep]:
            shutil.rmtree(path, ignore_errors=True)

    def restore(self, target_tree: Any, shardings: Any = None):
        return restore_latest(self.directory, target_tree, shardings)
