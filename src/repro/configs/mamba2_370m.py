"""mamba2-370m [arXiv:2405.21060]: attention-free SSM with SSD
(state-space duality), chunked scan. d_inner = 2*d_model = 2048,
64-dim SSM heads, state N=128."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    num_layers=3,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    ssm=True,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_groups=1,
    tie_embeddings=True,
)
