"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B]: dense, MHA (kv=16), QKV bias,
tied embeddings."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen1.5-0.5b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=160,
    vocab_size=256,
    qkv_bias=True,
    tie_embeddings=True,
)
