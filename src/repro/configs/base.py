"""Architecture + shape configs for the assigned evaluation pool.

Each assigned architecture gets one module in this package defining
``CONFIG`` (exact values from the assignment table) and ``SMOKE``
(a reduced same-family config for CPU smoke tests). ``get_config(arch)``
resolves either.

Shapes (same four for every LM arch):
  train_4k     seq 4096  x global_batch 256   -> train_step
  prefill_32k  seq 32768 x global_batch 32    -> prefill_step
  decode_32k   cache 32768 x global_batch 128 -> decode_step
  long_500k    cache 524288 x global_batch 1  -> decode_step (sub-quadratic
               archs only: mamba2 / hymba; skips noted in DESIGN.md §6)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

ARCH_IDS = (
    "qwen1.5-0.5b",
    "qwen3-4b",
    "h2o-danube-1.8b",
    "yi-6b",
    "hymba-1.5b",
    "qwen2-vl-2b",
    "phi3.5-moe-42b-a6.6b",
    "deepseek-moe-16b",
    "whisper-tiny",
    "mamba2-370m",
)

_MODULES = {
    "qwen1.5-0.5b": "qwen15_05b",
    "qwen3-4b": "qwen3_4b",
    "h2o-danube-1.8b": "h2o_danube_18b",
    "yi-6b": "yi_6b",
    "hymba-1.5b": "hymba_15b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-370m": "mamba2_370m",
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                 # 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0        # 0 = full attention
    num_global_layers: int = 0     # hymba: layers with full attention
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    mlp: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    # MoE
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    first_layer_dense: bool = False
    dense_d_ff: int = 0            # deepseek layer-0 dense MLP width
    moe_capacity_factor: float = 1.25  # expert buffer slack (1.0 = exact top-k)
    # SSM (mamba2 / hybrid)
    ssm: bool = False
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    hybrid: bool = False           # parallel attn + ssm heads per layer
    # encoder-decoder (whisper)
    encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500        # stub conv frontend output frames
    # VLM
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)
    num_patches: int = 1024        # stub vision frontend patches in sequence
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: O(1)-per-token decode state (SSM) or a
        bounded attention window (SWA). Pure full-attention archs skip the
        long_500k cell (DESIGN.md §6)."""
        return self.ssm or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (drives 6ND roofline math)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.num_heads:
            per_layer += d * self.num_heads * hd + d * self.num_kv_heads * hd * 2
            per_layer += self.num_heads * hd * d
        if self.ssm:
            din = self.ssm_inner
            g, n, h = self.ssm_groups, self.ssm_state, self.ssm_heads
            per_layer += d * (2 * din + 2 * g * n + h) + din * d
        if self.moe:
            per_layer += d * self.num_experts
            per_layer += self.num_experts * 3 * d * self.d_ff
            per_layer += self.num_shared_experts * 3 * d * self.d_ff
        elif self.mlp == "swiglu":
            per_layer += 3 * d * self.d_ff
        else:
            per_layer += 2 * d * self.d_ff
        total += per_layer * L
        if self.first_layer_dense and self.dense_d_ff:
            total += 3 * d * self.dense_d_ff - (
                d * self.num_experts
                + (self.num_experts + self.num_shared_experts) * 3 * d * self.d_ff
            )
        if self.encoder_decoder:
            enc = self.encoder_layers * (
                4 * d * d + 2 * d * self.d_ff
            )
            total += enc + self.num_layers * 4 * d * d  # cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        inactive = (
            (self.num_experts - self.experts_per_token) * 3 * d * self.d_ff * L
        )
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; options: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def smoke_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE


def list_archs() -> tuple[str, ...]:
    return ARCH_IDS


def shape_cells(arch: str) -> list[str]:
    """The dry-run cells for this arch (long_500k only if sub-quadratic)."""
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells
