"""hymba-1.5b [arXiv:2411.13676]: hybrid — parallel attention + mamba heads
in every block; SWA everywhere except 3 global-attention layers
(first / middle / last). Meta-tokens are omitted (stub; DESIGN.md §6).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=2048,
    num_global_layers=3,
    hybrid=True,
    ssm=True,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
)

SMOKE = ArchConfig(
    name="hymba-1.5b-smoke",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
    sliding_window=16,
    num_global_layers=2,
    hybrid=True,
    ssm=True,
    ssm_state=8,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_groups=1,
)
