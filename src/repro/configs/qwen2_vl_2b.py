"""qwen2-vl-2b [arXiv:2409.12191]: VLM backbone — M-RoPE (3 position
streams), GQA kv=2. Vision frontend is a STUB: input_specs provides
precomputed patch embeddings occupying the first `num_patches` sequence
positions (dynamic resolution folded into the stub)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    mrope_sections=(16, 24, 24),  # head_dim 128 -> half 64 = 16+24+24
    num_patches=1024,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen2-vl-2b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
    mrope=True,
    mrope_sections=(4, 2, 2),  # head_dim 16 -> half 8
    num_patches=8,
)
