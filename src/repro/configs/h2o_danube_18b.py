"""h2o-danube-1.8b [arXiv:2401.16818]: llama+mistral mix, GQA kv=8,
sliding-window attention (window 4096)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="h2o-danube-1.8b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    sliding_window=32,
)
