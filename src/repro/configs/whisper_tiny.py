"""whisper-tiny [arXiv:2212.04356]: encoder-decoder audio model. The conv
mel-frontend is a STUB — input_specs provides precomputed frame embeddings
[B, 1500, d]. LayerNorm + GELU MLP (no RoPE; learned positions)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    mlp="gelu",
    encoder_decoder=True,
    encoder_layers=4,
    # real whisper emits 1500 frames; the stub frontend pads to 1536 so the
    # encoder/cross attention tiles on 128-wide blocks (MXU alignment) and
    # takes the flash path instead of materialising f32 score matrices
    encoder_seq=1536,
)

SMOKE = ArchConfig(
    name="whisper-tiny-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=160,
    vocab_size=256,
    norm="layernorm",
    mlp="gelu",
    encoder_decoder=True,
    encoder_layers=2,
    encoder_seq=64,
)
