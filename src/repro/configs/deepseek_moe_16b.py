"""deepseek-moe-16b [arXiv:2401.06066]: fine-grained MoE — 64 routed experts
(top-6, d_ff 1408) + 2 shared experts; layer 0 is a dense MLP (d_ff 10944);
MHA kv=16."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=True,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    first_layer_dense=True,
    dense_d_ff=10944,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="deepseek-moe-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=48,
    vocab_size=256,
    moe=True,
    num_experts=8,
    experts_per_token=2,
    num_shared_experts=1,
    first_layer_dense=True,
    dense_d_ff=160,
)
