"""qwen3-4b [hf:Qwen/Qwen3-*]: dense, GQA kv=8, qk_norm, head_dim=128."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen3-4b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
    qk_norm=True,
)
