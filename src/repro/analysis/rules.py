"""The rule registry: distributed-training invariants checked per program.

Every rule is a function `(Program) -> list[Finding]` registered under a
stable name. `run_rules` drives the cross product (each rule decides
applicability from the program's kind/fields and returns [] when it does
not apply); a rule that raises is converted into an error finding rather
than crashing the gate, so a broken rule can never silently pass a PR.

The five core rules:

  no-scatter         traced jaxprs of scatter-free cells must not contain
                     scatter-add/scatter-max (and anchor cells MUST — a
                     blind walker is itself a violation)
  dtype-policy       the only narrowing converts from >=f32 a traced
                     program may contain are the wire codec's declared
                     wire dtypes (`repro.core.wire.narrow_wire_dtypes`)
  collective-budget  compiled HLO collective op counts and payload bytes
                     equal the analytic prediction
                     (`repro.gnn.sync.collective_budget`), with no
                     unbudgeted collective kinds
  donation           declared `donate_argnums` match the buffer-donation
                     policy (empty on XLA:CPU, carries donated elsewhere),
                     and donating compiles carry `input_output_alias`
  retrace-guard      driving a program sweep recompiles at most its
                     budget (static padded shapes / epoch-tier changes
                     only) — counted via jax.monitoring backend-compile
                     events on a pre-warmed process
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Iterable, Optional

from repro.analysis.hlo import analyze_hlo, input_output_aliases_from_hlo
from repro.analysis.jaxpr import narrowing_converts, primitive_names
from repro.analysis.programs import Program

__all__ = [
    "Finding", "Report", "RULES", "register_rule", "run_rules",
    "count_compiles", "check_scatter", "check_narrowing", "check_budget",
]

LEVELS = ("error", "warn", "info")


@dataclasses.dataclass
class Finding:
    rule: str
    program: str
    level: str                    # error | warn | info
    message: str
    data: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Report:
    findings: list
    programs_run: list
    rules_run: list
    elapsed_s: float = 0.0

    @property
    def errors(self) -> list:
        return [f for f in self.findings if f.level == "error"]

    @property
    def exit_code(self) -> int:
        return 1 if self.errors else 0

    def to_dict(self) -> dict:
        counts = {lv: 0 for lv in LEVELS}
        for f in self.findings:
            counts[f.level] = counts.get(f.level, 0) + 1
        return {
            "schema": "gnn-lint-report/v1",
            "programs": self.programs_run,
            "rules": self.rules_run,
            "counts": counts,
            "exit_code": self.exit_code,
            "elapsed_s": round(self.elapsed_s, 3),
            "findings": [f.to_dict() for f in self.findings],
        }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    doc: str
    fn: Callable[[Program], list]


RULES: dict = {}


def register_rule(name: str, doc: str):
    def deco(fn):
        RULES[name] = Rule(name=name, doc=doc, fn=fn)
        return fn

    return deco


def run_rules(programs: Iterable[Program],
              rules: Optional[Iterable[str]] = None) -> Report:
    """Run the selected rules (default: all) over the programs."""
    selected = [RULES[n] for n in (rules or sorted(RULES))]
    programs = list(programs)
    t0 = time.perf_counter()
    findings: list = []
    for prog in programs:
        for rule in selected:
            try:
                findings.extend(rule.fn(prog))
            except Exception as exc:  # a crashed rule must fail the gate
                findings.append(Finding(
                    rule=rule.name, program=prog.name, level="error",
                    message=f"rule crashed: {type(exc).__name__}: {exc}",
                ))
    return Report(
        findings=findings,
        programs_run=[p.name for p in programs],
        rules_run=[r.name for r in selected],
        elapsed_s=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# Shared check helpers (also the API the migrated tests call directly)
# ---------------------------------------------------------------------------


def check_scatter(jaxprs: Iterable, expect_free: bool) -> Optional[str]:
    """None when the traced programs match the expectation, else the
    violation message. `expect_free=False` is the anchor direction: the
    walker must SEE the scatter oracle's scatters."""
    from repro.kernels.ops import SCATTER_PRIMITIVES

    found: set = set()
    for cj in jaxprs:
        found |= primitive_names(cj) & set(SCATTER_PRIMITIVES)
    if expect_free and found:
        return f"scatter primitives in a scatter-free cell: {sorted(found)}"
    if not expect_free and not found:
        return ("anchor cell traced clean — the scatter walker is blind "
                f"(expected one of {list(SCATTER_PRIMITIVES)})")
    return None


def check_narrowing(jaxprs: Iterable, codec) -> list:
    """Narrowing converts (>=4-byte float source -> strictly smaller dtype)
    not licensed by the codec's wire dtypes. Returns [(src, dst, count)]."""
    from repro.core.wire import narrow_wire_dtypes

    allowed = set(narrow_wire_dtypes(codec)) | {"bool"}
    bad: list = []
    for cj in jaxprs:
        for (src, dst), n in narrowing_converts(cj).items():
            if dst not in allowed:
                bad.append((src, dst, n))
    return bad


def check_budget(hlo_text: str, budget: dict, k: int) -> list:
    """Hold compiled HLO to a `collective_budget` prediction. Returns
    violation messages (empty = the bytes XLA emitted are EXACTLY the
    analytic cluster bytes and every kind's op count is in range)."""
    res = analyze_hlo(hlo_text)
    problems: list = []
    for kind, want in budget.items():
        count = res["count_per_kind"].get(kind, 0)
        lo, hi = want["count"]
        if not lo <= count <= hi:
            problems.append(
                f"{kind}: {count} ops, budget [{lo}, {hi}]")
        got = res["bytes_per_kind"].get(kind, 0) * k
        if got != want["cluster_bytes"]:
            problems.append(
                f"{kind}: {got} cluster bytes (per-device x k={k}), "
                f"budget {want['cluster_bytes']}")
    extra = sorted(set(res["count_per_kind"]) - set(budget))
    if extra:
        problems.append(f"unbudgeted collective kinds emitted: {extra}")
    return problems


# ---------------------------------------------------------------------------
# Compile counting (retrace-guard)
# ---------------------------------------------------------------------------

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_compile_counter = {"n": 0, "installed": False}


def _install_compile_listener() -> None:
    # jax.monitoring listeners cannot be unregistered individually, so one
    # process-wide counter is installed on first use and shared forever
    if _compile_counter["installed"]:
        return
    import jax.monitoring

    def _listener(event, duration=0.0, **kwargs):
        if event == _COMPILE_EVENT:
            _compile_counter["n"] += 1

    jax.monitoring.register_event_duration_secs_listener(_listener)
    _compile_counter["installed"] = True


@contextlib.contextmanager
def count_compiles():
    """Counts XLA backend compiles inside the block: `box.count` after."""

    class _Box:
        count = 0

    _install_compile_listener()
    box = _Box()
    start = _compile_counter["n"]
    try:
        yield box
    finally:
        box.count = _compile_counter["n"] - start


# ---------------------------------------------------------------------------
# The rules
# ---------------------------------------------------------------------------


@register_rule(
    "no-scatter",
    "scatter-free cells trace without scatter-add/scatter-max; anchor "
    "cells must still trip the walker")
def _rule_no_scatter(prog: Program) -> list:
    if prog.kind != "jaxpr" or prog.expect_scatter_free is None:
        return []
    msg = check_scatter(prog.make(), prog.expect_scatter_free)
    if msg is not None:
        return [Finding("no-scatter", prog.name, "error", msg)]
    return [Finding("no-scatter", prog.name, "info",
                    "scatter-free" if prog.expect_scatter_free
                    else "anchor: scatter seen as expected")]


@register_rule(
    "dtype-policy",
    "the only narrowing converts from fp32+ are the wire codec's declared "
    "wire dtypes")
def _rule_dtype_policy(prog: Program) -> list:
    if prog.kind != "jaxpr" or prog.codec is None:
        return []
    bad = check_narrowing(prog.make(), prog.codec)
    if bad:
        detail = ", ".join(f"{s}->{d} x{n}" for s, d, n in bad)
        return [Finding(
            "dtype-policy", prog.name, "error",
            f"narrowing converts outside codec {prog.codec!r}: {detail}",
            data={"converts": [list(b) for b in bad]})]
    return [Finding("dtype-policy", prog.name, "info",
                    f"narrowing converts all licensed by {prog.codec!r}")]


@register_rule(
    "collective-budget",
    "compiled collective op counts and payload bytes equal the analytic "
    "collective_budget prediction, no unbudgeted kinds")
def _rule_collective_budget(prog: Program) -> list:
    if prog.kind != "hlo" or prog.budget is None:
        return []
    import jax

    if jax.device_count() < prog.devices:
        return [Finding(
            "collective-budget", prog.name, "info",
            f"skipped: needs {prog.devices} devices, have "
            f"{jax.device_count()} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={prog.devices})")]
    problems = check_budget(prog.make(), prog.budget(), prog.devices)
    if problems:
        return [Finding("collective-budget", prog.name, "error", p)
                for p in problems]
    return [Finding("collective-budget", prog.name, "info",
                    "HLO collectives match the analytic budget exactly")]


@register_rule(
    "donation",
    "declared donate_argnums match the buffer-donation policy; donating "
    "compiles carry input_output_alias")
def _rule_donation(prog: Program) -> list:
    if prog.kind != "donation":
        return []
    findings: list = []
    declared = tuple(prog.declared_donate()) if prog.declared_donate else ()
    if prog.expected_donate is not None:
        expected = tuple(prog.expected_donate())
        if declared != expected:
            findings.append(Finding(
                "donation", prog.name, "error",
                f"declares donate_argnums={declared}, policy expects "
                f"{expected}",
                data={"declared": list(declared),
                      "expected": list(expected)}))
    if prog.make is not None and prog.expect_alias is not None:
        aliases = input_output_aliases_from_hlo(prog.make())
        if prog.expect_alias and not aliases:
            findings.append(Finding(
                "donation", prog.name, "error",
                "donating program compiled WITHOUT input_output_alias — "
                "the donation is silently dropped"))
        elif not prog.expect_alias and aliases:
            findings.append(Finding(
                "donation", prog.name, "error",
                f"unexpected input_output_alias entries: {aliases}"))
    if not findings:
        findings.append(Finding("donation", prog.name, "info",
                                f"donation contract holds ({declared})"))
    return findings


@register_rule(
    "retrace-guard",
    "a pre-warmed sweep recompiles at most its budget — static padded "
    "shapes and scheduled codec-tier changes only")
def _rule_retrace_guard(prog: Program) -> list:
    if prog.kind != "retrace" or prog.sweep is None:
        return []
    # warm: eager op-by-op dispatch compiles populate the process caches.
    # A sweep may return a callable hot loop — then only the loop (steps/
    # answers) is measured and per-sweep setup (trainer/engine builds with
    # sweep-unique shapes) stays outside the counted window.
    hot = prog.sweep()
    if callable(hot):
        hot()
        hot = prog.sweep()
        with count_compiles() as box:
            hot()
    else:
        with count_compiles() as box:
            prog.sweep()
    if box.count > prog.retrace_budget:
        return [Finding(
            "retrace-guard", prog.name, "error",
            f"{box.count} backend compiles in a warmed sweep, budget "
            f"{prog.retrace_budget} — a shape- or weak-type-dependent "
            "retrace crept into this entry point",
            data={"compiles": box.count, "budget": prog.retrace_budget})]
    return [Finding(
        "retrace-guard", prog.name, "info",
        f"{box.count} compiles <= budget {prog.retrace_budget}",
        data={"compiles": box.count, "budget": prog.retrace_budget})]
