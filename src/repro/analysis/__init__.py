"""Static analysis over the repo's own traced jaxprs and compiled HLO.

The PR gate: `launch/gnn_lint.py` builds one representative program per
(entry point x model x backend x sync x codec) cell, runs every registered
rule over them and emits a machine-readable JSON report — exiting non-zero
on any error-level finding. The pieces:

  hlo.py        text-level HLO analysis (collective payload bytes per op
                kind under the output-shape convention, replica groups,
                scatter/convert inventory, input_output_alias)
  jaxpr.py      recursive jaxpr walking (primitive census, narrowing
                converts) across cond/scan/pjit/pallas_call sub-jaxprs
  programs.py   the analyzed-program grid + seeded violations
  rules.py      the rule registry (no-scatter, dtype-policy,
                collective-budget, donation, retrace-guard) and Report
  deadcode.py   advisory dead-export sweep over src/tests/benchmarks
"""

from repro.analysis.hlo import (
    analyze_hlo,
    collective_bytes_from_hlo,
    input_output_aliases_from_hlo,
)
from repro.analysis.jaxpr import (
    convert_ops,
    count_primitives,
    iter_eqns,
    narrowing_converts,
    primitive_names,
)
from repro.analysis.programs import Program, build_programs, violation_program
from repro.analysis.rules import (
    RULES,
    Finding,
    Report,
    check_budget,
    check_narrowing,
    check_scatter,
    count_compiles,
    register_rule,
    run_rules,
)

__all__ = [
    "analyze_hlo",
    "collective_bytes_from_hlo",
    "input_output_aliases_from_hlo",
    "convert_ops",
    "count_primitives",
    "iter_eqns",
    "narrowing_converts",
    "primitive_names",
    "Program",
    "build_programs",
    "violation_program",
    "RULES",
    "Finding",
    "Report",
    "check_budget",
    "check_narrowing",
    "check_scatter",
    "count_compiles",
    "register_rule",
    "run_rules",
]
