"""Compiled-HLO text analysis (promoted from `repro.launch.hlo`).

One parser serves every consumer — the multi-pod dry-run harness
(`launch/dryrun.py`), the ring scale-out benchmark, the distributed byte
pins (`tests/test_dist_lowering.py`), and the `repro.analysis` rules —
so the collective-byte accounting cannot drift between them.

`analyze_hlo` walks the HLO text once and extracts:

  * collectives  — one record per collective instruction (kind, payload
                   bytes, replica groups / source-target pairs), with the
                   async `-start`/`-done` pair counted ONCE: `-done` lines
                   carry no shape of their own and are skipped, and a
                   `-start` op's tuple output drops the in-flight operand
                   echo and the rank-0 integer context slots (u32[]/s32[]
                   handles) so bytes reflect the payload, never the
                   bookkeeping.
  * scatter_ops  — count of compiled `scatter` instructions (the lowered
                   form of data-dependent `at[].add`/`at[].max`).
  * convert_ops  — (src_dtype -> dst_dtype) counts of `convert`
                   instructions (the dtype-policy rule's raw material).
  * input_output_alias — (output_index, parameter) pairs declared in the
                   module header, i.e. which donated arguments XLA
                   actually aliased (the donation rule's raw material).

`collective_bytes_from_hlo` keeps its historical return shape
({bytes_per_kind, count_per_kind, total_bytes}) on top of the same walk.

Byte convention: a collective's payload is its OUTPUT shape (for
all-gather the gathered size, for reduce-scatter the scattered size) — a
consistent per-device proxy that the analytic formulas in
`repro.gnn.sync` (`sync_bytes_per_round` et al.) and the budget hook
(`collective_budget`) price identically.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = [
    "CollectiveOp",
    "analyze_hlo",
    "collective_bytes_from_hlo",
    "input_output_aliases_from_hlo",
]

_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?(?:\.\d+)?\s*\(",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# the annotation is a brace list of brace lists — `{{0,1},{1,2}}`; a
# non-greedy `.*?` would stop at the FIRST inner `}` and truncate every
# multi-group annotation, so consume inner groups explicitly
_REPLICA_GROUPS_RE = re.compile(
    r"replica_groups=\{((?:\{[0-9,\s]*\}[,\s]*)*)\}")
_SOURCE_TARGET_RE = re.compile(
    r"source_target_pairs=\{((?:\{[0-9,\s]*\}[,\s]*)*)\}")
_GROUP_RE = re.compile(r"\{([0-9,\s]*)\}")

_CONVERT_RE = re.compile(r"=\s*([a-z0-9]+)\[[0-9,]*\][^=]*?"
                         r"\bconvert(?:\.\d+)?\s*\(\s*([a-z0-9]+)\[")
_SCATTER_RE = re.compile(r"=\s*[^=]*?\bscatter(?:\.\d+)?\s*\(")


def _shape_entries(region: str) -> list:
    """[(dtype, nelems, rank0)] for every `dtype[dims]` in `region` (known
    dtypes only — `token[]` and opaque types carry no payload)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(region):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for tok in dims.split(","):
            if tok:
                n *= int(tok)
        out.append((dt, n, not dims))
    return out


def _payload_entries(kind_suffix: Optional[str], outputs: list,
                     operands: list) -> list:
    """Reduce an op's output shape entries to its true payload.

    Plain (sync) collectives: the output IS the payload. `-start` forms
    return a tuple holding async bookkeeping alongside the result:
    rank-0 integer context slots (`u32[]`/`s32[]` handles) and one echo of
    each operand buffer (the in-flight source). Both are dropped — but
    never the last remaining entry, so a `-start` whose output equals its
    operand (all-reduce) still counts its single payload once.
    """
    if kind_suffix != "-start":
        return [(dt, n) for dt, n, _ in outputs]
    entries = [(dt, n) for dt, n, rank0 in outputs
               if not (rank0 and dt in ("u32", "s32", "u64", "s64")
                       and len(outputs) > 1)]
    for op_dt, op_n, _ in operands:
        if len(entries) <= 1:
            break
        try:
            entries.remove((op_dt, op_n))
        except ValueError:
            pass
    return entries


def _parse_groups(text: str) -> list:
    return [
        [int(t) for t in grp.split(",") if t.strip()]
        for grp in _GROUP_RE.findall(text)
    ]


@dataclasses.dataclass
class CollectiveOp:
    """One compiled collective instruction."""

    kind: str                  # all-reduce | all-gather | ... (no suffix)
    is_start: bool             # async -start form
    payload_bytes: int         # output-shape payload (bookkeeping removed)
    dtypes: tuple              # payload dtypes, e.g. ("s8", "f32")
    replica_groups: list       # [[0,1,2,3]] etc. ([] when absent)
    source_target_pairs: list  # collective-permute routing ([] when absent)

    @property
    def group_size(self) -> int:
        """Devices per replica group (0 when unannotated)."""
        if self.replica_groups:
            return max(len(g) for g in self.replica_groups)
        if self.source_target_pairs:
            return len({p for pair in self.source_target_pairs for p in pair})
        return 0


def analyze_hlo(hlo_text: str) -> dict:
    """One-pass structural summary of compiled HLO text (module docstring).

    Returns {"collectives": [CollectiveOp], "bytes_per_kind",
    "count_per_kind", "total_bytes", "scatter_ops", "convert_ops",
    "input_output_alias"}.
    """
    collectives: list[CollectiveOp] = []
    scatter_ops = 0
    convert_ops: dict[tuple, int] = {}

    for raw in hlo_text.splitlines():
        line = raw.strip()
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]

        cm = _CONVERT_RE.search(line)
        if cm and " convert" in line:
            dst, src = cm.group(1), cm.group(2)
            convert_ops[(src, dst)] = convert_ops.get((src, dst), 0) + 1

        if (_SCATTER_RE.search(line) and "reduce-scatter" not in line
                and "select-and-scatter" not in line):
            scatter_ops += 1

        m = _COLLECTIVE_RE.search(rhs)
        if not m:
            continue
        kind, suffix = m.group(1), m.group(2)
        if suffix == "-done":
            # the paired -start already counted this transfer
            continue

        out_entries = _shape_entries(rhs[: m.start()])
        close = rhs.find(")", m.end())
        operand_region = rhs[m.end(): close if close >= 0 else len(rhs)]
        op_entries = _shape_entries(operand_region)
        payload = _payload_entries(suffix, out_entries, op_entries)
        attrs = rhs[close:] if close >= 0 else ""
        collectives.append(CollectiveOp(
            kind=kind,
            is_start=(suffix == "-start"),
            payload_bytes=sum(n * _DTYPE_BYTES[dt] for dt, n in payload),
            dtypes=tuple(sorted({dt for dt, _ in payload})),
            replica_groups=_parse_groups(
                g.group(1)) if (g := _REPLICA_GROUPS_RE.search(attrs)) else [],
            source_target_pairs=[
                tuple(p) for p in _parse_groups(g.group(1))
            ] if (g := _SOURCE_TARGET_RE.search(attrs)) else [],
        ))

    per_kind: dict[str, int] = {}
    count: dict[str, int] = {}
    for op in collectives:
        per_kind[op.kind] = per_kind.get(op.kind, 0) + op.payload_bytes
        count[op.kind] = count.get(op.kind, 0) + 1

    return {
        "collectives": collectives,
        "bytes_per_kind": per_kind,
        "count_per_kind": count,
        "total_bytes": int(sum(per_kind.values())),
        "scatter_ops": scatter_ops,
        "convert_ops": convert_ops,
        "input_output_alias": input_output_aliases_from_hlo(hlo_text),
    }


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Historical interface: {bytes_per_kind, count_per_kind, total_bytes}.

    Same walk as `analyze_hlo`, so the dry-run harness, the benchmarks and
    the analysis rules agree byte-for-byte.
    """
    res = analyze_hlo(hlo_text)
    return {"bytes_per_kind": res["bytes_per_kind"],
            "count_per_kind": res["count_per_kind"],
            "total_bytes": res["total_bytes"]}


def input_output_aliases_from_hlo(hlo_text: str) -> list:
    """[(output_index, parameter_number)] pairs the executable aliased.

    Parsed from the HloModule header's `input_output_alias={ {o}: (p, {},
    may-alias) }` section — present (even on XLA:CPU) exactly when the
    compiled program declared donated/aliased arguments.
    """
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return []
    i = start + len("input_output_alias=")
    depth = 0
    end = i
    for j in range(i, min(len(hlo_text), i + 4096)):
        c = hlo_text[j]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                end = j + 1
                break
    section = hlo_text[i:end]
    pairs = []
    for om, pm in re.findall(r"\{([0-9,\s]*)\}:\s*\((\d+)", section):
        out_idx = tuple(int(t) for t in om.split(",") if t.strip())
        pairs.append((out_idx if len(out_idx) != 1 else out_idx[0], int(pm)))
    return pairs
