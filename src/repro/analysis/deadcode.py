"""Dead-export detection over the repo's own sources (warn-level).

Collects every public top-level symbol defined under ``src/repro`` with
`ast` and counts identifier-token references to it across src/, tests/ and
benchmarks/. A symbol whose name is never mentioned outside its defining
statement is reported as a warn finding — advisory only (string-based
dispatch, __getattr__ re-exports and CLI entry points can all hide uses),
so it never affects the lint exit code. Suppress a finding by prefixing
the name with ``_``, deleting the symbol, or annotating the definition
line with ``# lint: keep`` (for deliberate API surface such as hooks for
optional builds or paper-documentation constants).
"""

from __future__ import annotations

import ast
import io
import pathlib
import tokenize
from typing import Iterable

__all__ = ["collect_exports", "reference_counts", "dead_exports"]

SOURCE_DIRS = ("src", "tests", "benchmarks")


def _py_files(root: pathlib.Path) -> list:
    files: list = []
    for d in SOURCE_DIRS:
        base = root / d
        if base.is_dir():
            files.extend(sorted(base.rglob("*.py")))
    return files


def collect_exports(root) -> dict:
    """{symbol: defining file} for every public module-level def/class/
    assignment under src/repro. Later definitions of a shared name keep
    every site (a name defined twice is 'used' if referenced anywhere)."""
    root = pathlib.Path(root)
    exports: dict = {}
    for path in sorted((root / "src" / "repro").rglob("*.py")):
        text = path.read_text()
        try:
            tree = ast.parse(text, filename=str(path))
        except SyntaxError:
            continue
        lines = text.splitlines()
        for node in tree.body:
            if "lint: keep" in lines[node.lineno - 1]:
                continue
            names: list = []
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                names = [node.name]
            elif isinstance(node, ast.Assign):
                names = [t.id for t in node.targets
                         if isinstance(t, ast.Name)]
            elif isinstance(node, ast.AnnAssign):
                if isinstance(node.target, ast.Name) and node.value is not None:
                    names = [node.target.id]
            for name in names:
                if name.startswith("_") or name == "__all__":
                    continue
                exports.setdefault(name, []).append(
                    str(path.relative_to(root)))
    return exports


def reference_counts(names: Iterable[str], files: Iterable) -> dict:
    """Identifier-token occurrence counts (NOT substring matches — `run`
    inside `run_rules` does not count) for each name across the files."""
    wanted = set(names)
    counts = {n: 0 for n in wanted}
    for path in files:
        try:
            text = pathlib.Path(path).read_text()
        except OSError:
            continue
        try:
            toks = tokenize.generate_tokens(io.StringIO(text).readline)
            for tok in toks:
                if tok.type == tokenize.NAME and tok.string in wanted:
                    counts[tok.string] += 1
        except tokenize.TokenizeError:
            continue
    return counts


def dead_exports(root) -> list:
    """[(symbol, defining_files)] for public exports referenced nowhere
    beyond their own definition line(s)."""
    root = pathlib.Path(root)
    exports = collect_exports(root)
    counts = reference_counts(exports, _py_files(root))
    dead: list = []
    for name, files in sorted(exports.items()):
        # each definition statement mentions the name exactly once; any
        # additional token anywhere (import, call, test, __all__ string is
        # NOT a token match — but a re-export `from x import name` is)
        if counts.get(name, 0) <= len(files):
            dead.append((name, files))
    return dead
