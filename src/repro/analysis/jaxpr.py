"""Traced-jaxpr analysis: the static half of the invariant rules.

A jaxpr is what `jax.jit` will compile — walking it catches regressions
BEFORE any (slow) XLA compile: a scatter primitive sneaking onto the tiled
hot path, a narrowing `convert_element_type` appearing on an fp32-default
path. The walker recurses into every sub-jaxpr (cond/scan/pjit/custom_vjp
bodies, `pallas_call` kernels), generalising the ad-hoc helper the
acceptance tests in `tests/test_aggregate.py` used to carry inline.
"""

from __future__ import annotations

from typing import Iterator

import numpy as np

__all__ = [
    "convert_ops",
    "count_primitives",
    "iter_eqns",
    "narrowing_converts",
    "primitive_names",
]


def _subjaxprs(value) -> Iterator:
    import jax.core as core

    if isinstance(value, core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, core.Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _subjaxprs(v)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _subjaxprs(v)


def iter_eqns(jaxpr) -> Iterator:
    """Every equation in a (Closed)Jaxpr, recursing into sub-jaxprs
    (cond/scan/pjit/custom_vjp/pallas_call bodies)."""
    j = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    for eqn in j.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub)


def primitive_names(jaxpr) -> set:
    """All primitive names reachable from a (Closed)Jaxpr."""
    return {eqn.primitive.name for eqn in iter_eqns(jaxpr)}


def count_primitives(jaxpr) -> dict:
    """{primitive name: occurrence count} over the whole jaxpr tree."""
    counts: dict[str, int] = {}
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        counts[name] = counts.get(name, 0) + 1
    return counts


def convert_ops(jaxpr) -> dict:
    """{(src_dtype_name, dst_dtype_name): count} of every
    `convert_element_type` in the jaxpr tree."""
    out: dict[tuple, int] = {}
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        src = np.dtype(eqn.invars[0].aval.dtype).name
        dst = np.dtype(eqn.params["new_dtype"]).name
        out[(src, dst)] = out.get((src, dst), 0) + 1
    return out


def narrowing_converts(jaxpr) -> dict:
    """Converts that SHRINK a floating payload: {(src, dst): count} where
    src is a float dtype of >= 4 bytes and dst is strictly smaller (bf16,
    f16, int8, fp8, ...). Integer index-width churn (i64 -> i32) and
    widenings (bool -> f32) are not wire compression and are ignored.
    """
    out: dict[tuple, int] = {}
    for (src, dst), n in convert_ops(jaxpr).items():
        sdt, ddt = np.dtype(src), np.dtype(dst)
        if (np.issubdtype(sdt, np.floating) and sdt.itemsize >= 4
                and ddt.itemsize < sdt.itemsize):
            out[(src, dst)] = out.get((src, dst), 0) + n
    return out
