"""Representative programs the static analyzer runs its rules over.

A `Program` is one (entry point x configuration) cell plus the invariants
the rules should hold it to. Four kinds:

  jaxpr     `make()` returns a list of traced `ClosedJaxpr`s (nothing
            compiles). Walked by the no-scatter and dtype-policy rules.
  hlo       `make()` returns compiled HLO text. Needs `devices` forced
            host devices (the rule skips with an info finding when the
            process has fewer). Checked by the collective-budget rule
            against `budget()` — the prediction from
            `repro.gnn.sync.collective_budget`.
  donation  declared vs expected `donate_argnums`, plus (optionally) a
            compiled probe whose `input_output_alias` header must agree.
  retrace   `sweep()` builds a FRESH trainer/engine and drives a few
            steps. The retrace-guard rule runs it twice — the first run
            warms the process-wide eager-dispatch caches — and counts
            backend compiles during the second against `retrace_budget`.

The default grid covers the paper's axes: {sage, gat} models x
{scatter, tiled, pallas} aggregation backends x {halo, ring, dense, local}
sync strategies x {fp32, int8, variable} wire codecs, over full-batch
training, mini-batch training, layer-wise inference and online serving.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import numpy as np

from repro.kernels.ops import scatter_free_traced

D = 8                 # feature/hidden width of every analysis program
K = 4                 # partitions for the distributed cells
NUM_CLASSES = 4

__all__ = ["Program", "build_programs", "violation_program", "GRIDS"]


@dataclasses.dataclass
class Program:
    """One analyzed program + the invariants rules hold it to."""

    name: str
    kind: str                                  # jaxpr | hlo | donation | retrace
    make: Optional[Callable[[], Any]] = None   # artifact builder (lazy)
    meta: dict = dataclasses.field(default_factory=dict)
    # --- no-scatter rule (jaxpr) -------------------------------------------
    # True: scatter-add/max must NOT appear; False: it MUST (anchor cell
    # proving the rule still sees scatters); None: report only.
    expect_scatter_free: Optional[bool] = None
    # --- dtype-policy rule (jaxpr): codec governing allowed narrow dtypes --
    codec: Optional[str] = None
    # --- collective-budget rule (hlo) --------------------------------------
    budget: Optional[Callable[[], dict]] = None
    devices: int = 1
    # --- donation rule ------------------------------------------------------
    declared_donate: Optional[Callable[[], tuple]] = None
    expected_donate: Optional[Callable[[], tuple]] = None
    expect_alias: Optional[bool] = None        # probe HLO must carry aliases
    # --- retrace-guard rule --------------------------------------------------
    sweep: Optional[Callable[[], None]] = None
    retrace_budget: Optional[int] = None


# ---------------------------------------------------------------------------
# Shared fixture (one small paper graph, cached per process)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _fixture():
    from repro.core.graph import paper_graph

    g = paper_graph("OR", scale=0.01, seed=0)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, D)).astype(np.float32)
    labels = rng.integers(0, NUM_CLASSES, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.3
    return g, feats, labels, train


@functools.lru_cache(maxsize=None)
def _assignment(k: int):
    from repro.core.edge_partition import partition_edges

    return partition_edges(_fixture()[0], k, "hdrf", seed=1)


def _spec(model: str, backend: str):
    from repro.gnn.models import GNNSpec

    return GNNSpec(model=model, feature_dim=D, hidden_dim=D,
                   num_classes=NUM_CLASSES, agg_backend=backend)


@functools.lru_cache(maxsize=None)
def _book_blocks(sync_mode: str, tiled: bool, k: int):
    from repro.gnn.fullbatch import build_book, build_device_blocks

    g, feats, labels, train = _fixture()
    if sync_mode == "ring":
        a = None
    elif k == 1:
        a = np.zeros(g.num_edges, np.int64)
    else:
        a = _assignment(k)
    book = build_book(g, a, k, sync_mode=sync_mode, tiled_layout=tiled)
    return book, build_device_blocks(book, feats, labels, train)


# ---------------------------------------------------------------------------
# jaxpr builders (trace only — run on any device count)
# ---------------------------------------------------------------------------


def _fullbatch_jaxpr(model: str, backend: str, sync_mode: str,
                     codec: Optional[str], k: int = K) -> list:
    import jax

    from repro.gnn import models
    from repro.gnn.fullbatch import make_step_fns, wrap_spmd

    spec = _spec(model, backend)
    book, blocks = _book_blocks(sync_mode, backend != "scatter", k)
    loss, _ = make_step_fns(spec, sync_mode, book.num_vertices, k,
                            codec=codec)
    wrapped = wrap_spmd(loss, k, "sim")
    params = models.init_params(spec, seed=0)
    return [jax.make_jaxpr(wrapped)(params, blocks)]


def _minibatch_jaxpr(model: str, backend: str,
                     codec: Optional[str] = None) -> list:
    import jax

    from repro.gnn.minibatch import MiniBatchTrainer, minibatch_loss

    g, feats, labels, train = _fixture()
    spec = _spec(model, backend)
    tr = MiniBatchTrainer.build(
        g, np.zeros(g.num_vertices, np.int64), 1, spec, feats, labels,
        train, global_batch=64, fanouts=(4, 4), seed=0, codec=codec,
    )
    pb = tr.engine.preparer.prepare()
    batch0 = jax.tree.map(lambda a: a[0], pb.stacked)
    sizes = tuple(tr._layer_sizes)

    def fn(p, b):
        return minibatch_loss(spec, p, b, sizes, axis=None)

    return [jax.make_jaxpr(fn)(tr.params, batch0)]


def _serving_jaxpr(model: str, backend: str) -> list:
    import jax

    from repro.core.partition_book import build_vertex_book
    from repro.gnn import models
    from repro.gnn.minibatch import mfg_forward
    from repro.serve.engine import build_serving

    g, feats, labels, train = _fixture()
    spec = _spec(model, backend)
    params = models.init_params(spec, seed=0)
    vbook = build_vertex_book(g, np.zeros(g.num_vertices, np.int64), 1)
    embeddings = [
        np.zeros((g.num_vertices, dout), np.float32)
        for _, dout in spec.dims()
    ]
    engines, batchers, _ = build_serving(
        g, vbook, spec, params, embeddings, hops=1, fanout=4, max_batch=8,
    )
    eng, bat = engines[0], batchers[0]
    batch = bat.build_mfg(np.arange(4, dtype=np.int64))
    x = np.zeros((batch.input_ids.shape[0], eng.store.row_dim), np.float32)
    dev = eng.device_batch(batch, x)
    sizes, lp = eng._sizes, eng._layer_params

    def fn(p, b):
        return mfg_forward(spec, p, b, sizes)

    return [jax.make_jaxpr(fn)(lp, dev)]


def _inference_jaxprs(model: str, backend: str, k: int = K) -> list:
    from repro.gnn import models
    from repro.gnn.inference import LayerwiseInference

    g, feats, labels, train = _fixture()
    spec = _spec(model, backend)
    params = models.init_params(spec, seed=0)
    a = (_assignment(k) if k > 1
         else np.zeros(g.num_edges, np.int64))
    eng = LayerwiseInference.build(g, a, k, spec, params, feats,
                                   sync_mode="halo")
    return eng.layer_jaxprs()


# ---------------------------------------------------------------------------
# hlo builders (compile one aggregate under shard_map — need K devices)
# ---------------------------------------------------------------------------


def _shard_map(fn, mesh, in_specs, out_specs):
    import jax

    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, check_vma=False,
                             in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map

    return shard_map(fn, mesh=mesh, check_rep=False,
                     in_specs=in_specs, out_specs=out_specs)


@functools.lru_cache(maxsize=1)
def _ring_fixture():
    from repro.core.partition_book import build_blockrow_book
    from repro.gnn.sync import build_ring_blocks

    g, feats, _, _ = _fixture()
    zeros = np.zeros(g.num_vertices, np.int32)
    book = build_blockrow_book(g, K)
    blocks = build_ring_blocks(book, feats, zeros, zeros.astype(bool))
    return book, blocks


@functools.lru_cache(maxsize=1)
def _halo_fixture():
    from repro.core.partition_book import build_edge_book
    from repro.gnn.sync import build_blocks

    g, feats, _, _ = _fixture()
    zeros = np.zeros(g.num_vertices, np.int32)
    book = build_edge_book(g, _assignment(K), K)
    blocks = build_blocks(book, feats, zeros, zeros.astype(bool))
    return book, blocks


def _ring_hlo(codec: Optional[str]) -> str:
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.gnn.sync import RingSync
    from repro.launch.mesh import make_mesh

    _, blocks = _ring_fixture()
    mesh = make_mesh((K,), ("parts",))

    def per_device(blocks_local):
        blk = jax.tree.map(lambda a: a[0], blocks_local)
        sync = RingSync(axis="parts", k=K, codec=codec)
        h = sync.edge_aggregate(blk, blk.x, lambda s, dst, m: s * m[:, None])
        return h[None]

    fn = _shard_map(per_device, mesh, (P("parts"),), P("parts"))
    return jax.jit(fn).lower(blocks).compile().as_text()


def _partial_agg_hlo(mode: str, codec: Optional[str]) -> str:
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.gnn.sync import make_sync
    from repro.launch.mesh import make_mesh

    book, blocks = _halo_fixture()
    mesh = make_mesh((K,), ("parts",))

    def per_device(blocks_local):
        blk = jax.tree.map(lambda a: a[0], blocks_local)
        sync = make_sync(mode, blk, book.num_vertices, "parts", codec=codec)
        h = sync.broadcast(sync.reduce_sum(blk.x))   # one reduce+broadcast
        return jax.tree.map(lambda a: a[None], h)

    fn = _shard_map(per_device, mesh, (P("parts"),), P("parts"))
    return jax.jit(fn).lower(blocks).compile().as_text()


def _sync_budget(mode: str, codec: Optional[str]) -> dict:
    from repro.gnn.sync import collective_budget

    book = (_ring_fixture() if mode == "ring" else _halo_fixture())[0]
    return collective_budget(book, D, mode, codec=codec)


# ---------------------------------------------------------------------------
# donation + retrace builders
# ---------------------------------------------------------------------------


def _donation_probe_hlo() -> str:
    import jax
    import jax.numpy as jnp

    fn = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    return fn.lower(jnp.zeros((8,), jnp.float32)).compile().as_text()


def _fresh_fullbatch(codec: Optional[str]):
    from repro.gnn.fullbatch import FullBatchTrainer

    g, feats, labels, train = _fixture()
    return FullBatchTrainer.build(
        g, np.zeros(g.num_edges, np.int64), 1, _spec("sage", "scatter"),
        feats, labels, train, seed=0, codec=codec,
    )


def _sweep_fullbatch_fp32():
    tr = _fresh_fullbatch(None)

    def hot():
        for _ in range(3):
            tr.train_step()

    return hot


def _sweep_fullbatch_variable():
    tr = _fresh_fullbatch("variable")

    def hot():
        for epoch in range(4):
            tr.set_epoch(epoch)
            tr.train_step()

    return hot


def _sweep_minibatch_variable():
    from repro.gnn.minibatch import MiniBatchTrainer

    g, feats, labels, train = _fixture()
    tr = MiniBatchTrainer.build(
        g, np.zeros(g.num_vertices, np.int64), 1, _spec("sage", "scatter"),
        feats, labels, train, global_batch=64, fanouts=(4, 4), seed=0,
        codec="variable",
    )

    def hot():
        for epoch in range(4):
            tr.set_epoch(epoch)
            tr.train_step()

    return hot


# serving retrace: `_compiled_step` is an lru_cache over (spec, hops, plan),
# so each sweep must present a spec the process has never served — otherwise
# the warm run would leave nothing to compile and the guard would measure 0.
_SERVE_SPIN = {"n": 0}


def _sweep_serving():
    from repro.core.partition_book import build_vertex_book
    from repro.gnn import models
    from repro.serve.engine import build_serving

    _SERVE_SPIN["n"] += 1
    g, feats, labels, train = _fixture()
    spec = dataclasses.replace(
        _spec("sage", "scatter"), num_classes=NUM_CLASSES + _SERVE_SPIN["n"])
    params = models.init_params(spec, seed=0)
    vbook = build_vertex_book(g, np.zeros(g.num_vertices, np.int64), 1)
    embeddings = [
        np.zeros((g.num_vertices, dout), np.float32)
        for _, dout in spec.dims()
    ]
    engines, batchers, _ = build_serving(
        g, vbook, spec, params, embeddings, hops=1, fanout=4, max_batch=8,
    )

    def hot():
        for ids in (np.arange(4, dtype=np.int64),
                    np.arange(4, 10, dtype=np.int64)):
            engines[0].answer(batchers[0].build_mfg(ids))

    return hot


# ---------------------------------------------------------------------------
# Grid assembly
# ---------------------------------------------------------------------------

MODELS = ("sage", "gat")
BACKENDS = ("scatter", "tiled")
SYNCS = ("halo", "ring")
WIRE_CODECS = ("fp32", "int8")

GRIDS = ("tiny", "smoke")


def _expect_free(backend: str, sync_mode: str, k: int) -> bool:
    """A traced program is scatter-free iff the aggregation backend avoids
    scatter AND the sync strategy does (halo/dense bucket-scatter at k>1)."""
    return scatter_free_traced(backend) and (sync_mode == "ring" or k == 1)


def _fullbatch_program(model, backend, sync_mode, codec, k=K) -> Program:
    name = f"fullbatch/{model}-{backend}-{sync_mode}-{codec or 'fp32'}-k{k}"
    return Program(
        name=name, kind="jaxpr",
        make=functools.partial(_fullbatch_jaxpr, model, backend, sync_mode,
                               codec, k),
        meta={"entry": "fullbatch", "model": model, "backend": backend,
              "sync": sync_mode, "k": k},
        expect_scatter_free=_expect_free(backend, sync_mode, k),
        codec=codec or "fp32",
    )


def _jaxpr_grid() -> list:
    progs = [
        _fullbatch_program(model, backend, sync_mode, codec)
        for model in MODELS
        for backend in BACKENDS
        for sync_mode in SYNCS
        for codec in WIRE_CODECS
    ]
    # pallas backend: scatter-free by construction on every platform — the
    # green cells proving the no-scatter rule passes real programs (plus the
    # k=1 hot paths the old tests/test_aggregate.py pins covered)
    progs += [
        _fullbatch_program("gat", "pallas", "ring", "fp32"),
        _fullbatch_program("sage", "pallas", "local", "fp32", k=1),
        _fullbatch_program("gat", "pallas", "local", "fp32", k=1),
        # anchor: the scatter oracle MUST trip the walker
        _fullbatch_program("gat", "scatter", "local", "fp32", k=1),
    ]
    progs += [
        Program(
            name="minibatch/gat-pallas-fp32",
            kind="jaxpr", make=functools.partial(_minibatch_jaxpr, "gat",
                                                 "pallas"),
            meta={"entry": "minibatch", "model": "gat", "backend": "pallas"},
            expect_scatter_free=True, codec="fp32",
        ),
        Program(
            name="minibatch/gat-scatter-fp32",
            kind="jaxpr", make=functools.partial(_minibatch_jaxpr, "gat",
                                                 "scatter"),
            meta={"entry": "minibatch", "model": "gat", "backend": "scatter"},
            expect_scatter_free=False, codec="fp32",
        ),
        Program(
            name="minibatch/sage-tiled-fp32",
            kind="jaxpr", make=functools.partial(_minibatch_jaxpr, "sage",
                                                 "tiled"),
            meta={"entry": "minibatch", "model": "sage", "backend": "tiled"},
            expect_scatter_free=scatter_free_traced("tiled"), codec="fp32",
        ),
        Program(
            name="serving/sage-pallas-fp32",
            kind="jaxpr", make=functools.partial(_serving_jaxpr, "sage",
                                                 "pallas"),
            meta={"entry": "serving", "model": "sage", "backend": "pallas"},
            expect_scatter_free=True, codec="fp32",
        ),
        Program(
            name="serving/gat-scatter-fp32",
            kind="jaxpr", make=functools.partial(_serving_jaxpr, "gat",
                                                 "scatter"),
            meta={"entry": "serving", "model": "gat", "backend": "scatter"},
            expect_scatter_free=False, codec="fp32",
        ),
        Program(
            name="inference/sage-tiled-halo-k4",
            kind="jaxpr", make=functools.partial(_inference_jaxprs, "sage",
                                                 "tiled", K),
            meta={"entry": "inference", "model": "sage", "backend": "tiled",
                  "sync": "halo", "k": K},
            expect_scatter_free=_expect_free("tiled", "halo", K),
            codec="fp32",
        ),
        Program(
            name="inference/gat-pallas-local-k1",
            kind="jaxpr", make=functools.partial(_inference_jaxprs, "gat",
                                                 "pallas", 1),
            meta={"entry": "inference", "model": "gat", "backend": "pallas",
                  "sync": "local", "k": 1},
            expect_scatter_free=True, codec="fp32",
        ),
    ]
    return progs


def _hlo_grid() -> list:
    cells = [
        ("ring", "fp32"), ("ring", "int8"),
        ("halo", "fp32"), ("halo", "int8"),
        ("dense", "fp32"),
    ]
    progs = []
    for mode, codec in cells:
        make = (functools.partial(_ring_hlo, codec) if mode == "ring"
                else functools.partial(_partial_agg_hlo, mode, codec))
        progs.append(Program(
            name=f"hlo/{mode}-{codec}", kind="hlo", make=make,
            meta={"entry": "sync-aggregate", "sync": mode},
            budget=functools.partial(_sync_budget, mode, codec),
            devices=K, codec=codec,
        ))
    return progs


def _donation_programs() -> list:
    def fb(lossless):
        from repro.gnn.fullbatch import step_donate_argnums
        return step_donate_argnums(lossless)

    def mb(lossless):
        from repro.gnn.minibatch import step_donate_argnums
        return step_donate_argnums(lossless)

    def policy(lossless, trainer):
        # the donation contract: every trainer donates its (params/opt or
        # blocks/ef) carries off-CPU and declares () on XLA:CPU, which
        # cannot alias and would warn once per compile otherwise
        import jax
        if jax.default_backend() == "cpu":
            return ()
        if trainer == "fullbatch":
            return () if lossless else (1, 3)
        return (0, 1) if lossless else (1, 3)

    return [
        Program(
            name="donation/jit-probe", kind="donation",
            make=_donation_probe_hlo, expect_alias=True,
            meta={"entry": "probe"},
            declared_donate=lambda: (0,), expected_donate=lambda: (0,),
        ),
        Program(
            name="donation/fullbatch-lossy", kind="donation",
            meta={"entry": "fullbatch"},
            declared_donate=functools.partial(fb, False),
            expected_donate=functools.partial(policy, False, "fullbatch"),
        ),
        Program(
            name="donation/minibatch-lossless", kind="donation",
            meta={"entry": "minibatch"},
            declared_donate=functools.partial(mb, True),
            expected_donate=functools.partial(policy, True, "minibatch"),
        ),
        Program(
            name="donation/minibatch-lossy", kind="donation",
            meta={"entry": "minibatch"},
            declared_donate=functools.partial(mb, False),
            expected_donate=functools.partial(policy, False, "minibatch"),
        ),
    ]


def _retrace_programs() -> list:
    return [
        Program(
            name="retrace/fullbatch-fp32", kind="retrace",
            sweep=_sweep_fullbatch_fp32, retrace_budget=1,
            meta={"entry": "fullbatch", "steps": 3},
        ),
        Program(
            name="retrace/fullbatch-variable", kind="retrace",
            # the epoch schedule changes wire tier once (int8 -> bf16 at
            # epoch 2), so exactly one EXTRA jit is the budget
            sweep=_sweep_fullbatch_variable, retrace_budget=2,
            meta={"entry": "fullbatch", "epochs": 4, "codec": "variable"},
        ),
        Program(
            name="retrace/minibatch-variable", kind="retrace",
            sweep=_sweep_minibatch_variable, retrace_budget=2,
            meta={"entry": "minibatch", "epochs": 4, "codec": "variable"},
        ),
        Program(
            name="retrace/serving", kind="retrace",
            # 1 jitted serve step + 1 eager result-slice compile on the
            # sweep's unique logits width; the second answer must hit both
            sweep=_sweep_serving, retrace_budget=2,
            meta={"entry": "serving", "answers": 2},
        ),
    ]


def build_programs(grid: str = "smoke") -> list:
    """The program set for a grid tier.

    tiny   a fast cross-section (seconds): one green + one anchor jaxpr
           cell per entry point, the donation policy checks, no compiles.
    smoke  the full CI gate: every jaxpr grid cell, the five compiled
           sync-aggregate HLO cells, donation probes and retrace sweeps.
    """
    if grid not in GRIDS:
        raise ValueError(f"unknown grid {grid!r}; choose from {GRIDS}")
    if grid == "tiny":
        return [
            _fullbatch_program("sage", "pallas", "ring", "int8"),
            _fullbatch_program("gat", "scatter", "local", "fp32", k=1),
            Program(
                name="minibatch/gat-pallas-fp32",
                kind="jaxpr",
                make=functools.partial(_minibatch_jaxpr, "gat", "pallas"),
                meta={"entry": "minibatch"},
                expect_scatter_free=True, codec="fp32",
            ),
        ] + _donation_programs()[1:]          # policy checks only, no probe
    return (_jaxpr_grid() + _hlo_grid() + _donation_programs()
            + _retrace_programs())


# ---------------------------------------------------------------------------
# Seeded violations (--inject-violation): prove each rule can fail
# ---------------------------------------------------------------------------


def _scatter_violation_jaxpr() -> list:
    import jax
    import jax.numpy as jnp

    def bad(h):
        return jnp.zeros((16, D)).at[jnp.arange(8)].add(h)

    return [jax.make_jaxpr(bad)(jnp.zeros((8, D)))]


def _dtype_violation_jaxpr() -> list:
    import jax
    import jax.numpy as jnp

    def bad(x):
        return x.astype(jnp.bfloat16).astype(jnp.float32)

    return [jax.make_jaxpr(bad)(jnp.zeros((8, D)))]


_BUDGET_VIOLATION_HLO = """
  %ar = f32[1024,8]{1,0} all-reduce(f32[1024,8]{1,0} %x), replica_groups={}
  %cp = f32[64]{0} collective-permute(f32[64]{0} %y), source_target_pairs={{0,1}}
"""


def _retrace_violation_sweep() -> None:
    import jax
    import jax.numpy as jnp

    step = jax.jit(lambda x: x * 2.0)
    for n in (4, 8, 16):        # shape-dependent: one compile per shape
        step(jnp.zeros((n,), jnp.float32))


def violation_program(rule: str) -> Program:
    """A program deliberately violating `rule` — the CLI's
    --inject-violation hook, proving the gate exits non-zero."""
    if rule == "no-scatter":
        return Program(
            name="injected/no-scatter", kind="jaxpr",
            make=_scatter_violation_jaxpr, expect_scatter_free=True,
            meta={"injected": True},
        )
    if rule == "dtype-policy":
        return Program(
            name="injected/dtype-policy", kind="jaxpr",
            make=_dtype_violation_jaxpr, codec="fp32",
            meta={"injected": True},
        )
    if rule == "collective-budget":
        return Program(
            name="injected/collective-budget", kind="hlo",
            make=lambda: _BUDGET_VIOLATION_HLO, devices=1,
            budget=lambda: {"all-reduce": {"count": (1, 1),
                                           "cluster_bytes": 64}},
            meta={"injected": True},
        )
    if rule == "donation":
        return Program(
            name="injected/donation", kind="donation",
            declared_donate=lambda: (0, 1), expected_donate=lambda: (),
            meta={"injected": True},
        )
    if rule == "retrace-guard":
        return Program(
            name="injected/retrace-guard", kind="retrace",
            sweep=_retrace_violation_sweep, retrace_budget=1,
            meta={"injected": True},
        )
    raise ValueError(f"no seeded violation for rule {rule!r}")
