"""Overlapped mini-batch execution: async sampling + feature prefetch
pipelined against the device step.

The paper's §5.1 phase breakdown (benchmarks/fig19_phase_times.py) shows
host-side sampling and feature loading dominating DistDGL step time — which
is why DistDGL runs its sampler processes *overlapped* with device compute.
This module is that control plane: the host work for batch t+1 runs
concurrently with the device step for batch t.

Pipeline stages, per mini-batch:

  draw      per-worker seed draw            (host, per-step RNG streams)
  sample    k workers' k-hop MFGs           (host thread pool, parallel)
  fetch     feature-store gather + stack    (host; RowStore is read-only)
  transfer  host -> device of the batch     (device_put, blocked)
  compute   the jitted train step           (device)

Two execution modes behind one `PipelineEngine.next_batch()` API:

  serial  (overlap=False)  draw..transfer inline on the caller's thread —
          the correctness oracle, and the mode whose contiguous phase
          timestamps make sample+fetch+transfer+compute == step wall.
  overlap (overlap=True)   draw..transfer on a producer thread, up to
          `prefetch_depth` batches ahead through a bounded queue, while
          the consumer runs the device step.

Determinism: batch t is a pure function of (seed, t), never of thread
schedule. One `np.random.SeedSequence(seed)` tree spawns a child per step,
which spawns one grandchild per worker; worker w's seed draw AND its
neighborhood sampling for step t both use that (t, w) generator. Overlapped
and serial modes therefore produce bitwise-identical batches — asserted in
tests/test_pipeline.py, not just documented here.

Dynamic seed re-balancing composes with prefetch with *delayed feedback*:
the share vector applied to batch t is whatever the trainer had published
when t was drawn, i.e. stale by up to `prefetch_depth` batches in overlap
mode (exactly like DistDGL's asynchronous samplers observe trainer state).
With rebalancing off (the default) the two modes are bitwise-identical.

Per-batch host phase wall times travel on `PreparedBatch`; the consumer
(minibatch.MiniBatchTrainer.train_step) combines them with its own queue
wait + compute timing into `StepMetrics`, including the overlap efficiency
(hidden host time / total host time) that fig19's overlapped-vs-serial
phase tables report.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

from repro.core.graph import Graph
from repro.core.partition_book import VertexPartitionBook
from repro.fault.inject import FaultInjector, InjectedFault, retry_call
from repro.gnn.feature_store import FeatureStore, FetchStats
from repro.gnn.sampling import SamplePlan, SampledBatch, sample_blocks
from repro.obs.trace import get_tracer

__all__ = ["BatchPreparer", "PipelineEngine", "PreparedBatch"]


@dataclasses.dataclass
class PreparedBatch:
    """One global mini-batch, host work done, resident on device."""

    index: int                     # step number this batch was drawn for
    stacked: Any                   # device tree consumed by the train step
    fetch_stats: "list[FetchStats]"  # per worker
    input_vertices: np.ndarray     # [k]
    remote_vertices: np.ndarray    # [k]
    edges: np.ndarray              # [k]
    sample_time: float             # host wall seconds (draw + sample)
    fetch_time: float              # host wall seconds (gather + stack)
    transfer_time: float           # host wall seconds (device_put, blocked)

    @property
    def host_time(self) -> float:
        return self.sample_time + self.fetch_time + self.transfer_time


class BatchPreparer:
    """Host side of the pipeline: produces `PreparedBatch` t from (seed, t).

    Owns the deterministic RNG tree and the full draw/sample/fetch/transfer
    recipe; knows nothing about threads — `prepare()` is called either
    inline (serial mode) or from the engine's producer thread (overlap
    mode), optionally fanning the per-worker sampling out on an executor.
    """

    def __init__(
        self,
        *,
        graph: Graph,
        book: VertexPartitionBook,
        store: FeatureStore,
        plan: SamplePlan,
        fanouts: "tuple[int, ...]",
        labels: np.ndarray,
        train_pools: "list[np.ndarray]",
        global_batch: int,
        tiled_layout: bool,
        seed: int = 0,
        injector: Optional[FaultInjector] = None,
        start_step: int = 0,
        retry_attempts: int = 3,
        retry_timeout: float = 5.0,
    ) -> None:
        self.graph = graph
        self.book = book
        self.store = store
        self.plan = plan
        self.fanouts = fanouts
        self.labels = labels
        self.train_pools = train_pools
        self.global_batch = global_batch
        self.tiled_layout = tiled_layout
        self.injector = injector
        self.retry_attempts = retry_attempts
        self.retry_timeout = retry_timeout
        if injector is not None and injector.k is None:
            injector.k = len(train_pools)
        self._root_ss = np.random.SeedSequence(seed)
        # Resume fast-forward: `spawn` is stateful (spawn-key counter), so
        # spawning `start_step` children at once and discarding them leaves
        # the tree exactly where a fresh preparer stands after `start_step`
        # prepare() calls — batch t is bitwise (seed, t) either way.
        if start_step > 0:
            self._root_ss.spawn(start_step)
        self._next_index = start_step
        # Force the lazily-built CSR (and degree-independent caches) now, on
        # one thread, so parallel per-worker sampling never races its
        # construction.
        graph.csr()

    # ------------------------------------------------------------------ rng
    def _step_seed_seqs(self) -> "list[np.random.SeedSequence]":
        """One independent `SeedSequence` per worker for the next step.

        `SeedSequence.spawn` is stateful (spawn-key counter), so step
        children MUST be spawned in step order — `prepare()` is the only
        caller and runs on a single control thread per engine. The worker
        grandchildren make batch t worker w a pure function of (seed, t, w),
        independent of sampling thread schedule — and a RETRIED (t, w) phase
        rebuilds its generator from the same sequence, so the retried batch
        is bitwise the first attempt.
        """
        (step_ss,) = self._root_ss.spawn(1)
        return list(step_ss.spawn(len(self.train_pools)))

    def _seed_counts(self, seed_share: Optional[np.ndarray]) -> np.ndarray:
        k = self.book.k
        shares = np.full(k, 1.0 / k) if seed_share is None else seed_share
        counts = np.maximum((shares * self.global_batch).astype(int), 1)
        return np.minimum(counts, self.plan.seeds)

    # ------------------------------------------------------------- sampling
    def _draw_and_sample(self, index: int, w: int,
                         ss: np.random.SeedSequence,
                         count: int) -> SampledBatch:
        """Worker w's draw + k-hop sampling for step `index`, one attempt.

        Everything random derives from `ss` inside this call, so the retry
        wrapper can re-invoke it after a transient fault and get the
        identical batch.
        """
        gen = np.random.default_rng(ss)
        if self.injector is not None:
            self.injector.on_sample(index, w)
        pool = self.train_pools[w]
        if pool.shape[0] == 0:
            seeds = np.zeros(0, np.int64)
        else:
            n = min(int(count), pool.shape[0])
            seeds = gen.choice(pool, size=n, replace=False).astype(np.int64)
        return sample_blocks(
            self.graph, seeds, self.fanouts, self.plan, gen,
            self.labels, owner=self.book.owner, worker=w,
            tiled_layout=self.tiled_layout,
        )

    def _sample_job(self, index: int, w: int, ss: np.random.SeedSequence,
                    count: int) -> SampledBatch:
        return retry_call(
            lambda: self._draw_and_sample(index, w, ss, count),
            phase="sample", attempts=self.retry_attempts,
            timeout=self.retry_timeout)

    # ------------------------------------------------------------- stacking
    def _gather_worker(self, index: int, w: int, ids: np.ndarray):
        if self.injector is not None:
            self.injector.on_fetch(index, w)
        return self.store.gather(w, ids)

    def _stack_batches(self, index: int, batches: "list[SampledBatch]"):
        """The feature-loading phase: every worker pulls its input vertices
        through the feature store ({shard, cache, remote} split — concurrent
        `gather` calls are safe, see the RowStore read-only contract), then
        stack into the static host-side batch layout (all numpy)."""
        xs = []
        fetch: "list[FetchStats]" = []
        for w, b in enumerate(batches):
            x = np.zeros((b.input_ids.shape[0], self.store.row_dim),
                         dtype=self.store.rows.dtype)
            valid = b.input_mask
            ids = b.input_ids[valid]
            x[valid], st = retry_call(
                lambda w=w, ids=ids: self._gather_worker(index, w, ids),
                phase="fetch", attempts=self.retry_attempts,
                timeout=self.retry_timeout)
            fetch.append(st)
            xs.append(x)
        stacked = {
            "x": np.stack(xs),
            "seed_labels": np.stack([b.seed_labels for b in batches]),
            "seed_mask": np.stack([b.seed_mask for b in batches]),
            "layers": [
                {
                    "esrc": np.stack([b.layers[li].esrc for b in batches]),
                    "edst": np.stack([b.layers[li].edst for b in batches]),
                    "emask": np.stack([b.layers[li].emask for b in batches]),
                    "deg": np.stack([b.layers[li].sampled_deg for b in batches]),
                }
                for li in range(len(self.fanouts))
            ],
        }
        if self.tiled_layout:  # only stacked/transferred when a backend reads it
            for li, lay in enumerate(stacked["layers"]):
                lay["agg_order"] = np.stack(
                    [b.layers[li].agg_order for b in batches])
                lay["agg_ldst"] = np.stack(
                    [b.layers[li].agg_ldst for b in batches])
        return stacked, fetch

    # -------------------------------------------------------------- prepare
    def prepare(
        self,
        seed_share: Optional[np.ndarray] = None,
        executor: Optional[ThreadPoolExecutor] = None,
    ) -> PreparedBatch:
        """Produce the next batch: draw + sample (parallel over workers when
        an executor is given), gather + stack, transfer. The tracer's
        `PhaseClock` keeps the phase spans contiguous (each boundary is ONE
        clock reading), so the three host times sum to the host wall and
        the recorded spans ARE the `PreparedBatch` durations."""
        index = self._next_index
        self._next_index += 1
        if self.injector is not None:
            self.injector.at_step(index)
        clock = get_tracer().phase_clock(cat="pipeline",
                                         args={"step": index})
        seqs = self._step_seed_seqs()
        counts = self._seed_counts(seed_share)
        jobs = [(index, w, ss, int(counts[w])) for w, ss in enumerate(seqs)]
        if executor is not None:
            batches = list(executor.map(
                lambda job: self._sample_job(*job), jobs))
        else:
            batches = [self._sample_job(*job) for job in jobs]
        sample_time = clock.split("pipeline.sample")
        stacked_np, fetch = self._stack_batches(index, batches)
        fetch_time = clock.split("pipeline.fetch")
        stacked = jax.device_put(stacked_np)
        stacked = jax.block_until_ready(stacked)
        transfer_time = clock.split("pipeline.transfer")
        return PreparedBatch(
            index=index,
            stacked=stacked,
            fetch_stats=fetch,
            input_vertices=np.array([b.num_input for b in batches]),
            remote_vertices=np.array([b.num_remote for b in batches]),
            edges=np.array([b.num_edges for b in batches]),
            sample_time=sample_time,
            fetch_time=fetch_time,
            transfer_time=transfer_time,
        )


class _Poison:
    """Producer -> consumer shutdown/error token."""

    def __init__(self, error: Optional[BaseException] = None) -> None:
        self.error = error


class PipelineEngine:
    """Bounded prefetch of `PreparedBatch`es against the device step.

    serial mode: `next_batch()` runs the preparer inline — no threads at
    all, so a serial trainer costs exactly what it did before the engine
    existed. overlap mode: a producer thread keeps a `prefetch_depth`-deep
    queue full (sampling fanned out on a worker thread pool), and
    `next_batch()` pops, reporting how long it had to wait — the exposed
    (un-hidden) host time of that step.
    """

    def __init__(
        self,
        preparer: BatchPreparer,
        *,
        overlap: bool = False,
        prefetch_depth: int = 2,
        sample_threads: Optional[int] = None,
    ) -> None:
        if prefetch_depth < 1:
            raise ValueError("prefetch_depth must be >= 1")
        self.preparer = preparer
        self.overlap = overlap
        self.prefetch_depth = prefetch_depth
        self._share: Optional[np.ndarray] = None
        self._share_lock = threading.Lock()
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._queue: Optional[queue.Queue] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._producer: Optional[threading.Thread] = None
        if overlap:
            k = len(preparer.train_pools)
            self._pool = ThreadPoolExecutor(
                max_workers=sample_threads or min(k, 8),
                thread_name_prefix="mb-sample",
            )
            self._queue = queue.Queue(maxsize=prefetch_depth)
            self._producer = threading.Thread(
                target=self._produce, name="mb-prefetch", daemon=True)
            self._producer.start()

    # ---------------------------------------------------------- share knob
    def set_seed_share(self, share: Optional[np.ndarray]) -> None:
        """Publish a new seed-share vector (dynamic re-balancing). Applied
        to the next batch *drawn* — in overlap mode that is up to
        `prefetch_depth` batches in the future (delayed feedback)."""
        with self._share_lock:
            self._share = None if share is None else np.asarray(share).copy()

    def _current_share(self) -> Optional[np.ndarray]:
        with self._share_lock:
            return self._share

    # ------------------------------------------------------------ producer
    def _produce(self) -> None:
        tracer = get_tracer()
        try:
            while not self._stop.is_set():
                pb = self.preparer.prepare(self._current_share(), self._pool)
                while not self._stop.is_set():
                    try:
                        self._queue.put(pb, timeout=0.05)
                        # prefetch-queue occupancy, sampled from the
                        # producer side after each successful put
                        tracer.gauge("pipeline.queue_depth",
                                     self._queue.qsize())
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # surface in the consumer, don't die mute
            self._error = e  # next_batch's liveness check reads this even
            #                  if the poison token below is never delivered
            while not self._stop.is_set():
                try:
                    self._queue.put(_Poison(e), timeout=0.05)
                    break
                except queue.Full:
                    continue

    # ------------------------------------------------------------ consumer
    def next_batch(self) -> "tuple[PreparedBatch, float]":
        """Return (batch, queue_wait_seconds). Serial mode prepares inline
        and reports the full host time as the wait (nothing is hidden)."""
        if self._stop.is_set():  # same lifecycle semantics in both modes
            raise RuntimeError("pipeline engine is closed")
        if not self.overlap:
            pb = self.preparer.prepare(self._current_share(), None)
            return pb, pb.host_time
        t0 = time.perf_counter()
        while True:
            if self._stop.is_set():
                raise RuntimeError("pipeline engine is closed")
            try:
                item = self._queue.get(timeout=0.1)
                break
            except queue.Empty:
                # never block forever on a producer that can no longer put
                if self._producer is not None and not self._producer.is_alive():
                    err = self._error
                    self.close()
                    raise RuntimeError("pipeline producer died") from err
        t1 = time.perf_counter()
        wait = t1 - t0
        tracer = get_tracer()
        if tracer.enabled:
            tracer.record_span("pipeline.queue_wait", t0, t1, cat="pipeline")
            tracer.gauge("pipeline.queue_depth", self._queue.qsize())
        if isinstance(item, _Poison):
            self.close()
            if isinstance(item.error, InjectedFault):
                # injected faults keep their identity across the producer
                # boundary so the consumer's recovery (crash -> resume) sees
                # the same exception type serial mode raises inline
                raise item.error
            if item.error is not None:
                raise RuntimeError("pipeline producer failed") from item.error
            raise RuntimeError("pipeline closed")
        return item, wait

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Stop the producer and release its threads (idempotent)."""
        self._stop.set()
        if self._queue is not None:
            while True:  # unblock a producer stuck on a full queue
                try:
                    self._queue.get_nowait()
                except queue.Empty:
                    break
        if self._producer is not None and self._producer.is_alive():
            self._producer.join(timeout=5.0)
        if self._pool is not None:
            self._pool.shutdown(wait=False)

    def __enter__(self) -> "PipelineEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
