"""DistDGL-style mini-batch distributed training (vertex partitioning).

Every worker owns one vertex partition (graph + features + its training
vertices). A training step is the paper's five phases (§5.1):

  1. mini-batch sampling   (host, per worker; k-hop fanout sampler)
  2. feature loading       (fetch features of input vertices; *remote*
                            vertices — owned by another worker — cross the
                            network: the paper's key DistDGL metric)
  3. forward pass          (device, data-parallel across workers)
  4. backward pass         (device; gradient all-reduce folded in)
  5. model update          (device)

Feature loading (phase 2) is routed through `gnn.feature_store.FeatureStore`:
each worker serves its own shard locally and holds a bounded static cache of
hot remote vertices (``cache_policy`` in {none, random, degree, halo},
``cache_budget`` vertices per worker — see feature_store.py). Per-step
`StepMetrics` therefore splits the paper's `remote_vertices` into
`cache_hits` (served locally from the cache) and `remote_misses` (the only
vertices whose feature bytes cross the network, `miss_bytes`). The cost
model prices the fetch phase from misses; sampling still pays remote
adjacency costs for ALL remote vertices because the cache holds features,
not adjacency.

Aggregation backend (`GNNSpec.agg_backend` in {scatter, tiled, pallas}): the
forward pass aggregates each MFG layer through `kernels.ops.aggregate` —
sums and GAT's stabilisation max alike. For the tiled/pallas backends the
host sampler attaches a per-layer tiled edge layout
(`SampledLayer.agg_order`/`agg_ldst`, sized by the static pad plan via
`LayerPad.tiled_plan`) so the device step — compiled once — runs the
pre-sorted segment-reduce instead of a data-dependent scatter; the sum's
backward is a plain gather (custom_vjp in ops.py), so gradients match the
scatter oracle, and the max is stop_gradient'd (exact by shift-invariance).

On this container the k workers are simulated with `jax.vmap(axis_name=...)`
over stacked per-worker batches — identical collective semantics to the
multi-worker `shard_map` deployment. Per-phase times for the paper's cluster
are produced by core/cost_model.py from the *measured* per-worker batch
metrics (input vertices, remote vertices, edges, flops), so the speedup
tables derive from real sampled data, not synthetic assumptions.

Straggler mitigation (beyond-paper, addresses the paper's §5.2(2) imbalance
finding): optional dynamic seed re-balancing shifts seeds from workers whose
sampled computation graphs run persistently large to underloaded ones.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.partition_book import VertexPartitionBook, build_vertex_book
from repro.gnn.feature_store import FeatureStore, FetchStats
from repro.kernels import ops
from repro.gnn.models import GNNSpec, init_params
from repro.gnn.sampling import (
    PAPER_FANOUTS,
    SamplePlan,
    SampledBatch,
    sample_blocks,
)

AXIS = "workers"


# ---------------------------------------------------------------------------
# Device-side mini-batch model (directed MFG layers + self connection).
# `lay` = dict(esrc, edst, emask, deg, agg_order, agg_ldst); n_dst is static
# (from the pad plan). Aggregation targets are sized n_dst+1; index n_dst is
# the padding sink. Every edge aggregation — the sums AND GAT's softmax
# stabilisation max — goes through `ops.aggregate` (`backend` in {scatter,
# tiled, pallas}); the tiled layout is per-layer, per-batch, shaped by the
# static pad plan (LayerPad.tiled_plan), so the device step still compiles
# once, and the GAT layer stack runs scatter-free under the tiled/pallas
# backends (the stabilisation max is stop_gradient'd — exact, softmax is
# shift-invariant).
# ---------------------------------------------------------------------------


def _mb_aggregate(messages, lay, n_dst: int, backend: str,
                  reduce: str = "sum"):
    """Reduce per-edge messages into the [n_dst+1, d] destination rows."""
    return ops.aggregate(
        messages, lay["edst"], n_dst + 1,
        edge_order=lay.get("agg_order"), local_dst=lay.get("agg_ldst"),
        backend=backend, reduce=reduce,
    )


def _mb_sage_layer(p, h_src, lay, n_dst: int, *, final: bool,
                   backend: str = "scatter"):
    msg = h_src[lay["esrc"]] * lay["emask"][:, None]
    agg = _mb_aggregate(msg, lay, n_dst, backend)
    mean = agg[:-1] / jnp.maximum(lay["deg"][:-1], 1.0)[:, None]
    h_self = h_src[:n_dst]
    out = h_self @ p["w_self"] + mean @ p["w_neigh"] + p["b"]
    return out if final else jax.nn.relu(out)


def _mb_gcn_layer(p, h_src, lay, n_dst: int, *, final: bool,
                  backend: str = "scatter"):
    deg_dst = lay["deg"][:-1] + 1.0
    msg = h_src[lay["esrc"]] * lay["emask"][:, None]
    agg = _mb_aggregate(msg, lay, n_dst, backend)
    h = (agg[:-1] + h_src[:n_dst]) / deg_dst[:, None]
    out = h @ p["w"] + p["b"]
    return out if final else jax.nn.relu(out)


def _mb_gat_layer(p, h_src, lay, n_dst: int, *, final: bool,
                  backend: str = "scatter"):
    heads, dh = p["a_src"].shape
    z = (h_src @ p["w"]).reshape(h_src.shape[0], heads, dh)
    s_src = jnp.einsum("nhd,hd->nh", z, p["a_src"])
    s_dst = jnp.einsum("nhd,hd->nh", z[:n_dst], p["a_dst"])
    s_dst_pad = jnp.pad(s_dst, ((0, 1), (0, 0)))
    e = jax.nn.leaky_relu(s_src[lay["esrc"]] + s_dst_pad[lay["edst"]], 0.2)
    e = jnp.where(lay["emask"][:, None], e, -1e30)
    e_self = jax.nn.leaky_relu(s_src[:n_dst] + s_dst, 0.2)

    # softmax stabilisation max through the same tiled segment-reduce as the
    # sums; stop_gradient is exact (softmax is shift-invariant) and keeps
    # the backward scatter-free (see ops.aggregate)
    m = _mb_aggregate(e, lay, n_dst, backend, reduce="max")
    m = jax.lax.stop_gradient(jnp.maximum(m[:-1], e_self))
    m_pad = jnp.pad(m, ((0, 1), (0, 0)))
    w = jnp.exp(e - m_pad[lay["edst"]]) * lay["emask"][:, None]
    w_self = jnp.exp(e_self - m)
    den = _mb_aggregate(w, lay, n_dst, backend)
    den = den[:-1] + w_self
    num = _mb_aggregate(
        (w[:, :, None] * z[lay["esrc"]]).reshape(-1, heads * dh),
        lay, n_dst, backend,
    ).reshape(n_dst + 1, heads, dh)
    num = num[:-1] + w_self[:, :, None] * z[:n_dst]
    out = (num / jnp.maximum(den, 1e-16)[:, :, None]).reshape(n_dst, heads * dh)
    out = (out + p["b"]) @ p["w_out"]
    return out if final else jax.nn.elu(out)


_MB_LAYERS = {"sage": _mb_sage_layer, "gcn": _mb_gcn_layer, "gat": _mb_gat_layer}


def mfg_forward(spec: GNNSpec, layer_params: Sequence, batch,
                layer_sizes: Sequence[int]) -> jnp.ndarray:
    """Forward one padded MFG stack through `layer_params`.

    `layer_params` may be a SUFFIX of the model's layers — the serving
    engine (repro.serve) recomputes only the last `hops` layers on top of
    stored layer-wise embeddings, so `batch["x"]` is then embedding rows,
    not feature rows. The stack always ends at the model's true final layer,
    so the final (no-activation) flag is simply the last entry.
    """
    h = batch["x"]
    layer_fn = _MB_LAYERS[spec.model]
    L = len(layer_params)
    for li, p in enumerate(layer_params):
        h = layer_fn(p, h, batch["layers"][li], layer_sizes[li],
                     final=(li == L - 1), backend=spec.agg_backend)
    return h


def minibatch_loss(spec: GNNSpec, params, batch, layer_sizes: Sequence[int],
                   axis: Optional[str] = AXIS) -> jnp.ndarray:
    """Per-worker loss on one padded MFG stack (psum-averaged over workers)."""
    h = mfg_forward(spec, params["layers"], batch, layer_sizes)
    logits = h[: batch["seed_labels"].shape[0]]
    logp = jax.nn.log_softmax(logits, axis=-1)
    labels = jnp.maximum(batch["seed_labels"], 0)
    picked = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    w = (batch["seed_mask"] & (batch["seed_labels"] >= 0)).astype(jnp.float32)
    local = jnp.stack([-(picked * w).sum(), w.sum()])
    tot = jax.lax.psum(local, axis) if axis else local
    return tot[0] / jnp.maximum(tot[1], 1.0)


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepMetrics:
    loss: float
    input_vertices: np.ndarray   # [k]
    remote_vertices: np.ndarray  # [k]
    edges: np.ndarray            # [k]
    sample_time_host: float      # seconds, wall (whole step, all workers)
    compute_time_host: float
    # feature-store phase accounting: remote = cache_hits + remote_misses
    cache_hits: np.ndarray = None      # [k]
    remote_misses: np.ndarray = None   # [k]
    miss_bytes: np.ndarray = None      # [k] feature bytes crossing the net

    @property
    def hit_rate(self) -> float:
        """Cache hits / remote feature requests, whole step.

        1.0 when the step needed no remote vertices (nothing to miss);
        0.0 when hit accounting is absent (`cache_hits=None`, i.e. no
        feature store was consulted) but remote vertices exist."""
        remote = float(self.remote_vertices.sum())
        if not remote:
            return 1.0
        if self.cache_hits is None:
            return 0.0
        return float(self.cache_hits.sum()) / remote


@dataclasses.dataclass
class MiniBatchTrainer:
    graph: Graph
    book: VertexPartitionBook
    spec: GNNSpec
    features: np.ndarray
    labels: np.ndarray
    train_vertices_per_worker: list
    fanouts: tuple
    plan: SamplePlan
    global_batch: int
    params: Any = None
    opt_state: Any = None
    rng: Optional[np.random.Generator] = None
    lr: float = 1e-3
    rebalance: bool = False
    store: Optional[FeatureStore] = None
    _load_ema: Optional[np.ndarray] = None
    _seed_share: Optional[np.ndarray] = None

    @classmethod
    def build(
        cls,
        graph: Graph,
        vertex_assignment: np.ndarray,
        k: int,
        spec: GNNSpec,
        features: np.ndarray,
        labels: np.ndarray,
        train_mask: np.ndarray,
        *,
        global_batch: int = 1024,
        fanouts: Optional[Sequence[int]] = None,
        seed: int = 0,
        lr: float = 1e-3,
        rebalance: bool = False,
        cache_policy: str = "none",
        cache_budget: int = 0,
    ) -> "MiniBatchTrainer":
        from repro.optim import adam_init

        book = build_vertex_book(graph, vertex_assignment, k)
        fanouts = tuple(fanouts or PAPER_FANOUTS[spec.num_layers])
        train_ids = np.where(train_mask)[0]
        per_worker = [train_ids[book.owner[train_ids] == w] for w in range(k)]
        seeds_per_worker = max(global_batch // k, 1)
        plan = SamplePlan.build(seeds_per_worker, fanouts)
        params = init_params(spec, seed=seed)
        features = features.astype(np.float32)
        store = FeatureStore.build(
            graph, book, policy=cache_policy, budget=cache_budget,
            features=features, seed=seed,
        )
        return cls(
            graph=graph, book=book, spec=spec,
            features=features, labels=labels.astype(np.int32),
            train_vertices_per_worker=per_worker, fanouts=fanouts, plan=plan,
            global_batch=global_batch, params=params,
            opt_state=adam_init(params), rng=np.random.default_rng(seed),
            lr=lr, rebalance=rebalance, store=store,
            _load_ema=np.ones(k), _seed_share=np.full(k, 1.0 / k),
        )

    # ------------------------------------------------------------- sampling
    def _draw_seeds(self) -> list:
        k = self.book.k
        shares = self._seed_share if self.rebalance else np.full(k, 1.0 / k)
        counts = np.maximum((shares * self.global_batch).astype(int), 1)
        counts = np.minimum(counts, self.plan.seeds)
        out = []
        for w in range(k):
            pool = self.train_vertices_per_worker[w]
            if pool.shape[0] == 0:
                out.append(np.zeros(0, np.int64))
                continue
            n = min(int(counts[w]), pool.shape[0])
            out.append(self.rng.choice(pool, size=n, replace=False).astype(np.int64))
        return out

    def _stack_batches(self, batches: list):
        """Host: the 'feature loading' phase — every worker pulls its input
        vertices through the feature store ({shard, cache, remote} split) —
        then stack. Returns (stacked, per-worker FetchStats)."""
        xs = []
        fetch: list[FetchStats] = []
        for w, b in enumerate(batches):
            x = np.zeros((b.input_ids.shape[0], self.features.shape[1]),
                         dtype=self.features.dtype)
            valid = b.input_mask
            x[valid], st = self.store.gather(w, b.input_ids[valid])
            fetch.append(st)
            xs.append(x)
        stacked = {
            "x": jnp.asarray(np.stack(xs)),
            "seed_labels": jnp.asarray(np.stack([b.seed_labels for b in batches])),
            "seed_mask": jnp.asarray(np.stack([b.seed_mask for b in batches])),
            "layers": [
                {
                    "esrc": jnp.asarray(np.stack([b.layers[li].esrc for b in batches])),
                    "edst": jnp.asarray(np.stack([b.layers[li].edst for b in batches])),
                    "emask": jnp.asarray(np.stack([b.layers[li].emask for b in batches])),
                    "deg": jnp.asarray(np.stack([b.layers[li].sampled_deg for b in batches])),
                }
                for li in range(len(self.fanouts))
            ],
        }
        if self._tiled_layout:  # only stacked/transferred when a backend reads it
            for li, lay in enumerate(stacked["layers"]):
                lay["agg_order"] = jnp.asarray(
                    np.stack([b.layers[li].agg_order for b in batches]))
                lay["agg_ldst"] = jnp.asarray(
                    np.stack([b.layers[li].agg_ldst for b in batches]))
        return stacked, fetch

    @property
    def _tiled_layout(self) -> bool:
        return self.spec.agg_backend != "scatter"

    @property
    def _layer_sizes(self) -> list:
        return [p.n_dst for p in self.plan.layers]

    # ------------------------------------------------------------------ step
    @functools.cached_property
    def _train_step(self):
        from repro.optim import adam_update

        spec = self.spec
        lr = self.lr
        sizes = tuple(self._layer_sizes)

        def loss_of(params, stacked):
            losses = jax.vmap(
                lambda batch: minibatch_loss(spec, params, batch, sizes),
                axis_name=AXIS,
            )(stacked)
            return jnp.mean(losses)

        def step(params, opt_state, stacked):
            loss, grads = jax.value_and_grad(loss_of)(params, stacked)
            new_p, new_s = adam_update(grads, opt_state, params, lr=lr)
            return loss, new_p, new_s

        return jax.jit(step)

    def train_step(self) -> StepMetrics:
        t0 = time.perf_counter()
        seeds = self._draw_seeds()
        batches = [
            sample_blocks(
                self.graph, s, self.fanouts, self.plan, self.rng,
                self.labels, owner=self.book.owner, worker=w,
                tiled_layout=self._tiled_layout,
            )
            for w, s in enumerate(seeds)
        ]
        t1 = time.perf_counter()
        stacked, fetch = self._stack_batches(batches)
        loss, self.params, self.opt_state = self._train_step(
            self.params, self.opt_state, stacked
        )
        loss = float(loss)
        t2 = time.perf_counter()

        inputs = np.array([b.num_input for b in batches])
        if self.rebalance:
            self._load_ema = 0.7 * self._load_ema + 0.3 * np.maximum(inputs, 1)
            inv = 1.0 / self._load_ema
            self._seed_share = inv / inv.sum()

        return StepMetrics(
            loss=loss,
            input_vertices=inputs,
            remote_vertices=np.array([b.num_remote for b in batches]),
            edges=np.array([b.num_edges for b in batches]),
            sample_time_host=t1 - t0,
            compute_time_host=t2 - t1,
            cache_hits=np.array([s.num_cache_hit for s in fetch]),
            remote_misses=np.array([s.num_remote_miss for s in fetch]),
            miss_bytes=np.array([s.miss_bytes for s in fetch]),
        )
