"""DistDGL-style mini-batch distributed training (vertex partitioning).

Every worker owns one vertex partition (graph + features + its training
vertices). A training step is the paper's five phases (§5.1):

  1. mini-batch sampling   (host, per worker; k-hop fanout sampler)
  2. feature loading       (fetch features of input vertices; *remote*
                            vertices — owned by another worker — cross the
                            network: the paper's key DistDGL metric)
  3. forward pass          (device, data-parallel across workers)
  4. backward pass         (device; gradient all-reduce folded in)
  5. model update          (device)

Phases 1-2 are host work, phases 3-5 one jitted device step — and like
DistDGL's sampler processes they need not run back-to-back: stepping is
delegated to `gnn.pipeline.PipelineEngine` (`overlap`/`prefetch_depth`
knobs on `build`, `--overlap/--prefetch-depth` on launch/gnn_train.py).
Serial mode (`overlap=False`, the default) runs phases 1-2 inline before
every device step — the correctness oracle, with contiguous per-phase
timestamps. Overlap mode prepares batches up to `prefetch_depth` ahead on
a producer thread (per-worker sampling fanned out on a thread pool) while
the device executes the current step; per-worker RNG streams
(`SeedSequence.spawn` per (step, worker)) make both modes produce
bitwise-identical batches from the same seed. `StepMetrics` carries true
wall times for all four host/device phases (sample / fetch / transfer /
compute) plus the step wall and the overlap efficiency (hidden host time
/ total host time), feeding the fig19 phase tables in either mode. The
device step donates params/opt_state buffers (in-place update) on
accelerator backends.

Feature loading (phase 2) is routed through `gnn.feature_store.FeatureStore`:
each worker serves its own shard locally and holds a bounded static cache of
hot remote vertices (``cache_policy`` in {none, random, degree, halo},
``cache_budget`` vertices per worker — see feature_store.py). Per-step
`StepMetrics` therefore splits the paper's `remote_vertices` into
`cache_hits` (served locally from the cache) and `remote_misses` (the only
vertices whose feature bytes cross the network, `miss_bytes`). The cost
model prices the fetch phase from misses; sampling still pays remote
adjacency costs for ALL remote vertices because the cache holds features,
not adjacency.

Aggregation backend (`GNNSpec.agg_backend` in {scatter, tiled, pallas}): the
forward pass aggregates each MFG layer through `kernels.ops.aggregate` —
sums and GAT's stabilisation max alike. For the tiled/pallas backends the
host sampler attaches a per-layer tiled edge layout
(`SampledLayer.agg_order`/`agg_ldst`, sized by the static pad plan via
`LayerPad.tiled_plan`) so the device step — compiled once — runs the
pre-sorted segment-reduce instead of a data-dependent scatter; the sum's
backward is a plain gather (custom_vjp in ops.py), so gradients match the
scatter oracle, and the max is stop_gradient'd (exact by shift-invariance).

On this container the k workers are simulated with `jax.vmap(axis_name=...)`
over stacked per-worker batches — identical collective semantics to the
multi-worker `shard_map` deployment. Per-phase times for the paper's cluster
are produced by core/cost_model.py from the *measured* per-worker batch
metrics (input vertices, remote vertices, edges, flops), so the speedup
tables derive from real sampled data, not synthetic assumptions.

Straggler mitigation (beyond-paper, addresses the paper's §5.2(2) imbalance
finding): optional dynamic seed re-balancing shifts seeds from workers whose
sampled computation graphs run persistently large to underloaded ones.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.partition_book import VertexPartitionBook, build_vertex_book
from repro.core.wire import as_codec, codec_grad_reduce
from repro.gnn.feature_store import FeatureStore
from repro.gnn.pipeline import BatchPreparer, PipelineEngine
from repro.kernels import ops
from repro.obs.trace import get_tracer
from repro.gnn.models import GNNSpec, init_params
from repro.gnn.sampling import PAPER_FANOUTS, SamplePlan

AXIS = "workers"


def step_donate_argnums(lossless: bool) -> tuple:
    """Donated argnums the jitted mini-batch train step declares.

    Lossless: params + opt_state (args 0, 1) — the in-place update the
    module docstring describes. Lossy: opt_state + the EF carry (args 1, 3
    of `step(params, opt_state, stacked, ef)`). XLA:CPU cannot alias
    donated buffers (warns per compile), so donation only engages off-CPU
    — the documented whitelist in the analysis donation rule.
    """
    if jax.default_backend() == "cpu":
        return ()
    return (0, 1) if lossless else (1, 3)


# ---------------------------------------------------------------------------
# Device-side mini-batch model (directed MFG layers + self connection).
# `lay` = dict(esrc, edst, emask, deg, agg_order, agg_ldst); n_dst is static
# (from the pad plan). Aggregation targets are sized n_dst+1; index n_dst is
# the padding sink. Every edge aggregation — the sums AND GAT's softmax
# stabilisation max — goes through `ops.aggregate` (`backend` in {scatter,
# tiled, pallas}); the tiled layout is per-layer, per-batch, shaped by the
# static pad plan (LayerPad.tiled_plan), so the device step still compiles
# once, and the GAT layer stack runs scatter-free under the tiled/pallas
# backends (the stabilisation max is stop_gradient'd — exact, softmax is
# shift-invariant).
# ---------------------------------------------------------------------------


def _mb_aggregate(messages, lay, n_dst: int, backend: str,
                  reduce: str = "sum"):
    """Reduce per-edge messages into the [n_dst+1, d] destination rows."""
    return ops.aggregate(
        messages, lay["edst"], n_dst + 1,
        edge_order=lay.get("agg_order"), local_dst=lay.get("agg_ldst"),
        backend=backend, reduce=reduce,
    )


def _mb_sage_layer(p, h_src, lay, n_dst: int, *, final: bool,
                   backend: str = "scatter"):
    msg = h_src[lay["esrc"]] * lay["emask"][:, None]
    agg = _mb_aggregate(msg, lay, n_dst, backend)
    mean = agg[:-1] / jnp.maximum(lay["deg"][:-1], 1.0)[:, None]
    h_self = h_src[:n_dst]
    out = h_self @ p["w_self"] + mean @ p["w_neigh"] + p["b"]
    return out if final else jax.nn.relu(out)


def _mb_gcn_layer(p, h_src, lay, n_dst: int, *, final: bool,
                  backend: str = "scatter"):
    deg_dst = lay["deg"][:-1] + 1.0
    msg = h_src[lay["esrc"]] * lay["emask"][:, None]
    agg = _mb_aggregate(msg, lay, n_dst, backend)
    h = (agg[:-1] + h_src[:n_dst]) / deg_dst[:, None]
    out = h @ p["w"] + p["b"]
    return out if final else jax.nn.relu(out)


def _mb_gat_layer(p, h_src, lay, n_dst: int, *, final: bool,
                  backend: str = "scatter"):
    heads, dh = p["a_src"].shape
    z = (h_src @ p["w"]).reshape(h_src.shape[0], heads, dh)
    s_src = jnp.einsum("nhd,hd->nh", z, p["a_src"])
    s_dst = jnp.einsum("nhd,hd->nh", z[:n_dst], p["a_dst"])
    s_dst_pad = jnp.pad(s_dst, ((0, 1), (0, 0)))
    e = jax.nn.leaky_relu(s_src[lay["esrc"]] + s_dst_pad[lay["edst"]], 0.2)
    e = jnp.where(lay["emask"][:, None], e, -1e30)
    e_self = jax.nn.leaky_relu(s_src[:n_dst] + s_dst, 0.2)

    # softmax stabilisation max through the same tiled segment-reduce as the
    # sums; stop_gradient is exact (softmax is shift-invariant) and keeps
    # the backward scatter-free (see ops.aggregate)
    m = _mb_aggregate(e, lay, n_dst, backend, reduce="max")
    m = jax.lax.stop_gradient(jnp.maximum(m[:-1], e_self))
    m_pad = jnp.pad(m, ((0, 1), (0, 0)))
    w = jnp.exp(e - m_pad[lay["edst"]]) * lay["emask"][:, None]
    w_self = jnp.exp(e_self - m)
    den = _mb_aggregate(w, lay, n_dst, backend)
    den = den[:-1] + w_self
    num = _mb_aggregate(
        (w[:, :, None] * z[lay["esrc"]]).reshape(-1, heads * dh),
        lay, n_dst, backend,
    ).reshape(n_dst + 1, heads, dh)
    num = num[:-1] + w_self[:, :, None] * z[:n_dst]
    out = (num / jnp.maximum(den, 1e-16)[:, :, None]).reshape(n_dst, heads * dh)
    out = (out + p["b"]) @ p["w_out"]
    return out if final else jax.nn.elu(out)


_MB_LAYERS = {"sage": _mb_sage_layer, "gcn": _mb_gcn_layer, "gat": _mb_gat_layer}


def mfg_forward(spec: GNNSpec, layer_params: Sequence, batch,
                layer_sizes: Sequence[int]) -> jnp.ndarray:
    """Forward one padded MFG stack through `layer_params`.

    `layer_params` may be a SUFFIX of the model's layers — the serving
    engine (repro.serve) recomputes only the last `hops` layers on top of
    stored layer-wise embeddings, so `batch["x"]` is then embedding rows,
    not feature rows. The stack always ends at the model's true final layer,
    so the final (no-activation) flag is simply the last entry.
    """
    h = batch["x"]
    layer_fn = _MB_LAYERS[spec.model]
    L = len(layer_params)
    for li, p in enumerate(layer_params):
        h = layer_fn(p, h, batch["layers"][li], layer_sizes[li],
                     final=(li == L - 1), backend=spec.agg_backend)
    return h


def minibatch_loss(spec: GNNSpec, params, batch, layer_sizes: Sequence[int],
                   axis: Optional[str] = AXIS) -> jnp.ndarray:
    """Per-worker loss on one padded MFG stack (psum-averaged over workers)."""
    h = mfg_forward(spec, params["layers"], batch, layer_sizes)
    logits = h[: batch["seed_labels"].shape[0]]
    logp = jax.nn.log_softmax(logits, axis=-1)
    labels = jnp.maximum(batch["seed_labels"], 0)
    picked = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    w = (batch["seed_mask"] & (batch["seed_labels"] >= 0)).astype(jnp.float32)
    local = jnp.stack([-(picked * w).sum(), w.sum()])
    tot = jax.lax.psum(local, axis) if axis else local
    return tot[0] / jnp.maximum(tot[1], 1.0)


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepMetrics:
    loss: float
    input_vertices: np.ndarray   # [k]
    remote_vertices: np.ndarray  # [k]
    edges: np.ndarray            # [k]
    sample_time_host: float      # seconds, wall (whole step, all workers)
    compute_time_host: float     # device step (serial: absorbs step overhead
    #                              so the four phases sum to step_wall_host)
    # feature-store phase accounting: remote = cache_hits + remote_misses
    cache_hits: np.ndarray = None      # [k]
    remote_misses: np.ndarray = None   # [k]
    miss_bytes: np.ndarray = None      # [k] logical (f32) miss bytes
    wire_bytes: np.ndarray = None      # [k] codec-encoded miss bytes
    # pipeline phase accounting (gnn/pipeline.py): host wall per phase, the
    # consumer-side step wall, and how much host time the prefetch hid
    fetch_time_host: float = 0.0       # feature gather + stack
    transfer_time_host: float = 0.0    # host -> device
    step_wall_host: float = 0.0        # next_batch + device step, consumer
    queue_wait_host: float = 0.0       # exposed (un-hidden) host time
    overlap: bool = False

    @property
    def host_time(self) -> float:
        """Host prep wall for this batch (sample + fetch + transfer)."""
        return self.sample_time_host + self.fetch_time_host + self.transfer_time_host

    @property
    def overlap_efficiency(self) -> float:
        """Hidden host time / total host time for this step.

        0.0 in serial mode (every host second is exposed before the device
        step); -> 1.0 in overlap steady state when the queue always has a
        batch ready; 1.0 when there was no host work at all."""
        host = self.host_time
        if host <= 0.0:
            return 1.0
        return max(host - self.queue_wait_host, 0.0) / host

    @property
    def hit_rate(self) -> float:
        """Cache hits / remote feature requests, whole step.

        1.0 when the step needed no remote vertices (nothing to miss);
        0.0 when hit accounting is absent (`cache_hits=None`, i.e. no
        feature store was consulted) but remote vertices exist."""
        remote = float(self.remote_vertices.sum())
        if not remote:
            return 1.0
        if self.cache_hits is None:
            return 0.0
        return float(self.cache_hits.sum()) / remote


@dataclasses.dataclass
class MiniBatchTrainer:
    graph: Graph
    book: VertexPartitionBook
    spec: GNNSpec
    features: np.ndarray
    labels: np.ndarray
    train_vertices_per_worker: list
    fanouts: tuple
    plan: SamplePlan
    global_batch: int
    params: Any = None
    opt_state: Any = None
    seed: int = 0
    lr: float = 1e-3
    rebalance: bool = False
    store: Optional[FeatureStore] = None
    overlap: bool = False
    prefetch_depth: int = 2
    codec: Any = None                  # wire codec name/instance (None=fp32)
    ef_state: Any = None               # error-feedback carry (lossy codecs)
    start_step: int = 0                # resume: first global step to draw
    injector: Any = None               # fault.FaultInjector (None = no faults)
    _load_ema: Optional[np.ndarray] = None
    _seed_share: Optional[np.ndarray] = None

    @classmethod
    def build(
        cls,
        graph: Graph,
        vertex_assignment: np.ndarray,
        k: int,
        spec: GNNSpec,
        features: np.ndarray,
        labels: np.ndarray,
        train_mask: np.ndarray,
        *,
        global_batch: int = 1024,
        fanouts: Optional[Sequence[int]] = None,
        seed: int = 0,
        lr: float = 1e-3,
        rebalance: bool = False,
        cache_policy: str = "none",
        cache_budget: int = 0,
        overlap: bool = False,
        prefetch_depth: int = 2,
        codec=None,
        start_step: int = 0,
        injector=None,
    ) -> "MiniBatchTrainer":
        from repro.optim import adam_init

        book = build_vertex_book(graph, vertex_assignment, k)
        fanouts = tuple(fanouts or PAPER_FANOUTS[spec.num_layers])
        train_ids = np.where(train_mask)[0]
        per_worker = [train_ids[book.owner[train_ids] == w] for w in range(k)]
        seeds_per_worker = max(global_batch // k, 1)
        plan = SamplePlan.build(seeds_per_worker, fanouts)
        params = init_params(spec, seed=seed)
        features = features.astype(np.float32)
        store = FeatureStore.build(
            graph, book, policy=cache_policy, budget=cache_budget,
            features=features, seed=seed, codec=codec,
        )
        return cls(
            graph=graph, book=book, spec=spec,
            features=features, labels=labels.astype(np.int32),
            train_vertices_per_worker=per_worker, fanouts=fanouts, plan=plan,
            global_batch=global_batch, params=params,
            opt_state=adam_init(params), seed=seed,
            lr=lr, rebalance=rebalance, store=store,
            overlap=overlap, prefetch_depth=prefetch_depth, codec=codec,
            start_step=start_step, injector=injector,
            _load_ema=np.ones(k), _seed_share=np.full(k, 1.0 / k),
        )

    # ------------------------------------------------------------- pipeline
    @functools.cached_property
    def engine(self) -> PipelineEngine:
        """The step execution engine (gnn/pipeline.py). Serial mode costs no
        threads; overlap mode starts the producer on first use."""
        preparer = BatchPreparer(
            graph=self.graph, book=self.book, store=self.store,
            plan=self.plan, fanouts=self.fanouts, labels=self.labels,
            train_pools=self.train_vertices_per_worker,
            global_batch=self.global_batch, tiled_layout=self._tiled_layout,
            seed=self.seed, injector=self.injector,
            start_step=self.start_step,
        )
        engine = PipelineEngine(
            preparer, overlap=self.overlap, prefetch_depth=self.prefetch_depth)
        if self.rebalance:
            engine.set_seed_share(self._seed_share)
        return engine

    def close(self) -> None:
        """Release the engine's producer/sampler threads (overlap mode)."""
        if "engine" in self.__dict__:
            self.engine.close()

    @property
    def _tiled_layout(self) -> bool:
        return self.spec.agg_backend != "scatter"

    @property
    def _layer_sizes(self) -> list:
        return [p.n_dst for p in self.plan.layers]

    # ------------------------------------------------------------------ step
    @functools.cached_property
    def _train_step(self):
        from repro.optim import adam_update

        spec = self.spec
        lr = self.lr
        sizes = tuple(self._layer_sizes)
        codec = as_codec(self.codec)

        # donate params/opt_state so the device step updates them in place —
        # the trainer never reads the old buffers again (declaration +
        # CPU whitelist live in step_donate_argnums).
        if codec.lossless:
            # historical step graph, untouched (bitwise-identical default)
            def loss_of(params, stacked):
                losses = jax.vmap(
                    lambda batch: minibatch_loss(spec, params, batch, sizes),
                    axis_name=AXIS,
                )(stacked)
                return jnp.mean(losses)

            def step(params, opt_state, stacked):
                loss, grads = jax.value_and_grad(loss_of)(params, stacked)
                new_p, new_s = adam_update(grads, opt_state, params, lr=lr)
                return loss, new_p, new_s

            return jax.jit(step, donate_argnums=step_donate_argnums(True))

        # lossy codec: per-worker grads completed by the error-feedback
        # compressed pmean; the EF residual rides along as a [k, ...] carry
        def per_worker(params, batch, ef):
            loss, grads = jax.value_and_grad(
                lambda p: minibatch_loss(spec, p, batch, sizes))(params)
            mean_grads, new_ef = codec_grad_reduce(codec, grads, ef, AXIS)
            return loss, mean_grads, new_ef

        def step(params, opt_state, stacked, ef):
            losses, grads, new_ef = jax.vmap(
                per_worker, in_axes=(None, 0, 0), axis_name=AXIS,
            )(params, stacked, ef)
            grads = jax.tree.map(lambda g: g[0], grads)  # replica-consistent
            new_p, new_s = adam_update(grads, opt_state, params, lr=lr)
            return jnp.mean(losses), new_p, new_s, new_ef

        return jax.jit(step, donate_argnums=step_donate_argnums(False))

    def _init_ef(self):
        """Per-worker zero EF residuals, stacked [k, ...]."""
        return jax.tree.map(
            lambda p: jnp.zeros((self.book.k,) + p.shape, jnp.float32),
            self.params)

    def set_epoch(self, epoch: int) -> None:
        """Advance epoch-scheduled codecs (VariableRatioCodec) on the
        gradient all-reduce. The feature-store codec is frozen at build time
        — features are layer-0 data, so the schedule's layer-0 tier applies
        to them throughout. Re-jits the step only when the schedule actually
        changes tier."""
        codec = as_codec(self.codec)
        advance = getattr(codec, "at_epoch", None)
        if advance is None:
            return
        new = advance(epoch)
        if (new.ratio(0), new.ratio(1)) != (codec.ratio(0), codec.ratio(1)):
            self.codec = new
            self.__dict__.pop("_train_step", None)
        else:
            self.codec = new

    def train_step(self) -> StepMetrics:
        t0 = time.perf_counter()
        pb, wait = self.engine.next_batch()
        t1 = time.perf_counter()
        if as_codec(self.codec).lossless:
            loss, self.params, self.opt_state = self._train_step(
                self.params, self.opt_state, pb.stacked
            )
        else:
            if self.ef_state is None:
                self.ef_state = self._init_ef()
            loss, self.params, self.opt_state, self.ef_state = (
                self._train_step(self.params, self.opt_state, pb.stacked,
                                 self.ef_state))
        loss = float(loss)  # blocks on the device step
        t2 = time.perf_counter()
        wall = t2 - t0
        # serial mode: phases are contiguous, so charge the (tiny) engine
        # overhead to compute and the four phases sum exactly to the wall
        compute = (t2 - t1) if self.overlap else (wall - pb.host_time)
        tracer = get_tracer()
        if tracer.enabled:
            # the step/compute spans share the StepMetrics timestamps —
            # one clock, whether read from the trace or from the row
            tracer.record_span("minibatch.compute", t1, t2, cat="step",
                               args={"step": pb.index})
            tracer.record_span("minibatch.step", t0, t2, cat="step",
                               args={"step": pb.index, "loss": loss})

        if self.rebalance:
            self._load_ema = (0.7 * self._load_ema
                              + 0.3 * np.maximum(pb.input_vertices, 1))
            inv = 1.0 / self._load_ema
            self._seed_share = inv / inv.sum()
            self.engine.set_seed_share(self._seed_share)

        fetch = pb.fetch_stats
        return StepMetrics(
            loss=loss,
            input_vertices=pb.input_vertices,
            remote_vertices=pb.remote_vertices,
            edges=pb.edges,
            sample_time_host=pb.sample_time,
            compute_time_host=compute,
            cache_hits=np.array([s.num_cache_hit for s in fetch]),
            remote_misses=np.array([s.num_remote_miss for s in fetch]),
            miss_bytes=np.array([s.miss_bytes for s in fetch]),
            wire_bytes=np.array([s.wire_bytes for s in fetch]),
            fetch_time_host=pb.fetch_time,
            transfer_time_host=pb.transfer_time,
            step_wall_host=wall,
            queue_wait_host=wait,
            overlap=self.overlap,
        )
