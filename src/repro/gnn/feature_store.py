"""Partitioned row stores: owner shards + per-worker static caches.

The paper's DistDGL analysis (§5.1, Figs. 16-19) shows that *feature loading
of remote input vertices* is the dominant, partitioning-sensitive cost of
mini-batch training. Real systems attack it with a per-worker cache of hot
remote vertex features (PaGraph, BGL, DistDGL's node-feature cache): the
cache is populated once from static graph information, and every mini-batch
lookup is served from {local shard, cache, remote fetch}.

This module reproduces that layer, generalised: `RowStore` is a partitioned
store of arbitrary [V, d] rows keyed by vertex id — feature rows during
training AND per-layer embedding rows during layer-wise inference serving
(gnn/inference.py) share the same lookup/split/accounting machinery, because
at serving time the partitioning-sensitive cost is the same mechanism:
remote rows crossing the network. `FeatureStore` is the feature-flavored
front (unchanged public API).

Each worker w of a `VertexPartitionBook` owns its partition's rows; on top
it holds a bounded static cache of remote vertices selected by one of four
policies:

  none    — no cache (DistDGL default; every remote vertex crosses the net)
  random  — uniform random remote vertices (ablation baseline)
  degree  — highest-degree remote vertices (PaGraph/BGL-style; power-law
            graphs concentrate sampled traffic on hubs)
  halo    — 1-hop boundary neighbors: remote vertices adjacent to w's
            partition, ranked by how many cut edges bind them to w (the
            vertices sampling is most likely to touch first)

`gather()` splits a batch's input vertices into {local, cache-hit,
remote-miss} with one vectorised pass and returns the assembled row block
plus a `FetchStats` record (counts and bytes per class). Only *miss* bytes
cross the network — `core/cost_model.py` prices the feature-loading phase
(`minibatch_step`) and the serving fetch phase (`serve_request`) from them.
A store built with a lossy wire codec (`repro/core/wire.py`) serves miss
rows from their codec-encoded remote representation — `gather` roundtrips
the miss block through encode/decode (local and cache rows never cross the
network and stay exact) — and `FetchStats.wire_bytes` reports the encoded
miss bytes next to the logical `miss_bytes` (equal under fp32).
Note the asymmetry with sampling: caching rows does NOT cache adjacency, so
remote-adjacency sampling costs still scale with all remote vertices.

Budgets are vertices per worker (`cache_budget`); `halo` may under-fill its
budget when the boundary is smaller than the budget — that is the policy's
defining property, not a bug.
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple, Optional

import numpy as np

from repro.core.graph import Graph
from repro.core.partition_book import VertexPartitionBook
from repro.core.wire import Codec, as_codec
from repro.fault import inject as fault_inject
from repro.obs.trace import get_tracer

__all__ = [
    "CACHE_POLICIES",
    "FetchStats",
    "FeatureStore",
    "RowStore",
    "select_cache_vertices",
]

CACHE_POLICIES = ("none", "random", "degree", "halo")


class FetchStats(NamedTuple):
    """Per-lookup feature-loading accounting (one worker, one batch).

    `miss_bytes` is the logical (f32) volume of rows that crossed the
    network; `wire_bytes` is what the store's codec actually shipped for
    them (== miss_bytes under fp32). The field defaults to 0 so positional
    seven-field construction keeps working.
    """

    num_input: int
    num_local: int
    num_cache_hit: int
    num_remote_miss: int
    local_bytes: int
    hit_bytes: int
    miss_bytes: int
    wire_bytes: int = 0

    @property
    def num_remote(self) -> int:
        return self.num_cache_hit + self.num_remote_miss

    @property
    def hit_rate(self) -> float:
        """Cache hits / remote requests (1.0 when nothing is remote)."""
        return self.num_cache_hit / self.num_remote if self.num_remote else 1.0

    @classmethod
    def merge(cls, stats: "list[FetchStats]") -> "FetchStats":
        """Field-wise sum; an empty list is the zero record (the serving
        engine legitimately sees zero-request micro-batch windows)."""
        return cls(*(int(sum(s[i] for s in stats))
                     for i in range(len(cls._fields))))


def select_cache_vertices(
    graph: Graph,
    book: VertexPartitionBook,
    policy: str,
    budget: int,
    seed: int = 0,
) -> list[np.ndarray]:
    """Static cache contents: per worker, the global ids of cached remote
    vertices (deterministic given seed; each array has <= budget entries)."""
    if policy not in CACHE_POLICIES:
        raise ValueError(f"unknown cache policy {policy!r}; options: {CACHE_POLICIES}")
    k, V = book.k, book.num_vertices
    owner = book.owner
    if policy == "none" or budget <= 0:
        return [np.zeros(0, np.int64) for _ in range(k)]

    if policy == "degree":
        # Hub-first: one global degree order, filtered per worker.
        order = np.argsort(-graph.degrees(), kind="stable")
        return [order[owner[order] != w][:budget].astype(np.int64) for w in range(k)]

    if policy == "halo":
        # Boundary-first: remote endpoints of cut edges, ranked by the number
        # of cut edges binding them to this partition (ties: degree, then id).
        src = graph.src.astype(np.int64)
        dst = graph.dst.astype(np.int64)
        cut = owner[src] != owner[dst]
        cs, cd = src[cut], dst[cut]
        pw = np.concatenate([owner[cs], owner[cd]]).astype(np.int64)
        pv = np.concatenate([cd, cs])
        uniq, counts = np.unique(pw * V + pv, return_counts=True)
        w_of = (uniq // V).astype(np.int64)
        v_of = (uniq % V).astype(np.int64)
        deg = graph.degrees()
        out = []
        for w in range(k):
            sel = w_of == w
            v, c = v_of[sel], counts[sel]
            order = np.lexsort((v, -deg[v], -c))
            out.append(v[order][:budget])
        return out

    # random baseline
    out = []
    for w in range(k):
        remote = np.where(owner != w)[0]
        rng = np.random.default_rng(seed + 7919 * w)
        n = min(budget, remote.shape[0])
        pick = rng.choice(remote, size=n, replace=False) if n else remote[:0]
        out.append(np.sort(pick).astype(np.int64))
    return out


@dataclasses.dataclass(frozen=True)
class RowStore:
    """Generic partitioned row store: owner shards + per-worker static caches.

    `rows` (the global [V, d] array) doubles as the union of owner shards
    and as the remote KV store for misses; cache hits are served from
    `cache_rows`, the copies frozen at build time — so a stale cache would
    be *observable*, not silently papered over. What the rows *are* is the
    caller's business: features (`FeatureStore`) or per-layer embeddings
    (gnn/inference.py's embedding stores).

    Read-only contract: a built store is immutable — `split`/`stats`/
    `gather` only read the frozen dataclass fields (owner array, sorted
    cache ids, cache rows, the global rows) and write exclusively to
    per-call locals, so any number of threads may call them concurrently
    for any workers (the overlapped pipeline, gnn/pipeline.py, does exactly
    that while the device steps). Anything that changes store contents must
    build a NEW store; tests/test_pipeline.py stress-gathers from k threads
    and asserts bitwise-equal results vs serial.
    """

    book: VertexPartitionBook
    policy: str
    budget: int
    row_dim: int
    bytes_per_row: int
    # Per-worker caches as SORTED id arrays (membership via searchsorted) —
    # O(sum cache sizes) memory, not O(k * V). cache_rows is aligned with
    # cache_ids, so the searchsorted position doubles as the row index.
    cache_ids: np.ndarray           # int64 [k, max_cache]; pad -> num_vertices
    cache_sizes: np.ndarray         # int64 [k]: true cache entries per worker
    cache_rows: Optional[np.ndarray]  # [k, max_cache, d] cached copies
    rows: Optional[np.ndarray]        # global [V, d] (None = accounting-only)
    # wire codec for remote-miss rows (None -> fp32 == exact, today's bytes)
    codec: Optional[Codec] = None

    @classmethod
    def create(
        cls,
        book: VertexPartitionBook,
        cache_vertices: "list[np.ndarray]",
        *,
        rows: Optional[np.ndarray] = None,
        row_dim: Optional[int] = None,
        policy: str = "none",
        budget: int = 0,
        codec=None,
    ) -> "RowStore":
        """Build a store whose worker-w cache holds `cache_vertices[w]`.

        With `rows=None` the store is accounting-only (split/stats work,
        gather does not) — `row_dim` then sizes the byte metrics. The cache
        selection is the caller's (e.g. `select_cache_vertices`), so one
        selection can be shared across many stores — the per-layer embedding
        stores reuse a single policy computation.
        """
        if rows is not None:
            row_dim = int(rows.shape[1])
        if row_dim is None:
            raise ValueError("need rows or row_dim for byte accounting")
        ids = [np.sort(np.asarray(c, dtype=np.int64)) for c in cache_vertices]
        sizes = np.array([c.shape[0] for c in ids], dtype=np.int64)
        max_cache = int(sizes.max()) if sizes.size else 0
        # pad with num_vertices: sorts after every real id, never matches one
        cache_ids = np.full((book.k, max_cache), book.num_vertices, dtype=np.int64)
        crows = None
        if rows is not None:
            crows = np.zeros((book.k, max_cache, row_dim), dtype=rows.dtype)
        for w, cw in enumerate(ids):
            cache_ids[w, : cw.shape[0]] = cw
            if crows is not None:
                crows[w, : cw.shape[0]] = rows[cw]
        return cls(
            book=book, policy=policy, budget=int(budget),
            row_dim=row_dim, bytes_per_row=4 * row_dim,
            cache_ids=cache_ids, cache_sizes=sizes, cache_rows=crows,
            rows=rows, codec=as_codec(codec),
        )

    @classmethod
    def from_policy(
        cls,
        graph: Graph,
        book: VertexPartitionBook,
        *,
        policy: str = "none",
        budget: int = 0,
        rows: Optional[np.ndarray] = None,
        row_dim: Optional[int] = None,
        seed: int = 0,
        codec=None,
    ) -> "RowStore":
        """Select the per-worker caches with `select_cache_vertices`, then
        `create` (which subclasses do NOT override, unlike `build`)."""
        ids = select_cache_vertices(graph, book, policy, budget, seed=seed)
        return cls.create(book, ids, rows=rows, row_dim=row_dim,
                          policy=policy, budget=budget, codec=codec)

    def cached_ids(self, worker: int) -> np.ndarray:
        """Global ids cached at `worker` (sorted, cache-row order)."""
        return self.cache_ids[worker, : self.cache_sizes[worker]]

    def split(self, worker: int, ids: np.ndarray):
        """Vectorised {local, cache-hit, remote-miss} split of input ids."""
        ids = np.asarray(ids, dtype=np.int64)
        local = self.book.owner[ids] == worker
        cached = self.cached_ids(worker)
        if cached.shape[0] == 0:
            hit = np.zeros_like(local)
        else:
            pos = np.minimum(np.searchsorted(cached, ids), cached.shape[0] - 1)
            hit = ~local & (cached[pos] == ids)
        miss = ~local & ~hit
        return local, hit, miss

    def _codec(self) -> Codec:
        return as_codec(self.codec)

    def _stats_of(self, ids: np.ndarray, local, hit, miss) -> FetchStats:
        nl, nh, nm = int(local.sum()), int(hit.sum()), int(miss.sum())
        b = self.bytes_per_row
        return FetchStats(
            num_input=int(ids.shape[0]),
            num_local=nl, num_cache_hit=nh, num_remote_miss=nm,
            local_bytes=nl * b, hit_bytes=nh * b, miss_bytes=nm * b,
            wire_bytes=self._codec().wire_bytes((nm, self.row_dim)),
        )

    def stats(self, worker: int, ids: np.ndarray) -> FetchStats:
        ids = np.asarray(ids, dtype=np.int64)
        return self._stats_of(ids, *self.split(worker, ids))

    def gather(self, worker: int, ids: np.ndarray) -> tuple[np.ndarray, FetchStats]:
        """Assemble the row block for `ids` from shard/cache/remote and
        return it with the phase accounting.

        Thread-safe (the class read-only contract): reads frozen store
        state only, writes only to the freshly-allocated `out` block."""
        if self.rows is None:
            raise ValueError("accounting-only store (built without rows)")
        hook = fault_inject.fetch_hook()
        if hook is not None:  # injection seam: may raise TransientFetchFault
            hook(worker, ids)
        tracer = get_tracer()
        t0 = time.perf_counter()
        ids = np.asarray(ids, dtype=np.int64)
        local, hit, miss = self.split(worker, ids)
        out = np.empty((ids.shape[0], self.row_dim), dtype=self.rows.dtype)
        out[local] = self.rows[ids[local]]                          # owner shard
        slot = np.searchsorted(self.cached_ids(worker), ids[hit])
        out[hit] = self.cache_rows[worker, slot]
        codec = self._codec()
        miss_rows = self.rows[ids[miss]]                            # remote fetch
        # MEASURED wire bytes: what the encoded representation actually
        # occupies (fp32 ships the rows as-is). The reconciliation gate
        # holds this against the Codec.wire_bytes formula in FetchStats.
        wire_measured = miss_rows.nbytes
        if not codec.lossless and miss_rows.shape[0]:
            # the remote side ships the encoded representation; only the
            # decoded rows exist on this worker
            payload, meta = codec.encode(miss_rows)
            wire_measured = payload.nbytes + (
                0 if meta is None else np.asarray(meta).nbytes)
            miss_rows = np.asarray(codec.decode(payload, meta),
                                   dtype=self.rows.dtype)
        out[miss] = miss_rows
        stats = self._stats_of(ids, local, hit, miss)
        if tracer.enabled:
            tracer.record_span("store.gather", t0, time.perf_counter(),
                               cat="fetch",
                               args={"worker": int(worker),
                                     "ids": int(ids.shape[0]),
                                     "miss": stats.num_remote_miss})
            tracer.add("fetch.wire_bytes", wire_measured)
            tracer.add("fetch.miss_bytes", stats.miss_bytes)
            tracer.gauge("cache.hit_rate", stats.hit_rate)
        return out, stats


class FeatureStore(RowStore):
    """Feature-flavored `RowStore` (the DistDGL feature-loading phase).

    Same store, same accounting — kept as its own name so training code and
    its knobs read as features, and so the pre-RowStore public API
    (`features`/`feature_dim`, graph-first `build`) stays intact.
    """

    @classmethod
    def build(
        cls,
        graph: Graph,
        book: VertexPartitionBook,
        *,
        policy: str = "none",
        budget: int = 0,
        features: Optional[np.ndarray] = None,
        feature_dim: Optional[int] = None,
        seed: int = 0,
        codec=None,
    ) -> "FeatureStore":
        """Build the store. With `features=None` the store is accounting-only
        (split/stats work, gather does not) — `feature_dim` then sizes the
        byte metrics."""
        return cls.from_policy(
            graph, book, policy=policy, budget=budget,
            rows=features, row_dim=feature_dim, seed=seed, codec=codec,
        )

    @property
    def features(self) -> Optional[np.ndarray]:
        return self.rows

    @property
    def feature_dim(self) -> int:
        return self.row_dim
