from repro.gnn.models import GNNSpec, init_params  # noqa: F401
