from repro.gnn.feature_store import (  # noqa: F401
    CACHE_POLICIES,
    FeatureStore,
    FetchStats,
    RowStore,
)
from repro.gnn.models import GNNSpec, init_params  # noqa: F401
from repro.gnn.pipeline import (  # noqa: F401
    BatchPreparer,
    PipelineEngine,
    PreparedBatch,
)
