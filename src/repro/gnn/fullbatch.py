"""DistGNN-style full-batch distributed GNN training (edge partitioning).

The per-device program (models.py + sync.py) is identical across three
execution modes:

  mode="sim"       jax.vmap(axis_name=AXIS) over the stacked [k, ...] blocks
                   — exact SPMD semantics on a single host device. This is
                   how the paper's 4..32-machine experiments run inside this
                   CPU container: the collectives are real (vmap implements
                   them), only the transport is local.
  mode="shard_map" jax.shard_map over a real mesh axis — the production
                   path; also what the multi-pod dry-run lowers.
  k == 1           the single-machine oracle (LocalSync), used as the
                   correctness reference: distributed == single, allclose.

The trainer measures, per step: loss, collective bytes (analytic, verified
against dry-run HLO), and per-partition compute cost proxies — feeding the
paper's speedup/memory analysis (core/cost_model.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.partition_book import EdgePartitionBook, build_edge_book
from repro.gnn import models
from repro.gnn.models import GNNSpec
from repro.gnn.sync import Block, build_blocks, make_sync, sync_bytes_per_round
from repro.optim import adam_init, adam_update

AXIS = "parts"


@dataclasses.dataclass
class FullBatchTrainer:
    spec: GNNSpec
    book: EdgePartitionBook
    blocks: Block                      # stacked [k, ...]
    sync_mode: str = "halo"            # halo | dense
    mode: str = "sim"                  # sim | shard_map
    mesh: Optional[jax.sharding.Mesh] = None
    params: Any = None
    opt_state: Any = None
    lr: float = 1e-2

    # ---------------------------------------------------------------- setup
    @classmethod
    def build(
        cls,
        graph: Graph,
        edge_assignment: np.ndarray,
        k: int,
        spec: GNNSpec,
        features: np.ndarray,
        labels: np.ndarray,
        train_mask: np.ndarray,
        *,
        sync_mode: str = "halo",
        mode: str = "sim",
        mesh: Optional[jax.sharding.Mesh] = None,
        seed: int = 0,
        lr: float = 1e-2,
    ) -> "FullBatchTrainer":
        book = build_edge_book(
            graph, edge_assignment, k,
            tiled_layout=(spec.agg_backend != "scatter"),
        )
        blocks = build_blocks(book, features, labels, train_mask)
        params = models.init_params(spec, seed=seed)
        return cls(
            spec=spec, book=book, blocks=blocks, sync_mode=sync_mode,
            mode=mode, mesh=mesh, params=params, opt_state=adam_init(params),
            lr=lr,
        )

    # ------------------------------------------------------------- plumbing
    def _per_device_loss(self, params, blk: Block) -> jnp.ndarray:
        sync_mode = "local" if self.book.k == 1 else self.sync_mode
        sync = make_sync(sync_mode, blk, self.book.num_vertices, AXIS)
        return models.loss_fn(self.spec, params, blk.x, blk, sync)

    def _wrap(self, fn):
        """Run a (params, stacked_blocks) function in the chosen mode."""
        if self.book.k == 1:
            return lambda params, blocks: fn(
                params, jax.tree.map(lambda a: a[0], blocks)
            )
        if self.mode == "sim":
            return jax.vmap(fn, in_axes=(None, 0), axis_name=AXIS)
        assert self.mesh is not None, "shard_map mode needs a mesh"
        P = jax.sharding.PartitionSpec

        def per_device(params, blocks_local):
            # shard_map keeps the sharded leading dim as size 1 (vmap strips
            # it) — squeeze in, unsqueeze out
            blk = jax.tree.map(lambda a: a[0], blocks_local)
            out = fn(params, blk)
            return jax.tree.map(lambda a: a[None], out)

        # jax >= 0.6 exposes jax.shard_map (check_vma); 0.4.x has the
        # experimental module (check_rep). Same semantics either way.
        if hasattr(jax, "shard_map"):
            return jax.shard_map(
                per_device,
                mesh=self.mesh,
                in_specs=(P(), P(AXIS)),
                out_specs=P(AXIS),
                check_vma=False,
            )
        from jax.experimental.shard_map import shard_map

        return shard_map(
            per_device,
            mesh=self.mesh,
            in_specs=(P(), P(AXIS)),
            out_specs=P(AXIS),
            check_rep=False,
        )

    # ----------------------------------------------------------------- api
    @functools.cached_property
    def _train_step(self):
        def loss_of(params, blocks):
            losses = self._wrap(self._per_device_loss)(params, blocks)
            return jnp.mean(losses)

        def step(params, opt_state, blocks):
            loss, grads = jax.value_and_grad(loss_of)(params, blocks)
            new_params, new_state = adam_update(
                grads, opt_state, params, lr=self.lr
            )
            return loss, new_params, new_state

        return jax.jit(step)

    @functools.cached_property
    def _forward(self):
        def fwd(params, blk: Block):
            sync_mode = "local" if self.book.k == 1 else self.sync_mode
            sync = make_sync(sync_mode, blk, self.book.num_vertices, AXIS)
            return models.forward(self.spec, params, blk.x, blk, sync)

        return jax.jit(lambda params, blocks: self._wrap(fwd)(params, blocks))

    def train_step(self) -> float:
        loss, self.params, self.opt_state = self._train_step(
            self.params, self.opt_state, self.blocks
        )
        return float(loss)

    def forward_logits_global(self) -> np.ndarray:
        """Master-row logits gathered to a global [V, C] array (testing)."""
        out = self._forward(self.params, self.blocks)
        if self.book.k == 1:
            out = out[None]
        return self.book.scatter_to_global(np.asarray(out))

    # ------------------------------------------------------------- accounting
    def comm_bytes_per_epoch(self) -> int:
        """Analytic collective traffic of one full-batch epoch (fwd+bwd).

        Backward of a reduce+broadcast pair is another broadcast+reduce pair
        -> 2x forward volume. GAT syncs 3 aggregates/layer, SAGE/GCN 1.
        """
        syncs_per_layer = 3 if self.spec.model == "gat" else 1
        dims = [d_out for _, d_out in self.spec.dims()]
        total = 0
        for d_out in dims:
            per = sync_bytes_per_round(self.book, d_out, self.sync_mode)
            total += syncs_per_layer * per * 2  # fwd + bwd
        # gradient all-reduce of the (replicated) model parameters
        n_params = sum(
            int(np.prod(p.shape)) for p in jax.tree.leaves(self.params)
        )
        total += 2 * self.book.k * n_params * 4
        return total

    def memory_bytes_per_partition(self) -> np.ndarray:
        """Analytic per-partition training memory (features + activations +
        graph structure), the quantity behind the paper's Fig. 10/11."""
        k = self.book.k
        f = self.spec.feature_dim
        h = self.spec.hidden_dim
        L = self.spec.num_layers
        verts = self.book.vmask.sum(axis=1)  # true local vertices
        edges = self.book.emask.sum(axis=1)
        feat = verts * f * 4
        # stored activations: one [Vloc, hidden] per layer (backward needs them)
        acts = verts * h * 4 * L
        structure = edges * 2 * 4
        halo = 2 * k * self.book.bucket * max(f, h) * 4
        return (feat + acts + structure + halo).astype(np.int64)
