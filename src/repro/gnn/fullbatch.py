"""Distributed full-batch GNN training (vertex-cut halo/dense + 1.5D ring).

The per-device program (models.py + sync.py) is identical across three
execution modes:

  mode="sim"       jax.vmap(axis_name=AXIS) over the stacked [k, ...] blocks
                   — exact SPMD semantics on a single host device. This is
                   how the paper's 4..32-machine experiments run inside this
                   CPU container: the collectives are real (vmap implements
                   them), only the transport is local.
  mode="shard_map" jax.shard_map over a real mesh axis — the production
                   path; also what the multi-pod dry-run lowers.
  k == 1           the single-machine oracle (LocalSync), used as the
                   correctness reference: distributed == single, allclose.

The step is composed from four orthogonal STAGE functions, so partition
layout (EdgePartitionBook vs BlockRowBook), sync strategy (halo / dense /
ring), and execution mode (sim / shard_map) are pluggable axes:

  build_book          partition layout     (edge book | 1.5D block rows)
  build_device_blocks static device state  (Block     | RingBlock)
  make_step_fns       per-device loss/forward closed over the SyncStrategy
  wrap_spmd           SPMD dispatch        (bare | vmap sim | shard_map)

`FullBatchTrainer` is the thin composition of the four; every combination
runs through the same trainer, with the k=1 LocalSync oracle pinning
correctness for all of them (tests/test_gnn_distributed.py, test_ring.py).

The trainer measures, per step: loss, collective bytes (analytic, verified
against dry-run HLO), and per-partition compute cost proxies — feeding the
paper's speedup/memory analysis (core/cost_model.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.partition_book import (
    BlockRowBook,
    build_blockrow_book,
    build_edge_book,
)
from repro.core.wire import as_codec, codec_grad_reduce
from repro.gnn import models
from repro.gnn.models import GNNSpec
from repro.gnn.sync import (
    build_blocks,
    build_ring_blocks,
    make_sync,
    sync_bytes_per_round,
    sync_wire_bytes_per_round,
)
from repro.obs.trace import get_tracer
from repro.optim import adam_init, adam_update

AXIS = "parts"


def step_donate_argnums(lossless: bool) -> tuple:
    """Donated argnums the jitted full-batch train step declares.

    The lossy (error-feedback) step donates opt_state and the EF carry —
    args 1 and 3 of `step(params, opt_state, blocks, ef)` — so the update
    happens in place; the lossless step keeps the historical undonated
    graph. XLA:CPU cannot alias donated buffers (it warns per compile), so
    donation only engages off-CPU — the documented whitelist in the
    analysis donation rule, which otherwise requires every declared donated
    arg to appear in the executable's `input_output_alias` table.
    """
    if lossless or jax.default_backend() == "cpu":
        return ()
    return (1, 3)


# ---------------------------------------------------------------------------
# Stage 1: partition layout
# ---------------------------------------------------------------------------


def build_book(
    graph: Graph,
    edge_assignment: Optional[np.ndarray],
    k: int,
    *,
    sync_mode: str = "halo",
    tiled_layout: bool = False,
):
    """Choose the static layout for a sync strategy.

    halo/dense/local run on an `EdgePartitionBook` (any edge partitioner);
    ring runs on a `BlockRowBook` (1.5D contiguous blocks — needs no
    partitioning heuristic, so `edge_assignment` is ignored / may be None).
    """
    if sync_mode == "ring":
        return build_blockrow_book(graph, k, tiled_layout=tiled_layout)
    if edge_assignment is None:
        raise ValueError(f"sync mode {sync_mode!r} needs an edge assignment")
    return build_edge_book(graph, edge_assignment, k,
                           tiled_layout=tiled_layout)


# ---------------------------------------------------------------------------
# Stage 2: static device state
# ---------------------------------------------------------------------------


def build_device_blocks(book, features, labels, train_mask):
    """Stacked [k, ...] device blocks matching the book's layout."""
    if isinstance(book, BlockRowBook):
        return build_ring_blocks(book, features, labels, train_mask)
    return build_blocks(book, features, labels, train_mask)


# ---------------------------------------------------------------------------
# Stage 3: per-device programs
# ---------------------------------------------------------------------------


def resolve_sync_mode(sync_mode: str, k: int) -> str:
    """k=1 collapses the partial-aggregate strategies to the LocalSync
    oracle. Ring stays ring: its blocks carry chunk tables, not halo
    tables, and its k=1 loop is already collective-free."""
    if k == 1 and sync_mode != "ring":
        return "local"
    return sync_mode


def make_step_fns(spec: GNNSpec, sync_mode: str, num_vertices: int, k: int,
                  codec=None):
    """(loss_fn, forward_fn), each `(params, blk) -> ...` on ONE device."""
    mode = resolve_sync_mode(sync_mode, k)

    def loss(params, blk):
        sync = make_sync(mode, blk, num_vertices, AXIS, codec=codec)
        return models.loss_fn(spec, params, blk.x, blk, sync)

    def forward(params, blk):
        sync = make_sync(mode, blk, num_vertices, AXIS, codec=codec)
        return models.forward(spec, params, blk.x, blk, sync)

    return loss, forward


# ---------------------------------------------------------------------------
# Stage 4: SPMD dispatch
# ---------------------------------------------------------------------------


def wrap_spmd(fn, k: int, mode: str,
              mesh: Optional[jax.sharding.Mesh] = None, n_mapped: int = 1):
    """Run a (params, *mapped) function in the chosen mode.

    The first argument is replicated (params); the next `n_mapped` arguments
    are stacked [k, ...] per-device trees (blocks, and for the lossy-codec
    train step the per-device error-feedback state as a second carry)."""
    if k == 1:
        return lambda params, *mapped: fn(
            params, *(jax.tree.map(lambda a: a[0], m) for m in mapped)
        )
    if mode == "sim":
        return jax.vmap(fn, in_axes=(None,) + (0,) * n_mapped,
                        axis_name=AXIS)
    assert mesh is not None, "shard_map mode needs a mesh"
    P = jax.sharding.PartitionSpec

    def per_device(params, *mapped_local):
        # shard_map keeps the sharded leading dim as size 1 (vmap strips
        # it) — squeeze in, unsqueeze out
        args = (jax.tree.map(lambda a: a[0], m) for m in mapped_local)
        out = fn(params, *args)
        return jax.tree.map(lambda a: a[None], out)

    specs = dict(in_specs=(P(),) + (P(AXIS),) * n_mapped, out_specs=P(AXIS))
    # jax >= 0.6 exposes jax.shard_map (check_vma); 0.4.x has the
    # experimental module (check_rep). Same semantics either way.
    if hasattr(jax, "shard_map"):
        return jax.shard_map(per_device, mesh=mesh, check_vma=False, **specs)
    from jax.experimental.shard_map import shard_map

    return shard_map(per_device, mesh=mesh, check_rep=False, **specs)


# ---------------------------------------------------------------------------
# The trainer: composition of the four stages
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FullBatchTrainer:
    spec: GNNSpec
    book: Any                          # EdgePartitionBook | BlockRowBook
    blocks: Any                        # Block | RingBlock, stacked [k, ...]
    sync_mode: str = "halo"            # halo | dense | ring
    mode: str = "sim"                  # sim | shard_map
    mesh: Optional[jax.sharding.Mesh] = None
    params: Any = None
    opt_state: Any = None
    lr: float = 1e-2
    codec: Any = None                  # wire codec name/instance (None=fp32)
    ef_state: Any = None               # error-feedback carry (lossy codecs)

    # ---------------------------------------------------------------- setup
    @classmethod
    def build(
        cls,
        graph: Graph,
        edge_assignment: Optional[np.ndarray],
        k: int,
        spec: GNNSpec,
        features: np.ndarray,
        labels: np.ndarray,
        train_mask: np.ndarray,
        *,
        sync_mode: str = "halo",
        mode: str = "sim",
        mesh: Optional[jax.sharding.Mesh] = None,
        seed: int = 0,
        lr: float = 1e-2,
        codec=None,
    ) -> "FullBatchTrainer":
        book = build_book(
            graph, edge_assignment, k, sync_mode=sync_mode,
            tiled_layout=(spec.agg_backend != "scatter"),
        )
        blocks = build_device_blocks(book, features, labels, train_mask)
        params = models.init_params(spec, seed=seed)
        return cls(
            spec=spec, book=book, blocks=blocks, sync_mode=sync_mode,
            mode=mode, mesh=mesh, params=params, opt_state=adam_init(params),
            lr=lr, codec=codec,
        )

    # ------------------------------------------------------------- plumbing
    @functools.cached_property
    def _step_fns(self):
        return make_step_fns(self.spec, self.sync_mode,
                             self.book.num_vertices, self.book.k,
                             codec=self.codec)

    def _wrap(self, fn, n_mapped: int = 1):
        return wrap_spmd(fn, self.book.k, self.mode, self.mesh,
                         n_mapped=n_mapped)

    def _init_ef(self):
        """Per-device zero EF residuals, stacked [k, ...] like the blocks."""
        base = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), self.params)
        if self.book.k > 1:
            base = jax.tree.map(
                lambda z: jnp.zeros((self.book.k,) + z.shape, z.dtype), base)
        return base

    # ----------------------------------------------------------------- api
    @functools.cached_property
    def _train_step(self):
        per_device_loss, _ = self._step_fns
        codec = as_codec(self.codec)

        if codec.lossless:
            # historical step graph, untouched: grads via the implicit vmap/
            # shard_map backward of the mean loss (bitwise-identical default)
            def loss_of(params, blocks):
                losses = self._wrap(per_device_loss)(params, blocks)
                return jnp.mean(losses)

            def step(params, opt_state, blocks):
                loss, grads = jax.value_and_grad(loss_of)(params, blocks)
                new_params, new_state = adam_update(
                    grads, opt_state, params, lr=self.lr
                )
                return loss, new_params, new_state

            return jax.jit(step)

        # lossy codec: per-device grads completed by the error-feedback
        # compressed pmean (== the implicit backward's gradient for fp32;
        # verified against it in tests/test_wire.py)
        k = self.book.k
        axis = AXIS if k > 1 else None

        def per_device(params, blk, ef):
            loss, grads = jax.value_and_grad(per_device_loss)(params, blk)
            mean_grads, new_ef = codec_grad_reduce(codec, grads, ef, axis)
            return loss, mean_grads, new_ef

        wrapped = self._wrap(per_device, n_mapped=2)

        def step(params, opt_state, blocks, ef):
            losses, grads, new_ef = wrapped(params, blocks, ef)
            if k > 1:
                # pmean made the grads replica-consistent; lane 0 is the mean
                losses = jnp.mean(losses)
                grads = jax.tree.map(lambda g: g[0], grads)
            new_params, new_state = adam_update(
                grads, opt_state, params, lr=self.lr
            )
            return losses, new_params, new_state, new_ef

        return jax.jit(step, donate_argnums=step_donate_argnums(False))

    @functools.cached_property
    def _forward(self):
        _, per_device_fwd = self._step_fns
        return jax.jit(
            lambda params, blocks: self._wrap(per_device_fwd)(params, blocks)
        )

    def train_step(self) -> float:
        with get_tracer().span("fullbatch.step", cat="step",
                               args={"sync": self.sync_mode}):
            if as_codec(self.codec).lossless:
                loss, self.params, self.opt_state = self._train_step(
                    self.params, self.opt_state, self.blocks
                )
                return float(loss)
            if self.ef_state is None:
                self.ef_state = self._init_ef()
            loss, self.params, self.opt_state, self.ef_state = \
                self._train_step(
                    self.params, self.opt_state, self.blocks, self.ef_state
                )
            return float(loss)

    def set_epoch(self, epoch: int) -> None:
        """Advance epoch-scheduled codecs (VariableRatioCodec). Re-jits the
        step only when the schedule actually changes tier."""
        codec = as_codec(self.codec)
        advance = getattr(codec, "at_epoch", None)
        if advance is None:
            return
        new = advance(epoch)
        # a tier change shows up in the per-layer ratios; same ratios mean
        # the same trace, so keep the compiled step
        if (new.ratio(0), new.ratio(1)) != (codec.ratio(0), codec.ratio(1)):
            self.codec = new
            for cached in ("_step_fns", "_train_step", "_forward"):
                self.__dict__.pop(cached, None)
        else:
            self.codec = new

    def forward_logits_global(self) -> np.ndarray:
        """Master-row logits gathered to a global [V, C] array (testing)."""
        out = self._forward(self.params, self.blocks)
        if self.book.k == 1:
            out = out[None]
        return self.book.scatter_to_global(np.asarray(out))

    # ------------------------------------------------------------- accounting
    def comm_bytes_per_epoch(self) -> int:
        """Analytic collective traffic of one full-batch epoch (fwd+bwd).

        Backward of a reduce+broadcast pair is another broadcast+reduce pair;
        backward of a ppermute ring is the reverse ring — either way 2x the
        forward volume. GAT syncs 3 aggregates/layer, SAGE/GCN 1; each
        aggregate is priced at its true payload width
        (`GNNSpec.aggregate_dims`), so the total matches the collectives a
        traced step actually records.
        """
        total = 0
        for layer_dims in self.spec.aggregate_dims(self.sync_mode):
            for d in layer_dims:
                per = sync_bytes_per_round(self.book, d, self.sync_mode)
                total += per * 2  # fwd + bwd
        # gradient all-reduce of the (replicated) model parameters
        n_params = sum(
            int(np.prod(p.shape)) for p in jax.tree.leaves(self.params)
        )
        total += 2 * self.book.k * n_params * 4
        return total

    def wire_bytes_per_epoch(self) -> int:
        """Codec-aware twin of `comm_bytes_per_epoch`: bytes that actually
        cross the network once payloads are encoded (== the logical number
        under the default fp32 codec)."""
        codec = as_codec(self.codec)
        total = 0
        ordinal = 0
        for layer_dims in self.spec.aggregate_dims(self.sync_mode):
            for d in layer_dims:
                per = sync_wire_bytes_per_round(
                    self.book, d, self.sync_mode, codec, layer=ordinal)
                total += per * 2  # fwd + bwd
                ordinal += 1
        # gradient all-reduce, priced per leaf (per-tensor codec meta)
        leaf_bytes = sum(
            codec.wire_bytes(p.shape) for p in jax.tree.leaves(self.params)
        )
        total += 2 * self.book.k * leaf_bytes
        return total

    def memory_bytes_per_partition(self) -> np.ndarray:
        """Analytic per-partition training memory (features + activations +
        graph structure), the quantity behind the paper's Fig. 10/11."""
        k = self.book.k
        f = self.spec.feature_dim
        h = self.spec.hidden_dim
        L = self.spec.num_layers
        verts = self.book.vmask.sum(axis=1)  # true local vertices
        if isinstance(self.book, BlockRowBook):
            edges = self.book.chunk_emask.sum(axis=(1, 2))
            # double-buffered rotation payload instead of halo buckets
            comm_buf = 2 * (self.book.v_block + 1) * max(f, h) * 4
        else:
            edges = self.book.emask.sum(axis=1)
            comm_buf = 2 * k * self.book.bucket * max(f, h) * 4
        feat = verts * f * 4
        # stored activations: one [Vloc, hidden] per layer (backward needs them)
        acts = verts * h * 4 * L
        structure = edges * 2 * 4
        return (feat + acts + structure + comm_buf).astype(np.int64)
