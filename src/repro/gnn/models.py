"""GNN models (GraphSAGE / GCN / GAT) as per-device JAX functions.

Every function here operates on ONE partition's local block:

  x      [Vloc+1, F]   local vertex states (last row = dummy/padding sink)
  esrc   [Eloc]        local src indices (pad -> dummy row)
  edst   [Eloc]        local dst indices
  degree [Vloc+1]      *global* symmetric degree of each local vertex
  master [Vloc+1]      bool, true where this partition owns the vertex

plus a `sync` object (repro.gnn.sync.ReplicaSync) that completes partial
aggregates across partitions. With the `LocalSync` no-op the same code is the
exact single-machine model — that equivalence is the core system invariant
and is tested (distributed forward == single-device forward, allclose).

Aggregation is over the symmetrised adjacency: each stored edge (u, v)
produces messages u->v and v->u (DGL-on-undirected semantics, which both
DistGNN and the paper's DistDGL setup use).

Models follow the paper's setup (§4.1/§5.1): GraphSAGE (mean), GCN, GAT.

Aggregation backend (`GNNSpec.agg_backend`): every edge aggregation — the
sum-aggregations AND GAT's per-destination softmax-stabilisation max — goes
through `kernels.ops.aggregate`, which dispatches on the knob —
  scatter — data-dependent `at[].add` / `at[].max` (the oracle)
  tiled   — pre-sorted/pre-blocked layout (`Block.agg_order`/`agg_ldst`,
            built by the partition book) through the tiled segment-reduce:
            jnp oracle off-TPU, the Pallas one-hot kernel on TPU. Backward
            of the sum is a plain gather (custom_vjp), so gradients match
            the scatter oracle to allclose; the stabilisation max is
            stop_gradient'd (exact — softmax is shift-invariant), so the
            O(E) edge-aggregation hot path of every model, GAT included,
            is scatter-free under tiled/pallas. (The k-way replica sync
            still scatters into its bucket-sized halo buffers —
            O(replicas), the network path, not the edge hot path.)
  pallas  — like tiled but forces the Pallas kernel (interpreted on CPU).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

Params = Any


@dataclasses.dataclass(frozen=True)
class GNNSpec:
    model: str = "sage"          # sage | gcn | gat
    feature_dim: int = 64
    hidden_dim: int = 64
    num_classes: int = 16
    num_layers: int = 2
    gat_heads: int = 4
    agg_backend: str = "scatter"  # scatter | tiled | pallas (ops.aggregate)

    def dims(self) -> list[tuple[int, int]]:
        ins = [self.feature_dim] + [self.hidden_dim] * (self.num_layers - 1)
        outs = [self.hidden_dim] * (self.num_layers - 1) + [self.num_classes]
        return list(zip(ins, outs))


def _glorot(rng: np.random.Generator, shape: tuple[int, ...]) -> jnp.ndarray:
    fan_in, fan_out = shape[0], shape[-1]
    limit = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jnp.asarray(rng.uniform(-limit, limit, size=shape), dtype=jnp.float32)


def init_params(spec: GNNSpec, seed: int = 0) -> Params:
    rng = np.random.default_rng(seed)
    layers = []
    for li, (din, dout) in enumerate(spec.dims()):
        if spec.model == "sage":
            layers.append({
                "w_self": _glorot(rng, (din, dout)),
                "w_neigh": _glorot(rng, (din, dout)),
                "b": jnp.zeros((dout,), jnp.float32),
            })
        elif spec.model == "gcn":
            layers.append({
                "w": _glorot(rng, (din, dout)),
                "b": jnp.zeros((dout,), jnp.float32),
            })
        elif spec.model == "gat":
            h = spec.gat_heads
            dh = max(dout // h, 1)
            layers.append({
                "w": _glorot(rng, (din, h * dh)),
                "a_src": _glorot(rng, (h, dh)),
                "a_dst": _glorot(rng, (h, dh)),
                "b": jnp.zeros((h * dh,), jnp.float32),
                "w_out": (_glorot(rng, (h * dh, dout))
                          if h * dh != dout else jnp.eye(h * dh, dtype=jnp.float32)),
            })
        else:
            raise ValueError(f"unknown model {spec.model!r}")
    return {"layers": layers}


# ---------------------------------------------------------------------------
# Aggregation primitives (local partials; `sync` completes them globally)
# ---------------------------------------------------------------------------


def _scatter_bidir(values_src, values_dst, blk, num_rows,
                   backend: str = "scatter", reduce: str = "sum"):
    """Reduce messages over the symmetrised edge list into vertex rows.

    values_src: [E, d] message carried by the edge toward `edst`
    values_dst: [E, d] message toward `esrc` (reverse direction)
    Padding edges point at the dummy row (num_rows-1) and carry the reduce
    identity's stand-in (zeros for sum, the -1e30 mask floor for max).

    Dispatches to `ops.aggregate`: the symmetrised list is the concatenation
    [values_src -> edst | values_dst -> esrc], whose tiled layout the
    partition book precomputed into `blk.agg_order`/`blk.agg_ldst`.

    For reduce="max", rows no valid edge reaches come back as -inf
    (tiled/pallas drop masked edges from the layout) or as the masked score
    floor -1e30 (scatter sees the masked messages) — callers clamp with
    `jnp.maximum` against a finite floor (e_self, then -1e29) before use,
    after which the backends agree exactly.
    """
    messages = jnp.concatenate([values_src, values_dst], axis=0)
    dst = jnp.concatenate([blk.edst, blk.esrc], axis=0)
    return ops.aggregate(
        messages, dst, num_rows,
        edge_order=blk.agg_order, local_dst=blk.agg_ldst, backend=backend,
        reduce=reduce,
    )


def sage_layer(p, x, blk, sync, *, final: bool,
               backend: str = "scatter") -> jnp.ndarray:
    n = x.shape[0]
    msg = x[blk.esrc] * blk.emask[:, None]
    msg_rev = x[blk.edst] * blk.emask[:, None]
    agg = _scatter_bidir(msg, msg_rev, blk, n, backend)
    agg = sync.reduce_sum(agg)          # mirrors' partials -> masters
    agg = sync.broadcast(agg)           # masters' totals  -> mirrors
    mean = agg / jnp.maximum(blk.degree, 1.0)[:, None]
    h = x @ p["w_self"] + mean @ p["w_neigh"] + p["b"]
    return h if final else jax.nn.relu(h)


def gcn_layer(p, x, blk, sync, *, final: bool,
              backend: str = "scatter") -> jnp.ndarray:
    n = x.shape[0]
    dnorm = 1.0 / jnp.sqrt(blk.degree + 1.0)  # self-loop-augmented degree
    msg = (x * dnorm[:, None])[blk.esrc] * blk.emask[:, None]
    msg_rev = (x * dnorm[:, None])[blk.edst] * blk.emask[:, None]
    agg = _scatter_bidir(msg, msg_rev, blk, n, backend)
    # Self-loop term once per vertex: gate by master so replicas don't
    # double-count it in the cross-partition reduction.
    self_term = x * (dnorm * dnorm)[:, None] * blk.master[:, None]
    agg = agg + self_term
    agg = sync.reduce_sum(agg)
    agg = sync.broadcast(agg)
    h = (agg * dnorm[:, None]) @ p["w"] + p["b"]
    return h if final else jax.nn.relu(h)


def gat_layer(p, x, blk, sync, *, final: bool,
              backend: str = "scatter") -> jnp.ndarray:
    n = x.shape[0]
    h_heads, dh = p["a_src"].shape
    z = (x @ p["w"]).reshape(n, h_heads, dh)
    s_src = jnp.einsum("nhd,hd->nh", z, p["a_src"])  # [n, H]
    s_dst = jnp.einsum("nhd,hd->nh", z, p["a_dst"])

    neg_inf = jnp.asarray(-1e30, x.dtype)

    def masked(e):
        return jnp.where(blk.emask[:, None], e, neg_inf)

    # scores for u->v and v->u over the symmetrised edge list
    e_fwd = masked(jax.nn.leaky_relu(s_src[blk.esrc] + s_dst[blk.edst], 0.2))
    e_rev = masked(jax.nn.leaky_relu(s_src[blk.edst] + s_dst[blk.esrc], 0.2))
    e_self = jnp.where(blk.master[:, None],
                       jax.nn.leaky_relu(s_src + s_dst, 0.2), neg_inf)

    # 1) global max per destination (for a stable softmax). Softmax is
    # shift-invariant, so the stabilisation shift needs no gradient:
    # stop_gradient is exact and keeps the backward free of any
    # scatter-max / argmax transpose (see ops.aggregate).
    m = _scatter_bidir(e_fwd, e_rev, blk, n, backend, reduce="max")
    m = jnp.maximum(m, e_self)
    m = sync.reduce_max(m)
    m = sync.broadcast(m)
    m_safe = jax.lax.stop_gradient(jnp.maximum(m, -1e29))  # isolated vertices

    # 2) global sum of exp
    w_fwd = jnp.exp(e_fwd - m_safe[blk.edst]) * blk.emask[:, None]
    w_rev = jnp.exp(e_rev - m_safe[blk.esrc]) * blk.emask[:, None]
    w_self = jnp.exp(e_self - m_safe) * blk.master[:, None]
    den = _scatter_bidir(w_fwd, w_rev, blk, n, backend)
    den = den + w_self
    den = sync.reduce_sum(den)
    den = sync.broadcast(den)
    den = jnp.maximum(den, 1e-16)

    # 3) attention-weighted aggregate
    num = _scatter_bidir(
        (w_fwd[:, :, None] * z[blk.esrc]).reshape(-1, h_heads * dh),
        (w_rev[:, :, None] * z[blk.edst]).reshape(-1, h_heads * dh),
        blk, n, backend,
    ).reshape(n, h_heads, dh)
    num = num + w_self[:, :, None] * z
    num = sync.reduce_sum(num.reshape(n, h_heads * dh)).reshape(n, h_heads, dh)
    num = sync.broadcast(num.reshape(n, h_heads * dh)).reshape(n, h_heads, dh)

    out = (num / den[:, :, None]).reshape(n, h_heads * dh) + p["b"]
    out = out @ p["w_out"]
    return out if final else jax.nn.elu(out)


_LAYERS = {"sage": sage_layer, "gcn": gcn_layer, "gat": gat_layer}


def forward(spec: GNNSpec, params: Params, x, blk, sync) -> jnp.ndarray:
    """Full model forward on one partition's block. Returns logits
    [Vloc+1, num_classes] (valid at every replica; loss is master-gated)."""
    layer_fn = _LAYERS[spec.model]
    h = x
    n_layers = len(params["layers"])
    for li, p in enumerate(params["layers"]):
        h = layer_fn(p, h, blk, sync, final=(li == n_layers - 1),
                     backend=spec.agg_backend)
        # dummy row must stay zero: it is a scatter sink for padding
        h = h.at[-1].set(0.0)
    return h


def loss_fn(spec: GNNSpec, params: Params, x, blk, sync) -> jnp.ndarray:
    """Masked softmax cross-entropy, averaged over global training vertices.

    Loss is counted only at master replicas (each training vertex counted
    exactly once across the cluster).
    """
    logits = forward(spec, params, x, blk, sync)
    logp = jax.nn.log_softmax(logits, axis=-1)
    labels = jnp.maximum(blk.labels, 0)
    picked = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    weight = (blk.train_mask & blk.master & (blk.labels >= 0)).astype(jnp.float32)
    local_sum = -(picked * weight).sum()
    local_cnt = weight.sum()
    total = sync.psum(jnp.stack([local_sum, local_cnt]))
    return total[0] / jnp.maximum(total[1], 1.0)
