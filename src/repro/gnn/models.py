"""GNN models (GraphSAGE / GCN / GAT) as per-device JAX functions.

Every function here operates on ONE partition's local block:

  x      [Vloc+1, F]   local vertex states (last row = dummy/padding sink)
  esrc   [Eloc]        local src indices (pad -> dummy row)
  edst   [Eloc]        local dst indices
  degree [Vloc+1]      *global* symmetric degree of each local vertex
  master [Vloc+1]      bool, true where this partition owns the vertex

plus a `sync` strategy (repro.gnn.sync.SyncStrategy). Every edge aggregation
goes through `sync.edge_aggregate(blk, payload, msg_fn, ...)`, which returns
the COMPLETE global per-destination reduce regardless of how features are
laid out or moved — partial-aggregate completion (Local/Dense/Halo over an
`EdgePartitionBook`) or ring-pipelined block rotation (RingSync over a
`BlockRowBook`). With the `LocalSync` no-op the same code is the exact
single-machine model — that equivalence is the core system invariant and is
tested (distributed forward == single-device forward, allclose, for every
strategy).

Self terms (GCN's self-loop, GAT's self-edge) are added AFTER completion,
ungated: completed aggregates and x are replica-consistent, so the term is
counted exactly once per vertex under every strategy — including ring,
where no replicas exist at all.

Aggregation is over the symmetrised adjacency: each stored edge (u, v)
produces messages u->v and v->u (DGL-on-undirected semantics, which both
DistGNN and the paper's DistDGL setup use).

Models follow the paper's setup (§4.1/§5.1): GraphSAGE (mean), GCN, GAT.

Aggregation backend (`GNNSpec.agg_backend`): every edge aggregation — the
sum-aggregations AND GAT's per-destination softmax-stabilisation max — goes
through `kernels.ops.aggregate`, which dispatches on the knob —
  scatter — data-dependent `at[].add` / `at[].max` (the oracle)
  tiled   — pre-sorted/pre-blocked layout (`Block.agg_order`/`agg_ldst`,
            built by the partition book) through the tiled segment-reduce:
            jnp oracle off-TPU, the Pallas one-hot kernel on TPU. Backward
            of the sum is a plain gather (custom_vjp), so gradients match
            the scatter oracle to allclose; the stabilisation max is
            stop_gradient'd (exact — softmax is shift-invariant), so the
            O(E) edge-aggregation hot path of every model, GAT included,
            is scatter-free under tiled/pallas. (The k-way replica sync
            still scatters into its bucket-sized halo buffers —
            O(replicas), the network path, not the edge hot path.)
  pallas  — like tiled but forces the Pallas kernel (interpreted on CPU).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


@dataclasses.dataclass(frozen=True)
class GNNSpec:
    model: str = "sage"          # sage | gcn | gat
    feature_dim: int = 64
    hidden_dim: int = 64
    num_classes: int = 16
    num_layers: int = 2
    gat_heads: int = 4
    agg_backend: str = "scatter"  # scatter | tiled | pallas (ops.aggregate)

    def dims(self) -> list[tuple[int, int]]:
        ins = [self.feature_dim] + [self.hidden_dim] * (self.num_layers - 1)
        outs = [self.hidden_dim] * (self.num_layers - 1) + [self.num_classes]
        return list(zip(ins, outs))

    def aggregate_dims(self, mode: str = "halo") -> list[list[int]]:
        """Per layer, the wire width of every `sync.edge_aggregate` the
        layer issues, in issue order — the dims that actually cross the
        network, which depend on WHAT the strategy ships:

          halo/dense/local complete partial AGGREGATES (message rows):
            sage/gcn  [d_in]                (msg = masked src features)
            gat       [H, H, H·dh]          (max scores, exp-sum, weighted z)
          ring rotates the PAYLOAD itself:
            sage/gcn  [d_in]                (payload == message width)
            gat       [H, H+H·dh, H+H·dh]   (s_src, then the shared
                                             [s_src | z] for den and num)

        The byte accountants (`FullBatchTrainer.*_bytes_per_epoch`,
        `LayerwiseInference.sync_bytes`) and the runtime reconciliation
        gate sum `sync_*bytes_per_round` over exactly these widths, which
        is what makes measured-vs-model byte checks exact.
        """
        out = []
        for din, dout in self.dims():
            if self.model == "gat":
                h = self.gat_heads
                dh = max(dout // h, 1)
                if mode == "ring":
                    out.append([h, h + h * dh, h + h * dh])
                else:
                    out.append([h, h, h * dh])
            else:
                out.append([din])
        return out


def _glorot(rng: np.random.Generator, shape: tuple[int, ...]) -> jnp.ndarray:
    fan_in, fan_out = shape[0], shape[-1]
    limit = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jnp.asarray(rng.uniform(-limit, limit, size=shape), dtype=jnp.float32)


def init_params(spec: GNNSpec, seed: int = 0) -> Params:
    rng = np.random.default_rng(seed)
    layers = []
    for li, (din, dout) in enumerate(spec.dims()):
        if spec.model == "sage":
            layers.append({
                "w_self": _glorot(rng, (din, dout)),
                "w_neigh": _glorot(rng, (din, dout)),
                "b": jnp.zeros((dout,), jnp.float32),
            })
        elif spec.model == "gcn":
            layers.append({
                "w": _glorot(rng, (din, dout)),
                "b": jnp.zeros((dout,), jnp.float32),
            })
        elif spec.model == "gat":
            h = spec.gat_heads
            dh = max(dout // h, 1)
            layers.append({
                "w": _glorot(rng, (din, h * dh)),
                "a_src": _glorot(rng, (h, dh)),
                "a_dst": _glorot(rng, (h, dh)),
                "b": jnp.zeros((h * dh,), jnp.float32),
                "w_out": (_glorot(rng, (h * dh, dout))
                          if h * dh != dout else jnp.eye(h * dh, dtype=jnp.float32)),
            })
        else:
            raise ValueError(f"unknown model {spec.model!r}")
    return {"layers": layers}


# ---------------------------------------------------------------------------
# Aggregation primitives (local partials; `sync` completes them globally)
# ---------------------------------------------------------------------------


def sage_layer(p, x, blk, sync, *, final: bool,
               backend: str = "scatter") -> jnp.ndarray:
    agg = sync.edge_aggregate(
        blk, x, lambda src, dst, mask: src * mask[:, None], backend=backend)
    mean = agg / jnp.maximum(blk.degree, 1.0)[:, None]
    h = x @ p["w_self"] + mean @ p["w_neigh"] + p["b"]
    return h if final else jax.nn.relu(h)


def gcn_layer(p, x, blk, sync, *, final: bool,
              backend: str = "scatter") -> jnp.ndarray:
    dnorm = 1.0 / jnp.sqrt(blk.degree + 1.0)  # self-loop-augmented degree
    agg = sync.edge_aggregate(
        blk, x * dnorm[:, None],
        lambda src, dst, mask: src * mask[:, None], backend=backend)
    # Self-loop term after completion: the completed aggregate and x are
    # replica-consistent, so no master gating is needed.
    agg = agg + x * (dnorm * dnorm)[:, None]
    h = (agg * dnorm[:, None]) @ p["w"] + p["b"]
    return h if final else jax.nn.relu(h)


def gat_layer(p, x, blk, sync, *, final: bool,
              backend: str = "scatter") -> jnp.ndarray:
    n = x.shape[0]
    h_heads, dh = p["a_src"].shape
    z = (x @ p["w"]).reshape(n, h_heads, dh)
    s_src = jnp.einsum("nhd,hd->nh", z, p["a_src"])  # [n, H]
    s_dst = jnp.einsum("nhd,hd->nh", z, p["a_dst"])

    neg_inf = jnp.asarray(-1e30, x.dtype)

    def score(src_s, dst):
        # attention logit of an edge: src payload rows + the LOCAL dst table
        return jax.nn.leaky_relu(src_s + s_dst[dst], 0.2)

    # 1) global max per destination (for a stable softmax). Rows no valid
    # edge reaches come back at the -1e30 mask floor (scatter) or -inf
    # (tiled/pallas drop masked edges) — the e_self/-1e29 clamps below make
    # the backends agree exactly. Softmax is shift-invariant, so the shift
    # needs no gradient: stop_gradient is exact and keeps the backward free
    # of any scatter-max / argmax transpose (see ops.aggregate).
    m = sync.edge_aggregate(
        blk, s_src,
        lambda src, dst, mask: jnp.where(mask[:, None], score(src, dst),
                                         neg_inf),
        reduce="max", backend=backend)
    e_self = jax.nn.leaky_relu(s_src + s_dst, 0.2)
    m = jnp.maximum(m, e_self)
    m_safe = jax.lax.stop_gradient(jnp.maximum(m, -1e29))  # isolated vertices

    # 2) + 3) share ONE payload carrying [s_src | z]: a single rotation/
    # gather serves both the weight and the message, and — crucially for
    # lossy wire codecs — the denominator and numerator decode the SAME
    # encoded scores. Codec encoding is deterministic, so both aggregates
    # see bit-identical attention weights and the softmax normalisation
    # survives quantisation (separate payloads would quantise s_src at two
    # different per-tensor scales and bias num/den against each other).
    payload = jnp.concatenate([s_src, z.reshape(n, h_heads * dh)], axis=1)

    # 2) global sum of exp (self term added post-completion, ungated:
    # completed aggregates are replica-consistent)
    den = sync.edge_aggregate(
        blk, payload,
        lambda src, dst, mask: (jnp.exp(score(src[:, :h_heads], dst)
                                        - m_safe[dst]) * mask[:, None]),
        backend=backend)
    w_self = jnp.exp(e_self - m_safe)
    den = jnp.maximum(den + w_self, 1e-16)

    def weighted_msg(src, dst, mask):
        w = jnp.exp(score(src[:, :h_heads], dst) - m_safe[dst]) * mask[:, None]
        zf = src[:, h_heads:].reshape(-1, h_heads, dh)
        return (w[:, :, None] * zf).reshape(-1, h_heads * dh)

    num = sync.edge_aggregate(blk, payload, weighted_msg, backend=backend)
    num = num.reshape(n, h_heads, dh) + w_self[:, :, None] * z

    out = (num / den[:, :, None]).reshape(n, h_heads * dh) + p["b"]
    out = out @ p["w_out"]
    return out if final else jax.nn.elu(out)


_LAYERS = {"sage": sage_layer, "gcn": gcn_layer, "gat": gat_layer}


def forward(spec: GNNSpec, params: Params, x, blk, sync) -> jnp.ndarray:
    """Full model forward on one partition's block. Returns logits
    [Vloc+1, num_classes] (valid at every replica; loss is master-gated)."""
    layer_fn = _LAYERS[spec.model]
    h = x
    # aggregate ordinals restart per forward pass (VariableRatioCodec ramps
    # its compression ratio on them; a no-op for fixed-ratio codecs)
    reset = getattr(sync, "reset_layer_counter", None)
    if reset is not None:
        reset()
    n_layers = len(params["layers"])
    for li, p in enumerate(params["layers"]):
        h = layer_fn(p, h, blk, sync, final=(li == n_layers - 1),
                     backend=spec.agg_backend)
        # dummy row must stay zero: it is a scatter sink for padding
        h = h.at[-1].set(0.0)
    return h


def loss_fn(spec: GNNSpec, params: Params, x, blk, sync) -> jnp.ndarray:
    """Masked softmax cross-entropy, averaged over global training vertices.

    Loss is counted only at master replicas (each training vertex counted
    exactly once across the cluster).
    """
    logits = forward(spec, params, x, blk, sync)
    logp = jax.nn.log_softmax(logits, axis=-1)
    labels = jnp.maximum(blk.labels, 0)
    picked = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    weight = (blk.train_mask & blk.master & (blk.labels >= 0)).astype(jnp.float32)
    local_sum = -(picked * weight).sum()
    local_cnt = weight.sum()
    total = sync.psum(jnp.stack([local_sum, local_cnt]))
    return total[0] / jnp.maximum(total[1], 1.0)
