"""Distributed k-hop neighborhood sampling (DistDGL regime).

Sampling is data-dependent pointer chasing — it stays on the host (NumPy),
exactly where DistDGL runs it (CPU sampler processes), overlapped with device
compute. The sampled message-flow graphs (MFGs) are padded to static shapes
so the device step compiles once.

Layout convention (same as DGL's MFGs): the destination nodes of layer i are
a *prefix* of its source nodes, so self-features are `h_prev[:n_dst]`.

Per-step metrics mirror the paper's §5.1: number of input vertices, number of
remote input vertices (owned by another worker — the network-fetch set),
edges of the computation graph.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.core.graph import Graph
from repro.kernels.tiling import (
    DEFAULT_BLOCK_E,
    DEFAULT_TILE_V,
    prepare_tiled_edges,
    tiled_shape,
)

# Paper §5.1: fanouts per number of layers.
PAPER_FANOUTS = {2: (25, 20), 3: (15, 10, 5), 4: (10, 10, 5, 5)}


class LayerPad(NamedTuple):
    n_src: int
    n_dst: int
    n_edges: int

    def tiled_plan(self, fanout: int,
                   tile_v: int = DEFAULT_TILE_V,
                   block_e: int = DEFAULT_BLOCK_E) -> tuple[int, int]:
        """Static (n_tiles, per_tile) of this layer's tiled-aggregation
        layout. A row tile holds <= tile_v destination rows, each with at
        most `fanout` sampled in-edges, so per_tile is bounded without ever
        looking at a concrete batch — the pad plan stays static."""
        _, n_tiles = tiled_shape(self.n_dst + 1, tile_v)  # + padding sink row
        cap = min(self.n_edges, tile_v * fanout)
        per_tile = max(-(-cap // block_e), 1) * block_e
        return n_tiles, per_tile


@dataclasses.dataclass(frozen=True)
class SamplePlan:
    """Static padding plan for a (seeds, fanouts) configuration."""

    seeds: int
    fanouts: tuple[int, ...]
    layers: tuple[LayerPad, ...]  # ordered input-side -> output-side

    @classmethod
    def build(cls, seeds: int, fanouts: Sequence[int]) -> "SamplePlan":
        # layer L-1 consumes frontier_{L-1} -> produces the seed outputs.
        # Worst case frontier growth: n_{i+1} = n_i * (1 + fanout_i).
        fanouts = tuple(int(f) for f in fanouts)
        n = [seeds]
        for f in reversed(fanouts):  # from output side to input side
            n.append(n[-1] * (1 + f))
        n = list(reversed(n))  # n[0] = input frontier bound, n[-1] = seeds
        layers = []
        for i, f in enumerate(fanouts):
            n_src = n[i]
            n_dst = n[i + 1]
            layers.append(LayerPad(n_src=n_src, n_dst=n_dst, n_edges=n_dst * f))
        return cls(seeds=seeds, fanouts=fanouts, layers=tuple(layers))


class SampledLayer(NamedTuple):
    esrc: np.ndarray  # [n_edges] positions into this layer's src frontier
    edst: np.ndarray  # [n_edges] positions into the dst prefix
    emask: np.ndarray
    n_dst: np.ndarray  # scalar int32 (true dst count)
    sampled_deg: np.ndarray  # [n_dst_pad] float32: true #sampled in-neighbors
    # tiled aggregation layout (kernels.tiling.prepare_tiled_edges over the
    # real edges of this MFG layer; static shape = LayerPad.tiled_plan).
    # None unless the sampler was asked for it (tiled/pallas backends only).
    agg_order: Optional[np.ndarray] = None  # [E_tiled] int32 (pad -> n_edges)
    agg_ldst: Optional[np.ndarray] = None   # [E_tiled] int32 (pad -> tile_v)


class SampledBatch(NamedTuple):
    """One worker's mini-batch, padded to the plan. All numpy."""

    input_ids: np.ndarray     # [n_src_pad0] global vertex ids (pad -> -1)
    input_mask: np.ndarray
    layers: tuple[SampledLayer, ...]
    seed_labels: np.ndarray   # [seeds]
    seed_mask: np.ndarray
    # metrics
    num_input: int
    num_remote: int
    num_edges: int


def _sample_hop(
    indptr: np.ndarray,
    indices: np.ndarray,
    frontier: np.ndarray,
    fanout: int,
    rng: np.random.Generator,
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorised fanout sampling without replacement for a whole frontier.

    Returns (src_global_ids, dst_positions). O(E_frontier log E_frontier):
    expand all adjacency entries, give each a random key, keep the `fanout`
    smallest keys per destination segment.
    """
    deg = (indptr[frontier + 1] - indptr[frontier]).astype(np.int64)
    total = int(deg.sum())
    if total == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    cum = np.cumsum(deg) - deg
    # seg_off = offset within each destination's adjacency segment; after the
    # per-segment sort below it is ALSO the position within each dst group
    # (both are 0..deg-1 ramps over the same segments), so one repeat serves
    # both uses — tests pin the output against the two-repeat formulation
    seg_off = np.arange(total, dtype=np.int64) - np.repeat(cum, deg)
    all_pos = np.repeat(indptr[frontier], deg) + seg_off
    all_src = indices[all_pos].astype(np.int64)
    all_dst = np.repeat(np.arange(frontier.shape[0], dtype=np.int64), deg)
    keys = rng.random(total)
    order = np.lexsort((keys, all_dst))
    keep = order[seg_off < fanout]
    return all_src[keep], all_dst[keep]


def sample_blocks(
    graph: Graph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    plan: SamplePlan,
    rng: np.random.Generator,
    labels: np.ndarray,
    owner: Optional[np.ndarray] = None,
    worker: int = 0,
    tiled_layout: bool = False,
) -> SampledBatch:
    """Sample a k-hop MFG stack for `seeds` (innermost hop first in output).

    `tiled_layout` additionally attaches the per-layer tiled aggregation
    layout (agg_order/agg_ldst) — only the tiled/pallas backends read it, so
    the default scatter path skips the extra host argsort per layer."""
    indptr, indices = graph.csr()
    fanouts = tuple(int(f) for f in fanouts)

    frontier = np.asarray(seeds, dtype=np.int64)
    layer_edges: list[tuple[np.ndarray, np.ndarray]] = []  # (src_gid, dst_pos)
    frontiers: list[np.ndarray] = [frontier]
    in_frontier = np.zeros(graph.num_vertices, dtype=bool)
    in_frontier[frontier] = True

    # outermost loop runs from the seed side inward (hop L-1 ... 0)
    for f in reversed(fanouts):
        src_g, dst_p = _sample_hop(indptr, indices, frontier, f, rng)
        layer_edges.append((src_g, dst_p))
        # next frontier = dst prefix ∪ new sources (prefix convention)
        extra = np.unique(src_g[~in_frontier[src_g]])
        in_frontier[extra] = True
        frontier = np.concatenate([frontier, extra])
        frontiers.append(frontier)

    # frontiers[i] = frontier consumed by hop i counted from the seed side;
    # reverse everything into input-side-first order.
    layer_edges.reverse()
    frontiers.reverse()  # frontiers[0] = deepest (input) frontier

    layers: list[SampledLayer] = []
    pos_of = np.full(graph.num_vertices, -1, dtype=np.int64)
    for i, (src_g, dst_p) in enumerate(layer_edges):
        pad = plan.layers[i]
        src_frontier = frontiers[i]
        dst_count = frontiers[i + 1].shape[0]
        # map global src ids to positions in src_frontier (vectorised)
        pos_of[src_frontier] = np.arange(src_frontier.shape[0])
        src_pos = pos_of[src_g]
        n_e = src_pos.shape[0]
        if n_e > pad.n_edges:  # can't happen by construction, but guard
            raise AssertionError("sample overflow vs plan")
        esrc = np.full(pad.n_edges, pad.n_src, dtype=np.int32)  # pad -> dummy
        edst = np.full(pad.n_edges, pad.n_dst, dtype=np.int32)
        emask = np.zeros(pad.n_edges, dtype=bool)
        esrc[:n_e] = src_pos
        edst[:n_e] = dst_p
        emask[:n_e] = True
        deg = np.zeros(pad.n_dst + 1, dtype=np.float32)
        np.add.at(deg, dst_p, 1.0)
        agg_order = agg_ldst = None
        if tiled_layout:
            _, per_tile = pad.tiled_plan(fanouts[i])
            agg_order, agg_ldst, _ = prepare_tiled_edges(
                edst, pad.n_dst + 1, per_tile=per_tile, valid=emask,
            )
            agg_order = agg_order.astype(np.int32)
        layers.append(
            SampledLayer(
                esrc=esrc, edst=edst, emask=emask,
                n_dst=np.int32(dst_count), sampled_deg=deg,
                agg_order=agg_order,
                agg_ldst=agg_ldst,
            )
        )

    inputs = frontiers[0]
    pad0 = plan.layers[0].n_src
    input_ids = np.full(pad0, -1, dtype=np.int64)
    input_ids[: inputs.shape[0]] = inputs
    input_mask = input_ids >= 0

    num_remote = int((owner[inputs] != worker).sum()) if owner is not None else 0
    seed_labels = np.full(plan.seeds, -1, dtype=np.int32)
    seed_labels[: seeds.shape[0]] = labels[seeds]
    seed_mask = np.zeros(plan.seeds, dtype=bool)
    seed_mask[: seeds.shape[0]] = True

    return SampledBatch(
        input_ids=input_ids,
        input_mask=input_mask,
        layers=tuple(layers),
        seed_labels=seed_labels,
        seed_mask=seed_mask,
        num_input=int(inputs.shape[0]),
        num_remote=num_remote,
        num_edges=int(sum(int(l.emask.sum()) for l in layers)),
    )
