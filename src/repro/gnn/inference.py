"""Distributed layer-wise full-graph GNN inference (the serving substrate).

Training samples neighborhoods because a k-hop receptive field explodes;
inference over *all* vertices does not need to: computing every vertex's
layer-l embedding before any layer-(l+1) embedding touches each edge exactly
once per layer — the standard layer-wise trick (DGL's
`inference()` idiom, PinSAGE's MapReduce stage). Distribution reuses the
training substrate unchanged:

  * the graph is partitioned by the existing `EdgePartitionBook`; each
    partition runs the per-device layer functions from `gnn/models.py`
    (aggregating through `kernels.ops.aggregate`, so the tiled/pallas
    backends run scatter-free) with halo exchange via `gnn/sync.py` —
    so layer-wise inference == the full-batch forward, allclose, by
    construction (tested per backend);
  * after each layer the master rows are gathered into a global [V, d_l]
    embedding matrix and frozen into a `RowStore` (feature_store.py) — the
    per-layer **embedding store** that the online serving path
    (`repro.serve`) answers requests from, with the same
    {local, cache-hit, remote-miss} accounting and cache policies as the
    training-time feature store.

The engine is offline/batch (run once per model snapshot, amortised over
millions of requests); `repro.serve.engine` is the online half.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.partition_book import (
    EdgePartitionBook,
    VertexPartitionBook,
    build_edge_book,
    build_vertex_book,
)
from repro.gnn import models
from repro.gnn.feature_store import RowStore, select_cache_vertices
from repro.gnn.models import GNNSpec
from repro.gnn.sync import Block, build_blocks, make_sync, sync_bytes_per_round
from repro.obs.trace import get_tracer

AXIS = "parts"

__all__ = [
    "LayerwiseInference",
    "build_embedding_stores",
    "edge_assignment_from_vertex",
]


def edge_assignment_from_vertex(graph: Graph, owner: np.ndarray) -> np.ndarray:
    """Edge partition induced by a vertex partition: each edge lives with its
    destination's owner (DistDGL's convention), so the layer-wise engine can
    run over graphs that were partitioned for the mini-batch regime."""
    return np.asarray(owner, dtype=np.int64)[graph.dst]


@dataclasses.dataclass
class LayerwiseInference:
    """Compute all layer-l embeddings for every vertex before layer l+1.

    One jitted step per layer (vmap over the k stacked partition blocks, or
    the bare block for k=1 — same wrapping as `FullBatchTrainer`); between
    layers the completed states stay on device, and the master rows of each
    layer are gathered host-side into the global [V, d_l] matrices that the
    embedding stores are built from.
    """

    spec: GNNSpec
    book: EdgePartitionBook
    blocks: Block
    params: Any
    sync_mode: str = "halo"
    # measured by the last run(): seconds per layer, host wall clock
    layer_times: Optional[list] = None

    @classmethod
    def build(
        cls,
        graph: Graph,
        edge_assignment: np.ndarray,
        k: int,
        spec: GNNSpec,
        params: Any,
        features: np.ndarray,
        *,
        sync_mode: str = "halo",
    ) -> "LayerwiseInference":
        book = build_edge_book(
            graph, edge_assignment, k,
            tiled_layout=(spec.agg_backend != "scatter"),
        )
        zeros = np.zeros(graph.num_vertices, dtype=np.int32)
        blocks = build_blocks(book, features.astype(np.float32), zeros,
                              zeros.astype(bool))
        return cls(spec=spec, book=book, blocks=blocks, params=params,
                   sync_mode=sync_mode)

    # ------------------------------------------------------------------ jit
    def _per_device_layer(self, li: int):
        """Per-device layer function (p, x, blk) -> states — the unit both
        `_layer_steps` jits and `layer_jaxprs` traces for analysis."""
        spec, book, sync_mode = self.spec, self.book, self.sync_mode
        layer_fn = models._LAYERS[spec.model]
        final = li == spec.num_layers - 1

        def per_device(p, x, blk: Block):
            mode = "local" if book.k == 1 else sync_mode
            sync = make_sync(mode, blk, book.num_vertices, AXIS)
            h = layer_fn(p, x, blk, sync, final=final,
                         backend=spec.agg_backend)
            # dummy row must stay zero: it is a scatter sink for padding
            return h.at[-1].set(0.0)

        return per_device

    @functools.cached_property
    def _layer_steps(self) -> list:
        """One jitted (params_l, states, blocks) -> states function per
        layer. Compiled lazily on first use; static across runs."""
        book = self.book

        def make(li: int):
            per_device = self._per_device_layer(li)
            if book.k == 1:
                def single(p, states, blocks):
                    blk = jax.tree.map(lambda a: a[0], blocks)
                    return per_device(p, states[0], blk)[None]
                return jax.jit(single)
            return jax.jit(jax.vmap(per_device, in_axes=(None, 0, 0),
                                    axis_name=AXIS))

        return [make(li) for li in range(self.spec.num_layers)]

    def layer_jaxprs(self) -> list:
        """Traced per-layer jaxprs (one ClosedJaxpr per layer) — what the
        analysis rules (no-scatter, dtype-policy) walk for this entry
        point. Trace only: nothing compiles, nothing runs."""
        n_rows = int(self.blocks.x.shape[-2])
        jaxprs = []
        for li in range(self.spec.num_layers):
            per_device = self._per_device_layer(li)
            din = self.spec.dims()[li][0]
            if self.book.k == 1:
                blk0 = jax.tree.map(lambda a: a[0], self.blocks)
                jaxprs.append(jax.make_jaxpr(per_device)(
                    self.params["layers"][li],
                    jnp.zeros((n_rows, din), jnp.float32), blk0))
            else:
                jaxprs.append(jax.make_jaxpr(
                    jax.vmap(per_device, in_axes=(None, 0, 0),
                             axis_name=AXIS))(
                    self.params["layers"][li],
                    jnp.zeros((self.book.k, n_rows, din), jnp.float32),
                    self.blocks))
        return jaxprs

    # ------------------------------------------------------------------ api
    def run(self) -> list:
        """Full layer-wise pass. Returns the per-layer global embedding
        matrices [V, d_l] (layer outputs, input-side first; the last entry
        is the final-layer logits)."""
        states = self.blocks.x  # [k, Vloc+1, F]
        outs: list[np.ndarray] = []
        times: list[float] = []
        tracer = get_tracer()
        for li, step in enumerate(self._layer_steps):
            # layer_times are the span durations — one timing source
            with tracer.span("inference.layer", cat="inference",
                             args={"layer": li}) as sp:
                states = step(self.params["layers"][li], states, self.blocks)
                states.block_until_ready()
            times.append(sp.duration)
            outs.append(self.book.scatter_to_global(np.asarray(states)))
        self.layer_times = times
        return outs

    def sync_bytes(self) -> int:
        """Analytic halo traffic of one full layer-wise pass (forward only —
        inference has no backward): every aggregate priced at its true
        payload width (`GNNSpec.aggregate_dims`)."""
        return sum(
            sync_bytes_per_round(self.book, d, self.sync_mode)
            for layer_dims in self.spec.aggregate_dims(self.sync_mode)
            for d in layer_dims
        )


def build_embedding_stores(
    graph: Graph,
    book: VertexPartitionBook,
    embeddings: list,
    *,
    policy: str = "none",
    budget: int = 0,
    seed: int = 0,
    codec=None,
) -> list:
    """Freeze per-layer embeddings into `RowStore`s sharded by `book`.

    The cache-vertex selection (same four policies as the feature store) is
    computed ONCE from static graph information and shared by every layer's
    store — at serving time a vertex that is worth caching is worth caching
    at every layer it is read from.
    """
    ids = select_cache_vertices(graph, book, policy, budget, seed=seed)
    return [
        RowStore.create(book, ids, rows=np.asarray(h, dtype=np.float32),
                        policy=policy, budget=budget, codec=codec)
        for h in embeddings
    ]


def vertex_book_for(graph: Graph, book: EdgePartitionBook) -> VertexPartitionBook:
    """The vertex-partition book induced by an edge partition's masters —
    the sharding the serving path uses when training partitioned edges."""
    return build_vertex_book(graph, book.master_assignment(), book.k)
