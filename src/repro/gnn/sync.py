"""Replica-synchronisation strategies for edge-partitioned full-batch GNNs.

Three interchangeable implementations of the same contract (complete the
partial aggregates that per-partition scatter-sums produce):

  LocalSync  — no-op; correct only for k=1. The single-machine oracle.
  DenseSync  — scatter into a global [V, d] buffer and `psum` it. Volume is
               O(V·d) per sync, *independent of partitioning quality*. This
               is the naive baseline the halo exchange is measured against.
  HaloSync   — static-routed all_to_all using the partition book's replica
               lists. One reduce+broadcast pair moves 2·k·B·d elements per
               device (B = max pair bucket) = 2·k²·B·d·4 bytes cluster-wide
               (`sync_bytes_per_round`, pinned against the compiled HLO in
               tests/test_dist_lowering.py). The volume tracks the
               replication factor — the paper's key mechanism, expressed in
               XLA-compilable form (DESIGN.md §2).

All three work identically under `jax.vmap(axis_name=...)` (CPU simulation of
k workers) and `jax.shard_map` (real meshes / the multi-pod dry-run), because
they only use axis-name collectives.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition_book import EdgePartitionBook


class Block(NamedTuple):
    """One partition's static device block (all jnp arrays, pytree-able).

    Leading [k, ...] when stacked for vmap/shard_map; per-device inside.
    """

    x: jnp.ndarray           # [Vloc+1, F] features
    labels: jnp.ndarray      # [Vloc+1] int32 (-1 pad)
    train_mask: jnp.ndarray  # [Vloc+1] bool
    esrc: jnp.ndarray        # [Eloc] int32
    edst: jnp.ndarray        # [Eloc] int32
    emask: jnp.ndarray       # [Eloc] bool
    degree: jnp.ndarray      # [Vloc+1] float32 (global symmetric degree)
    master: jnp.ndarray      # [Vloc+1] bool
    vmask: jnp.ndarray       # [Vloc+1] bool
    send_idx: jnp.ndarray    # [k, B] int32
    send_mask: jnp.ndarray   # [k, B] bool
    recv_idx: jnp.ndarray    # [k, B] int32
    recv_mask: jnp.ndarray   # [k, B] bool
    vglobal: jnp.ndarray     # [Vloc+1] int32 (pad -> V, the global dummy row)
    # tiled aggregation layout over the symmetrised edge list [edst | esrc]
    # (kernels.ops.prepare_tiled_edges; used by the tiled/pallas backends)
    agg_order: jnp.ndarray   # [E_tiled] int32 (pad -> 2*Eloc)
    agg_ldst: jnp.ndarray    # [E_tiled] int32 (pad -> tile_v)


def build_blocks(
    book: EdgePartitionBook,
    features: np.ndarray,
    labels: np.ndarray,
    train_mask: np.ndarray,
) -> Block:
    """Stacked [k, ...] Block from a partition book + global node data."""
    x = book.local_features(features.astype(np.float32))
    # one dummy row is already included (index v_max)
    lab = book.local_labels(labels.astype(np.int32))
    tm = np.zeros((book.k, book.v_max + 1), dtype=bool)
    safe = np.where(book.vglobal >= 0, book.vglobal, 0)
    tm[:] = train_mask[safe]
    tm &= book.vmask
    vg = np.where(book.vglobal >= 0, book.vglobal, book.num_vertices)
    return Block(
        x=jnp.asarray(x),
        labels=jnp.asarray(lab),
        train_mask=jnp.asarray(tm),
        esrc=jnp.asarray(book.esrc),
        edst=jnp.asarray(book.edst),
        emask=jnp.asarray(book.emask),
        degree=jnp.asarray(book.degree),
        master=jnp.asarray(book.master),
        vmask=jnp.asarray(book.vmask),
        send_idx=jnp.asarray(book.send_idx),
        send_mask=jnp.asarray(book.send_mask),
        recv_idx=jnp.asarray(book.recv_idx),
        recv_mask=jnp.asarray(book.recv_mask),
        vglobal=jnp.asarray(vg.astype(np.int32)),
        agg_order=jnp.asarray(book.agg_order),
        agg_ldst=jnp.asarray(book.agg_ldst),
    )


# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LocalSync:
    """k=1: partial aggregates are already complete."""

    def reduce_sum(self, h):
        return h

    def reduce_max(self, h):
        return h

    def broadcast(self, h):
        return h

    def psum(self, v):
        return v


@dataclasses.dataclass(frozen=True)
class DenseSync:
    """Naive baseline: materialise the global vertex state and psum it."""

    blk: Block
    num_vertices: int
    axis: str

    def _to_global(self, h):
        g = jnp.zeros((self.num_vertices + 1, h.shape[-1]), h.dtype)
        g = g.at[self.blk.vglobal].add(h * self.blk.vmask[:, None])
        return g

    def reduce_sum(self, h):
        g = jax.lax.psum(self._to_global(h), self.axis)
        return g[self.blk.vglobal] * self.blk.vmask[:, None]

    def reduce_max(self, h):
        g = jnp.full((self.num_vertices + 1, h.shape[-1]), -1e30, h.dtype)
        g = g.at[self.blk.vglobal].max(jnp.where(self.blk.vmask[:, None], h, -1e30))
        g = jax.lax.pmax(g, self.axis)
        return jnp.where(self.blk.vmask[:, None], g[self.blk.vglobal], h)

    def broadcast(self, h):
        # reduce already produced globally-complete values on every replica
        return h

    def psum(self, v):
        return jax.lax.psum(v, self.axis)


@dataclasses.dataclass(frozen=True)
class HaloSync:
    """Static-routed replica synchronisation (the paper-faithful path).

    reduce_*: every mirror packs its partial rows for each master partition
    into fixed buckets; one all_to_all later, masters scatter-accumulate.
    broadcast: the exact reverse routing pushes completed rows back.
    """

    blk: Block
    axis: str

    def _exchange(self, buf):
        # buf [k, B, d]; result[j] = what device j sent to me
        return jax.lax.all_to_all(buf, self.axis, split_axis=0, concat_axis=0)

    def reduce_sum(self, h):
        blk = self.blk
        send = h[blk.send_idx] * blk.send_mask[..., None]
        recv = self._exchange(send)
        # pads point at the dummy row and carry zeros -> harmless adds
        return h.at[blk.recv_idx].add(recv)

    def reduce_max(self, h):
        blk = self.blk
        send = jnp.where(blk.send_mask[..., None], h[blk.send_idx], -1e30)
        recv = self._exchange(send)
        return h.at[blk.recv_idx].max(jnp.where(blk.recv_mask[..., None], recv, -1e30))

    def broadcast(self, h):
        blk = self.blk
        send = h[blk.recv_idx] * blk.recv_mask[..., None]
        recv = self._exchange(send)
        current = h[blk.send_idx]
        updated = jnp.where(blk.send_mask[..., None], recv, current)
        return h.at[blk.send_idx].set(updated)

    def psum(self, v):
        return jax.lax.psum(v, self.axis)


def make_sync(mode: str, blk: Block, num_vertices: int, axis: str):
    if mode == "local":
        return LocalSync()
    if mode == "dense":
        return DenseSync(blk=blk, num_vertices=num_vertices, axis=axis)
    if mode == "halo":
        return HaloSync(blk=blk, axis=axis)
    raise ValueError(f"unknown sync mode {mode!r}")


def sync_bytes_per_round(book: EdgePartitionBook, d: int, mode: str) -> int:
    """Analytic collective volume of ONE reduce+broadcast pair, all devices.

    Used by the study harness and checked against the dry-run HLO.
    """
    if mode == "halo":
        # each of k devices sends a [k, B, d] f32 buffer per all_to_all and a
        # reduce+broadcast pair is 2 exchanges: 2·k²·B·d·4 bytes cluster-wide
        # (= 2·k·B·d elements per device, as the HaloSync docstring states)
        return 2 * book.k * book.k * book.bucket * d * 4
    if mode == "dense":
        # psum of [V+1, d] on k devices (ring all-reduce ~ 2x payload)
        return 2 * book.k * (book.num_vertices + 1) * d * 4
    return 0
