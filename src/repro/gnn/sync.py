"""Synchronisation strategies for distributed full-batch GNNs.

The `SyncStrategy` protocol is ONE method every model layer builds on:

    edge_aggregate(blk, payload, msg_fn, *, reduce, backend) -> [Vloc+1, d]

Give it per-vertex payload rows and a message function
`msg_fn(src_rows, dst_idx, edge_mask) -> [E, d]`; it returns the COMPLETE
(globally consistent) per-destination aggregate over the symmetrised
adjacency. `psum(v)` completes scalars (the loss). How completion happens —
and what it costs — is the strategy:

  LocalSync  — k=1 oracle: one local `ops.aggregate` pass, nothing moves.
  DenseSync  — aggregate locally, scatter into a global [V+1, d] buffer and
               `psum` it. Volume O(V·d) per sync, *independent of
               partitioning quality* — the naive baseline.
  HaloSync   — aggregate locally, then complete replicas via static-routed
               all_to_all from the partition book's replica lists. One
               reduce+broadcast pair moves 2·k·B·d elements per device
               (B = max pair bucket) = 2·k²·B·d·4 bytes cluster-wide
               (`sync_bytes_per_round`, pinned against compiled HLO in
               tests/test_dist_lowering.py). Volume tracks the replication
               factor — the paper's key mechanism (README architecture map).
  RingSync   — 1.5D block rotation (CAGNET regime, `BlockRowBook`): no
               replicas exist, so nothing is "completed" — instead the
               payload blocks rotate around a `lax.ppermute` ring. Stage s
               aggregates the pre-rotated edge chunk (dst local, src in the
               currently-held block) while the next block is in flight:
               k−1 `ppermute` stages of (V/k + 1)·d elements each per
               device, i.e. k·(k−1)·(V/k + 1)·d·4 bytes cluster-wide per
               aggregate — compare halo's 2·k²·B·d·4 (replication-
               dependent) and dense's 2·k·(V+1)·d·4 (always worst-case).
               Per round, ring < dense for every k ≥ 2 since
               (k−1)/k · V < 2·V; no second broadcast pass is needed
               because block rows are owned exactly once.

Per-aggregate collective volume (cluster-wide; wire bytes are the same
formulas with the f32 element replaced by `codec.wire_bytes`, see
`sync_wire_bytes_per_round`):

    strategy   logical bytes (fp32)          wire bytes (codec c)
    dense      2·k·(V+1)·d·4                 2·k·c.wire_bytes((V+1, d))
    halo       2·k²·B·d·4                    2·k·c.wire_bytes((k, B, d))
    ring       k·(k−1)·(Vb+1)·d·4            k·(k−1)·c.wire_bytes((Vb+1, d))

Every strategy carries a `codec` (repro/core/wire.py, default fp32 ==
today's bytes): payloads encode BEFORE the collective and decode after, so
the compiled HLO moves the compressed dtype — `all_to_all`/`ppermute` of
int8 is ¼ the bytes, pinned in tests/test_dist_lowering.py. The fp32 codec
is the identity, keeping the default trace bitwise-identical to the
pre-codec code. Lossy caveats: DenseSync's `reduce_max` and the -1e30 mask
fills stay f32 (an extreme fill through a per-tensor scale would erase the
signal; the receiver re-masks, so fills never influence results anyway).

Local/Dense/Halo additionally keep their historical low-level surface
(`reduce_sum` / `reduce_max` / `broadcast`) — partial-aggregate completion —
which `edge_aggregate` composes; RingSync has no such decomposition (the
communication IS the aggregation loop).

All strategies work identically under `jax.vmap(axis_name=...)` (CPU
simulation of k workers) and `jax.shard_map` (real meshes / the multi-pod
dry-run), because they only use axis-name collectives.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition_book import BlockRowBook, EdgePartitionBook
from repro.core.wire import Codec, as_codec
from repro.kernels import ops
from repro.obs.trace import get_tracer


def _nbytes(x) -> int:
    """Static byte size of an array/tracer from its aval (shape/dtype are
    concrete even under vmap/jit tracing; scalars count their itemsize)."""
    if x is None:
        return 0
    return int(np.prod(x.shape)) * int(np.dtype(x.dtype).itemsize)


def _record_collective(kind: str, cluster_bytes: int,
                       wire_bytes: Optional[int] = None, *,
                       layer: int = 0) -> None:
    """Report one collective to the installed tracer at jax TRACE time.

    Fires once per compilation (not per executed step) — the runtime
    reconciliation gate compares these single-trace totals against
    `collective_budget`/`sync_wire_bytes_per_round` for one forward pass.
    Loss-scalar psums are deliberately not recorded: the budget's scope is
    "per complete aggregate", matching the static gate.
    """
    tr = get_tracer()
    if tr.enabled:
        tr.collective(kind, cluster_bytes, wire_bytes=wire_bytes,
                      layer=layer)


class Block(NamedTuple):
    """One partition's static device block (all jnp arrays, pytree-able).

    Leading [k, ...] when stacked for vmap/shard_map; per-device inside.
    """

    x: jnp.ndarray           # [Vloc+1, F] features
    labels: jnp.ndarray      # [Vloc+1] int32 (-1 pad)
    train_mask: jnp.ndarray  # [Vloc+1] bool
    esrc: jnp.ndarray        # [Eloc] int32
    edst: jnp.ndarray        # [Eloc] int32
    emask: jnp.ndarray       # [Eloc] bool
    degree: jnp.ndarray      # [Vloc+1] float32 (global symmetric degree)
    master: jnp.ndarray      # [Vloc+1] bool
    vmask: jnp.ndarray       # [Vloc+1] bool
    send_idx: jnp.ndarray    # [k, B] int32
    send_mask: jnp.ndarray   # [k, B] bool
    recv_idx: jnp.ndarray    # [k, B] int32
    recv_mask: jnp.ndarray   # [k, B] bool
    vglobal: jnp.ndarray     # [Vloc+1] int32 (pad -> V, the global dummy row)
    # tiled aggregation layout over the symmetrised edge list [edst | esrc]
    # (kernels.ops.prepare_tiled_edges; used by the tiled/pallas backends)
    agg_order: jnp.ndarray   # [E_tiled] int32 (pad -> 2*Eloc)
    agg_ldst: jnp.ndarray    # [E_tiled] int32 (pad -> tile_v)


def build_blocks(
    book: EdgePartitionBook,
    features: np.ndarray,
    labels: np.ndarray,
    train_mask: np.ndarray,
) -> Block:
    """Stacked [k, ...] Block from a partition book + global node data."""
    x = book.local_features(features.astype(np.float32))
    # one dummy row is already included (index v_max)
    lab = book.local_labels(labels.astype(np.int32))
    tm = np.zeros((book.k, book.v_max + 1), dtype=bool)
    safe = np.where(book.vglobal >= 0, book.vglobal, 0)
    tm[:] = train_mask[safe]
    tm &= book.vmask
    vg = np.where(book.vglobal >= 0, book.vglobal, book.num_vertices)
    return Block(
        x=jnp.asarray(x),
        labels=jnp.asarray(lab),
        train_mask=jnp.asarray(tm),
        esrc=jnp.asarray(book.esrc),
        edst=jnp.asarray(book.edst),
        emask=jnp.asarray(book.emask),
        degree=jnp.asarray(book.degree),
        master=jnp.asarray(book.master),
        vmask=jnp.asarray(book.vmask),
        send_idx=jnp.asarray(book.send_idx),
        send_mask=jnp.asarray(book.send_mask),
        recv_idx=jnp.asarray(book.recv_idx),
        recv_mask=jnp.asarray(book.recv_mask),
        vglobal=jnp.asarray(vg.astype(np.int32)),
        agg_order=jnp.asarray(book.agg_order),
        agg_ldst=jnp.asarray(book.agg_ldst),
    )


# ---------------------------------------------------------------------------
# SyncStrategy protocol
# ---------------------------------------------------------------------------


class _CodecSync:
    """Wire-codec plumbing shared by every strategy.

    Strategies are frozen dataclasses, so the trace-time aggregate counter
    lives behind `object.__setattr__`. `models.forward` resets it at the
    top of each forward pass, making the ordinal "which aggregate of this
    forward is encoding" — the depth signal `VariableRatioCodec` ramps on.
    Python-level state only: it is fixed at trace time, never a tracer.
    """

    def _codec(self) -> Codec:
        return as_codec(getattr(self, "codec", None))

    def reset_layer_counter(self) -> None:
        object.__setattr__(self, "_agg_layer", 0)

    def _take_layer(self) -> int:
        layer = int(getattr(self, "_agg_layer", 0))
        object.__setattr__(self, "_agg_layer", layer + 1)
        return layer


class _PartialAggSync(_CodecSync):
    """Shared `edge_aggregate` for the partial-aggregate family.

    Local/Dense/Halo all follow the same recipe: reduce messages over the
    symmetrised local edge list (both directions of every stored edge, via
    `ops.aggregate` so scatter/tiled/pallas backends all serve), then
    complete the per-partition partials with the strategy's reduce+broadcast
    pair. `msg_fn(src_rows, dst_idx, edge_mask)` sees the payload rows
    gathered at the edge's source and the LOCAL destination index (for
    destination-side tables such as GAT's softmax shift).
    """

    def edge_aggregate(self, blk: "Block", payload, msg_fn, *,
                       reduce: str = "sum", backend: str = "scatter"):
        object.__setattr__(self, "_cur_layer", self._take_layer())
        n = payload.shape[0]
        messages = jnp.concatenate([
            msg_fn(payload[blk.esrc], blk.edst, blk.emask),
            msg_fn(payload[blk.edst], blk.esrc, blk.emask),
        ], axis=0)
        dst = jnp.concatenate([blk.edst, blk.esrc], axis=0)
        agg = ops.aggregate(
            messages, dst, n,
            edge_order=blk.agg_order, local_dst=blk.agg_ldst,
            backend=backend, reduce=reduce,
        )
        agg = self.reduce_max(agg) if reduce == "max" else self.reduce_sum(agg)
        return self.broadcast(agg)


@dataclasses.dataclass(frozen=True)
class LocalSync(_PartialAggSync):
    """k=1: partial aggregates are already complete (codec: nothing moves)."""

    codec: Optional[Union[str, Codec]] = None

    def reduce_sum(self, h):
        return h

    def reduce_max(self, h):
        return h

    def broadcast(self, h):
        return h

    def psum(self, v):
        return v


@dataclasses.dataclass(frozen=True)
class DenseSync(_PartialAggSync):
    """Naive baseline: materialise the global vertex state and psum it."""

    blk: Block
    num_vertices: int
    axis: str
    codec: Optional[Union[str, Codec]] = None

    def _to_global(self, h):
        g = jnp.zeros((self.num_vertices + 1, h.shape[-1]), h.dtype)
        g = g.at[self.blk.vglobal].add(h * self.blk.vmask[:, None])
        return g

    def reduce_sum(self, h):
        codec = self._codec()
        g = self._to_global(h)
        if not codec.lossless:
            # quantise the per-device partial BEFORE the psum (the reduce
            # sums dequantised views — same semantics as compressed_psum)
            payload, meta = codec.encode(
                g, layer=getattr(self, "_cur_layer", 0))
            g = codec.decode(payload, meta)
        # wire_bytes=None: the reduce moves the DEQUANTISED f32 view, so
        # the transport-model formula (2x encoded) intentionally diverges
        _record_collective("all-reduce",
                           self.blk.send_idx.shape[0] * _nbytes(g),
                           layer=getattr(self, "_cur_layer", 0))
        g = jax.lax.psum(g, self.axis)
        return g[self.blk.vglobal] * self.blk.vmask[:, None]

    def reduce_max(self, h):
        g = jnp.full((self.num_vertices + 1, h.shape[-1]), -1e30, h.dtype)
        g = g.at[self.blk.vglobal].max(jnp.where(self.blk.vmask[:, None], h, -1e30))
        _record_collective("all-reduce",
                           self.blk.send_idx.shape[0] * _nbytes(g),
                           layer=getattr(self, "_cur_layer", 0))
        g = jax.lax.pmax(g, self.axis)
        return jnp.where(self.blk.vmask[:, None], g[self.blk.vglobal], h)

    def broadcast(self, h):
        # reduce already produced globally-complete values on every replica
        return h

    def psum(self, v):
        return jax.lax.psum(v, self.axis)


@dataclasses.dataclass(frozen=True)
class HaloSync(_PartialAggSync):
    """Static-routed replica synchronisation (the paper-faithful path).

    reduce_*: every mirror packs its partial rows for each master partition
    into fixed buckets; one all_to_all later, masters scatter-accumulate.
    broadcast: the exact reverse routing pushes completed rows back.

    The codec brackets `_exchange`: the [k, B, d] bucket buffer encodes
    before the all_to_all (the HLO moves the compressed dtype) and decodes
    after. Scale meta is per SENDER, so it travels by `all_gather` — after
    the all_to_all, received bucket j was encoded by device j, i.e. decoded
    with gathered meta[j]. Lossy `reduce_max` sends 0.0 in masked slots
    instead of -1e30 (the receiver re-masks, so the fill is inert either
    way; an extreme fill would destroy a per-tensor int8 scale).
    """

    blk: Block
    axis: str
    codec: Optional[Union[str, Codec]] = None

    def _exchange(self, buf):
        # buf [k, B, d]; result[j] = what device j sent to me
        codec = self._codec()
        lay = getattr(self, "_cur_layer", 0)
        payload, meta = codec.encode(buf, layer=lay)
        k = payload.shape[0]
        pb, mb = _nbytes(payload), _nbytes(meta)
        # cluster bytes follow the HLO output-shape convention (k devices x
        # per-device [k, B, d] payload); wire bytes add the sender meta
        _record_collective("all-to-all", k * pb, k * (pb + mb), layer=lay)
        out = jax.lax.all_to_all(payload, self.axis,
                                 split_axis=0, concat_axis=0)
        if meta is not None:
            # [k] sender scales, ordered by device index == bucket index
            _record_collective("all-gather", k * k * mb, layer=lay)
            meta = jax.lax.all_gather(meta, self.axis).reshape(-1, 1, 1)
        return codec.decode(out, meta)

    def reduce_sum(self, h):
        blk = self.blk
        send = h[blk.send_idx] * blk.send_mask[..., None]
        recv = self._exchange(send)
        # pads point at the dummy row and carry zeros -> harmless adds
        return h.at[blk.recv_idx].add(recv)

    def reduce_max(self, h):
        blk = self.blk
        fill = 0.0 if not self._codec().lossless else -1e30
        send = jnp.where(blk.send_mask[..., None], h[blk.send_idx], fill)
        recv = self._exchange(send)
        return h.at[blk.recv_idx].max(jnp.where(blk.recv_mask[..., None], recv, -1e30))

    def broadcast(self, h):
        blk = self.blk
        send = h[blk.recv_idx] * blk.recv_mask[..., None]
        recv = self._exchange(send)
        current = h[blk.send_idx]
        updated = jnp.where(blk.send_mask[..., None], recv, current)
        return h.at[blk.send_idx].set(updated)

    def psum(self, v):
        return jax.lax.psum(v, self.axis)


# ---------------------------------------------------------------------------
# RingSync (1.5D block rotation over a BlockRowBook)
# ---------------------------------------------------------------------------


class RingBlock(NamedTuple):
    """One block row's static device state (stacked [k, ...] for SPMD).

    Same row layout as `Block` (dummy row at index v_block) so the model
    code is identical; the halo routing tables are replaced by the
    pre-rotated ring chunks.
    """

    x: jnp.ndarray            # [Vb+1, F] features of the OWNED block
    labels: jnp.ndarray       # [Vb+1] int32 (-1 pad)
    train_mask: jnp.ndarray   # [Vb+1] bool
    degree: jnp.ndarray       # [Vb+1] float32 global symmetric degree
    master: jnp.ndarray       # [Vb+1] bool (== vmask: single-owner layout)
    vmask: jnp.ndarray        # [Vb+1] bool
    vglobal: jnp.ndarray      # [Vb+1] int32 (pad -> V)
    # pre-rotated edge chunks: row s = the directed edges whose src lives in
    # the block this device holds at ring stage s (dst indices are local)
    chunk_esrc: jnp.ndarray   # [k, c_max] int32 (pad -> Vb dummy row)
    chunk_edst: jnp.ndarray   # [k, c_max] int32
    chunk_emask: jnp.ndarray  # [k, c_max] bool
    # per-chunk tiled layouts ([k, 0] when built without tiled_layout)
    chunk_agg_order: jnp.ndarray  # [k, E_tiled] int32
    chunk_agg_ldst: jnp.ndarray   # [k, E_tiled] int32


def build_ring_blocks(
    book: BlockRowBook,
    features: np.ndarray,
    labels: np.ndarray,
    train_mask: np.ndarray,
) -> RingBlock:
    """Stacked [k, ...] RingBlock from a 1.5D book + global node data."""
    x = book.local_features(features.astype(np.float32))
    lab = book.local_labels(labels.astype(np.int32))
    tm = np.zeros((book.k, book.v_block + 1), dtype=bool)
    safe = np.where(book.vglobal >= 0, book.vglobal, 0)
    tm[:] = train_mask[safe]
    tm &= book.vmask
    vg = np.where(book.vglobal >= 0, book.vglobal, book.num_vertices)
    return RingBlock(
        x=jnp.asarray(x),
        labels=jnp.asarray(lab),
        train_mask=jnp.asarray(tm),
        degree=jnp.asarray(book.degree),
        master=jnp.asarray(book.vmask),
        vmask=jnp.asarray(book.vmask),
        vglobal=jnp.asarray(vg.astype(np.int32)),
        chunk_esrc=jnp.asarray(book.chunk_esrc),
        chunk_edst=jnp.asarray(book.chunk_edst),
        chunk_emask=jnp.asarray(book.chunk_emask),
        chunk_agg_order=jnp.asarray(book.chunk_agg_order),
        chunk_agg_ldst=jnp.asarray(book.chunk_agg_ldst),
    )


@dataclasses.dataclass(frozen=True)
class RingSync(_CodecSync):
    """1.5D ring-pipelined aggregation (CAGNET-style block rotation).

    At stage s device p holds block (p+s) mod k of the payload; the matching
    pre-rotated chunk (static index s — no dynamic gather of chunk tables)
    is aggregated locally while `lax.ppermute` ships the NEXT block, so the
    transfer overlaps the segment-SpMM. k−1 permutes of [Vb+1, d] per
    aggregate; no reduce/broadcast pair exists because every row is owned
    exactly once.

    The codec encodes the block ONCE before the loop; the encoded
    (payload, meta) pair is what rotates — every hop ships the compressed
    dtype (no re-encode drift: each device decodes the same bits), and the
    stage decodes only the view it aggregates. With fp32 the encode/decode
    are identity and the trace is exactly the historical one.
    """

    axis: str
    k: int
    codec: Optional[Union[str, Codec]] = None

    def _perm(self):
        # device j hands its current block to j-1: after s hops, device p
        # holds block (p+s) mod k — matching chunk (p, s)'s src block
        return [(j, (j - 1) % self.k) for j in range(self.k)]

    def edge_aggregate(self, blk: RingBlock, payload, msg_fn, *,
                       reduce: str = "sum", backend: str = "scatter"):
        codec = self._codec()
        n = payload.shape[0]
        tiled = blk.chunk_agg_order.shape[-1] > 0
        lay = self._take_layer()
        buf, meta = codec.encode(payload, layer=lay)
        acc = None
        for s in range(self.k):
            # issue the transfer BEFORE this stage's compute: XLA schedules
            # the collective-permute-start/done pair around the SpMM
            if s < self.k - 1:
                _record_collective("collective-permute",
                                   self.k * _nbytes(buf),
                                   self.k * _nbytes(buf), layer=lay)
                nxt = jax.lax.ppermute(buf, self.axis, self._perm())
                if meta is not None:
                    _record_collective("collective-permute",
                                       self.k * _nbytes(meta),
                                       self.k * _nbytes(meta), layer=lay)
                nxt_meta = (jax.lax.ppermute(meta, self.axis, self._perm())
                            if meta is not None else None)
            else:
                nxt = nxt_meta = None
            cur = codec.decode(buf, meta)
            messages = msg_fn(cur[blk.chunk_esrc[s]], blk.chunk_edst[s],
                              blk.chunk_emask[s])
            part = ops.aggregate(
                messages, blk.chunk_edst[s], n,
                edge_order=blk.chunk_agg_order[s] if tiled else None,
                local_dst=blk.chunk_agg_ldst[s] if tiled else None,
                backend=backend, reduce=reduce,
            )
            if acc is None:
                acc = part
            else:
                acc = jnp.maximum(acc, part) if reduce == "max" else acc + part
            if nxt is not None:
                buf, meta = nxt, nxt_meta
        return acc

    def psum(self, v):
        if self.k == 1:
            return v
        return jax.lax.psum(v, self.axis)


SYNC_MODES = ("local", "dense", "halo", "ring")


def make_sync(mode: str, blk, num_vertices: int, axis: str, codec=None):
    """Instantiate a SyncStrategy. `blk` is a `Block` for local/dense/halo
    and a `RingBlock` for ring (1.5D layouts have no halo tables).
    `codec` is a name or `repro.core.wire.Codec` (None -> fp32)."""
    codec = as_codec(codec)
    if mode == "local":
        return LocalSync(codec=codec)
    if mode == "dense":
        return DenseSync(blk=blk, num_vertices=num_vertices, axis=axis,
                         codec=codec)
    if mode == "halo":
        return HaloSync(blk=blk, axis=axis, codec=codec)
    if mode == "ring":
        if not isinstance(blk, RingBlock):
            raise TypeError(
                "sync mode 'ring' needs a RingBlock (build_ring_blocks over "
                f"a BlockRowBook); got {type(blk).__name__}")
        return RingSync(axis=axis, k=int(blk.chunk_esrc.shape[0]),
                        codec=codec)
    raise ValueError(
        f"unknown sync mode {mode!r}: valid strategies are "
        f"{', '.join(SYNC_MODES)}")


def sync_bytes_per_round(book, d: int, mode: str) -> int:
    """Analytic collective volume of ONE complete aggregate, all devices.

    For halo/dense that is a reduce+broadcast pair; for ring it is the k−1
    `ppermute` stages. Used by the study harness and checked against the
    dry-run HLO (tests/test_dist_lowering.py).
    """
    if mode == "halo":
        # each of k devices sends a [k, B, d] f32 buffer per all_to_all and a
        # reduce+broadcast pair is 2 exchanges: 2·k²·B·d·4 bytes cluster-wide
        # (= 2·k·B·d elements per device, as the HaloSync docstring states)
        return 2 * book.k * book.k * book.bucket * d * 4
    if mode == "dense":
        # psum of [V+1, d] on k devices (ring all-reduce ~ 2x payload)
        return 2 * book.k * (book.num_vertices + 1) * d * 4
    if mode == "ring":
        # k-1 ppermute stages, each device shipping its [Vb+1, d] f32 block
        if not isinstance(book, BlockRowBook):
            raise TypeError("ring volume needs a BlockRowBook")
        return book.k * (book.k - 1) * (book.v_block + 1) * d * 4
    return 0


def ring_bytes_per_round(book: BlockRowBook, d: int) -> int:
    """Cluster-wide `ppermute` bytes of one ring aggregate (k·(k−1)·(Vb+1)·d·4)."""
    return sync_bytes_per_round(book, d, "ring")


def collective_budget(book, d: int, mode: str, codec=None,
                      layer: int = 0) -> dict:
    """Predicted compiled-HLO collective budget of ONE complete aggregate.

    The analysis subsystem's collective-budget rule compiles one aggregate
    per (sync_mode, codec) cell under shard_map and holds the HLO to this
    prediction — per collective KIND (the HLO op name), an exact/ranged op
    count and the exact cluster-wide payload bytes under the parser's
    output-shape convention (repro.analysis.hlo):

      halo   2 all_to_alls (reduce+broadcast pair); each op's per-device
             output is the [k, B, d] bucket buffer in the codec's wire
             dtype. Lossy codecs with scale meta gather sender scales
             separately: +2 all-gathers of [k] f32.
      ring   k−1 ppermute stages; payload AND meta rotate via
             collective-permute, so the kind total equals
             `sync_wire_bytes_per_round` exactly. Codecs with meta may
             lower the scale as a separate permute per stage, so the op
             count lands in [k−1, 2(k−1)].
      dense  1 all-reduce of the global [V+1, d] buffer. DenseSync
             quantises then psums the DEQUANTISED view, so the wire stays
             f32 for every codec; the HLO output-shape convention counts
             the reduce once (the analytic formula's ring-allreduce 2x is
             a transport model, not an op count).

    Returns {kind: {"count": (lo, hi), "cluster_bytes": int}}.
    """
    codec = as_codec(codec)
    elem = int(np.dtype(codec.wire_dtype(layer=layer)).itemsize)
    k = book.k

    def wb(shape):
        try:
            return codec.wire_bytes(shape, layer=layer)
        except TypeError:
            return codec.wire_bytes(shape)

    if mode == "halo":
        b = book.bucket
        budget = {"all-to-all": {
            "count": (2, 2),
            "cluster_bytes": 2 * k * k * b * d * elem,
        }}
        meta = wb((k, b, d)) - k * b * d * elem  # per-tensor scale bytes
        if meta > 0:
            # each exchange all_gathers the k sender scales ([k] f32)
            budget["all-gather"] = {"count": (2, 2),
                                    "cluster_bytes": 2 * k * k * meta}
        return budget
    if mode == "ring":
        if not isinstance(book, BlockRowBook):
            raise TypeError("ring budget needs a BlockRowBook")
        has_meta = wb((book.v_block + 1, d)) > (book.v_block + 1) * d * elem
        return {"collective-permute": {
            "count": (k - 1, 2 * (k - 1)) if has_meta else (k - 1, k - 1),
            "cluster_bytes": sync_wire_bytes_per_round(
                book, d, "ring", codec, layer=layer),
        }}
    if mode == "dense":
        return {"all-reduce": {
            "count": (1, 1),
            "cluster_bytes": k * (book.num_vertices + 1) * d * 4,
        }}
    raise ValueError(f"no collective budget for sync mode {mode!r}")


def sync_wire_bytes_per_round(book, d: int, mode: str, codec=None,
                              layer: int = 0) -> int:
    """Codec-aware twin of `sync_bytes_per_round`: bytes that actually cross
    the network for ONE complete aggregate, all devices, after encoding.

    Same collective structure, with each per-device f32 buffer priced by
    `codec.wire_bytes` (payload + meta) instead of nelem·4 — the fp32 codec
    reproduces `sync_bytes_per_round` exactly. `layer` is the aggregate
    ordinal (only `VariableRatioCodec` cares).
    """
    codec = as_codec(codec)

    def wb(shape):
        try:
            return codec.wire_bytes(shape, layer=layer)
        except TypeError:  # fixed-ratio codecs take no layer kwarg
            return codec.wire_bytes(shape)

    if mode == "halo":
        # 2 all_to_alls per round, each device encoding one [k, B, d] buffer
        return 2 * book.k * wb((book.k, book.bucket, d))
    if mode == "dense":
        # psum of the quantised view: ~2x the encoded [V+1, d] buffer per
        # device (ring all-reduce), matching the logical formula's factor
        return 2 * book.k * wb((book.num_vertices + 1, d))
    if mode == "ring":
        if not isinstance(book, BlockRowBook):
            raise TypeError("ring volume needs a BlockRowBook")
        # k-1 ppermute stages per device, each shipping one encoded block
        return book.k * (book.k - 1) * wb((book.v_block + 1, d))
    return 0
