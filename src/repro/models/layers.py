"""Transformer/SSM building blocks, pure JAX, config-driven.

Everything is written for pjit/GSPMD: no manual collectives here — sharding
comes from in/out shardings and parameter PartitionSpecs (repro.dist).
Attention is memory-efficient (blockwise online softmax via lax.scan) so
32k-token prefill never materialises an S x S score matrix. Matmul dims stay
multiples of 128 where the configs allow (MXU alignment).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE (incl. M-RoPE for the VLM backbone)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, D]; positions [..., S] (broadcastable). Standard pairing:
    rotate (x[..., :D/2], x[..., D/2:])."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles).astype(x.dtype)
    sin = jnp.sin(angles).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_mrope(
    x: jnp.ndarray,
    pos3: jnp.ndarray,
    theta: float,
    sections: tuple[int, ...],
) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: the D/2 frequency slots are split into
    temporal/height/width sections, each rotated by its own position stream.

    x [B, H, S, D]; pos3 [3, B, S]; sum(sections) == D//2.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, d)
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)  # [half]
    # choose which position stream drives each frequency slot
    sec_id = np.repeat(np.arange(len(sections)), sections)  # [half]
    pos = pos3[sec_id, :, :]                        # [half, B, S]
    pos = jnp.moveaxis(pos, 0, -1)                  # [B, S, half]
    angles = pos.astype(jnp.float32) * freqs        # [B, S, half]
    cos = jnp.cos(angles)[:, None].astype(x.dtype)  # [B, 1, S, half]
    sin = jnp.sin(angles)[:, None].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention (blockwise, online softmax) — the pure-JAX reference; the Pallas
# flash kernel in repro.kernels targets the same contract on TPU.
# ---------------------------------------------------------------------------


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    if groups == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, groups, s, d)).reshape(b, h * groups, s, d)


def attention(
    q: jnp.ndarray,            # [B, Hq, Sq, D]
    k: jnp.ndarray,            # [B, Hkv, Skv, D]
    v: jnp.ndarray,            # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    window: int = 0,           # 0 = unbounded (full attention)
    q_offset=0,                # scalar or traced: global position of q[0]
    kv_valid_len=None,         # mask out cache slots >= this (decode)
    block_q: int = 512,
    block_k: int = 1024,
    softmax_scale: Optional[float] = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    groups = hq // hkv
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = softmax_scale if softmax_scale is not None else 1.0 / float(np.sqrt(d))

    # adaptive block sizes (whisper's 1536-frame encoder needs 512-wide kv)
    while block_q > 128 and sq % block_q:
        block_q //= 2
    while block_k > 128 and skv % block_k:
        block_k //= 2
    divisible = (sq % block_q == 0) and (skv % block_k == 0)
    if sq * skv <= 1_048_576 or skv <= block_k or not divisible:
        # small: direct path (also the decode path, Sq == 1)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
        q_idx = q_offset + jnp.arange(sq)
        k_idx = jnp.arange(skv)
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= q_idx[:, None] >= k_idx[None, :]
        if window:
            mask &= q_idx[:, None] - k_idx[None, :] < window
        if kv_valid_len is not None:
            mask &= (k_idx[None, :] < kv_valid_len)
        scores = jnp.where(mask, scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bhkd->bhqd", p, v)

    # blockwise flash attention with a custom VJP: forward keeps only
    # (out, lse); backward recomputes P per block pair. Without this, scan
    # autodiff saves every f32 probability block — measured tens of GiB per
    # layer on the 4k-train cells.
    static = (bool(causal), int(window), int(block_q), int(block_k),
              float(scale))
    return _flash_core(static, q, k, v)


def _block_mask(static, q_idx, k_idx):
    causal, window, *_ = static
    mask = jnp.ones((q_idx.shape[0], k_idx.shape[0]), bool)
    if causal:
        mask &= q_idx[:, None] >= k_idx[None, :]
    if window:
        mask &= q_idx[:, None] - k_idx[None, :] < window
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_core(static, q, k, v):
    out, _ = _flash_fwd_inner(static, q, k, v)
    return out


def _flash_fwd_inner(static, q, k, v):
    causal, window, block_q, block_k, scale = static
    b, h, sq, d = q.shape
    skv = k.shape[2]
    nq, nk = sq // block_q, skv // block_k
    qs = q.reshape(b, h, nq, block_q, d).transpose(2, 0, 1, 3, 4)
    ks = k.reshape(b, h, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, h, nk, block_k, d).transpose(2, 0, 1, 3, 4)

    def q_block(_, args):
        qi, q_blk = args
        q_idx = qi * block_q + jnp.arange(block_q)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            k_idx = ki * block_k + jnp.arange(block_k)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
            s = jnp.where(_block_mask(static, q_idx, k_idx), s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(q_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        a0 = jnp.zeros((b, h, block_q, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), ks, vs))
        l_safe = jnp.maximum(l, 1e-30)
        o_blk = (acc / l_safe[..., None]).astype(q.dtype)
        lse_blk = m + jnp.log(l_safe)
        return None, (o_blk, lse_blk)

    _, (o_stack, lse_stack) = jax.lax.scan(q_block, None, (jnp.arange(nq), qs))
    out = o_stack.transpose(1, 2, 0, 3, 4).reshape(b, h, sq, d)
    lse = lse_stack.transpose(1, 2, 0, 3).reshape(b, h, sq)
    return out, lse


def _flash_fwd(static, q, k, v):
    out, lse = _flash_fwd_inner(static, q, k, v)
    return out, (q, k, v, out, lse)


def _flash_bwd(static, res, dout):
    causal, window, block_q, block_k, scale = static
    q, k, v, out, lse = res
    b, h, sq, d = q.shape
    skv = k.shape[2]
    nq, nk = sq // block_q, skv // block_k
    delta = jnp.sum(out.astype(jnp.float32) * dout.astype(jnp.float32), axis=-1)

    qs = q.reshape(b, h, nq, block_q, d).transpose(2, 0, 1, 3, 4)
    dos = dout.reshape(b, h, nq, block_q, d).transpose(2, 0, 1, 3, 4)
    lses = lse.reshape(b, h, nq, block_q).transpose(2, 0, 1, 3)
    deltas = delta.reshape(b, h, nq, block_q).transpose(2, 0, 1, 3)
    ks = k.reshape(b, h, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, h, nk, block_k, d).transpose(2, 0, 1, 3, 4)

    def kv_block(dq_acc, kv_args):
        ki, k_blk, v_blk = kv_args
        k_idx = ki * block_k + jnp.arange(block_k)

        def q_step(carry, q_args):
            dk_blk, dv_blk, dq_acc = carry
            qi, q_blk, do_blk, lse_blk, dl_blk = q_args
            q_idx = qi * block_q + jnp.arange(block_q)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
            mask = _block_mask(static, q_idx, k_idx)
            p = jnp.where(mask, jnp.exp(s - lse_blk[..., None]), 0.0)
            dv_blk = dv_blk + jnp.einsum(
                "bhqk,bhqd->bhkd", p, do_blk.astype(jnp.float32)
            )
            dp = jnp.einsum("bhqd,bhkd->bhqk", do_blk, v_blk).astype(jnp.float32)
            ds = p * (dp - dl_blk[..., None]) * scale
            dk_blk = dk_blk + jnp.einsum("bhqk,bhqd->bhkd", ds, q_blk.astype(jnp.float32))
            dq_contrib = jnp.einsum("bhqk,bhkd->bhqd", ds, k_blk.astype(jnp.float32))
            dq_acc = jax.lax.dynamic_update_slice(
                dq_acc,
                jax.lax.dynamic_slice(
                    dq_acc, (0, 0, qi * block_q, 0), (b, h, block_q, d)
                ) + dq_contrib,
                (0, 0, qi * block_q, 0),
            )
            return (dk_blk, dv_blk, dq_acc), None

        z = jnp.zeros((b, h, block_k, d), jnp.float32)
        (dk_blk, dv_blk, dq_acc), _ = jax.lax.scan(
            q_step, (z, z, dq_acc), (jnp.arange(nq), qs, dos, lses, deltas)
        )
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, h, sq, d), jnp.float32)
    dq, (dk_stack, dv_stack) = jax.lax.scan(
        kv_block, dq0, (jnp.arange(nk), ks, vs)
    )
    dk = dk_stack.transpose(1, 2, 0, 3, 4).reshape(b, h, skv, d)
    dv = dv_stack.transpose(1, 2, 0, 3, 4).reshape(b, h, skv, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_core.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def gated_mlp(p, x, pin=None):
    """SwiGLU (llama/qwen style): w2(silu(w1 x) * w3 x).
    `pin` (optional) asserts the TP layout of the [.., f] hidden."""
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    if pin is not None:
        h = pin(h)
    return h @ p["w2"]


def gelu_mlp(p, x):
    """Plain GELU MLP (whisper style)."""
    return jax.nn.gelu(x @ p["w1"] + p["b1"], approximate=True) @ p["w2"] + p["b2"]


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-bucketed grouped matmul)
# ---------------------------------------------------------------------------


def moe_ffn(
    p,
    x: jnp.ndarray,              # [B, S, d]
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    pin=None,
    dispatch_dtype=None,         # e.g. jnp.float8_e4m3fn: quantised dispatch
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-bucketed top-k MoE with PER-SEQUENCE dispatch, fully batched.

    Every op keeps the explicit batch dim (dim 0), so GSPMD preserves
    batch-over-data sharding end to end; expert weights [E, d, f] shard over
    the model axis (expert parallelism) and the grouped matmuls become
    all_to_all-style exchanges. Two structural tricks keep it
    partition-friendly:
      * position-in-expert via boundary cummax (no per-row searchsorted),
      * un-dispatch via the INVERSE of the sort permutation + sum over the
        k choices (no scatter-add at all; the only scatter is the bucket
        write, a batched put_along_axis).
    `pin` (optional) re-asserts batch sharding on the big intermediates.
    Returns (out [B, S, d], aux load-balance loss scalar).
    """
    B, S, dm = x.shape
    E = p["w1"].shape[0]
    pin = pin or (lambda t: t)
    C = min(max(int(capacity_factor * top_k * S / E), 1), S)
    Sk = S * top_k

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)                   # [B,S,k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = gate_idx.reshape(B, Sk)
    flat_w = gate_vals.reshape(B, Sk)
    token_of = jnp.repeat(jnp.arange(S), top_k)[None, :]                # [1,Sk]

    order = jnp.argsort(flat_e, axis=-1, stable=True)                   # [B,Sk]
    inv_order = jnp.argsort(order, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    sw = jnp.take_along_axis(flat_w, order, axis=-1)
    st = jnp.take_along_axis(jnp.broadcast_to(token_of, (B, Sk)), order, axis=-1)

    iota = jnp.arange(Sk)[None, :]
    boundary = jnp.concatenate(
        [jnp.ones((B, 1), bool), se[:, 1:] != se[:, :-1]], axis=-1
    )
    group_start = jax.lax.cummax(jnp.where(boundary, iota, 0), axis=1)
    pos = iota - group_start
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)                         # [B,Sk]

    xg = jnp.take_along_axis(x, st[..., None], axis=1)                  # [B,Sk,d]
    # Bucket write WITHOUT any scatter: within the sorted layout, expert e's
    # entries start at prefix[e] (all-counts prefix) and kept slots are the
    # first min(count, C) of each group, so slot (e, c) maps ANALYTICALLY to
    # sorted position prefix[e] + c. GSPMD partitions gathers along the
    # batch dim fine; the scatter formulation replicated the buffers
    # (measured 125-308 GiB/device on the MoE train cells).
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=1)  # [B,E]
    prefix = jnp.cumsum(counts, axis=-1) - counts                         # excl.
    c_iota = jnp.arange(C)[None, None, :]
    j = prefix[..., None] + c_iota                                        # [B,E,C]
    valid = c_iota < jnp.minimum(counts, C)[..., None]
    j_flat = jnp.clip(j.reshape(B, E * C), 0, Sk - 1)
    bufe = jnp.take_along_axis(xg, j_flat[..., None], axis=1)             # gather
    bufe = jnp.where(valid.reshape(B, E * C, 1), bufe, 0)
    if dispatch_dtype is not None:
        # quantised dispatch (DeepSeek-V3 style): the batch->expert
        # all_to_all that GSPMD inserts between the (batch-pinned) buffers
        # and the (expert-sharded) grouped matmul moves 1-byte payloads.
        # Per-token scale keeps the dynamic range.
        scale = jnp.maximum(jnp.abs(bufe).max(axis=-1, keepdims=True), 1e-6)
        q8 = (bufe / scale * 240.0).astype(dispatch_dtype)
        q8 = pin(q8)
        bufe = q8.astype(x.dtype) * (pin(scale) / 240.0)
    bufe = pin(bufe).reshape(B, E, C, dm)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", bufe, p["w1"]))
    h = pin(h * jnp.einsum("becd,edf->becf", bufe, p["w3"]))
    y = jnp.einsum("becf,efd->becd", h, p["w2"]).reshape(B, E * C, dm)
    y = pin(jnp.concatenate([y, jnp.zeros((B, 1, dm), y.dtype)], axis=1))

    contrib = jnp.take_along_axis(y, slot[..., None], axis=1)
    contrib = contrib * (sw * keep)[..., None].astype(y.dtype)          # [B,Sk,d]
    # un-dispatch: undo the sort, then fold the k choices per token
    contrib = jnp.take_along_axis(contrib, inv_order[..., None], axis=1)
    out = contrib.reshape(B, S, top_k, dm).sum(axis=2)

    # Switch-style aux loss: E * sum_e fraction_e * mean_prob_e
    frac = (
        jax.nn.one_hot(flat_e, E, dtype=jnp.float32).sum(axis=1) / Sk
    )                                                                    # [B,E]
    aux = E * jnp.mean(jnp.sum(frac * probs.mean(axis=1), axis=-1))
    return out, aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state-space duality, chunked scan)
# ---------------------------------------------------------------------------


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{j < t <= i} x[..., t].
    Lower-triangular; -inf above the diagonal."""
    T = x.shape[-1]
    x_cum = jnp.cumsum(x, axis=-1)
    diff = x_cum[..., :, None] - x_cum[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,    # [B, S, H, P]
    dt: jnp.ndarray,   # [B, S, H]  (post-softplus)
    A: jnp.ndarray,    # [H] (negative)
    Bm: jnp.ndarray,   # [B, S, G, N]
    Cm: jnp.ndarray,   # [B, S, G, N]
    *,
    chunk: int = 128,
    init_state: Optional[jnp.ndarray] = None,  # [B, H, P, N]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba2 SSD forward (Dao & Gu 2024, Listing 1) in chunked form:
    quadratic attention-like term inside chunks + linear state recurrence
    across chunks. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    b, s, h, p_dim = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    # chunk-major layout for a sequential scan over chunks; the per-chunk
    # body is checkpointed so backward holds ONE chunk's quadratic
    # intermediates ([b,h,l,l]) instead of all nc of them at once.
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, h, p_dim), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(b, nc, chunk, h), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(b, nc, chunk, g, n), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(b, nc, chunk, g, n), 1, 0)

    def chunk_body(state, inp):
        xci, dtci, Bci, Cci = inp                       # [b, l, h, p] etc
        Bh = jnp.repeat(Bci, rep, axis=2)               # [b, l, h, n]
        Ch = jnp.repeat(Cci, rep, axis=2)
        dA = jnp.moveaxis(dtci * A[None, None, :], -1, 1)   # [b, h, l]
        dA_cs = jnp.cumsum(dA, axis=-1)
        Lm = jnp.exp(_segsum(dA))                       # [b, h, l, l]
        CB = jnp.einsum("blhn,bshn->bhls", Ch, Bh)
        scores = CB * Lm
        xdt = (xci * dtci[..., None]).astype(jnp.float32)   # [b, l, h, p]
        y_diag = jnp.einsum("bhls,bshp->blhp", scores, xdt)
        decay_to_end = jnp.exp(dA_cs[..., -1:] - dA_cs)     # [b, h, l]
        chunk_state = jnp.einsum("blhn,bhl,blhp->bhpn", Bh.astype(jnp.float32),
                                 decay_to_end, xdt)
        decay_in = jnp.exp(dA_cs)                           # [b, h, l]
        y_off = jnp.einsum("blhn,bhl,bhpn->blhp",
                           Ch.astype(jnp.float32), decay_in, state)
        chunk_decay = jnp.exp(dA_cs[..., -1])               # [b, h]
        new_state = state * chunk_decay[..., None, None] + chunk_state
        y = (y_diag + y_off).astype(x.dtype)                # [b, l, h, p]
        return new_state, y

    init = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, h, p_dim, n), jnp.float32)
    )
    final, ys = jax.lax.scan(jax.checkpoint(chunk_body), init,
                             (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p_dim)
    return y, final.astype(x.dtype)


def ssd_decode_step(
    x: jnp.ndarray,    # [B, H, P]
    dt: jnp.ndarray,   # [B, H]
    A: jnp.ndarray,    # [H]
    Bm: jnp.ndarray,   # [B, G, N]
    Cm: jnp.ndarray,   # [B, G, N]
    state: jnp.ndarray,  # [B, H, P, N]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSM update: h' = exp(dt A) h + dt * x B^T; y = h' C."""
    h = state.shape[1]
    g = Bm.shape[1]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # [B, H, N]
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt * A[None, :])[..., None, None]      # [B, H, 1, 1]
    add = (dt[..., None] * x.astype(jnp.float32))[..., None] * Bh[:, :, None, :]
    new_state = state.astype(jnp.float32) * decay + add
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state.astype(state.dtype)


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, state=None):
    """Depthwise causal conv. x [B, S, C], w [W, C]. If `state` [B, W-1, C]
    is given, runs in streaming mode and returns (y, new_state)."""
    width = w.shape[0]
    if state is not None:
        full = jnp.concatenate([state, x], axis=1)
        new_state = full[:, -(width - 1):, :]
        y = sum(full[:, i : i + x.shape[1], :] * w[i] for i in range(width))
        return y, new_state
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    y = sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(width))
    return y
