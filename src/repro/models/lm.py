"""Config-driven LM assembly: init / train forward / prefill / decode.

One code path covers all ten assigned architectures:

  dense        pre-norm blocks: GQA attention (+bias/qk_norm/SWA) + SwiGLU
  moe          attention + capacity-bucketed top-k MoE (+ shared experts,
               + deepseek's dense layer 0)
  ssm          mamba2 blocks (SSD chunked scan / streaming decode)
  hybrid       hymba: parallel attention + mamba heads in one block;
               SWA layers scanned, 3 global-attention layers interleaved
  vlm          qwen2-vl: M-RoPE, stub patch embeddings prefix
  audio        whisper: encoder stack (stub frame embeddings) + decoder with
               cross-attention; LayerNorm/GELU, learned positions

Parameters are *stacked over layers* ([L, ...] leading dim) and the forward
runs `lax.scan` over layers — compile time stays flat in depth, which is what
makes the 512-device dry-run tractable, and is also how production JAX LM
frameworks (MaxText et al.) are built. Caches are likewise stacked.

`init_params` is pure (jax.random) so the dry-run can take
`jax.eval_shape(init_params, ...)` and never allocate the real model.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Any

# Optional NamedShardings for the residual stream [B, S, d], set by the
# distributed step builders (repro.dist.steps). Without this pin, GSPMD can
# resolve the FSDP-weights-vs-batch-activations conflict by replicating the
# batch — measured 177 GiB/device on yi-6b train_4k before the constraint.
# `sp` additionally shards the sequence dim over 'model' (Megatron-style
# sequence parallelism) so the remat-saved layer inputs divide by the TP
# degree; `dp` is the batch-only fallback for non-divisible sequence lengths.
# Plain Python globals: set before tracing, captured at trace.
_ACT_SHARDING_SP: Optional[Any] = None
_ACT_SHARDING_DP: Optional[Any] = None
_ACT_SP_DIVISOR: int = 1


# MoE dispatch locality: when set, the MoE FFN runs inside a shard_map that
# is *manual* over the data-parallel axes (each DP shard dispatches its own
# tokens — the production EP pattern) and *auto* over 'model' (experts).
# Without this, the global flatten + argsort in the dispatch forces GSPMD to
# replicate token buffers (measured 209 GiB/device on phi3.5-moe train_4k).
_MOE_MESH: Optional[Any] = None
_MOE_DP_AXES: tuple = ()

# Selective-remat policy name: None = full recompute (save block inputs
# only); "ssm_proj" = additionally save the tagged SSM in_proj outputs so the
# backward recompute skips the dominant SSM matmul (+~35 MB/layer on
# mamba2-370m train_4k, -25% recompute flops).
_REMAT_POLICY: Optional[str] = None


def set_remat_policy(name: Optional[str]) -> None:  # lint: keep — dist-build hook
    global _REMAT_POLICY
    _REMAT_POLICY = name


# MoE dispatch payload dtype (None = model dtype). Set to
# jnp.float8_e4m3fn to quantise the expert all_to_all (§Perf experiments).
_MOE_DISPATCH_DTYPE: Optional[Any] = None


def set_moe_dispatch_dtype(dtype) -> None:  # lint: keep — dist-build hook
    global _MOE_DISPATCH_DTYPE
    _MOE_DISPATCH_DTYPE = dtype


def set_activation_sharding(dp, sp=None, sp_divisor: int = 1,
                            moe_mesh=None, moe_dp_axes: tuple = ()) -> None:
    global _ACT_SHARDING_SP, _ACT_SHARDING_DP, _ACT_SP_DIVISOR
    global _MOE_MESH, _MOE_DP_AXES
    _ACT_SHARDING_DP = dp
    _ACT_SHARDING_SP = sp
    _ACT_SP_DIVISOR = max(sp_divisor, 1)
    _MOE_MESH = moe_mesh
    _MOE_DP_AXES = tuple(moe_dp_axes)


def _pin(x):
    if x.ndim != 3:
        return x
    if _ACT_SHARDING_SP is not None and x.shape[1] % _ACT_SP_DIVISOR == 0 \
            and x.shape[1] > 1:
        return jax.lax.with_sharding_constraint(x, _ACT_SHARDING_SP)
    if _ACT_SHARDING_DP is not None:
        return jax.lax.with_sharding_constraint(x, _ACT_SHARDING_DP)
    return x


def _pin_batched(t):
    """Pin dim 0 of an arbitrary-rank tensor to the batch axes (MoE bufs)."""
    if _ACT_SHARDING_DP is None:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec0 = _ACT_SHARDING_DP.spec[0]
    ns = NamedSharding(_ACT_SHARDING_DP.mesh, P(spec0, *([None] * (t.ndim - 1))))
    return jax.lax.with_sharding_constraint(t, ns)


def _pin_dim(t, dim: int, require_divisible: bool = True):
    """Pin dim 0 to batch and `dim` to 'model' (TP interior layouts:
    attention heads [B,H,S,D] dim 1, MLP hidden [B,S,F] dim 2). Falls back
    to batch-only when the dim doesn't divide the TP degree (hymba's 25
    heads, whisper's 6)."""
    if _ACT_SHARDING_DP is None:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = _ACT_SHARDING_DP.mesh
    spec0 = _ACT_SHARDING_DP.spec[0]
    msize = _ACT_SP_DIVISOR
    if msize > 1 and (not require_divisible or t.shape[dim] % msize == 0):
        spec = [None] * t.ndim
        spec[0] = spec0
        spec[dim] = "model"
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, P(*spec)))
    # non-divisible: leave the layout to GSPMD (forcing batch-only pins or
    # DP residuals both measured worse on hymba/whisper); memory pressure on
    # these archs is handled by gradient accumulation instead
    return t


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def _attn_block_params(key, cfg: ArchConfig, n_layers: int, dt):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "wq": _dense_init(ks[0], (n_layers, d, hq * hd), dt),
        "wk": _dense_init(ks[1], (n_layers, d, hkv * hd), dt),
        "wv": _dense_init(ks[2], (n_layers, d, hkv * hd), dt),
        "wo": _dense_init(ks[3], (n_layers, hq * hd, d), dt),
        "ln1": jnp.ones((n_layers, d), jnp.float32),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, hq * hd), dt)
        p["bk"] = jnp.zeros((n_layers, hkv * hd), dt)
        p["bv"] = jnp.zeros((n_layers, hkv * hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((n_layers, hd), jnp.float32)
        p["k_norm"] = jnp.ones((n_layers, hd), jnp.float32)
    if cfg.norm == "layernorm":
        p["ln1_b"] = jnp.zeros((n_layers, d), jnp.float32)
    return p


def _mlp_block_params(key, cfg: ArchConfig, n_layers: int, dt):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 8)
    if cfg.moe:
        E, fe = cfg.num_experts, cfg.d_ff
        p = {
            "router": _dense_init(ks[0], (n_layers, d, E), jnp.float32),
            "w1": _dense_init(ks[1], (n_layers, E, d, fe), dt),
            "w3": _dense_init(ks[2], (n_layers, E, d, fe), dt),
            "w2": _dense_init(ks[3], (n_layers, E, fe, d), dt),
            "ln2": jnp.ones((n_layers, d), jnp.float32),
        }
        if cfg.num_shared_experts:
            fs = cfg.num_shared_experts * cfg.d_ff
            p["shared_w1"] = _dense_init(ks[4], (n_layers, d, fs), dt)
            p["shared_w3"] = _dense_init(ks[5], (n_layers, d, fs), dt)
            p["shared_w2"] = _dense_init(ks[6], (n_layers, fs, d), dt)
        return p
    if cfg.mlp == "gelu":
        return {
            "w1": _dense_init(ks[0], (n_layers, d, f), dt),
            "b1": jnp.zeros((n_layers, f), dt),
            "w2": _dense_init(ks[1], (n_layers, f, d), dt),
            "b2": jnp.zeros((n_layers, d), dt),
            "ln2": jnp.ones((n_layers, d), jnp.float32),
            "ln2_b": jnp.zeros((n_layers, d), jnp.float32),
        }
    return {
        "w1": _dense_init(ks[0], (n_layers, d, f), dt),
        "w3": _dense_init(ks[1], (n_layers, d, f), dt),
        "w2": _dense_init(ks[2], (n_layers, f, d), dt),
        "ln2": jnp.ones((n_layers, d), jnp.float32),
    }


def _ssm_block_params(key, cfg: ArchConfig, n_layers: int, dt):
    d = cfg.d_model
    din = cfg.ssm_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _dense_init(ks[0], (n_layers, d, 2 * din + 2 * g * n + h), dt),
        "conv_w": _dense_init(ks[1], (n_layers, cfg.conv_width, conv_dim), dt, scale=0.5),
        "dt_bias": jnp.zeros((n_layers, h), jnp.float32),
        "a_log": jnp.zeros((n_layers, h), jnp.float32),  # A = -exp(a_log) = -1
        "d_skip": jnp.ones((n_layers, h), jnp.float32),
        "ssm_norm": jnp.ones((n_layers, din), jnp.float32),
        "out_proj": _dense_init(ks[2], (n_layers, din, d), dt),
        "ln_ssm": jnp.ones((n_layers, d), jnp.float32),
    }


def _block_group_params(key, cfg: ArchConfig, n_layers: int, *, moe_override=None):
    """Params for a stack of `n_layers` homogeneous blocks."""
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    p: dict = {}
    if cfg.num_heads:
        p.update(_attn_block_params(ks[0], cfg, n_layers, dt))
    if cfg.ssm:
        p.update(_ssm_block_params(ks[1], cfg, n_layers, dt))
    if cfg.d_ff or cfg.moe:
        c = cfg if moe_override is None else moe_override
        p.update(_mlp_block_params(ks[2], c, n_layers, dt))
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 10)
    params: dict = {
        "embed": _dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.norm == "layernorm":
        params["final_norm_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["unembed"] = _dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dt)

    if cfg.encoder_decoder:
        params["enc_pos"] = _dense_init(ks[2], (cfg.encoder_seq, cfg.d_model), dt, scale=0.02)
        params["enc_blocks"] = _block_group_params(ks[3], cfg, cfg.encoder_layers)
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        params["enc_final_norm_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        # decoder cross-attention stack
        d, hd = cfg.d_model, cfg.resolved_head_dim
        hq, hkv = cfg.num_heads, cfg.num_kv_heads
        kc = jax.random.split(ks[4], 5)
        params["cross"] = {
            "wq": _dense_init(kc[0], (cfg.num_layers, d, hq * hd), dt),
            "wk": _dense_init(kc[1], (cfg.num_layers, d, hkv * hd), dt),
            "wv": _dense_init(kc[2], (cfg.num_layers, d, hkv * hd), dt),
            "wo": _dense_init(kc[3], (cfg.num_layers, hq * hd, d), dt),
            "ln": jnp.ones((cfg.num_layers, d), jnp.float32),
            "ln_b": jnp.zeros((cfg.num_layers, d), jnp.float32),
        }
        # whisper decoder uses learned positions, no RoPE. Sized to cover the
        # assigned decode shapes (mechanical; real whisper uses 448).
        params["dec_pos"] = _dense_init(ks[5], (65536, cfg.d_model), dt, scale=0.02)

    n_main = cfg.num_layers
    if cfg.hybrid and cfg.num_global_layers:
        n_main = cfg.num_layers - cfg.num_global_layers
        params["global_blocks"] = _block_group_params(ks[6], cfg, cfg.num_global_layers)
    if cfg.first_layer_dense:
        n_main = cfg.num_layers - 1
        dense_cfg = dataclasses.replace(
            cfg, moe=False, d_ff=cfg.dense_d_ff, name=cfg.name + "-dense0"
        )
        params["dense0"] = _block_group_params(ks[7], dense_cfg, 1)
    params["blocks"] = _block_group_params(ks[8], cfg, n_main)
    return params


# ---------------------------------------------------------------------------
# block forwards (one layer, unstacked params)
# ---------------------------------------------------------------------------


def _norm(cfg, x, scale, bias=None):
    if cfg.norm == "layernorm":
        return L.layernorm(x, scale, bias if bias is not None else jnp.zeros_like(scale))
    return L.rmsnorm(x, scale)


def _attn_forward(
    cfg: ArchConfig, p, x, *, positions, pos3=None, window, cache=None,
    cache_index=None, cross_kv=None, causal=True,
):
    """Attention sub-block. Returns (out, new_cache)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = _pin_dim(q.reshape(b, s, hq, hd).transpose(0, 2, 1, 3), 1)

    if cross_kv is not None:
        k, v = cross_kv
        out = L.attention(q, k, v, causal=False)
        out = _pin_dim(out, 1).transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
        return out @ p["wo"], None

    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    k = _pin_dim(k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3), 1)
    v = _pin_dim(v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3), 1)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"])
        k = L.rmsnorm(k, p["k_norm"])
    if cfg.mrope and pos3 is not None:
        q = L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.encoder_decoder:
        pass  # whisper: learned positions added at embedding time
    else:
        q = L.apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = L.apply_rope(k, positions[:, None, :], cfg.rope_theta)

    if cache is None:
        out = _pin_dim(L.attention(q, k, v, causal=causal, window=window), 1)
        new_cache = None
    else:
        ck, cv = cache["k"], cache["v"]
        cache_len = ck.shape[2]
        if s == 1:
            # decode: write slot (ring-buffered when windowed)
            slot = cache_index % cache_len if window else cache_index
            ck = jax.lax.dynamic_update_slice(ck, k, (0, 0, slot, 0))
            cv = jax.lax.dynamic_update_slice(cv, v, (0, 0, slot, 0))
            valid = jnp.minimum(cache_index + 1, cache_len)
            out = L.attention(q, ck, cv, causal=False, kv_valid_len=valid)
        else:
            # prefill: bulk write. Windowed caches keep the tail, laid out in
            # ring order (token position p -> slot p % W) so decode appends
            # consistently.
            if window and cache_len < s:
                k_w = jnp.roll(k[:, :, -cache_len:], s % cache_len, axis=2)
                v_w = jnp.roll(v[:, :, -cache_len:], s % cache_len, axis=2)
            else:
                k_w, v_w = k, v
            ck = jax.lax.dynamic_update_slice(ck, k_w, (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v_w, (0, 0, 0, 0))
            out = L.attention(q, k, v, causal=causal, window=window)
        new_cache = {"k": ck, "v": cv}
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return out @ p["wo"], new_cache


def _mlp_forward(cfg: ArchConfig, p, x):
    """Dense or MoE FFN on [B, S, d]. Returns (out, aux_loss).

    MoE dispatch is PER SEQUENCE (Switch-style groups, vmapped over batch):
    each batch row sorts/buckets only its own S*k assignments, so the token
    buffers keep the batch dim and shard over data like every other
    activation. A global flatten+argsort instead forces GSPMD to replicate
    the dispatch buffers (measured 209 GiB/device on phi3.5-moe train_4k).
    """
    if cfg.moe and "router" in p:
        moe_params = {"router": p["router"], "w1": p["w1"],
                      "w3": p["w3"], "w2": p["w2"]}
        b, s, d = x.shape
        chunk = 1024
        if s > chunk and s % chunk == 0:
            # sequence-chunked dispatch with an inner checkpoint: backward
            # holds one chunk's dispatch buffers instead of the whole
            # sequence's (the buffers are ~2.5x token bytes in f32).
            def moe_chunk(xc):
                return L.moe_ffn(moe_params, xc,
                                 top_k=cfg.experts_per_token,
                                 capacity_factor=cfg.moe_capacity_factor,
                                 pin=_pin_batched,
                                 dispatch_dtype=_MOE_DISPATCH_DTYPE)

            def body(aux_acc, xc):
                o, a = jax.checkpoint(moe_chunk)(xc)
                return aux_acc + a, o

            xr = jnp.moveaxis(x.reshape(b, s // chunk, chunk, d), 1, 0)
            aux_sum, outs = jax.lax.scan(body, jnp.zeros((), jnp.float32), xr)
            out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)
            aux = aux_sum / (s // chunk)
        else:
            out, aux = L.moe_ffn(moe_params, x, top_k=cfg.experts_per_token,
                                 capacity_factor=cfg.moe_capacity_factor,
                                 pin=_pin_batched,
                                 dispatch_dtype=_MOE_DISPATCH_DTYPE)
        if "shared_w1" in p:
            shared = jax.nn.silu(x @ p["shared_w1"]) * (x @ p["shared_w3"])
            out = out + shared @ p["shared_w2"]
        return out, aux
    if cfg.mlp == "gelu":
        return L.gelu_mlp(p, x), 0.0
    return L.gated_mlp(p, x, pin=lambda t: _pin_dim(t, 2)), 0.0


def _ssm_forward(cfg: ArchConfig, p, x, *, cache=None, cache_index=None):
    """Mamba2 sub-block on [B, S, d]. Returns (out, new_cache)."""
    b, s, d = x.shape
    din = cfg.ssm_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim
    proj = x @ p["in_proj"]  # [b, s, 2*din + 2*g*n + h]
    # remat tag: selective-remat policies can save this (the dominant matmul
    # of an SSM block) so the backward recompute skips it
    from jax.ad_checkpoint import checkpoint_name
    proj = checkpoint_name(proj, "ssm_proj")
    z, xb, dt_raw = jnp.split(proj, [din, 2 * din + 2 * g * n], axis=-1)
    A = -jnp.exp(p["a_log"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [b,s,h]

    if cache is None or s > 1:
        conv_in = xb
        if cache is not None:  # prefill with state capture
            conv_out, conv_state = L.causal_conv1d(
                conv_in, p["conv_w"],
                state=jnp.zeros((b, cfg.conv_width - 1, conv_in.shape[-1]), x.dtype),
            )
        else:
            conv_out = L.causal_conv1d(conv_in, p["conv_w"])
            conv_state = None
        conv_out = jax.nn.silu(conv_out)
        xs, B_, C_ = jnp.split(conv_out, [din, din + g * n], axis=-1)
        xs = xs.reshape(b, s, h, pdim)
        Bm = B_.reshape(b, s, g, n)
        Cm = C_.reshape(b, s, g, n)
        chunk = 128
        while s % chunk:
            chunk //= 2
        y, final_state = L.ssd_chunked(xs, dt, A, Bm, Cm, chunk=chunk)
        y = (y + xs * p["d_skip"][None, None, :, None]).astype(x.dtype)
        y = y.reshape(b, s, din)
        new_cache = (
            {"conv": conv_state, "ssm": final_state} if cache is not None else None
        )
    else:
        conv_out, conv_state = L.causal_conv1d(xb, p["conv_w"], state=cache["conv"])
        conv_out = jax.nn.silu(conv_out)
        xs, B_, C_ = jnp.split(conv_out[:, 0], [din, din + g * n], axis=-1)
        xs = xs.reshape(b, h, pdim)
        Bm = B_.reshape(b, g, n)
        Cm = C_.reshape(b, g, n)
        y, new_state = L.ssd_decode_step(xs, dt[:, 0], A, Bm, Cm, cache["ssm"])
        y = (y + xs * p["d_skip"][None, :, None]).astype(x.dtype)
        y = y.reshape(b, 1, din)
        new_cache = {"conv": conv_state, "ssm": new_state}

    y = L.rmsnorm(y, p["ssm_norm"]) * jax.nn.silu(z)
    return y @ p["out_proj"], new_cache


def block_forward(
    cfg: ArchConfig, p, x, *, positions, pos3=None, window, cache=None,
    cache_index=None, cross_kv=None,
):
    """One decoder block. Returns (x, new_cache, aux_loss)."""
    aux = 0.0
    new_cache: dict = {}
    if cfg.hybrid:
        # hymba: attention and mamba heads in parallel on the same normed input
        h = _norm(cfg, x, p["ln1"])
        attn_out, c_attn = _attn_forward(
            cfg, p, h, positions=positions, pos3=pos3, window=window,
            cache=None if cache is None else cache.get("attn"),
            cache_index=cache_index,
        )
        ssm_out, c_ssm = _ssm_forward(
            cfg, p, h, cache=None if cache is None else cache.get("ssm_c"),
            cache_index=cache_index,
        )
        x = x + 0.5 * (attn_out + ssm_out)
        if cache is not None:
            new_cache = {"attn": c_attn, "ssm_c": c_ssm}
    elif cfg.ssm:
        h = _norm(cfg, x, p["ln_ssm"])
        out, c_ssm = _ssm_forward(
            cfg, p, h, cache=None if cache is None else cache.get("ssm_c"),
            cache_index=cache_index,
        )
        x = x + out
        if cache is not None:
            new_cache = {"ssm_c": c_ssm}
    else:
        h = _norm(cfg, x, p["ln1"], p.get("ln1_b"))
        out, c_attn = _attn_forward(
            cfg, p, h, positions=positions, pos3=pos3, window=window,
            cache=None if cache is None else cache.get("attn"),
            cache_index=cache_index,
        )
        x = x + out
        if cache is not None:
            new_cache = {"attn": c_attn}

    if cross_kv is not None:
        pc = p["cross"]
        h = L.layernorm(x, pc["ln"], pc["ln_b"])
        out, _ = _attn_forward(
            cfg, {"wq": pc["wq"], "wk": pc["wk"], "wv": pc["wv"], "wo": pc["wo"]},
            h, positions=positions, window=0, cross_kv=cross_kv,
        )
        x = x + out

    if cfg.d_ff or cfg.moe:
        h = _norm(cfg, x, p["ln2"], p.get("ln2_b"))
        out, aux = _mlp_forward(cfg, p, h)
        x = x + out
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# scan-over-layers orchestration
# ---------------------------------------------------------------------------


def _slice_tree(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def _layer_of(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _scan_group(
    cfg: ArchConfig,
    stacked: Params,
    x,
    *,
    positions,
    pos3=None,
    window: int,
    caches=None,
    cache_index=None,
    enc_out=None,
    remat: bool = False,
):
    """lax.scan over a homogeneous stack of blocks.

    `caches` is a stacked pytree ([L, ...] leading) or None. Cross-attention
    (whisper): `enc_out` given -> K/V computed per layer inside the scan;
    decode instead finds precomputed {"cross_k","cross_v"} inside the cache.
    Returns (x, new_caches, aux_sum).
    """

    def body(carry, scans):
        h = carry
        p, c = scans
        cross_kv = None
        if enc_out is not None:
            pc = p["cross"]
            b, se, d = enc_out.shape
            hd = cfg.resolved_head_dim
            ck = (enc_out @ pc["wk"]).reshape(b, se, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
            cv = (enc_out @ pc["wv"]).reshape(b, se, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
            cross_kv = (ck, cv)
        elif c is not None and "cross_k" in c:
            cross_kv = (c["cross_k"], c["cross_v"])

        block_cache = None
        if c is not None:
            block_cache = {k: v for k, v in c.items() if not k.startswith("cross_")}
            if not block_cache:
                block_cache = None

        def fwd(p_, h_, cache_, cross_kv_):
            h_ = _pin(h_)
            out, c_, a_ = block_forward(
                cfg, p_, h_, positions=positions, pos3=pos3, window=window,
                cache=cache_, cache_index=cache_index, cross_kv=cross_kv_,
            )
            return _pin(out), c_, a_

        if remat:
            if _REMAT_POLICY:
                fwd = jax.checkpoint(
                    fwd,
                    policy=jax.checkpoint_policies.save_only_these_names(
                        _REMAT_POLICY),
                )
            else:
                fwd = jax.checkpoint(fwd)
        h, new_c, aux = fwd(p, h, block_cache, cross_kv)
        out_c = new_c if new_c else None
        if c is not None and cross_kv is not None and "cross_k" in (c or {}):
            out_c = dict(out_c or {})
            out_c["cross_k"] = c["cross_k"]
            out_c["cross_v"] = c["cross_v"]
        if enc_out is not None and caches is not None:
            # prefill of enc-dec: persist cross K/V into the cache
            out_c = dict(out_c or {})
            out_c["cross_k"] = cross_kv[0]
            out_c["cross_v"] = cross_kv[1]
        return h, (out_c, aux)

    if caches is None:
        x, (_, auxs) = jax.lax.scan(body, x, (stacked, None))
        return x, None, jnp.sum(auxs)
    x, (new_caches, auxs) = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches, jnp.sum(auxs)


def _run_decoder_stack(
    cfg: ArchConfig, params, x, *, positions, pos3=None,
    caches=None, cache_index=None, enc_out=None, remat=False,
):
    """Dispatch over the arch's block-group layout. Returns (x, caches, aux)."""
    aux_total = 0.0
    new_caches: dict = {}

    if cfg.first_layer_dense:
        d0_cache = None if caches is None else caches.get("dense0")
        x, c0, aux = _scan_group(
            cfg, params["dense0"], x, positions=positions, pos3=pos3,
            window=cfg.sliding_window, caches=d0_cache,
            cache_index=cache_index, remat=remat,
        )
        aux_total += aux
        if caches is not None:
            new_caches["dense0"] = c0

    if cfg.hybrid and cfg.num_global_layers:
        ng = cfg.num_global_layers
        n_main = cfg.num_layers - ng
        h1 = n_main // 2
        seg_sizes = [h1, n_main - h1]
        g_params = params["global_blocks"]
        m_params = params["blocks"]
        g_caches = None if caches is None else caches.get("global_blocks")
        m_caches = None if caches is None else caches.get("blocks")
        new_g, new_m = [], []
        mlo = 0
        for gi in range(ng):
            x, cg, aux = _scan_group(
                cfg, _slice_tree(g_params, gi, gi + 1), x,
                positions=positions, pos3=pos3, window=0,  # global attention
                caches=None if g_caches is None else _slice_tree(g_caches, gi, gi + 1),
                cache_index=cache_index, remat=remat,
            )
            aux_total += aux
            new_g.append(cg)
            if gi < len(seg_sizes):
                seg = seg_sizes[gi]
                x, cm, aux = _scan_group(
                    cfg, _slice_tree(m_params, mlo, mlo + seg), x,
                    positions=positions, pos3=pos3, window=cfg.sliding_window,
                    caches=None if m_caches is None else _slice_tree(m_caches, mlo, mlo + seg),
                    cache_index=cache_index, remat=remat,
                )
                aux_total += aux
                new_m.append(cm)
                mlo += seg
        if caches is not None:
            new_caches["global_blocks"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_g
            )
            new_caches["blocks"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_m
            )
    else:
        b_caches = None if caches is None else caches.get("blocks")
        stacked = params["blocks"]
        if cfg.encoder_decoder:
            # cross-attention params ride along in the layer scan
            stacked = {**stacked, "cross": params["cross"]}
        x, cb, aux = _scan_group(
            cfg, stacked, x, positions=positions, pos3=pos3,
            window=cfg.sliding_window, caches=b_caches,
            cache_index=cache_index, enc_out=enc_out, remat=remat,
        )
        aux_total += aux
        if caches is not None:
            new_caches["blocks"] = cb

    return x, (new_caches if caches is not None else None), aux_total


def _encode(cfg: ArchConfig, params, frames):
    """Whisper encoder on stub frame embeddings [B, S_enc, d]."""
    x = frames + params["enc_pos"][None, : frames.shape[1]]
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1]), frames.shape[:2]
    )

    def body(h, p):
        hn = L.layernorm(h, p["ln1"], p.get("ln1_b", jnp.zeros_like(p["ln1"])))
        out, _ = _attn_forward(
            cfg, p, hn, positions=positions, window=0, causal=False
        )
        h = h + out
        hn = L.layernorm(h, p["ln2"], p["ln2_b"])
        h = h + L.gelu_mlp(p, hn)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.layernorm(x, params["enc_final_norm"], params["enc_final_norm_b"])


def _embed_inputs(cfg: ArchConfig, params, batch):
    """Token (+stub modality) embedding. Returns (x, positions, pos3)."""
    tokens = batch["tokens"]
    b, s_tok = tokens.shape
    x = params["embed"][tokens]
    pos3 = batch.get("pos3")
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    if cfg.encoder_decoder:
        start = batch.get("pos_offset", 0)
        x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], start, s_tok, 0)[None]
    positions = batch.get(
        "positions",
        jnp.broadcast_to(jnp.arange(x.shape[1]), (b, x.shape[1])),
    )
    return x, positions, pos3


def _logits(cfg: ArchConfig, params, x):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["unembed"]


@jax.custom_vjp
def _softmax_xent(logits, targets):
    """Memory-efficient CE: forward keeps only (bf16 logits, f32 lse) as
    residuals; backward reconstructs softmax on the fly. Avoids the naive
    log_softmax path that materialises several f32 [B,S,V] copies (measured:
    ~10 GB/device on the 0.5B train_4k cell before this)."""
    nll, _ = _softmax_xent_fwd(logits, targets)
    return nll


def _softmax_xent_fwd(logits, targets):
    l32 = logits.astype(jnp.float32)
    mx = jax.lax.stop_gradient(l32.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(l32 - mx), axis=-1)) + mx[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.sum(
        jnp.where(iota == targets[..., None], l32, 0.0), axis=-1
    )
    return lse - picked, (logits, targets, lse)


def _softmax_xent_bwd(res, g):
    logits, targets, lse = res
    p = jnp.exp(logits.astype(jnp.float32) - lse[..., None])
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    onehot = (iota == targets[..., None]).astype(jnp.float32)
    dlogits = (p - onehot) * g[..., None]
    return dlogits.astype(logits.dtype), None


_softmax_xent.defvjp(_softmax_xent_fwd, _softmax_xent_bwd)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def loss_fn(cfg: ArchConfig, params, batch, *, remat: bool = True):
    """Next-token CE (+ MoE aux). batch: tokens [B,S] (+pos3/patch_embeds/
    frames). For VLM the patch prefix is excluded from the loss."""
    x, positions, pos3 = _embed_inputs(cfg, params, batch)
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
    x, _, aux = _run_decoder_stack(
        cfg, params, x, positions=positions, pos3=pos3,
        enc_out=enc_out, remat=remat,
    )
    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_b"))
    tokens = batch["tokens"]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        p_len = batch["patch_embeds"].shape[1]
        x = x[:, p_len:]
    logits = _logits(cfg, params, x[:, :-1])
    targets = tokens[:, 1:]
    nll = _softmax_xent(logits, targets)
    loss = nll.mean()
    return loss + 0.01 * aux


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int, dtype=None):
    """Stacked decode caches sized for `max_len` (ring-buffered for SWA)."""
    dt = dtype or _dtype(cfg)
    hd = cfg.resolved_head_dim
    hkv = cfg.num_kv_heads

    def attn_cache(n_layers, window):
        clen = min(window, max_len) if window else max_len
        return {
            "k": jnp.zeros((n_layers, batch_size, hkv, clen, hd), dt),
            "v": jnp.zeros((n_layers, batch_size, hkv, clen, hd), dt),
        }

    def ssm_cache(n_layers):
        din = cfg.ssm_inner
        conv_dim = din + 2 * cfg.ssm_groups * cfg.ssm_state
        return {
            "conv": jnp.zeros((n_layers, batch_size, cfg.conv_width - 1, conv_dim), dt),
            "ssm": jnp.zeros(
                (n_layers, batch_size, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dt
            ),
        }

    caches: dict = {}
    n_main = cfg.num_layers
    if cfg.first_layer_dense:
        n_main -= 1
        caches["dense0"] = {"attn": attn_cache(1, cfg.sliding_window)}
    if cfg.hybrid and cfg.num_global_layers:
        ng = cfg.num_global_layers
        n_main -= ng
        caches["global_blocks"] = {
            "attn": attn_cache(ng, 0),
            "ssm_c": ssm_cache(ng),
        }
        caches["blocks"] = {
            "attn": attn_cache(n_main, cfg.sliding_window),
            "ssm_c": ssm_cache(n_main),
        }
        return caches
    if cfg.ssm and not cfg.hybrid:
        caches["blocks"] = {"ssm_c": ssm_cache(n_main)}
        return caches
    blocks: dict = {"attn": attn_cache(n_main, cfg.sliding_window)}
    if cfg.encoder_decoder:
        blocks["cross_k"] = jnp.zeros(
            (n_main, batch_size, hkv, cfg.encoder_seq, hd), dt
        )
        blocks["cross_v"] = jnp.zeros_like(blocks["cross_k"])
    caches["blocks"] = blocks
    return caches


def prefill(cfg: ArchConfig, params, batch, max_len: Optional[int] = None):
    """Forward over a prompt, producing (last-token logits, filled caches)."""
    x, positions, pos3 = _embed_inputs(cfg, params, batch)
    b, s = x.shape[0], x.shape[1]
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
    caches = init_cache(cfg, b, max_len or s)
    x, caches, _ = _run_decoder_stack(
        cfg, params, x, positions=positions, pos3=pos3,
        caches=caches, cache_index=jnp.asarray(0, jnp.int32),
        enc_out=enc_out, remat=False,
    )
    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_b"))
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def decode_step(cfg: ArchConfig, params, tokens, caches, cache_index, *, pos3=None):
    """One greedy-decode step. tokens [B, 1]; cache_index: scalar int32 —
    number of tokens already in the cache. Returns (logits [B,V], caches)."""
    b = tokens.shape[0]
    positions = jnp.full((b, 1), cache_index, jnp.int32)
    batch = {"tokens": tokens, "positions": positions, "pos_offset": cache_index}
    if pos3 is not None:
        batch["pos3"] = pos3
    x, positions, pos3 = _embed_inputs(cfg, params, batch)
    x, caches, _ = _run_decoder_stack(
        cfg, params, x, positions=positions, pos3=pos3,
        caches=caches, cache_index=cache_index, remat=False,
    )
    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_b"))
    return _logits(cfg, params, x)[:, 0], caches
