"""Serve a reduced model with batched requests: prefill + greedy decode
(the decode_32k / long_500k dry-run cells use the same decode_step).

  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-370m
"""

import argparse

import numpy as np

from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    seqs, t_prefill, t_decode = serve(
        args.arch, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)
    print(f"[example] {args.arch}: generated {seqs.shape[0]}x{seqs.shape[1]} "
          f"tokens; prefill {t_prefill:.2f}s, decode {t_decode:.2f}s")
    print("[example] first sequence:", np.asarray(seqs[0])[:20].tolist())


if __name__ == "__main__":
    main()
