"""Quickstart: partition a graph, train a GNN distributed, verify the
partitioning invariants, and inspect the paper's core correlation — on the
current knob set (aggregation backends, feature cache).

  PYTHONPATH=src python examples/quickstart.py [--scale 0.05] [--k 8]
"""

import argparse

import numpy as np

from repro.core import cost_model
from repro.core.edge_partition import partition_edges
from repro.core.graph import paper_graph
from repro.core.metrics import edge_partition_metrics
from repro.core.vertex_partition import partition_vertices
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.minibatch import MiniBatchTrainer
from repro.gnn.models import GNNSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--k", type=int, default=8)
    args = ap.parse_args()
    k = args.k

    # 1. a graph from the paper's categories (Orkut-like social graph)
    g = paper_graph("OR", scale=args.scale, seed=0)
    print(f"graph: {g.num_vertices} vertices, {g.num_edges} edges")

    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, 64)).astype(np.float32)
    labels = rng.integers(0, 16, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.3
    spec = GNNSpec(model="sage", feature_dim=64, hidden_dim=64, num_classes=16)

    # 2. the paper's comparison, in three lines per partitioner
    for method in ["random", "hdrf", "hep100"]:
        a = partition_edges(g, k, method, seed=1)
        m = edge_partition_metrics(g, a, k)
        tr = FullBatchTrainer.build(g, a, k, spec, feats, labels, train,
                                    sync_mode="halo", mode="sim")
        est = cost_model.fullbatch_epoch(tr.book, spec)
        loss = tr.train_step()
        print(f"{method:8s} rf={m.replication_factor:5.2f} "
              f"sync_traffic={tr.comm_bytes_per_epoch()/2**20:7.1f} MiB "
              f"cluster_epoch={est.epoch_time*1e3:7.1f} ms  loss={loss:.4f}")

    # 3. the invariants that make the system safe to scale:
    #    (a) distributed == single-machine forward, and (b) the tiled
    #    aggregation backend (agg_backend, the Pallas segment-SpMM layout)
    #    == the scatter oracle — so partitioning and kernel choice never
    #    change the math
    ref = FullBatchTrainer.build(
        g, np.zeros(g.num_edges, np.int32), 1, spec, feats, labels, train)
    a = partition_edges(g, k, "hep100", seed=1)
    tr = FullBatchTrainer.build(g, a, k, spec, feats, labels, train, mode="sim")
    err = np.abs(tr.forward_logits_global() - ref.forward_logits_global()).max()
    print(f"distributed == single-machine forward: max err {err:.2e}")

    import dataclasses
    tiled = FullBatchTrainer.build(
        g, a, k, dataclasses.replace(spec, agg_backend="tiled"),
        feats, labels, train, mode="sim")
    err = np.abs(tiled.forward_logits_global() - ref.forward_logits_global()).max()
    print(f"tiled agg backend == scatter oracle:    max err {err:.2e}")

    # 4. the DistDGL regime with a feature cache (cache_policy): remote
    #    misses — the bytes that cross the network — drop when hot remote
    #    vertices are cached
    owner = partition_vertices(g, k, "metis", seed=1)
    for policy, budget in (("none", 0), ("degree", g.num_vertices // 10)):
        mb = MiniBatchTrainer.build(
            g, owner, k, spec, feats, labels, train, global_batch=128,
            seed=2, cache_policy=policy, cache_budget=budget)
        sm = mb.train_step()
        print(f"minibatch cache={policy:6s} remote={int(sm.remote_vertices.sum()):5d} "
              f"hit_rate={sm.hit_rate:.2f} "
              f"miss_bytes={int(sm.miss_bytes.sum()):8d}  loss={sm.loss:.4f}")


if __name__ == "__main__":
    main()
