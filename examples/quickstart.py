"""Quickstart: partition a graph, train a GNN distributed, verify the
partitioning invariant, and inspect the paper's core correlation.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import cost_model
from repro.core.edge_partition import partition_edges
from repro.core.graph import paper_graph
from repro.core.metrics import edge_partition_metrics
from repro.gnn.fullbatch import FullBatchTrainer
from repro.gnn.models import GNNSpec


def main() -> None:
    # 1. a graph from the paper's categories (Orkut-like social graph)
    g = paper_graph("OR", scale=0.05, seed=0)
    print(f"graph: {g.num_vertices} vertices, {g.num_edges} edges")

    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_vertices, 64)).astype(np.float32)
    labels = rng.integers(0, 16, g.num_vertices).astype(np.int32)
    train = rng.random(g.num_vertices) < 0.3
    spec = GNNSpec(model="sage", feature_dim=64, hidden_dim=64, num_classes=16)

    # 2. the paper's comparison, in three lines per partitioner
    for method in ["random", "hdrf", "hep100"]:
        a = partition_edges(g, 8, method, seed=1)
        m = edge_partition_metrics(g, a, 8)
        tr = FullBatchTrainer.build(g, a, 8, spec, feats, labels, train,
                                    sync_mode="halo", mode="sim")
        est = cost_model.fullbatch_epoch(tr.book, spec)
        loss = tr.train_step()
        print(f"{method:8s} rf={m.replication_factor:5.2f} "
              f"sync_traffic={tr.comm_bytes_per_epoch()/2**20:7.1f} MiB "
              f"cluster_epoch={est.epoch_time*1e3:7.1f} ms  loss={loss:.4f}")

    # 3. the invariant that makes partitioning safe: distributed == single
    ref = FullBatchTrainer.build(
        g, np.zeros(g.num_edges, np.int32), 1, spec, feats, labels, train)
    a = partition_edges(g, 8, "hep100", seed=1)
    tr = FullBatchTrainer.build(g, a, 8, spec, feats, labels, train, mode="sim")
    err = np.abs(tr.forward_logits_global() - ref.forward_logits_global()).max()
    print(f"distributed == single-machine forward: max err {err:.2e}")


if __name__ == "__main__":
    main()
