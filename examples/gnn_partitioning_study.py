"""End-to-end driver: the paper's study on one graph — partition with all 12
algorithms, train both regimes for a few epochs, print the speedup table.

  PYTHONPATH=src python examples/gnn_partitioning_study.py [--scale 0.05]
"""

import argparse

import numpy as np

from repro.core.study import (
    EDGE_METHODS,
    VERTEX_METHODS,
    fullbatch_row,
    fullbatch_speedup,
    minibatch_row,
    minibatch_speedup,
)
from repro.gnn.models import GNNSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="OR")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--k", type=int, default=8)
    args = ap.parse_args()

    spec = GNNSpec(model="sage", feature_dim=512, hidden_dim=64,
                   num_classes=16, num_layers=3)

    print(f"== DistGNN regime (full-batch, edge partitioning), "
          f"{args.graph} x{args.scale}, k={args.k}")
    rows = [fullbatch_row(args.graph, m, args.k, spec, scale=args.scale)
            for m in EDGE_METHODS]
    for r in sorted(fullbatch_speedup(rows), key=lambda r: -r["speedup"]):
        print(f"  {r['method']:8s} rf={r['rf']:6.2f} "
              f"speedup={r['speedup']:5.2f}x mem%={r['memory_pct_random']:5.1f} "
              f"amortize={r['amortize_epochs']:6.2f} epochs")

    print(f"== DistDGL regime (mini-batch, vertex partitioning)")
    rows = [minibatch_row(args.graph, m, args.k, spec, scale=args.scale,
                          global_batch=128, steps=2, run_device_step=False)
            for m in VERTEX_METHODS]
    for r in sorted(minibatch_speedup(rows), key=lambda r: -r["speedup"]):
        print(f"  {r['method']:8s} cut={r['edge_cut']:5.3f} "
              f"speedup={r['speedup']:5.2f}x net%={r['net_pct_random']:5.1f} "
              f"remote/step={r['remote_vertices']:7.0f}")


if __name__ == "__main__":
    main()
