"""End-to-end driver: the paper's study on one graph — partition with all 12
algorithms, score both training regimes (the mini-batch side with the
feature cache on), and the serving regime the training study feeds.

  PYTHONPATH=src python examples/gnn_partitioning_study.py [--scale 0.05]
"""

import argparse

import numpy as np

from repro.core.study import (
    EDGE_METHODS,
    VERTEX_METHODS,
    fullbatch_row,
    fullbatch_speedup,
    minibatch_row,
    minibatch_speedup,
    serve_row,
)
from repro.gnn.models import GNNSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="OR")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--agg-backend", default="scatter",
                    choices=["scatter", "tiled", "pallas"])
    ap.add_argument("--cache-policy", default="degree",
                    choices=["none", "random", "degree", "halo"])
    args = ap.parse_args()

    spec = GNNSpec(model="sage", feature_dim=512, hidden_dim=64,
                   num_classes=16, num_layers=3,
                   agg_backend=args.agg_backend)

    print(f"== DistGNN regime (full-batch, edge partitioning), "
          f"{args.graph} x{args.scale}, k={args.k}")
    rows = [fullbatch_row(args.graph, m, args.k, spec, scale=args.scale)
            for m in EDGE_METHODS]
    for r in sorted(fullbatch_speedup(rows), key=lambda r: -r["speedup"]):
        print(f"  {r['method']:8s} rf={r['rf']:6.2f} "
              f"speedup={r['speedup']:5.2f}x mem%={r['memory_pct_random']:5.1f} "
              f"amortize={r['amortize_epochs']:6.2f} epochs")

    print(f"== DistDGL regime (mini-batch, vertex partitioning), "
          f"feature cache policy={args.cache_policy}")
    budget = 0 if args.cache_policy == "none" else 200
    rows = [minibatch_row(args.graph, m, args.k, spec, scale=args.scale,
                          global_batch=128, steps=2, run_device_step=False,
                          cache_policy=args.cache_policy, cache_budget=budget)
            for m in VERTEX_METHODS]
    for r in sorted(minibatch_speedup(rows), key=lambda r: -r["speedup"]):
        print(f"  {r['method']:8s} cut={r['edge_cut']:5.3f} "
              f"speedup={r['speedup']:5.2f}x net%={r['net_pct_random']:5.1f} "
              f"hit_rate={r['hit_rate']:.2f} "
              f"remote/step={r['remote_vertices']:7.0f}")

    print("== serving regime (layer-wise embeddings + micro-batched requests)")
    serve_spec = GNNSpec(model="sage", feature_dim=64, hidden_dim=256,
                         num_classes=16, num_layers=2,
                         agg_backend=args.agg_backend)
    for m in ("random", "metis"):
        r = serve_row(args.graph, m, min(args.k, 4), serve_spec,
                      scale=args.scale, qps=200.0, n_requests=160,
                      cache_policy=args.cache_policy, cache_budget=budget)
        print(f"  {m:8s} cut={r['partition_quality']:5.3f} "
              f"p50={r['latency_p50']*1e3:6.2f}ms "
              f"p99={r['latency_p99']*1e3:6.2f}ms "
              f"hit_rate={r['hit_rate']:.2f} "
              f"miss={r['miss_bytes']/2**20:6.2f} MiB "
              f"sustainable={r['qps_sustainable']:7.0f} qps")


if __name__ == "__main__":
    main()
