"""Train a reduced LM (any of the 10 assigned archs) for a few hundred steps
with checkpoint/restart — the end-to-end training driver on CPU scale.

  PYTHONPATH=src python examples/lm_pretrain.py --arch qwen1.5-0.5b --steps 200
"""

import argparse

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()
    losses = train(
        args.arch, smoke=True, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=20,
    )
    n = max(len(losses) // 10, 1)
    first = sum(losses[:n]) / n
    last = sum(losses[-n:]) / n
    print(f"[example] mean loss first-10%: {first:.4f} -> last-10%: {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
